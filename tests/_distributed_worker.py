"""Subprocess worker: distributed-training features on an 8-device host mesh.

Covers: BRIDGE grad sync == GSPMD sync, int8-compressed sync trains, GPipe
pipeline == sequential, elastic restart onto a different mesh shape.
Prints 'ALL-OK' on success.
"""
import os
import sys
import tempfile

N = int(sys.argv[1]) if len(sys.argv) > 1 else 8
# Drop any inherited device-count flag (the CI matrix leg runs the suite
# under 8 host devices; the last occurrence wins in XLA).
_inherited = " ".join(
    tok for tok in os.environ.get("XLA_FLAGS", "").split()
    if not tok.startswith("--xla_force_host_platform_device_count"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N} {_inherited}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.launch.mesh import make_mesh  # noqa: E402
from repro.launch.pipeline import run_pipeline  # noqa: E402
from repro.launch.train import TrainConfig, train  # noqa: E402

assert jax.device_count() == N

def quiet(*_):
    return None

# --- 1. bridge grad sync equals gspmd sync ------------------------------------
kw = {"arch": "stablelm-3b", "steps": 4, "batch_size": 8, "seq_len": 32}
_, _, losses_gspmd = train(TrainConfig(grad_sync="gspmd", **kw), quiet)
_, _, losses_bridge = train(TrainConfig(grad_sync="bridge", **kw), quiet)
np.testing.assert_allclose(losses_bridge, losses_gspmd, rtol=2e-4)
print("ok bridge_grad_sync == gspmd", losses_bridge[-1])

# --- 2. compressed sync still trains -------------------------------------------
_, _, losses_c = train(TrainConfig(grad_sync="bridge-compressed", **kw), quiet)
assert np.isfinite(losses_c).all()
assert losses_c[-1] < losses_c[0] * 1.5  # not diverging
print("ok compressed_grad_sync", losses_c[-1])

# --- 3. 2D mesh (data x model) trains ------------------------------------------
_, _, losses_2d = train(TrainConfig(
    arch="qwen3-moe-235b-a22b", steps=3, batch_size=4, seq_len=16,
    mesh_shape=(2, N // 2), mesh_axes=("data", "model")), quiet)
assert np.isfinite(losses_2d).all()
print("ok 2d_mesh_moe_train", losses_2d[-1])

# --- 4. GPipe pipeline == sequential ---------------------------------------------
n_stages = min(4, N)
mesh = make_mesh((n_stages,), ("pod",))
S, D = n_stages, 16
key = jax.random.PRNGKey(0)
stage_w = jax.random.normal(key, (S, D, D)) / jnp.sqrt(D)


def stage_fn(w, x):
    return jnp.tanh(x @ w)


x = jax.random.normal(key, (8, D))
seq = x
for s in range(S):
    seq = stage_fn(stage_w[s], seq)
out = run_pipeline(mesh, "pod", stage_fn, stage_w, x, n_micro=4)
np.testing.assert_allclose(np.asarray(out), np.asarray(seq), atol=1e-5)
print("ok gpipe == sequential")

# --- 5. elastic restart: save on (8 data), resume on (2 data x 4 model) -----------
with tempfile.TemporaryDirectory() as d:
    kw2 = {"arch": "stablelm-3b", "batch_size": 8, "seq_len": 32,
           "checkpoint_dir": d, "checkpoint_every": 2}
    _, _, l1 = train(TrainConfig(steps=2, **kw2), quiet)
    _, _, l2 = train(TrainConfig(steps=4, mesh_shape=(2, 4),
                                 mesh_axes=("data", "model"), **kw2), quiet)
    # reference: uninterrupted 4 steps on the original mesh
    _, _, lf = train(TrainConfig(
        steps=4, arch="stablelm-3b", batch_size=8, seq_len=32), quiet)
    np.testing.assert_allclose(l2[-1], lf[-1], rtol=2e-3)
print("ok elastic_restart_reshard", l2[-1])

print("ALL-OK")
