"""Artifact integrity: the committed dry-run/roofline records stay coherent.

Skipped when results/ has not been generated (fresh checkout) — regenerate
with `python -m repro.launch.dryrun --all --mesh both`.
"""
import glob
import json
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "results", "dryrun")


pytestmark = pytest.mark.skipif(
    not os.path.isdir(DRYRUN), reason="dry-run artifacts not generated")


def _cells():
    out = []
    for p in glob.glob(os.path.join(DRYRUN, "*.json")):
        with open(p) as f:
            out.append(json.load(f))
    return out


def test_all_runnable_cells_present_and_ok():
    from repro import configs

    cells = _cells()
    seen = {(c["arch"], c["shape"], c["mesh"]) for c in cells}
    errors = [c for c in cells if "error" in c]
    assert not errors, [(c["arch"], c["shape"], c["mesh"]) for c in errors]
    expected = 0
    for a, s in configs.cells():
        ok, _ = configs.runnable(a, s)
        if not ok:
            continue
        expected += 2
        for mesh in ("pod", "multipod"):
            assert (a, s, mesh) in seen, (a, s, mesh)
    assert len(seen) == expected == 66


def test_mesh_sizes_and_metrics_sane():
    for c in _cells():
        assert c["devices"] == (512 if c["mesh"] == "multipod" else 256)
        assert c["flops"] > 0
        assert c["collectives"]["total_bytes"] > 0  # distributed: must talk
        cal = c["calibrated"]
        assert cal["flops"] >= c["flops"] * 0.99  # extrapolation >= one-shot


def test_roofline_rows_cover_cells():
    from benchmarks.roofline import derive, load_cells

    rows = [d for c in load_cells(DRYRUN) if (d := derive(c))]
    assert len(rows) == 66
    for r in rows:
        assert r["dominant"] in ("compute", "memory", "collective")
        assert r["bound_s"] > 0
