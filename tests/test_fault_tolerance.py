"""Fault tolerance & substrate: checkpoint/restart, elastic restore, data
determinism, optimizer, and the synthetic-LM learnability sanity check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (garbage_collect, latest_step, restore,
                              restore_into, save)
from repro.data import SyntheticLM
from repro.optim import adamw_init, adamw_update, cosine_warmup_schedule


# --- checkpoint store -----------------------------------------------------------


def _tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.float32)},
        "nested": [jnp.zeros((2, 2)), {"x": jnp.full((5,), 7.0)}],
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save(d, 10, tree)
    assert latest_step(d) == 10
    back = restore_into(d, jax.tree.map(lambda x: x, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4):
        save(d, s, _tree())
    assert latest_step(d) == 4
    removed = garbage_collect(d, keep=2)
    assert len(removed) == 2
    assert latest_step(d) == 4
    restore(d, 3)  # kept
    with pytest.raises(FileNotFoundError):
        restore(d, 1)  # collected


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save(d, 1, {"w": jnp.zeros((3, 4))})
    with pytest.raises(ValueError):
        restore_into(d, {"w": jnp.zeros((4, 4))})


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save from a (1,)-mesh job, restore sharded for a (2, 2) mesh."""
    if jax.device_count() < 1:
        pytest.skip("no devices")
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    save(d, 5, tree)

    # Pretend the new job has a different mesh: single-device CPU can still
    # express the sharding metadata path via NamedSharding on a (1, 1) mesh.
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))
    back = restore_into(d, tree, sharding_fn=lambda k, a: sh)
    assert back["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


# --- data pipeline ----------------------------------------------------------------


def test_data_deterministic_and_shard_disjoint():
    d = SyntheticLM(vocab_size=97, seq_len=16, seed=3)
    b1 = d.batch(step=5, shard=2, batch_size=4)
    b2 = d.batch(step=5, shard=2, batch_size=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # resumable
    b3 = d.batch(step=5, shard=3, batch_size=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])      # shards differ
    b4 = d.batch(step=6, shard=2, batch_size=4)
    assert not np.array_equal(b1["tokens"], b4["tokens"])      # steps differ
    # labels are next-token targets
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_learnable_structure():
    """Most transitions follow the affine rule (a model can learn it)."""
    d = SyntheticLM(vocab_size=101, seq_len=64, noise=0.05)
    b = d.batch(0, 0, 32)
    pred = (b["tokens"] * d.mult + d.add) % 101
    agree = (pred == b["labels"]).mean()
    assert agree > 0.85


# --- optimizer ---------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    w = {"w": jnp.array([3.0, -2.0, 1.0])}
    st = adamw_init(w)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, st, m = adamw_update(g, st, w, lr=0.05, weight_decay=0.0)
    assert float(loss(w)) < 1e-3
    assert int(st.step) == 200


def test_adamw_grad_clipping_and_schedule():
    sched = cosine_warmup_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) < float(sched(jnp.asarray(9)))
    assert float(sched(jnp.asarray(99))) < float(sched(jnp.asarray(20)))
    w = {"w": jnp.ones((4,))}
    st = adamw_init(w)
    huge = {"w": jnp.full((4,), 1e9)}
    _, _, metrics = adamw_update(huge, st, w, lr=1e-3, max_grad_norm=1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(2e9, rel=1e-3)


# --- end-to-end restart equivalence ---------------------------------------------------


def test_train_restart_bitwise_resume(tmp_path):
    """Train 6 steps; vs train 3, 'crash', resume 3: same final loss."""
    from repro.launch.train import TrainConfig, train

    def run(steps, ckdir, every=3):
        tc = TrainConfig(arch="stablelm-3b", steps=steps, batch_size=4,
                         seq_len=32, checkpoint_dir=ckdir,
                         checkpoint_every=every)
        _, _, losses = train(tc, progress=lambda *_: None)
        return losses

    full = run(6, str(tmp_path / "a"))
    part1 = run(3, str(tmp_path / "b"))
    part2 = run(6, str(tmp_path / "b"))  # resumes from step 3
    np.testing.assert_allclose(part2[-1], full[-1], rtol=1e-4)
    np.testing.assert_allclose(part1[-1], full[2], rtol=1e-4)
