"""Static fast-path certifier: soundness and engine-integration tests.

The certificate claims a uniform lane cannot trip either of batchsim's
runtime canonical-order guards, so its vectorized playback is exact without
them.  These tests hold it to that claim:

  - differential grid: certified lanes ride the fast path AND are bit-exact
    against the scalar sparse `FabricSim` oracle;
  - the certificate is refused whenever a soundness precondition fails
    (per-node skew, snapshot-resumed traces, alpha_s == 0 regimes);
  - guard-free playback (``certify=True`` with a fully certified batch) is
    bit-identical to guard-checked playback (``certify=False``).
"""
import random

import numpy as np
import pytest

from repro.analysis import (certify_batch, certify_lane, certify_trace_batch,
                            certify_trace_lane)
from repro.core import FabricSim, PAPER_DEFAULT, Schedule, straggler_speeds
from repro.core.batchsim import (BatchLane, TraceLane, batch_run,
                                 batch_run_trace)
from repro.core.bruck import schedule_length
from repro.core.schedules import every_step_schedule, static_schedule

MB = 1024.0 ** 2
REL_TOL = 1e-9


def random_schedule(rng: random.Random, kind: str, n: int, r: int = 2) -> Schedule:
    s = schedule_length(kind, n, r)
    x = tuple([0] + [rng.randint(0, 1) for _ in range(s - 1)])
    return Schedule(kind=kind, n=n, x=x, r=r)


def scalar_reference(lane: BatchLane, cm, chunks: int):
    sim = FabricSim(
        chunks_per_msg=chunks, overlap=lane.overlap, mode="sparse",
        link_speed=list(lane.link_speed) if lane.link_speed else None,
        payload_scale=list(lane.payload_scale) if lane.payload_scale else None)
    eff_cm = cm if lane.delta is None else cm.replace(delta=lane.delta)
    return sim.run(lane.schedule, lane.m_bytes, eff_cm)


# --- never unsafe-but-certified: the differential grid ------------------------


@pytest.mark.parametrize("n", [6, 12, 48])
def test_certified_lanes_bit_exact_vs_scalar_oracle(n):
    """Same seeded grid shape as the batchsim fuzz: every certified lane
    must take the fast path and reproduce the scalar oracle exactly."""
    rng = random.Random(2000 + n)
    certified_seen = 0
    for r in (2, 3):
        for kind in ("a2a", "rs", "ag"):
            for straggler in (None, {n // 2: 0.3}):
                sched = random_schedule(rng, kind, n, r)
                m = rng.choice([0.25, 2.0]) * MB
                delta = rng.choice([1e-6, 1e-3, 15e-3])
                chunks = rng.choice([1, 2, 4])
                speed = (tuple(straggler_speeds(n, straggler))
                         if straggler else None)
                cm = PAPER_DEFAULT.replace(delta=delta)
                lane = BatchLane(schedule=sched, m_bytes=m, link_speed=speed)
                res = batch_run([lane], cm, chunks_per_msg=chunks)
                if not res.certified[0]:
                    continue
                certified_seen += 1
                assert res.fast_path[0]  # certified implies fast path
                ref = scalar_reference(lane, cm, chunks)
                assert res.completion[0] == pytest.approx(
                    ref.completion, rel=REL_TOL)
                np.testing.assert_allclose(res.node_done[0], ref.node_done,
                                           rtol=REL_TOL)
                np.testing.assert_allclose(res.step_done[0], ref.step_done,
                                           rtol=REL_TOL)
                assert res.chunks_moved[0] == ref.chunks_moved
                assert res.reconfigs_paid[0] == ref.reconfigs_paid
    # every uniform lane certifies under the paper regime (alpha_s > 0)
    assert certified_seen >= 6


def test_exhaustive_small_n_certificates_sound():
    """All 0/1 tails at n=8: certificate granted => fast path, no fallback,
    oracle-exact, for every kind under the paper cost model."""
    for kind in ("a2a", "rs", "ag"):
        s = schedule_length(kind, 8, 2)
        for bits in range(1 << (s - 1)):
            x = (0,) + tuple((bits >> i) & 1 for i in range(s - 1))
            lane = BatchLane(schedule=Schedule(kind=kind, n=8, x=x, r=2),
                             m_bytes=MB)
            assert certify_lane(lane, PAPER_DEFAULT)
            res = batch_run([lane], PAPER_DEFAULT, chunks_per_msg=2,
                            allow_fallback=False)
            assert res.certified[0] and res.fast_path[0]
            ref = scalar_reference(lane, PAPER_DEFAULT, 2)
            assert res.completion[0] == pytest.approx(ref.completion,
                                                      rel=REL_TOL)


# --- refusal cases ------------------------------------------------------------


def test_skewed_lanes_are_not_certified():
    sched = every_step_schedule("a2a", 12)
    slow = tuple(straggler_speeds(12, {3: 0.25}))
    skew = [1.0] * 12
    skew[5] = 4.0
    assert not certify_lane(
        BatchLane(schedule=sched, m_bytes=MB, link_speed=slow), PAPER_DEFAULT)
    assert not certify_lane(
        BatchLane(schedule=sched, m_bytes=MB, payload_scale=tuple(skew)),
        PAPER_DEFAULT)
    assert certify_lane(BatchLane(schedule=sched, m_bytes=MB), PAPER_DEFAULT)


def test_alpha_s_zero_regime_is_not_certified():
    free = PAPER_DEFAULT.replace(alpha_s=0.0)
    lane = BatchLane(schedule=every_step_schedule("a2a", 8), m_bytes=MB)
    assert not certify_lane(lane, free)
    res = batch_run([lane], free, chunks_per_msg=2)
    assert not res.certified[0]  # guards stay armed; result still exact
    ref = scalar_reference(lane, free, 2)
    assert res.completion[0] == pytest.approx(ref.completion, rel=REL_TOL)


def test_multi_hop_zero_payload_needs_alpha_h():
    """With alpha_s > 0 but alpha_h == 0 and zero payload, guard 1 is only
    provably idle when every relay is single-hop."""
    cm = PAPER_DEFAULT.replace(alpha_h=0.0)
    single_hop = every_step_schedule("a2a", 16)  # per-step gcd => hops == 1
    multi_hop = static_schedule("a2a", 16)       # g=1 segment relays hops > 1
    assert certify_lane(BatchLane(schedule=single_hop, m_bytes=0.0), cm)
    assert not certify_lane(BatchLane(schedule=multi_hop, m_bytes=0.0), cm)
    # positive payload restores the strict guard-1 inequality
    assert certify_lane(BatchLane(schedule=multi_hop, m_bytes=MB), cm)


def test_snapshot_resumed_trace_lane_not_certified():
    sched = every_step_schedule("a2a", 8)
    phases = ((sched, MB), (every_step_schedule("ag", 8), MB / 2))
    base = TraceLane(phases=phases)
    assert certify_trace_lane(base, PAPER_DEFAULT)
    warm = batch_run_trace([base], PAPER_DEFAULT, chunks_per_msg=2)
    snap = warm.snapshot(0)
    resumed = TraceLane(phases=phases, initial=snap)
    assert not certify_trace_lane(resumed, PAPER_DEFAULT)


# --- guard-free playback is bit-identical -------------------------------------


def test_certify_flag_does_not_change_results():
    rng = random.Random(31)
    n = 16
    lanes = [BatchLane(schedule=random_schedule(rng, kind, n),
                       m_bytes=rng.choice([0.5, 2.0]) * MB,
                       overlap=rng.choice([0.0, 0.5]))
             for kind in ("a2a", "rs", "ag") for _ in range(3)]
    on = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=4, certify=True)
    off = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=4, certify=False)
    assert on.certified.all()
    assert not off.certified.any()
    np.testing.assert_array_equal(on.completion, off.completion)
    np.testing.assert_array_equal(on.node_done, off.node_done)
    np.testing.assert_array_equal(on.step_done, off.step_done)
    np.testing.assert_array_equal(on.chunks_moved, off.chunks_moved)
    np.testing.assert_array_equal(on.delta_stall, off.delta_stall)


def test_certify_flag_does_not_change_trace_results():
    rng = random.Random(33)
    n = 12
    lanes = []
    for _ in range(4):
        phases = tuple(
            (random_schedule(rng, kind, n), rng.choice([0.5, 2.0]) * MB)
            for kind in ("a2a", "rs", "ag"))
        lanes.append(TraceLane(phases=phases))
    on = batch_run_trace(lanes, PAPER_DEFAULT, chunks_per_msg=2, certify=True)
    off = batch_run_trace(lanes, PAPER_DEFAULT, chunks_per_msg=2,
                          certify=False)
    assert on.certified.all()
    assert not off.certified.any()
    np.testing.assert_array_equal(on.completion, off.completion)
    np.testing.assert_array_equal(on.delta_stall, off.delta_stall)


def test_mixed_batch_keeps_guards_for_uncertified_lanes():
    """A straggler lane in the batch keeps the guards armed; the uniform
    lanes are still certified and everyone stays oracle-exact."""
    n = 12
    sched = every_step_schedule("a2a", n)
    lanes = [
        BatchLane(schedule=sched, m_bytes=MB),
        BatchLane(schedule=sched, m_bytes=MB,
                  link_speed=tuple(straggler_speeds(n, {2: 0.2}))),
    ]
    res = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=4)
    assert bool(res.certified[0]) and not bool(res.certified[1])
    for b, lane in enumerate(lanes):
        ref = scalar_reference(lane, PAPER_DEFAULT, 4)
        assert res.completion[b] == pytest.approx(ref.completion, rel=REL_TOL)


def test_certify_batch_matches_per_lane():
    sched = every_step_schedule("rs", 8)
    lanes = [BatchLane(schedule=sched, m_bytes=MB),
             BatchLane(schedule=sched, m_bytes=MB,
                       link_speed=tuple(straggler_speeds(8, {1: 0.5})))]
    mask = certify_batch(lanes, PAPER_DEFAULT)
    assert mask.dtype == bool and mask.tolist() == [True, False]
    tl = TraceLane(phases=((sched, MB),))
    assert certify_trace_batch([tl], PAPER_DEFAULT).tolist() == [True]
