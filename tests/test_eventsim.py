"""Event-driven simulator vs the analytic cost model (fluid-limit check)."""
import pytest

from repro.core import (CostModel, PAPER_DEFAULT, baselines, collective_time,
                        periodic_a2a, static_schedule)
from repro.core.eventsim import (collective_time_event, ring_allreduce_event,
                                 simulate_step)

MB, US = 1024.0 ** 2, 1e-6


def test_single_hop_exact():
    """h=1: no congestion, event time == alpha_h + m*beta exactly."""
    cm = CostModel(alpha_s=0, alpha_h=1e-6, bandwidth=1e9, delta=0)
    r = simulate_step(16, 1, 1, nbytes=1e6, cm=cm, chunks_per_msg=4)
    assert r.completion == pytest.approx(1e-6 + 1e6 / 1e9, rel=1e-9)


@pytest.mark.parametrize("n,g,off", [(16, 1, 4), (32, 2, 8), (64, 1, 16)])
def test_event_converges_to_cost_model(n, g, off):
    """With fine chunking, the event time approaches h*alpha_h + c*m*beta
    (c = h): the Section 2 model is the fluid limit of the event sim."""
    cm = CostModel(alpha_s=0, alpha_h=1e-6, bandwidth=100e9, delta=0)
    m = 4 * MB
    h = off // g
    analytic = h * cm.alpha_h + h * m * cm.beta
    coarse = simulate_step(n, g, off, m, cm, chunks_per_msg=1).completion
    fine = simulate_step(n, g, off, m, cm, chunks_per_msg=64).completion
    assert fine <= coarse  # pipelining can only help
    assert fine == pytest.approx(analytic, rel=0.10)
    # 1-chunk store-and-forward upper bracket: <= h * (alpha_h + c*m*beta)
    assert coarse <= h * (cm.alpha_h + h * m * cm.beta) * (1 + 1e-9)


@pytest.mark.parametrize("R", [0, 1, 2])
def test_collective_event_vs_analytic(R):
    n, m = 32, 2 * MB
    cm = PAPER_DEFAULT
    sched = periodic_a2a(n, R)
    t_event = collective_time_event(sched, m, cm, chunks_per_msg=32)
    t_analytic = collective_time(sched, m, cm).total
    assert t_event == pytest.approx(t_analytic, rel=0.15)


def test_bridge_speedup_holds_at_event_level():
    """The headline Fig-5 style speedup must survive event-level simulation."""
    n, m = 64, 16 * MB
    cm = PAPER_DEFAULT.replace(delta=10 * US)
    from repro.core import plan
    sched_b = plan("a2a", n, m, cm, paper_faithful=True).schedule
    t_b = collective_time_event(sched_b, m, cm, chunks_per_msg=16)
    t_s = collective_time_event(static_schedule("a2a", n), m, cm,
                                chunks_per_msg=16)
    analytic_ratio = (collective_time(static_schedule("a2a", n), m, cm).total
                      / collective_time(sched_b, m, cm).total)
    event_ratio = t_s / t_b
    assert event_ratio == pytest.approx(analytic_ratio, rel=0.15)
    assert event_ratio > 3.0  # the claim band survives


def test_simulate_step_rejects_mismatched_link_speed():
    """Regression: a link_speed list whose length != n used to be accepted
    silently, misattributing straggler rates to the wrong nodes."""
    cm = PAPER_DEFAULT
    with pytest.raises(ValueError, match="link_speed"):
        simulate_step(16, 1, 4, 1e6, cm, link_speed=[1.0] * 8)
    with pytest.raises(ValueError, match="link_speed"):
        simulate_step(16, 1, 4, 1e6, cm, link_speed=[1.0] * 17)
    # the correct length still works
    r = simulate_step(16, 1, 4, 1e6, cm, link_speed=[1.0] * 16)
    assert r.completion > 0


def test_ring_allreduce_event_matches_baseline():
    n, m = 16, 1 * MB
    cm = PAPER_DEFAULT
    t_event = ring_allreduce_event(n, m, cm)
    t_analytic = baselines.ring("ar", n, m, cm).total
    assert t_event == pytest.approx(t_analytic, rel=0.05)


def test_bridge_more_straggler_robust_than_static():
    """Beyond-paper: a degraded transceiver amplifies static Bruck more than
    BRIDGE (exposure scales with per-step hop multiplicity c_k = h_k)."""
    from benchmarks.straggler import straggler_amplification
    out = straggler_amplification(n=16, m=2 * MB, kappas=(1.0, 4.0), chunks=8)
    assert out["bridge"][4.0] < out["static"][4.0]
    assert out["speedup"][4.0] >= out["speedup"][1.0]
