"""Static verifier tests: clean artifacts verify, corruptions are caught.

Three layers:
  1. zero-violation grids — everything the planner / trace planner / online
     planner / plan service produce on the existing test grids must verify
     clean (the verifier must not reject working artifacts);
  2. the tier-1 mutation-catch test — every corruption in
     `repro.analysis.mutations` must be caught by its designated rule;
  3. trust-boundary behaviour — a corrupted plan raises `VerificationError`
     at the boundary and is never inserted into the LRU caches.
"""
import dataclasses

import pytest

from repro.analysis import (VerificationError, verify_plan, verify_schedule,
                            verify_served_plan, verify_snapshot, verify_tape,
                            verify_trace_plan)
from repro.analysis.mutations import run_mutations
from repro.core.batchsim import FabricSnapshot, compile_tape
from repro.core.cost_model import PAPER_DEFAULT, CostModel
from repro.core.schedules import (Schedule, every_step_schedule,
                                  schedule_length, static_schedule)
from repro.planner import Planner, PlanRequest
from repro.workloads.serve import PlanService, ServeRequest, build_request_pool
from repro.workloads.trace_planner import TRACE_PLAN_MODES, plan_trace
from repro.workloads.traces import CollectiveEvent, Trace, mixed_trace

MB = 1024.0 ** 2


# --- zero violations on clean artifacts ---------------------------------------


@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
@pytest.mark.parametrize("n,r", [(4, 2), (8, 2), (12, 2), (16, 2),
                                 (17, 2), (9, 3), (27, 3)])
def test_clean_schedules_verify(kind, n, r):
    for sched in (static_schedule(kind, n, r=r),
                  every_step_schedule(kind, n, r=r)):
        assert not verify_schedule(sched), verify_schedule(sched)


@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_all_enumerated_schedules_verify(kind):
    import itertools

    s = schedule_length(kind, 8, 2)
    for tail in itertools.product((0, 1), repeat=s - 1):
        sched = Schedule(kind=kind, n=8, x=(0,) + tail, r=2)
        assert not verify_tape(compile_tape(sched))


@pytest.mark.parametrize("kind", ["a2a", "rs", "ag", "ar"])
@pytest.mark.parametrize("n", [8, 16])
def test_planner_results_verify(kind, n):
    planner = Planner(cache_size=0, verify=False)
    for init_g, max_R in [(None, None), (2, None), (None, 1)]:
        res = planner.plan(PlanRequest(kind=kind, n=n, m_bytes=4 * MB,
                                       init_g=init_g, max_R=max_R))
        assert not verify_plan(res), verify_plan(res)


def test_planner_sim_fabric_results_verify():
    planner = Planner(cache_size=0, verify=False)
    res = planner.plan(PlanRequest(kind="a2a", n=8, m_bytes=MB,
                                   fabric="ocs-sim"))
    assert not verify_plan(res), verify_plan(res)


@pytest.mark.parametrize("mode", TRACE_PLAN_MODES)
def test_trace_plans_verify(mode):
    trace = mixed_trace(16, moe_layers=1, decode_steps=2)
    tp = plan_trace(trace, PAPER_DEFAULT, mode=mode)
    assert not verify_trace_plan(tp, cm=PAPER_DEFAULT), \
        verify_trace_plan(tp, cm=PAPER_DEFAULT)


def test_budgeted_trace_plan_verifies():
    trace = mixed_trace(16, moe_layers=1, decode_steps=2)
    tp = plan_trace(trace, PAPER_DEFAULT, mode="carryover",
                    delta_budget=2e-5)
    assert not verify_trace_plan(tp, cm=PAPER_DEFAULT)


def test_online_plans_verify():
    from repro.workloads.online_planner import run_online

    trace = mixed_trace(16, moe_layers=1, decode_steps=2)
    tp, _ = run_online(trace, PAPER_DEFAULT, window=3)
    assert not verify_trace_plan(tp, cm=PAPER_DEFAULT), \
        verify_trace_plan(tp, cm=PAPER_DEFAULT)


def test_served_plans_verify_across_pool():
    service = PlanService(cm=PAPER_DEFAULT, cache_size=0, verify=False)
    for req in build_request_pool(16)[:12]:
        sp = service.serve(req)
        assert not verify_served_plan(sp, PAPER_DEFAULT), \
            verify_served_plan(sp, PAPER_DEFAULT)


def test_clean_snapshot_verifies():
    snap = FabricSnapshot(n=8, link_offset=2, node_ready=(0.5,) * 8,
                          port_free=(1.0,) * 8)
    assert not verify_snapshot(snap)


# --- the tier-1 mutation-catch test -------------------------------------------


def test_every_mutation_caught_by_its_rule():
    outcomes = run_mutations()
    missed = [o for o in outcomes if not o.caught]
    assert not missed, "\n".join(
        f"{o.name}: wanted {o.rule}, fired {o.fired}" for o in missed)
    assert len({o.rule for o in outcomes}) >= 15
    assert len(outcomes) >= 15


def test_mutations_fire_no_rules_on_good_fixtures():
    # sanity: the harness corrupts copies, never the shared fixtures
    from repro.analysis.mutations import (_good_plan, _good_served_plan,
                                          _good_trace_plan)

    run_mutations()
    assert not verify_plan(_good_plan())
    assert not verify_trace_plan(_good_trace_plan(), cm=PAPER_DEFAULT)
    assert not verify_served_plan(_good_served_plan(), PAPER_DEFAULT)


# --- trust boundaries: raise + never cache ------------------------------------


def _corrupt(res):
    return dataclasses.replace(res, schedule=static_schedule("rs", res.request.n))


def test_planner_rejects_corrupt_plan_and_does_not_cache(monkeypatch):
    planner = Planner(cache_size=8)
    req = PlanRequest(kind="a2a", n=8, m_bytes=MB)
    good = planner._plan_uncached(req)
    monkeypatch.setattr(Planner, "_plan_uncached",
                        lambda self, r: _corrupt(good))
    with pytest.raises(VerificationError, match="plan/kind"):
        planner.plan(req)
    assert len(planner._cache) == 0
    monkeypatch.undo()
    # after the corruption is gone, the same request plans and caches fine
    res = planner.plan(req)
    assert not verify_plan(res)
    assert len(planner._cache) == 1


def test_planner_verify_flag_disables_audit(monkeypatch):
    planner = Planner(cache_size=0, verify=False)
    req = PlanRequest(kind="a2a", n=8, m_bytes=MB)
    good = planner._plan_uncached(req)
    monkeypatch.setattr(Planner, "_plan_uncached",
                        lambda self, r: _corrupt(good))
    assert planner.plan(req).schedule.kind == "rs"  # served unchecked


def test_service_rejects_corrupt_window_and_does_not_cache(monkeypatch):
    import repro.workloads.serve as serve_mod

    real_dp = serve_mod.window_dp

    def crooked_dp(n, cand_lists, cm, **kw):
        chosen = list(real_dp(n, cand_lists, cm, **kw))
        chosen[-1] = dataclasses.replace(
            chosen[-1], g_last=(chosen[-1].g_last % (n - 1)) + 1
            if chosen[-1].g_last != (chosen[-1].g_last % (n - 1)) + 1
            else chosen[-1].g_last + 1)
        return chosen

    monkeypatch.setattr(serve_mod, "window_dp", crooked_dp)
    service = PlanService(cm=PAPER_DEFAULT, cache_size=8)
    req = ServeRequest(events=(CollectiveEvent("a2a", MB, "t0"),
                               CollectiveEvent("ag", MB / 2, "t1")),
                       n=16, init_g=2)
    with pytest.raises(VerificationError, match="serve/"):
        service.serve(req)
    assert len(service._cache) == 0
    monkeypatch.undo()
    sp = service.serve(req)
    assert not verify_served_plan(sp, PAPER_DEFAULT)
    assert len(service._cache) == 1


def test_online_planner_rejects_corrupt_window(monkeypatch):
    import repro.workloads.online_planner as op_mod

    real_dp = op_mod.window_dp

    def crooked_dp(n, cand_lists, cm, **kw):
        chosen = list(real_dp(n, cand_lists, cm, **kw))
        chosen[0] = dataclasses.replace(chosen[0], paid=chosen[0].paid + 1)
        return chosen

    monkeypatch.setattr(op_mod, "window_dp", crooked_dp)
    op = op_mod.OnlinePlanner(16, cm=PAPER_DEFAULT, window=2)
    op.predict((CollectiveEvent("a2a", MB, "t0"),
                CollectiveEvent("ag", MB / 2, "t1")))
    with pytest.raises(VerificationError, match="window/paid"):
        op.observe()


def test_verification_error_carries_violations():
    sched = Schedule(kind="a2a", n=16, x=(0, 0, 1, 0), r=2)
    bad = dataclasses.replace(compile_tape(sched), hops=(9, 9, 9, 9))
    violations = verify_tape(bad)
    assert violations and any(v.rule == "tape/hops" for v in violations)
    err = VerificationError(violations, context="test artifact")
    assert "tape/hops" in str(err) and "test artifact" in str(err)
    assert err.violations == tuple(violations)


def test_certified_regimes_match_guard_decisions():
    # alpha_s == 0 disables the overtaking certificate: verifier stays
    # orthogonal, but the certifier must refuse (covered in depth in
    # tests/test_certifier.py; this is the analysis-package smoke coupling)
    from repro.analysis import certify_lane
    from repro.core.batchsim import BatchLane

    sched = every_step_schedule("a2a", 8)
    lane = BatchLane(schedule=sched, m_bytes=MB)
    assert certify_lane(lane, PAPER_DEFAULT)
    free = CostModel(alpha_s=0.0, alpha_h=0.0,
                     bandwidth=PAPER_DEFAULT.bandwidth,
                     delta=PAPER_DEFAULT.delta)
    assert not certify_lane(lane, free)
