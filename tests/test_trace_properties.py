"""Hypothesis property tests for cross-collective fabric carryover (skipped
if hypothesis is absent; CI installs it, and the seeded-grid versions in
tests/test_traces.py always run).

Properties:
  - for ANY two consecutive schedules, the carryover boundary pays exactly
    the changed-circuit diff (`changed_links` of the fabric's final vs the
    next collective's initial link offsets) — and 0 swaps when collective i
    ends on exactly the offsets collective i+1 starts with;
  - `run_trace` full-pause equals the sum of independent `FabricSim` runs
    bit-for-bit on random traces;
  - the batched trace engine agrees with the scalar sparse carryover loop
    within 1e-9 relative on random traces and scenario knobs.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (FabricSim, PAPER_DEFAULT, Schedule, TraceLane,  # noqa: E402
                        batch_run_trace, changed_links,
                        trace_boundary_changed)
from repro.core.bruck import schedule_length  # noqa: E402

MB = 1024.0 ** 2


def _schedule(data, ns, label="sched") -> Schedule:
    n = data.draw(st.sampled_from(ns), label=f"{label}.n")
    kind = data.draw(st.sampled_from(["a2a", "rs", "ag"]), label=f"{label}.kind")
    s = schedule_length(kind, n, 2)
    bits = data.draw(st.lists(st.integers(0, 1), min_size=s - 1, max_size=s - 1),
                     label=f"{label}.x")
    return Schedule(kind=kind, n=n, x=tuple([0] + bits), r=2)


def _phases(data, ns, max_phases=3):
    n = data.draw(st.sampled_from(ns), label="n")
    count = data.draw(st.integers(2, max_phases), label="phases")
    out = []
    for i in range(count):
        kind = data.draw(st.sampled_from(["a2a", "rs", "ag"]),
                         label=f"kind{i}")
        s = schedule_length(kind, n, 2)
        bits = data.draw(st.lists(st.integers(0, 1), min_size=s - 1,
                                  max_size=s - 1), label=f"x{i}")
        m = data.draw(st.sampled_from([0.25 * MB, 2 * MB]), label=f"m{i}")
        out.append((Schedule(kind=kind, n=n, x=tuple([0] + bits), r=2), m))
    return tuple(out)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_property_boundary_delta_equals_changed_circuit_diff(data):
    """For any two consecutive schedules, the sparse trace pays exactly the
    changed-circuit diff at the boundary, and nothing when collective i ends
    on exactly the offsets collective i+1 starts with."""
    phases = _phases(data, [6, 12, 16], max_phases=2)
    (s1, m1), (s2, m2) = phases
    expect = changed_links(s1.n, s1.link_offsets()[-1], s2.link_offsets()[0])
    assert trace_boundary_changed([s1, s2]) == (expect,)
    if s1.link_offsets()[-1] == s2.link_offsets()[0]:
        assert expect == 0

    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 1e-3])))
    sim = FabricSim(chunks_per_msg=2, mode="sparse")
    res = sim.run_trace(phases, cm)
    paid_alone = sum(sim.run(s, m, cm).reconfigs_paid for s, m in phases)
    assert res.reconfigs_paid - paid_alone == expect
    assert res.delta_stall == pytest.approx(
        res.reconfigs_paid * cm.delta_sparse(1, 0.0))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_full_pause_trace_is_sum_of_independents(data):
    phases = _phases(data, [6, 12, 16])
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 1e-3])))
    sim = FabricSim(chunks_per_msg=2, mode="full-pause")
    res = sim.run_trace(phases, cm)
    assert res.completion == sum(sim.run(s, m, cm).completion
                                 for s, m in phases)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_batched_trace_matches_scalar(data):
    phases = _phases(data, [6, 12, 16])
    n = phases[0][0].n
    overlap = data.draw(st.sampled_from([0.0, 0.75]), label="overlap")
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 1e-3])))
    speed = None
    if data.draw(st.booleans(), label="straggler"):
        node = data.draw(st.integers(0, n - 1), label="node")
        rate = data.draw(st.sampled_from([0.25, 0.8]), label="rate")
        speed = tuple(rate if v == node else 1.0 for v in range(n))
    ref = FabricSim(chunks_per_msg=2, overlap=overlap, mode="sparse",
                    link_speed=list(speed) if speed else None
                    ).run_trace(phases, cm)
    res = batch_run_trace(
        [TraceLane(phases=phases, overlap=overlap, link_speed=speed)],
        cm, chunks_per_msg=2)
    assert res.completion[0] == pytest.approx(ref.completion, rel=1e-9)
    assert res.chunks_moved[0] == ref.chunks_moved
