"""Hypothesis property tests for FabricSim (skipped if hypothesis is absent;
CI installs it, and the seeded-random versions in test_fabricsim.py always
run).

Properties:
  - full-pause / zero-overlap FabricSim reproduces `collective_time_event`
    exactly (bit-for-bit) on random schedules;
  - sparse-diff completion is monotonically <= full-pause across random
    schedules at n in {6, 12, 48, 96}.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import FabricSim, PAPER_DEFAULT, Schedule  # noqa: E402
from repro.core.bruck import schedule_length  # noqa: E402
from repro.core.eventsim import collective_time_event  # noqa: E402

MB = 1024.0 ** 2


def _schedule(data, ns) -> Schedule:
    n = data.draw(st.sampled_from(ns), label="n")
    kind = data.draw(st.sampled_from(["a2a", "rs", "ag"]), label="kind")
    s = schedule_length(kind, n, 2)
    bits = data.draw(st.lists(st.integers(0, 1), min_size=s - 1, max_size=s - 1),
                     label="x")
    return Schedule(kind=kind, n=n, x=tuple([0] + bits), r=2)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_full_pause_reproduces_eventsim(data):
    sched = _schedule(data, [6, 12, 16])
    m = data.draw(st.sampled_from([0.25 * MB, 4 * MB]), label="m")
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 1e-3])))
    res = FabricSim(chunks_per_msg=4, mode="full-pause").run(sched, m, cm)
    assert res.completion == collective_time_event(sched, m, cm, chunks_per_msg=4)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_sparse_le_full_pause(data):
    sched = _schedule(data, [6, 12, 48, 96])
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 15e-3])))
    full = FabricSim(chunks_per_msg=2, mode="full-pause").run(sched, MB, cm)
    sparse = FabricSim(chunks_per_msg=2, mode="sparse").run(sched, MB, cm)
    assert sparse.completion <= full.completion * (1 + 1e-12)
