"""Hypothesis property tests for FabricSim (skipped if hypothesis is absent;
CI installs it, and the seeded-random versions in test_fabricsim.py always
run).

Properties:
  - full-pause / zero-overlap FabricSim reproduces `collective_time_event`
    exactly (bit-for-bit) on random schedules;
  - sparse-diff completion is monotonically <= full-pause across random
    schedules at n in {6, 12, 48, 96};
  - the vectorized batch engine (`core.batchsim`) agrees with the scalar
    sparse loop within 1e-9 relative tolerance on random schedules and
    scenario knobs (fast path or oracle fallback alike).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import FabricSim, PAPER_DEFAULT, Schedule  # noqa: E402
from repro.core.bruck import schedule_length  # noqa: E402
from repro.core.eventsim import collective_time_event  # noqa: E402

MB = 1024.0 ** 2


def _schedule(data, ns) -> Schedule:
    n = data.draw(st.sampled_from(ns), label="n")
    kind = data.draw(st.sampled_from(["a2a", "rs", "ag"]), label="kind")
    s = schedule_length(kind, n, 2)
    bits = data.draw(st.lists(st.integers(0, 1), min_size=s - 1, max_size=s - 1),
                     label="x")
    return Schedule(kind=kind, n=n, x=tuple([0] + bits), r=2)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_full_pause_reproduces_eventsim(data):
    sched = _schedule(data, [6, 12, 16])
    m = data.draw(st.sampled_from([0.25 * MB, 4 * MB]), label="m")
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 1e-3])))
    res = FabricSim(chunks_per_msg=4, mode="full-pause").run(sched, m, cm)
    assert res.completion == collective_time_event(sched, m, cm, chunks_per_msg=4)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_sparse_le_full_pause(data):
    sched = _schedule(data, [6, 12, 48, 96])
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 15e-3])))
    full = FabricSim(chunks_per_msg=2, mode="full-pause").run(sched, MB, cm)
    sparse = FabricSim(chunks_per_msg=2, mode="sparse").run(sched, MB, cm)
    assert sparse.completion <= full.completion * (1 + 1e-12)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_batched_matches_scalar_sparse(data):
    from repro.core.batchsim import BatchLane, batch_run

    sched = _schedule(data, [6, 12, 48])
    n = sched.n
    m = data.draw(st.sampled_from([0.25 * MB, 4 * MB]), label="m")
    overlap = data.draw(st.sampled_from([0.0, 0.75]), label="overlap")
    cm = PAPER_DEFAULT.replace(delta=data.draw(st.sampled_from([1e-6, 1e-3])))
    speed = None
    if data.draw(st.booleans(), label="straggler"):
        node = data.draw(st.integers(0, n - 1), label="node")
        rate = data.draw(st.sampled_from([0.25, 0.8]), label="rate")
        speed = tuple(rate if v == node else 1.0 for v in range(n))
    ref = FabricSim(chunks_per_msg=2, overlap=overlap, mode="sparse",
                    link_speed=list(speed) if speed else None).run(sched, m, cm)
    res = batch_run([BatchLane(schedule=sched, m_bytes=m, overlap=overlap,
                               link_speed=speed)], cm, chunks_per_msg=2)
    assert res.completion[0] == pytest.approx(ref.completion, rel=1e-9)
    assert res.chunks_moved[0] == ref.chunks_moved
