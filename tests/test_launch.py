"""Launch layer: sharding rules, BRIDGE gradient-sync planner, dry-run cell.

The 512-device dry-run itself runs as a subprocess (XLA device-count flags
must not leak into this process); one fast cell is exercised end-to-end.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_collective_planner_regimes():
    from repro.collectives import plan_gradient_sync
    from repro.core import CostModel

    # latency-dominated (tiny payload): log-step bruck wins
    cm = CostModel(alpha_s=1e-6, alpha_h=1e-6, bandwidth=50e9, delta=1e-6)
    p_small = plan_gradient_sync(64, 1e3, cm)
    assert p_small.impl == "bruck"
    # static fabric: no reconfiguration schedules (hardware-routed permutes)
    assert p_small.rs_schedule is None
    # OCS fabric: the paper's schedules drive the optical switch
    p_ocs = plan_gradient_sync(64, 1e3, cm, fabric="ocs")
    assert p_ocs.impl == "bruck" and p_ocs.rs_schedule is not None
    # bandwidth-dominated (huge payload): ring wins
    p_big = plan_gradient_sync(64, 4e9, cm)
    assert p_big.impl == "ring"
    assert p_big.alternatives["ring"] < p_big.alternatives["bruck"]
    # non-power-of-two world: generalized Bruck is available and wins the
    # latency-dominated regime (log-step beats 2(n-1) ring steps)
    p_np2 = plan_gradient_sync(48, 1e3, cm)
    assert p_np2.impl == "bruck"
    assert p_np2.alternatives["bruck"] < p_np2.alternatives["ring"]
    # ... and still loses the bandwidth-dominated regime to ring
    assert plan_gradient_sync(48, 4e9, cm).impl == "ring"


def test_param_sharding_rules():
    import jax
    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.launch.shardings import param_shardings
    from repro.models import init_params

    cfg = configs.get("qwen3-moe-235b-a22b").scaled_down()
    mesh = make_mesh((1, 1), ("data", "model"))
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    sh = param_shardings(mesh, shapes)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    specs = {jax.tree_util.keystr(p): s.spec for p, s in flat}
    # embedding: vocab over model, features over data
    emb = [v for k, v in specs.items() if "embed" in k and "table" in k][0]
    assert tuple(emb) == ("model", "data")
    # expert weights: E over model (EP), d over data (FSDP)
    ew = [v for k, v in specs.items() if "w_gate" in k][0]
    assert tuple(ew)[:3] == (None, "model", "data")  # lead dim = scan reps
    # norms replicated
    nm = [v for k, v in specs.items() if "final_norm" in k][0]
    assert all(a is None for a in tuple(nm)) or tuple(nm) == ()


def test_ep_data_variant_fully_shards_experts():
    import jax
    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.launch.shardings import param_shardings
    from repro.models import init_params

    cfg = configs.get("arctic-480b").scaled_down()
    mesh = make_mesh((1, 1), ("data", "model"))
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    sh = param_shardings(mesh, shapes, moe_expert_axis="data")
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    for p, s in flat:
        k = jax.tree_util.keystr(p)
        if "w_gate" in k and "dense" not in k:
            assert tuple(s.spec)[:4] == (None, "data", None, "model"), (k, s.spec)
        if "w_down" in k and "dense" not in k:
            assert tuple(s.spec)[:4] == (None, "data", "model", None), (k, s.spec)


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One real 512-device lower+compile through the CLI (fast cell)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-3b", "--shape", "long_500k", "--mesh", "multipod",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=1200, cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK rwkv6-3b__long_500k__multipod" in proc.stdout, proc.stdout
    import json
    with open(tmp_path / "rwkv6-3b__long_500k__multipod.json") as f:
        res = json.load(f)
    assert res["devices"] == 512
    assert res["flops"] > 0
    assert res["calibrated"]["flops"] >= res["flops"]


def test_collective_byte_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128] %x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(f32[256] %y), to_apply=%sum
  %cp = f32[2,4]{1,0} collective-permute(f32[2,4] %z), source_target_pairs={{0,1}}
  %done = f32[2,4]{1,0} collective-permute-done(f32[2,4] %cp)
  %other = f32[4]{0} add(f32[4] %a, f32[4] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"]["bytes"] == 8 * 128 * 2
    assert out["all-reduce"]["bytes"] == 256 * 4
    assert out["collective-permute"]["count"] == 1  # start/done not doubled
    assert out["total_bytes"] == 8 * 128 * 2 + 256 * 4 + 2 * 4 * 4
