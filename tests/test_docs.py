"""Tier-1 coverage of the docs gate (benchmarks/docs_gate.py).

The CI docs job runs ``make docs-gate`` standalone; these tests run the
same two checks in-process so a dead doc link or a rotten doc example
fails the ordinary test suite too, plus unit checks on the gate's own
parsing (a broken link checker that never finds anything would otherwise
pass forever).
"""
import os
import subprocess
import sys

import pytest

from benchmarks import docs_gate

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_no_dead_links_in_readme_and_docs():
    assert docs_gate.check_links(ROOT) == []


def test_link_checker_catches_dead_links_and_anchors(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (tmp_path / "README.md").write_text(
        "# Title\n\n## A Real Section\n\n"
        "[ok](docs/a.md) [ok-anchor](#a-real-section)\n"
        "[dead](docs/missing.md) [dead-anchor](#no-such-heading)\n"
        "[dead-frag](docs/a.md#nope)\n"
        "```\n[not-a-link-in-code](nowhere.md)\n```\n")
    (docs / "a.md").write_text("# A\n\n## Kept Heading\n")
    errors = docs_gate.check_links(str(tmp_path))
    assert sorted(e.split(": ", 1)[1] for e in errors) == [
        "dead anchor #no-such-heading",
        "dead anchor docs/a.md#nope",
        "dead link docs/missing.md",
    ]


def test_python_block_extraction_skips_bash(tmp_path):
    doc = tmp_path / "d.md"
    doc.write_text("pre\n```python\nx = 1\n```\n"
                   "```bash\nexit 1\n```\n"
                   "```python\ny = x + 1\n```\n")
    blocks = docs_gate.python_blocks(str(doc))
    assert [src for _, src in blocks] == ["x = 1\n", "y = x + 1\n"]
    assert [ln for ln, _ in blocks] == [3, 9]


def test_batch_engine_doc_examples_execute():
    jax = pytest.importorskip("jax")  # noqa: F841 - doc blocks use the backend
    assert docs_gate.run_doc_examples(ROOT) == []


@pytest.mark.slow
def test_docs_gate_cli_green():
    pytest.importorskip("jax")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.docs_gate", "--root", ROOT],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK: docs gate passed" in proc.stdout
