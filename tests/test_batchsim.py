"""Batch fabric engine: compiled tapes + vectorized playback vs the scalar
sparse `FabricSim` oracle.

Pins the tentpole invariants:
  - differential fuzz over a seeded n x r x R x delta x straggler grid:
    batched results match the scalar sparse loop within 1e-9 relative
    tolerance (fast-path lanes are bit-exact; guarded lanes fall back to
    the oracle itself);
  - uniform lanes (the planning hot path) always take the vectorized fast
    path — `allow_fallback=False` proves it;
  - tapes are compiled once per Schedule and reused;
  - heterogeneous (schedule, m, delta, overlap, skew) lanes batch together;
  - lane/batch validation rejects mismatched shapes and bad knobs.
"""
import random

import numpy as np
import pytest

from repro.core import (FabricSim, PAPER_DEFAULT, Schedule, periodic_a2a,
                        straggler_speeds)
from repro.core.batchsim import (BatchLane, batch_run, clear_tape_caches,
                                 compile_tape)
from repro.core.bruck import schedule_length, steps_for

MB = 1024.0 ** 2
REL_TOL = 1e-9


def random_schedule(rng: random.Random, kind: str, n: int, r: int = 2) -> Schedule:
    s = schedule_length(kind, n, r)
    x = tuple([0] + [rng.randint(0, 1) for _ in range(s - 1)])
    return Schedule(kind=kind, n=n, x=x, r=r)


def scalar_reference(lane: BatchLane, cm, chunks: int):
    sim = FabricSim(
        chunks_per_msg=chunks, overlap=lane.overlap, mode="sparse",
        link_speed=list(lane.link_speed) if lane.link_speed else None,
        payload_scale=list(lane.payload_scale) if lane.payload_scale else None)
    eff_cm = cm if lane.delta is None else cm.replace(delta=lane.delta)
    return sim.run(lane.schedule, lane.m_bytes, eff_cm)


def assert_lane_matches(res, b: int, ref) -> None:
    assert res.completion[b] == pytest.approx(ref.completion, rel=REL_TOL)
    np.testing.assert_allclose(res.node_done[b], ref.node_done, rtol=REL_TOL)
    np.testing.assert_allclose(res.step_done[b], ref.step_done, rtol=REL_TOL)
    assert res.chunks_moved[b] == ref.chunks_moved
    assert res.reconfigs_paid[b] == ref.reconfigs_paid
    assert res.delta_stall[b] == pytest.approx(ref.delta_stall, rel=1e-12, abs=0.0) \
        or res.delta_stall[b] == ref.delta_stall


# --- differential fuzz vs the scalar oracle -----------------------------------


@pytest.mark.parametrize("n", [6, 12, 48, 96])
def test_differential_grid_matches_scalar(n):
    """Seeded n x r x R x delta x straggler grid: the batched engine agrees
    with the scalar sparse loop within 1e-9 relative everywhere."""
    rng = random.Random(1000 + n)
    fast = fallback = 0
    for r in (2, 3):
        for kind in ("a2a", "rs", "ag"):
            for straggler in (None, {n // 2: 0.3}):
                sched = random_schedule(rng, kind, n, r)
                m = rng.choice([0.25, 2.0]) * MB
                delta = rng.choice([1e-6, 1e-3, 15e-3])
                chunks = rng.choice([1, 2, 4])
                speed = (tuple(straggler_speeds(n, straggler))
                         if straggler else None)
                cm = PAPER_DEFAULT.replace(delta=delta)
                lane = BatchLane(schedule=sched, m_bytes=m, link_speed=speed)
                res = batch_run([lane], cm, chunks_per_msg=chunks)
                ref = scalar_reference(lane, cm, chunks)
                assert_lane_matches(res, 0, ref)
                if res.fast_path[0]:
                    fast += 1
                else:
                    fallback += 1
    # uniform lanes must ride the fast path; straggler lanes may fall back
    assert fast >= 6  # at least all uniform (r, kind) combinations


def test_uniform_lanes_never_fall_back():
    """Nominal (no straggler / skew) lanes are exactly the planning hot path:
    the canonical-order check must hold, so fallback never triggers."""
    rng = random.Random(7)
    lanes = []
    for kind in ("a2a", "rs", "ag"):
        for _ in range(4):
            lanes.append(BatchLane(
                schedule=random_schedule(rng, kind, 48),
                m_bytes=rng.choice([0.25, 4.0]) * MB,
                delta=rng.choice([1e-6, 1e-3]),
                overlap=rng.choice([0.0, 0.75])))
    res = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=4,
                    allow_fallback=False)  # raises if any guard trips
    assert res.fast_path.all()
    for b, lane in enumerate(lanes):
        assert_lane_matches(res, b, scalar_reference(lane, PAPER_DEFAULT, 4))


def test_heterogeneous_batch_matches_per_lane_scalar():
    """One batched call over mixed schedules / payloads / deltas / overlap /
    skew lanes reproduces every per-lane scalar run."""
    rng = random.Random(21)
    n = 16
    skew = [1.0] * n
    skew[3] = 4.0
    lanes = [
        BatchLane(schedule=periodic_a2a(n, 2), m_bytes=2 * MB),
        BatchLane(schedule=periodic_a2a(n, 0), m_bytes=0.5 * MB, delta=1e-3),
        BatchLane(schedule=random_schedule(rng, "rs", n), m_bytes=MB,
                  overlap=0.9, delta=15e-3),
        BatchLane(schedule=random_schedule(rng, "ag", n), m_bytes=4 * MB,
                  payload_scale=tuple(skew)),
        BatchLane(schedule=periodic_a2a(n, 3), m_bytes=2 * MB,
                  link_speed=tuple(straggler_speeds(n, {5: 0.25}))),
    ]
    res = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=2)
    for b, lane in enumerate(lanes):
        assert_lane_matches(res, b, scalar_reference(lane, PAPER_DEFAULT, 2))


def test_zero_payload_and_single_chunk_edge_cases():
    sched = periodic_a2a(8, 1)
    for m, chunks in ((0.0, 1), (MB, 1), (0.0, 8)):
        lane = BatchLane(schedule=sched, m_bytes=m)
        res = batch_run([lane], PAPER_DEFAULT, chunks_per_msg=chunks)
        ref = scalar_reference(lane, PAPER_DEFAULT, chunks)
        assert_lane_matches(res, 0, ref)


# --- FabricSim mode="batched" -------------------------------------------------


def test_fabricsim_batched_mode_matches_sparse():
    sched = periodic_a2a(32, 3)
    m, cm = 4 * MB, PAPER_DEFAULT.replace(delta=1e-3)
    sparse = FabricSim(chunks_per_msg=8, overlap=0.5, mode="sparse").run(sched, m, cm)
    batched = FabricSim(chunks_per_msg=8, overlap=0.5, mode="batched").run(sched, m, cm)
    assert batched.mode == "batched"
    assert batched.completion == pytest.approx(sparse.completion, rel=REL_TOL)
    assert batched.node_done == pytest.approx(sparse.node_done, rel=REL_TOL)
    assert batched.step_done == pytest.approx(sparse.step_done, rel=REL_TOL)
    assert batched.chunks_moved == sparse.chunks_moved
    assert batched.reconfigs_paid == sparse.reconfigs_paid
    assert batched.changed_links == sparse.changed_links


def test_fabricsim_batched_mode_accepts_scenario_knobs():
    n = 16
    sched = periodic_a2a(n, 2)
    skew = [1.0] * n
    skew[0] = 2.0
    for kw in ({"link_speed": straggler_speeds(n, {4: 0.5})},
               {"payload_scale": skew}):
        sparse = FabricSim(chunks_per_msg=4, mode="sparse", **kw).run(
            sched, MB, PAPER_DEFAULT)
        batched = FabricSim(chunks_per_msg=4, mode="batched", **kw).run(
            sched, MB, PAPER_DEFAULT)
        assert batched.completion == pytest.approx(sparse.completion, rel=REL_TOL)


# --- tape compilation ---------------------------------------------------------


def test_tape_is_compiled_once_per_schedule():
    sched = periodic_a2a(24, 2)
    t1 = compile_tape(sched)
    t2 = compile_tape(Schedule(kind="a2a", n=24, x=sched.x, r=2))
    assert t1 is t2  # lru_cache on the (hashable) Schedule
    clear_tape_caches()
    assert compile_tape(sched) is not t1


def test_tape_structure_matches_schedule():
    sched = Schedule(kind="a2a", n=16, x=(0, 1, 0, 1, 0, 0), r=4)
    tape = compile_tape(sched)
    steps = steps_for("a2a", 16, 1.0, 4)
    assert tape.S == len(steps)
    assert tape.offsets == tuple(st.offset for st in steps)
    assert list(tape.g_step) == sched.link_offsets()
    assert tape.hops == tuple(st.offset // g for st, g in
                              zip(steps, sched.link_offsets(), strict=True))
    assert tape.changed_links == sched.reconfig_changed_links()
    # duplicate-gcd boundary (first) is free, second pays
    assert tape.changed_pay == (False, False, False, True, False, False)
    # m-scaling is exact: nbytes == m * counts / n bit-for-bit
    m = 3.7 * MB
    for st, cnt in zip(steps_for("a2a", 16, m, 4), tape.counts, strict=True):
        assert st.nbytes == m * cnt / 16


def test_tape_arrays_are_readonly():
    arrays = compile_tape(periodic_a2a(8, 1)).arrays
    with pytest.raises(ValueError):
        arrays["hops"][0] = 99


# --- guard + fallback ---------------------------------------------------------


def test_severe_straggler_falls_back_and_still_matches():
    n = 48
    sched = periodic_a2a(n, 2)
    lane = BatchLane(schedule=sched, m_bytes=2 * MB,
                     link_speed=tuple(straggler_speeds(n, {n // 2: 0.25})))
    res = batch_run([lane], PAPER_DEFAULT, chunks_per_msg=8)
    assert not res.fast_path[0]  # event order genuinely diverges
    ref = scalar_reference(lane, PAPER_DEFAULT, 8)
    assert res.completion[0] == ref.completion  # oracle re-run: bit-equal
    with pytest.raises(RuntimeError, match="canonical-order"):
        batch_run([lane], PAPER_DEFAULT, chunks_per_msg=8,
                  allow_fallback=False)


def test_fallback_only_affects_guarded_lanes():
    n = 48
    lanes = [
        BatchLane(schedule=periodic_a2a(n, 2), m_bytes=2 * MB),
        BatchLane(schedule=periodic_a2a(n, 2), m_bytes=2 * MB,
                  link_speed=tuple(straggler_speeds(n, {0: 0.25}))),
    ]
    res = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=4)
    assert res.fast_path[0] and not res.fast_path[1]
    for b, lane in enumerate(lanes):
        assert_lane_matches(res, b, scalar_reference(lane, PAPER_DEFAULT, 4))


# --- validation ---------------------------------------------------------------


def test_batch_run_validates_lane_shapes():
    with pytest.raises(ValueError, match="at least one lane"):
        batch_run([], PAPER_DEFAULT)
    mixed_n = [BatchLane(schedule=periodic_a2a(16, 1), m_bytes=MB),
               BatchLane(schedule=periodic_a2a(32, 1), m_bytes=MB)]
    with pytest.raises(ValueError, match=r"\(n, S\)"):
        batch_run(mixed_n, PAPER_DEFAULT)
    mixed_s = [BatchLane(schedule=periodic_a2a(16, 1), m_bytes=MB),
               BatchLane(schedule=periodic_a2a(16, 1, r=4), m_bytes=MB)]
    with pytest.raises(ValueError, match=r"\(n, S\)"):
        batch_run(mixed_s, PAPER_DEFAULT)


def test_lane_validation():
    sched = periodic_a2a(16, 1)
    with pytest.raises(ValueError, match="overlap"):
        BatchLane(schedule=sched, m_bytes=MB, overlap=1.5)
    with pytest.raises(ValueError, match="payload"):
        BatchLane(schedule=sched, m_bytes=-1.0)
    with pytest.raises(ValueError, match="delta"):
        BatchLane(schedule=sched, m_bytes=MB, delta=-1e-6)
    with pytest.raises(ValueError, match="link_speed"):
        BatchLane(schedule=sched, m_bytes=MB, link_speed=(1.0,) * 8)
    with pytest.raises(ValueError, match="payload_scale"):
        BatchLane(schedule=sched, m_bytes=MB, payload_scale=(0.0,) * 16)


def test_result_accessor_is_fabricresult_compatible():
    lanes = [BatchLane(schedule=periodic_a2a(12, 1), m_bytes=MB),
             BatchLane(schedule=periodic_a2a(12, 2), m_bytes=2 * MB)]
    res = batch_run(lanes, PAPER_DEFAULT, chunks_per_msg=2)
    assert len(res) == 2
    one = res.result(1)
    assert one.mode == "batched"
    assert one.completion == res.completion[1]
    assert one.changed_links == lanes[1].schedule.reconfig_changed_links()
    assert isinstance(one.node_done, tuple) and len(one.node_done) == 12


# --- mid-trace snapshot / restore ---------------------------------------------


def random_phases(rng: random.Random, n: int, k: int):
    phases = []
    for _ in range(k):
        kind = rng.choice(["a2a", "rs", "ag"])
        phases.append((random_schedule(rng, kind, n, rng.choice([2, 3])),
                       rng.choice([0.25, 1.0, 4.0]) * MB))
    return tuple(phases)


def assert_states_match(a, b):
    assert a.n == b.n and a.link_offset == b.link_offset
    assert a.chunks_moved == b.chunks_moved
    assert a.reconfigs_paid == b.reconfigs_paid
    assert a.delta_stall == pytest.approx(b.delta_stall, rel=REL_TOL)
    np.testing.assert_allclose(a.node_ready, b.node_ready, rtol=REL_TOL)
    np.testing.assert_allclose(a.port_free, b.port_free, rtol=REL_TOL)


@pytest.mark.parametrize("n", [6, 12, 48])
def test_snapshot_restore_grid_matches_uninterrupted_run(n):
    """Differential fuzz across restore boundaries: running a trace straight
    through equals capturing a mid-trace `FabricSnapshot` at every split
    point and resuming from it — on the scalar sparse engine, and on the
    batched engine fed the scalar snapshot — within 1e-9."""
    from repro.core import TraceLane, batch_run_trace

    rng = random.Random(7000 + n)
    for delta in (1e-6, 1e-3, 15e-3):
        cm = PAPER_DEFAULT.replace(delta=delta)
        phases = random_phases(rng, n, rng.choice([3, 4]))
        chunks = rng.choice([1, 2, 4])
        sim = FabricSim(chunks_per_msg=chunks, mode="sparse")
        full = sim.run_trace(phases, cm, capture_state=True)
        for split in range(1, len(phases)):
            snap = sim.run_trace(phases[:split], cm,
                                 capture_state=True).final_state
            resumed = sim.run_trace(phases[split:], cm, initial=snap,
                                    capture_state=True)
            assert resumed.completion == pytest.approx(full.completion,
                                                       rel=REL_TOL)
            np.testing.assert_allclose(resumed.node_done, full.node_done,
                                       rtol=REL_TOL)
            assert resumed.reconfigs_paid == full.reconfigs_paid
            assert resumed.chunks_moved == full.chunks_moved
            assert resumed.delta_stall == pytest.approx(full.delta_stall,
                                                        rel=REL_TOL)
            assert_states_match(resumed.final_state, full.final_state)
            # the batched engine resumes from the same scalar snapshot
            batch = batch_run_trace(
                [TraceLane(phases=phases[split:], initial=snap)], cm,
                chunks_per_msg=chunks)
            assert batch.completion[0] == pytest.approx(full.completion,
                                                        rel=REL_TOL)
            assert batch.reconfigs_paid[0] == full.reconfigs_paid
            assert batch.chunks_moved[0] == full.chunks_moved
            assert_states_match(batch.snapshot(0), full.final_state)


def test_batched_capture_matches_scalar_capture():
    """`FabricSim(mode='batched').run_trace(..., capture_state=True)` and the
    scalar sparse engine capture the same resumable state."""
    rng = random.Random(11)
    n = 16
    phases = random_phases(rng, n, 3)
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    scalar = FabricSim(chunks_per_msg=2, mode="sparse").run_trace(
        phases, cm, capture_state=True)
    batched = FabricSim(chunks_per_msg=2, mode="batched").run_trace(
        phases, cm, capture_state=True)
    assert_states_match(batched.final_state, scalar.final_state)
    assert scalar.final_state.clock == pytest.approx(
        max(scalar.node_done), rel=REL_TOL)


def test_snapshot_restore_rejects_full_pause_and_mismatched_n():
    from repro.core import FabricSnapshot, TraceLane

    n = 8
    phases = ((periodic_a2a(n, 1), MB),)
    sparse = FabricSim(mode="sparse")
    snap = sparse.run_trace(phases, PAPER_DEFAULT,
                            capture_state=True).final_state
    pause = FabricSim(mode="full-pause")
    with pytest.raises(ValueError, match="full-pause"):
        pause.run_trace(phases, PAPER_DEFAULT, initial=snap)
    with pytest.raises(ValueError, match="full-pause"):
        pause.run_trace(phases, PAPER_DEFAULT, capture_state=True)
    other = ((periodic_a2a(12, 1), MB),)
    with pytest.raises(ValueError, match="n=8"):
        sparse.run_trace(other, PAPER_DEFAULT, initial=snap)
    with pytest.raises(ValueError, match="n=8"):
        TraceLane(phases=other, initial=snap)
    with pytest.raises(ValueError, match="at least 2"):
        FabricSnapshot(n=1, link_offset=1, node_ready=(0.0,),
                       port_free=(0.0,))
    with pytest.raises(ValueError, match="node_ready"):
        FabricSnapshot(n=4, link_offset=1, node_ready=(0.0,) * 3,
                       port_free=(0.0,) * 4)
    with pytest.raises(ValueError, match="port_free"):
        FabricSnapshot(n=4, link_offset=1, node_ready=(0.0,) * 4,
                       port_free=(0.0,) * 3)


@pytest.mark.parametrize("n", [8, 12])
def test_faulted_lanes_match_scalar_degraded_run(n):
    """Mid-trace fault lanes: the batched engine routes them to the scalar
    oracle and surfaces the identical `DegradedState`; clean lanes in the
    same batch are untouched."""
    from repro.core import FaultSpec, FaultTimeline, TraceLane, batch_run_trace

    rng = random.Random(9000 + n)
    for delta in (1e-6, 1e-3):
        cm = PAPER_DEFAULT.replace(delta=delta)
        phases = random_phases(rng, n, 3)
        chunks = rng.choice([1, 2, 4])
        sim = FabricSim(chunks_per_msg=chunks, mode="sparse")
        clean = sim.run_trace(phases, cm)
        for kind, policy in (("link-down", "drop"), ("link-flap", "requeue"),
                             ("node-leave", "drop"), ("node-join", "drop")):
            node = n if kind == "node-join" else rng.randrange(n)
            repair = 0.1 * clean.completion if kind == "link-flap" else 0.0
            # abrupt kinds strike mid-run; graceful kinds drain the in-flight
            # phase, so the fault must land before the *first* phase ends or
            # a 3-phase trace may simply complete (no-op fault)
            t_f = (0.5 * clean.completion
                   if kind in ("link-down", "link-flap")
                   else 0.5 * clean.phase_done[0])
            tl = FaultTimeline(n=n, faults=(
                FaultSpec(kind=kind, time=t_f, node=node,
                          repair_s=repair),), policy=policy)
            ref = sim.run_trace(phases, cm, faults=tl, capture_state=True)
            assert ref.degraded is not None
            batch = batch_run_trace(
                [TraceLane(phases=phases),
                 TraceLane(phases=phases, faults=tl)],
                cm, chunks_per_msg=chunks)
            assert batch.degraded[0] is None
            assert batch.degraded[1] == ref.degraded
            assert batch.completion[0] == pytest.approx(clean.completion,
                                                        rel=REL_TOL)
            got = batch.result(1)
            assert got.degraded == ref.degraded
            assert got.completion == ref.completion  # both inf: degraded
            np.testing.assert_allclose(got.phase_done, ref.phase_done,
                                       rtol=REL_TOL)
            assert got.chunks_moved == ref.chunks_moved
            # a degraded lane's resumable state lives on the DegradedState
            with pytest.raises(ValueError, match="degraded"):
                batch.snapshot(1)
            assert_states_match(batch.degraded[1].snapshot,
                                ref.degraded.snapshot)
            # faulted lanes need the scalar fallback path
            with pytest.raises(ValueError, match="fallback"):
                batch_run_trace([TraceLane(phases=phases, faults=tl)], cm,
                                chunks_per_msg=chunks, allow_fallback=False)


def test_fresh_snapshot_resume_equals_cold_run():
    """Resuming from an all-idle snapshot is exactly a cold run with an
    extra entry swap only when the configured circuit differs."""
    from repro.core import FabricSnapshot

    n = 12
    sched = periodic_a2a(n, 2)
    phases = ((sched, MB),)
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    sim = FabricSim(chunks_per_msg=2, mode="sparse")
    cold = sim.run_trace(phases, cm)
    g0 = sched.link_offsets()[0]
    idle = FabricSnapshot(n=n, link_offset=g0, node_ready=(0.0,) * n,
                          port_free=(0.0,) * n)
    same = sim.run_trace(phases, cm, initial=idle)
    assert same.completion == cold.completion  # matching circuit: free entry
    moved = FabricSnapshot(n=n, link_offset=g0 + 1, node_ready=(0.0,) * n,
                           port_free=(0.0,) * n)
    swapped = sim.run_trace(phases, cm, initial=moved)
    assert swapped.completion > cold.completion
    # the entry swap is a (port, boundary) event on every port
    assert swapped.reconfigs_paid == cold.reconfigs_paid + n
