"""Multi-tenant fabric sharing (repro.workloads.tenancy): port-partition
disjointness, shared <= serialized on a seeded grid, full-pause bit-equality
with naive serialization, tenant-keyed plan-cache isolation, the typed
FabricKind/SharingMode API with its deprecation shims, and lossless JSON
round trips.

The hypothesis properties (weight monotonicity of the optimal weighted
objective, per-tenant completion never past the serialized baseline) run
when hypothesis is installed (CI installs it).
"""
import json
import warnings

import pytest

from repro.analysis import verify_shared_plan
from repro.core import FabricSim, PAPER_DEFAULT
from repro.planner import FabricKind, Planner, PlanRequest, SharingMode
from repro.workloads import (CollectiveEvent, PlanService, ServeRequest,
                             SharedFabricRequest, SharedPlan, TenantSpec,
                             decode_ag_trace, mixed_trace, moe_a2a_trace,
                             plan_shared, score_shared_plans)


def _cm(delta):
    return PAPER_DEFAULT.replace(delta=delta)


def _tenants(k, world, *, shares=False, seed=0, weights=(2.0, 1.0, 1.5)):
    gens = (
        lambda n, s: mixed_trace(n, seed=s),
        lambda n, s: decode_ag_trace(n, decode_steps=3, seed=s, jitter=0.25),
        lambda n, s: moe_a2a_trace(n, layers=2, seed=s),
    )
    return tuple(
        TenantSpec(name=f"t{i}", trace=gens[i % len(gens)](world, seed + i),
                   weight=weights[i % len(weights)],
                   port_share=(1.0 / k if shares else None))
        for i in range(k))


# --- port partition: disjoint ranges, perfect isolation ------------------------


def test_port_partition_disjoint_and_verified():
    """K=3 port-partitioned tenants get pairwise-disjoint in-range port
    ranges sized to their worlds, isolate perfectly (ratio exactly 1.0),
    and the whole artifact passes the tenant/* verifier rules."""
    req = SharedFabricRequest(tenants=_tenants(3, 4, shares=True), n=12,
                              cost_model=_cm(1e-3),
                              sharing=SharingMode.PORT_PARTITION)
    sp = plan_shared(req)
    ranges = [t.ports for t in sp.tenants]
    for lo, hi in ranges:
        assert 0 <= lo < hi <= req.n
    for i, (lo, hi) in enumerate(ranges):
        assert hi - lo == sp.request.tenants[i].trace.n
        for lo2, hi2 in ranges[i + 1:]:
            assert hi <= lo2 or hi2 <= lo
    for t in sp.tenants:
        assert t.isolation == pytest.approx(1.0, abs=1e-12)
        assert t.plan is not None and t.plan.total_time == t.completion_s
    assert sp.phases == () and sp.order == ()
    assert verify_shared_plan(sp) == []


def test_port_partition_must_fit():
    with pytest.raises(ValueError, match="does not fit"):
        SharedFabricRequest(tenants=_tenants(3, 8), n=16,
                            sharing=SharingMode.PORT_PARTITION)
    with pytest.raises(ValueError, match="exceeds its port share"):
        SharedFabricRequest(
            tenants=(TenantSpec(name="a", trace=mixed_trace(8, seed=0),
                                port_share=0.25),),
            n=16, sharing=SharingMode.PORT_PARTITION)


# --- shared never worse than naive serialization -------------------------------


@pytest.mark.parametrize("delta", [10e-6, 1e-3, 15e-3])
@pytest.mark.parametrize("k", [2, 3])
@pytest.mark.parametrize("sharing", [SharingMode.TIME_SLICE,
                                     SharingMode.PORT_PARTITION])
def test_shared_never_worse_than_serialized(sharing, k, delta):
    """The structural gate: on every grid point the shared plan beats (or
    ties) playing the tenants' independent plans back-to-back with a
    full-fabric swap per hand-off — on makespan AND weighted completion —
    and every tenant stays within its structural isolation bound."""
    n = 12
    world = n if sharing == SharingMode.TIME_SLICE else n // k
    req = SharedFabricRequest(
        tenants=_tenants(k, world, shares=(sharing
                                           == SharingMode.PORT_PARTITION)),
        n=n, cost_model=_cm(delta), sharing=sharing)
    sp = plan_shared(req)
    tol = 1 + 1e-9
    assert sp.makespan_s <= sp.serialized_s * tol
    assert sp.weighted_completion_s <= sp.serialized_weighted_s * tol
    for t in sp.tenants:
        assert t.completion_s <= sp.makespan_s * tol
        assert t.isolation <= t.isolation_bound * tol
    assert verify_shared_plan(sp) == []


def test_global_budget_split_and_caps():
    """A global delta budget splits weight-proportionally across tenants
    without their own budget; an explicit per-tenant budget wins; the paid
    intra-collective stall respects every cap."""
    tenants = (
        TenantSpec(name="a", trace=mixed_trace(12, seed=0), weight=3.0),
        TenantSpec(name="b", trace=mixed_trace(12, seed=1), weight=1.0,
                   delta_budget=0.002),
    )
    req = SharedFabricRequest(tenants=tenants, n=12, cost_model=_cm(15e-3),
                              delta_budget=0.01)
    budgets = req.resolved_budgets()
    assert budgets["b"] == 0.002
    assert budgets["a"] == pytest.approx(0.008)  # the rest of the pool
    sp = plan_shared(req)
    unit = _cm(15e-3).delta_sparse(12, 0.0)
    for t in sp.tenants:
        assert t.paid_reconfigs * unit <= budgets[t.name] + unit * 1e-9
    assert verify_shared_plan(sp) == []


# --- full-pause playback vs serialization --------------------------------------


def test_time_slice_full_pause_bit_equal_to_sum_of_independents():
    """Under a full-pause fabric every phase pays the full swap, so playing
    the interleaved tape equals accumulating each phase's independent run
    left-to-right — bit-for-bit, not approximately: time-slicing's win
    comes only from sparse (changed==0) hand-offs, which full-pause
    playback does not price."""
    req = SharedFabricRequest(tenants=_tenants(2, 12), n=12,
                              cost_model=_cm(1e-3))
    sp = plan_shared(req)
    tape = sp.fabric_phases()
    assert len(tape) == len(sp.phases) > 0
    sim = FabricSim(chunks_per_msg=4, mode="full-pause")
    whole = sim.run_trace(tape, req.cost_model).completion
    total = 0.0
    for phase in tape:
        total += sim.run_trace([phase], req.cost_model).completion
    assert whole == total  # bit-equal, by construction of full-pause mode


def test_sparse_playback_matches_batch_scoring():
    """`score_shared_plans` (batch engine) agrees with scalar sparse
    FabricSim playback of the same interleaved tape."""
    req = SharedFabricRequest(tenants=_tenants(2, 12), n=12,
                              cost_model=_cm(1e-3))
    sp = plan_shared(req)
    batch = score_shared_plans([sp], req.cost_model, chunks_per_msg=4)
    sim = FabricSim(chunks_per_msg=4, mode="sparse")
    scalar = sim.run_trace(sp.fabric_phases(), req.cost_model).completion
    assert batch[0] == pytest.approx(scalar, rel=1e-9)


def test_fabric_phases_rejects_port_partition():
    req = SharedFabricRequest(tenants=_tenants(2, 6, shares=True), n=12,
                              sharing=SharingMode.PORT_PARTITION)
    sp = plan_shared(req)
    with pytest.raises(ValueError, match="port-partitioned"):
        sp.fabric_phases()


# --- tenant-keyed plan caches (stale-hit regression) ---------------------------


def test_planner_cache_is_tenant_keyed():
    """Two tenants with identical geometry must never share a Planner LRU
    entry: a tenant-specific pricing change (per-tenant budgets already
    differ) must not be served another tenant's stale plan."""
    planner = Planner(verify=False)
    base = dict(kind="a2a", n=8, m_bytes=1 << 20, cost_model=_cm(1e-3))
    req_a = PlanRequest(tenant="tenant-a", **base)
    req_b = PlanRequest(tenant="tenant-b", **base)
    assert Planner.cache_key(req_a) != Planner.cache_key(req_b)
    planner.plan(req_a)
    planner.plan(req_b)
    assert planner.cache_info().hits == 0
    assert planner.cache_info().misses == 2
    planner.plan(req_a)  # same tenant: a genuine hit
    assert planner.cache_info().hits == 1


def test_planner_cache_keys_per_tenant_budget():
    base = dict(kind="rs", n=8, m_bytes=1 << 20, cost_model=_cm(15e-3))
    with_budget = PlanRequest(delta_budget=0.001, **base)
    without = PlanRequest(**base)
    assert Planner.cache_key(with_budget) != Planner.cache_key(without)


def test_serve_cache_is_tenant_keyed():
    service = PlanService(cm=_cm(1e-3), verify=False)
    events = (CollectiveEvent("a2a", 1 << 20, "x"),
              CollectiveEvent("ag", 1 << 19, "y"))
    req_a = ServeRequest(n=8, events=events, tenant="tenant-a")
    req_b = ServeRequest(n=8, events=events, tenant="tenant-b")
    assert PlanService.request_key(req_a) != PlanService.request_key(req_b)
    service.serve(req_a)
    service.serve(req_b)
    assert service.cache_info().hits == 0
    assert service.cache_info().misses == 2
    service.serve(req_b)
    assert service.cache_info().hits == 1


# --- typed enums, deprecation shims, JSON round trips --------------------------


def test_fabric_kind_coercion_warns_and_is_lossless():
    with pytest.warns(DeprecationWarning, match="bare string"):
        assert FabricKind.coerce("ocs") is FabricKind.OCS
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert FabricKind.coerce(FabricKind.OCS_SIM) is FabricKind.OCS_SIM
        assert FabricKind.coerce("static", warn=False) is FabricKind.STATIC
    with pytest.raises(ValueError, match="fabric"):
        FabricKind.coerce("optical-teleport")


def test_sharing_mode_coercion_warns_and_is_lossless():
    with pytest.warns(DeprecationWarning, match="bare string"):
        assert SharingMode.coerce("time-slice") is SharingMode.TIME_SLICE
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert (SharingMode.coerce("port-partition", warn=False)
                is SharingMode.PORT_PARTITION)
    with pytest.raises(ValueError, match="sharing"):
        SharingMode.coerce("round-robin")


def test_enums_compare_and_serialize_as_strings():
    """str-subclass enums keep every legacy call site working: equality with
    the bare string, str() round trip, and plain-string JSON payloads."""
    assert FabricKind.OCS == "ocs" and str(FabricKind.OCS) == "ocs"
    assert SharingMode.TIME_SLICE == "time-slice"
    assert json.loads(json.dumps({"fabric": str(FabricKind.OCS_OVERLAP)})) \
        == {"fabric": "ocs-overlap"}


def test_plan_request_bare_string_warns_and_round_trips():
    with pytest.warns(DeprecationWarning, match="bare string"):
        req = PlanRequest(kind="a2a", n=8, m_bytes=1 << 20, fabric="ocs")
    assert req.fabric is FabricKind.OCS
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # loaders must round-trip silently
        back = PlanRequest.from_json(req.to_json())
    assert back == req and back.fabric is FabricKind.OCS


def test_shared_request_bare_string_warns_and_round_trips():
    with pytest.warns(DeprecationWarning, match="bare string"):
        req = SharedFabricRequest(tenants=_tenants(2, 12), n=12,
                                  sharing="time-slice")
    assert req.sharing is SharingMode.TIME_SLICE
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        back = SharedFabricRequest.from_dict(req.to_dict())
    assert back == req
    assert back.sharing is SharingMode.TIME_SLICE
    assert back.fabric is FabricKind.OCS


def test_shared_plan_json_round_trip_lossless():
    req = SharedFabricRequest(tenants=_tenants(2, 12), n=12,
                              cost_model=_cm(15e-3), delta_budget=0.01)
    sp = plan_shared(req)
    back = SharedPlan.from_json(sp.to_json())
    assert back == sp
    assert back.to_dict() == sp.to_dict()
    assert verify_shared_plan(back) == []


def test_deprecated_entry_points_warn():
    from repro.collectives import gradient_sync_plan, plan_gradient_sync
    from repro.core import schedules

    with pytest.warns(DeprecationWarning, match="plan_gradient_sync"):
        plan_gradient_sync(8, 1 << 20)
    with pytest.warns(DeprecationWarning, match="core.schedules.plan"):
        schedules.plan("a2a", 8, 1 << 20, PAPER_DEFAULT)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the replacement is warning-free
        gradient_sync_plan(8, 1 << 20)


def test_tenant_spec_validation():
    with pytest.raises(ValueError, match="weight"):
        TenantSpec(name="a", trace=mixed_trace(8, seed=0), weight=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        TenantSpec(name="", trace=mixed_trace(8, seed=0))
    with pytest.raises(ValueError, match="unique"):
        SharedFabricRequest(
            tenants=(TenantSpec(name="a", trace=mixed_trace(8, seed=0)),
                     TenantSpec(name="a", trace=mixed_trace(8, seed=1))),
            n=8)


# --- hypothesis properties (skipped when hypothesis is absent) -----------------


def test_weighted_objective_monotone_in_sla_weight():
    """Raising any tenant's SLA weight can only raise the optimal weighted
    objective (every schedule's objective rises pointwise, so the min over
    schedules rises), while the makespan gate keeps holding."""
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings  # noqa: E402
    from hypothesis import strategies as st  # noqa: E402

    weights = st.tuples(st.floats(0.5, 4.0), st.floats(0.5, 4.0))

    @settings(max_examples=8, deadline=None)
    @given(w=weights, bump=st.floats(0.1, 2.0),
           which=st.integers(min_value=0, max_value=1))
    def prop(w, bump, which):
        def solve(wa, wb):
            tenants = (
                TenantSpec(name="a", trace=mixed_trace(8, seed=0), weight=wa),
                TenantSpec(name="b", trace=decode_ag_trace(
                    8, decode_steps=3, seed=1), weight=wb),
            )
            return plan_shared(SharedFabricRequest(
                tenants=tenants, n=8, cost_model=_cm(15e-3)))
        base = solve(*w)
        bumped = solve(w[0] + (bump if which == 0 else 0.0),
                       w[1] + (bump if which == 1 else 0.0))
        assert bumped.weighted_completion_s >= \
            base.weighted_completion_s * (1 - 1e-9)
        for sp in (base, bumped):
            assert sp.makespan_s <= sp.serialized_s * (1 + 1e-9)

    prop()


def test_every_tenant_completion_within_serialized():
    """No tenant ever finishes later than the naive serialization of the
    whole mix — sharing a fabric can cost a tenant at most its structural
    isolation bound, for any weighting."""
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings  # noqa: E402
    from hypothesis import strategies as st  # noqa: E402

    @settings(max_examples=8, deadline=None)
    @given(w=st.lists(st.floats(0.5, 4.0), min_size=2, max_size=3),
           delta=st.sampled_from([10e-6, 1e-3, 15e-3]))
    def prop(w, delta):
        gens = (
            lambda n, s: mixed_trace(n, seed=s),
            lambda n, s: decode_ag_trace(n, decode_steps=3, seed=s),
            lambda n, s: moe_a2a_trace(n, layers=2, seed=s),
        )
        tenants = tuple(
            TenantSpec(name=f"t{i}", trace=gens[i % len(gens)](8, i),
                       weight=wi) for i, wi in enumerate(w))
        sp = plan_shared(SharedFabricRequest(
            tenants=tenants, n=8, cost_model=_cm(delta)))
        tol = 1 + 1e-9
        for t in sp.tenants:
            assert t.completion_s <= sp.serialized_s * tol
            assert t.isolation <= t.isolation_bound * tol

    prop()
