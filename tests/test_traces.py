"""Workload traces: generators, cross-collective planning, fabric carryover.

Pins the tentpole invariants of the trace layer:

  - `changed_links` (the free-function generalization of
    `Schedule.reconfig_changed_links`) on uniform and per-node offsets;
  - trace/plan JSON round trips and deterministic generators;
  - carryover <= cold-fabric <= (never worse than) the trace planner's
    structural guarantees across the delta grid, joint budget allocation;
  - `FabricSim.run_trace` full-pause == sum of independent runs bit-for-bit
    (seeded-grid version; the hypothesis variant lives in
    tests/test_trace_properties.py);
  - sparse carryover boundary accounting == the changed-circuit diff, and
    the batched trace engine == the scalar one at 1e-9.
"""
import random

import pytest

from repro.core import (FabricSim, PAPER_DEFAULT, Schedule, TraceLane,
                        batch_run_trace, changed_links, periodic,
                        static_schedule, trace_boundary_changed)
from repro.core.bruck import schedule_length
from repro.workloads import (CollectiveEvent, Trace, TracePlan, concat_traces,
                             decode_ag_trace, mixed_trace, moe_a2a_trace,
                             plan_trace, train_step_trace)

MB = 1024.0 ** 2


def random_schedule(rng: random.Random, n: int, kind: str, r: int = 2) -> Schedule:
    s = schedule_length(kind, n, r)
    return Schedule(kind=kind, n=n, r=r,
                    x=tuple([0] + [rng.randint(0, 1) for _ in range(s - 1)]))


# --- changed_links ------------------------------------------------------------


def test_changed_links_uniform_offsets():
    assert changed_links(8, 1, 1) == 0
    assert changed_links(8, 1, 2) == 8
    assert changed_links(8, 2, 4) == 8
    # offsets are compared mod n (the egress target is (u + g) mod n)
    assert changed_links(8, 1, 9) == 0


def test_changed_links_per_node_offsets():
    assert changed_links(4, [1, 1, 2, 2], [1, 1, 2, 2]) == 0
    assert changed_links(4, [1, 1, 2, 2], [1, 2, 2, 2]) == 1
    assert changed_links(4, 1, [1, 1, 1, 3]) == 1
    with pytest.raises(ValueError):
        changed_links(4, [1, 1], [1, 1, 1, 1])
    with pytest.raises(ValueError):
        changed_links(0, 1, 1)


def test_changed_links_matches_schedule_method():
    rng = random.Random(7)
    for n in (6, 12, 16, 48):
        for kind in ("a2a", "rs", "ag"):
            sched = random_schedule(rng, n, kind)
            offs = sched.link_offsets()
            segs = sched.segments
            expect = tuple(changed_links(n, offs[a_prev], offs[a])
                           for (a_prev, _), (a, _) in zip(segs, segs[1:],
                                                          strict=False))
            assert sched.reconfig_changed_links() == expect


def test_trace_boundary_changed_free_iff_offsets_match():
    n = 16
    a2a = periodic("a2a", n, 0)     # single segment, g = 1
    rs = static_schedule("rs", n)   # g = 1 throughout
    assert trace_boundary_changed([a2a, rs]) == (0,)
    high = periodic("a2a", n, 3)    # last segment g != 1
    assert high.link_offsets()[-1] != rs.link_offsets()[0]
    assert trace_boundary_changed([high, rs]) == (n,)


# --- trace records and generators --------------------------------------------


def test_event_and_trace_validation():
    with pytest.raises(ValueError):
        CollectiveEvent(kind="bcast", m_bytes=1.0)
    with pytest.raises(ValueError):
        CollectiveEvent(kind="a2a", m_bytes=-1.0)
    ev = CollectiveEvent(kind="a2a", m_bytes=MB)
    with pytest.raises(ValueError):
        Trace(name="t", n=1, events=(ev,))
    with pytest.raises(ValueError):
        Trace(name="t", n=8, events=())
    with pytest.raises(ValueError):
        Trace(name="t", n=8, events=(ev,), r=1)


def test_trace_json_round_trip():
    tr = mixed_trace(16, seed=5)
    back = Trace.from_json(tr.to_json())
    assert back == tr
    assert back.to_dict() == tr.to_dict()


def test_generators_deterministic_in_seed():
    a = moe_a2a_trace(16, seed=3, jitter=0.25)
    b = moe_a2a_trace(16, seed=3, jitter=0.25)
    c = moe_a2a_trace(16, seed=4, jitter=0.25)
    assert a == b
    assert a != c
    d1 = decode_ag_trace(16, seed=1, jitter=0.5)
    d2 = decode_ag_trace(16, seed=1, jitter=0.5)
    assert d1 == d2


def test_generator_payloads_from_configs():
    moe = moe_a2a_trace(8, layers=2, tokens_per_device=1024, jitter=0.0)
    # 2 events (dispatch + combine) per layer at tokens x d_model x 2 bytes
    assert len(moe) == 4
    assert all(ev.kind == "a2a" for ev in moe.events)
    assert moe.events[0].m_bytes == 1024 * 4096 * 2  # qwen3 d_model = 4096
    train = train_step_trace(8, steps=2, buckets=3)
    assert len(train) == 6
    assert all(ev.kind == "ar" for ev in train.events)
    assert len({ev.m_bytes for ev in train.events}) == 1  # equal buckets
    with pytest.raises(ValueError):
        moe_a2a_trace(8, arch="stablelm-3b")  # dense arch has no MoE layers


def test_phases_flatten_composite_ar():
    tr = train_step_trace(8, steps=1, buckets=1)
    phases = tr.phases()
    assert [kind for kind, _, _ in phases] == ["rs", "ag"]
    assert phases[0][1] == phases[1][1] == tr.events[0].m_bytes
    mixed = concat_traces("both", [tr, decode_ag_trace(8, decode_steps=2)])
    assert len(mixed.phases()) == 2 + 2


# --- trace planning -----------------------------------------------------------


@pytest.mark.parametrize("delta", [10e-6, 1e-3, 15e-3])
def test_carryover_never_worse_than_cold_or_static(delta):
    cm = PAPER_DEFAULT.replace(delta=delta)
    for trace in (mixed_trace(16, seed=0), decode_ag_trace(12, decode_steps=4),
                  train_step_trace(16, steps=1, buckets=2)):
        static = plan_trace(trace, cm, mode="static")
        cold = plan_trace(trace, cm, mode="cold")
        carry = plan_trace(trace, cm, mode="carryover")
        assert carry.total_time <= cold.total_time * (1 + 1e-12)
        assert carry.total_time <= static.total_time * (1 + 1e-12)
        assert len(carry.phases) == len(trace.phases())


def test_boundary_cost_zero_iff_offsets_align():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    carry = plan_trace(mixed_trace(16, seed=0), cm, mode="carryover")
    for plan_prev, plan_next, changed, cost in zip(
            carry.phases, carry.phases[1:], carry.boundary_changed,
            carry.boundary_cost, strict=False):
        expect = changed_links(carry.trace.n,
                               plan_prev.schedule.link_offsets()[-1],
                               plan_next.schedule.link_offsets()[0])
        assert changed == expect
        assert (cost == 0.0) == (changed == 0)
        if changed:
            assert cost == cm.delta_sparse(changed, 0.0)


def test_cold_mode_charges_full_boundary_everywhere():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    trace = decode_ag_trace(16, decode_steps=3)
    cold = plan_trace(trace, cm, mode="cold")
    assert cold.boundary_changed == (16, 16)
    assert cold.boundary_cost == (cm.delta, cm.delta)
    assert cold.total_time == pytest.approx(
        sum(p.time for p in cold.phases) + 2 * cm.delta)


def test_trace_delta_budget_is_joint_not_per_phase():
    # at micro-delta the unconstrained optimum spends reconfigurations
    cm = PAPER_DEFAULT.replace(delta=10e-6)
    trace = mixed_trace(16, seed=0)
    free = plan_trace(trace, cm, mode="carryover")
    assert free.paid_reconfigs > 0
    # a budget for exactly the spent amount changes nothing
    budget = free.paid_reconfigs * cm.delta
    same = plan_trace(trace, cm, mode="carryover", delta_budget=budget)
    assert same.total_time == free.total_time
    # halving the budget still yields a feasible (possibly uneven) allocation
    half = plan_trace(trace, cm, mode="carryover", delta_budget=budget / 2)
    assert half.paid_reconfigs * cm.delta <= budget / 2 + 1e-15
    assert half.total_time >= free.total_time
    # zero budget forces zero intra-collective reconfigurations
    none = plan_trace(trace, cm, mode="carryover", delta_budget=0.0)
    assert none.paid_reconfigs == 0
    # and the joint spend may concentrate on few phases: with budget for one
    # reconfiguration, at most one phase pays (per-phase rationing would
    # forbid any)
    one = plan_trace(trace, cm, mode="carryover", delta_budget=cm.delta)
    assert one.paid_reconfigs <= 1


def test_trace_plan_json_round_trip():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    tp = plan_trace(mixed_trace(16, seed=2), cm, mode="carryover",
                    delta_budget=5e-3)
    back = TracePlan.from_json(tp.to_json())
    assert back == tp
    assert back.schedules() == tp.schedules()


def test_plan_trace_validation():
    trace = decode_ag_trace(8, decode_steps=2)
    with pytest.raises(ValueError):
        plan_trace(trace, mode="warm")
    with pytest.raises(ValueError):
        plan_trace(trace, fabric="ocs-sim")
    with pytest.raises(ValueError):
        plan_trace(trace, overlap=0.5)  # needs fabric="ocs-overlap"
    with pytest.raises(ValueError):
        plan_trace(trace, delta_budget=-1.0)


def test_plan_trace_overlap_fabric():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    trace = mixed_trace(16, seed=0)
    plain = plan_trace(trace, cm, mode="cold")
    hidden = plan_trace(trace, cm, mode="cold", fabric="ocs-overlap",
                        overlap=0.75)
    # the overlap credit shrinks every full boundary charge
    assert hidden.boundary_time == pytest.approx(plain.boundary_time * 0.25)
    carry = plan_trace(trace, cm, mode="carryover", fabric="ocs-overlap",
                       overlap=0.75)
    assert carry.total_time <= hidden.total_time * (1 + 1e-12)


# --- fabric execution of traces ----------------------------------------------


def test_run_trace_full_pause_equals_sum_of_independent_runs():
    """Seeded grid: the full-pause trace is bit-for-bit the legacy
    sum-of-independent-collectives number."""
    rng = random.Random(11)
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    for n in (6, 12, 48):
        for _ in range(3):
            phases = [
                (random_schedule(rng, n, rng.choice(["a2a", "rs", "ag"])),
                 rng.choice([0.25 * MB, MB, 4 * MB]))
                for _ in range(rng.randint(2, 4))
            ]
            sim = FabricSim(chunks_per_msg=2, mode="full-pause")
            res = sim.run_trace(phases, cm)
            indep = [sim.run(sched, m, cm) for sched, m in phases]
            assert res.completion == sum(r.completion for r in indep)
            assert res.phase_done[-1] == res.completion
            assert res.chunks_moved == sum(r.chunks_moved for r in indep)
            assert res.reconfigs_paid == sum(r.reconfigs_paid for r in indep)
            assert res.delta_stall == sum(r.delta_stall for r in indep)


def test_run_trace_sparse_boundary_pays_exactly_the_changed_diff():
    n = 16
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    sim = FabricSim(chunks_per_msg=2, mode="sparse")
    aligned = [(periodic("a2a", n, 0), MB), (static_schedule("rs", n), MB)]
    misaligned = [(periodic("a2a", n, 3), MB), (static_schedule("rs", n), MB)]
    for phases, boundary in ((aligned, 0), (misaligned, n)):
        res = sim.run_trace(phases, cm)
        parts = [sim.run(sched, m, cm) for sched, m in phases]
        extra = res.reconfigs_paid - sum(p.reconfigs_paid for p in parts)
        assert res.boundary_changed == (boundary,)
        assert extra == boundary
        assert res.delta_stall == pytest.approx(
            res.reconfigs_paid * cm.delta_sparse(1, 0.0))


def test_run_trace_single_phase_matches_run():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    sched = periodic("a2a", 12, 2)
    sim = FabricSim(chunks_per_msg=4, mode="sparse")
    one = sim.run_trace([(sched, MB)], cm)
    ref = sim.run(sched, MB, cm)
    assert one.completion == ref.completion
    assert one.reconfigs_paid == ref.reconfigs_paid
    assert one.node_done == ref.node_done


def test_run_trace_validation():
    cm = PAPER_DEFAULT
    sim = FabricSim(mode="sparse")
    with pytest.raises(ValueError):
        sim.run_trace([], cm)
    with pytest.raises(ValueError):
        sim.run_trace([(periodic("a2a", 8, 1), MB),
                       (periodic("rs", 16, 1), MB)], cm)
    with pytest.raises(ValueError):
        sim.run_trace([(periodic("a2a", 8, 1), -MB)], cm)
    with pytest.raises(ValueError):
        TraceLane(phases=())


def test_batched_trace_matches_scalar_sparse():
    rng = random.Random(23)
    for n in (6, 12, 48):
        for _trial in range(3):
            phases = tuple(
                (random_schedule(rng, n, rng.choice(["a2a", "rs", "ag"])),
                 rng.choice([0.25 * MB, 2 * MB]))
                for _ in range(rng.randint(2, 4)))
            delta = rng.choice([1e-6, 1e-3])
            overlap = rng.choice([0.0, 0.75])
            speed = None
            if rng.random() < 0.5:
                speed = tuple(0.25 if v == rng.randrange(n) else 1.0
                              for v in range(n))
            cm = PAPER_DEFAULT.replace(delta=delta)
            ref = FabricSim(chunks_per_msg=2, mode="sparse", overlap=overlap,
                            link_speed=list(speed) if speed else None
                            ).run_trace(phases, cm)
            res = batch_run_trace(
                [TraceLane(phases=phases, overlap=overlap, link_speed=speed)],
                cm, chunks_per_msg=2)
            assert res.completion[0] == pytest.approx(ref.completion, rel=1e-9)
            assert res.chunks_moved[0] == ref.chunks_moved
            got = res.result(0)
            assert got.boundary_changed == ref.boundary_changed
            for a, b in zip(got.phase_done, ref.phase_done, strict=True):
                assert a == pytest.approx(b, rel=1e-9)
            for a, b in zip(got.step_done, ref.step_done, strict=True):
                assert a == pytest.approx(b, rel=1e-9)


def test_fabricsim_batched_mode_run_trace():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    phases = [(periodic("a2a", 12, 2), MB), (periodic("rs", 12, 1), 2 * MB)]
    ref = FabricSim(chunks_per_msg=4, mode="sparse").run_trace(phases, cm)
    got = FabricSim(chunks_per_msg=4, mode="batched").run_trace(phases, cm)
    assert got.mode == "batched"
    assert got.completion == pytest.approx(ref.completion, rel=1e-9)


def test_planned_trace_executes_on_fabric():
    """End-to-end: plan a trace with carryover, play it on the fabric; the
    sparse execution respects the planner's boundary accounting."""
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    trace = mixed_trace(16, seed=1)
    carry = plan_trace(trace, cm, mode="carryover")
    res = FabricSim(chunks_per_msg=2, mode="sparse").run_trace(
        carry.fabric_phases(), cm)
    assert res.boundary_changed == carry.boundary_changed
    assert res.completion > 0
