"""Validate the paper's headline evaluation claims against our reproduction.

Every assertion cites the paper section it checks.  Bands are deliberately a
little loose (we reproduce the cost model analytically, not packet-level
ns-3), but tight enough that a broken scheduler/simulator fails loudly.
"""
import pytest

from benchmarks import figures
from repro.core import PAPER_DEFAULT, baselines, num_steps, plan

KB, MB = 1024.0, 1024.0 ** 2
US, MS = 1e-6, 1e-3


@pytest.fixture(scope="module")
def fig5():
    return figures.fig5()


@pytest.fixture(scope="module")
def fig8():
    return figures.fig8()


@pytest.fixture(scope="module")
def fig9():
    return figures.fig9()


@pytest.fixture(scope="module")
def fig12():
    return figures.fig12()


def test_a2a_up_to_10x_over_static(fig5):
    """Abstract/4.2: 'reduces All-to-All completion time by typically 3x to
    10x over static baselines' — peak 10.4x in Fig 5a."""
    peak = max(fig5["vs_sbruck"].values())
    assert 9.0 <= peak <= 12.0, peak


def test_a2a_gain_survives_millisecond_delays(fig5):
    """4.2: 'even by up to 5x when reconfiguration delays are in the
    milliseconds' and '1.4x even for a reconfiguration delay of 5 ms'."""
    ms_keys = {k: v for k, v in fig5["vs_sbruck"].items()
               if "d1000us" in k or "d5000us" in k}
    assert max(ms_keys.values()) >= 4.0
    d5 = {k: v for k, v in fig5["vs_sbruck"].items() if "d5000us" in k}
    assert max(d5.values()) >= 1.4


def test_a2a_beats_both_baselines_in_sparse_regime(fig5):
    """4.2/Fig 5b: up to ~2.1-2.6x over min(S-BRUCK, G-BRUCK)."""
    peak = max(fig5["vs_best"].values())
    assert 1.9 <= peak <= 3.0, peak


def test_a2a_never_slower_than_static(fig5):
    """BRIDGE with optimal R>=0 can always fall back to R=0 = S-BRUCK."""
    assert min(fig5["vs_sbruck"].values()) >= 1.0 - 1e-9


def test_fig8_shapes(fig8):
    """4.2/Fig 8: 1.4-3x at small m, rising to ~10x at large m; G-BRUCK
    matches BRIDGE above ~16 MB; inset peak ~2.1x over the best baseline."""
    small = fig8["bridge_vs_s"]["1KB"]
    assert 1.0 <= small <= 3.5
    big = fig8["bridge_vs_s"]["262144KB"]
    assert big >= 9.0
    # G-Bruck converges to Bridge for large messages
    ratio = fig8["bridge_vs_s"]["262144KB"] / fig8["gbruck_vs_s"]["262144KB"]
    assert abs(ratio - 1.0) < 0.05
    assert 1.8 <= max(fig8["bridge_vs_best"].values()) <= 2.6


def test_rs_up_to_6x_over_ring(fig9, fig12):
    """Abstract: 'exceeds the bandwidth-optimal RING algorithm by 1.5x to
    6.6x on low to moderate-sized workloads' (up to 8.5x in Fig 9a)."""
    peak = max(fig9["vs_ring"].values())
    assert 5.0 <= peak <= 10.0, peak
    # Fig 12 (delta=10us): up to ~5.0x over RING, up to ~1.3x over best
    assert 4.0 <= max(fig12["bridge"].values()) <= 6.0
    assert 1.2 <= max(fig12["bridge_vs_best"].values()) <= 1.6


def test_rs_uniformly_beats_rhd(fig9):
    """Abstract/4.3: 'uniformly outperforms existing reconfiguration
    strategies' with up to 1.5x over R-HD."""
    assert min(fig9["vs_rhd"].values()) >= 1.0 - 1e-9
    assert 1.3 <= max(fig9["vs_rhd"].values()) <= 1.7


def test_ring_wins_for_large_messages():
    """4.3: 'for delta = 0.15 ms RING begins to outperform BRIDGE' at large
    m — the bandwidth-bound regime."""
    n, m = 64, 256 * MB
    cm = PAPER_DEFAULT.replace(delta=0.15 * MS)
    t_ring = baselines.ring("rs", n, m, cm).total
    t_b = baselines.bridge("rs", n, m, cm).total
    assert t_ring < t_b * 1.05  # ring at least matches bridge here


def test_fig1_bruck_subrings_beat_hd():
    """Fig 1: with reuse, Bruck's cumulative AllReduce cost drops below HD
    for the same R; HD curves coincide until reconfigurations start."""
    out = figures.fig1()
    for R in (1, 2):
        assert out[f"final_bruck_R{R}"] < out[f"final_hd_R{R}"]
    # identical prefixes for HD (reconfigs are a suffix)
    hd0, hd1 = out["hd_R0"], out["hd_R1"]
    assert hd0[:6] == pytest.approx(hd1[:6])


def test_scheduler_runtime_milliseconds():
    """3.4: 'optimal schedules were computed within milliseconds for
    networks of up to 256'."""
    out = figures.scheduler_runtime()
    assert out["per_plan_ms"] < 100.0


def test_ports_extension_still_beneficial():
    """3.7: with z < 2n ports reconfiguration helps 'in sufficiently large
    networks'."""
    out = figures.ports_extension()
    assert out["n256_z64"] > 1.5
    assert out["n256_z128"] > out["n256_z64"] * 0.9  # more ports >= fewer


def test_optimal_R_monotone_in_delta():
    """3.6: as delta grows the optimal number of reconfigurations falls."""
    n, m = 64, 4 * MB
    rs = []
    for d in (0.0, 10 * US, 1 * MS, 100 * MS):
        p = plan("a2a", n, m, PAPER_DEFAULT.replace(delta=d),
                 paper_faithful=True)
        rs.append(p.schedule.R)
    assert rs == sorted(rs, reverse=True)
    assert rs[0] == num_steps(n) - 1 and rs[-1] == 0


def test_bridge_beats_even_episodic_rhd():
    """Beyond-paper robustness: BRIDGE vs a *strengthened* R-HD that may pay
    2*delta to shortcut any single step (not just suffixes).  The subring
    reuse argument must survive the stronger adversary on RS workloads."""
    n = 64
    worst = float("inf")
    for m in (16 * KB, 1 * MB, 16 * MB):
        for d in (1 * US, 10 * US, 150 * US):
            cm = PAPER_DEFAULT.replace(delta=d)
            t_b = baselines.bridge("rs", n, m, cm).total
            t_e = baselines.r_hd_episodic_time("rs", n, m, cm)
            worst = min(worst, t_e / t_b)
    assert worst >= 0.999, worst  # never loses


def test_a2a_n256_at_most_1ms_delay():
    """EXPERIMENTS note: at delta <= 1 ms, n = 256 keeps ~>=1.4x over static
    for every message size (the paper's Fig-7 claim, with the delta=5ms +
    tiny-m corner excluded as impractical per its Section 4.2).  Band floor
    1.3: at (1 MB, 1 ms) our analytic model gives 1.34x vs the paper's
    packet-level 1.4x — the only >5% claim gap, noted in EXPERIMENTS S1."""
    n = 256
    for m in (1 * MB, 32 * MB):
        for d in (10 * US, 1 * MS):
            cm = PAPER_DEFAULT.replace(delta=d)
            t_b = baselines.bridge("a2a", n, m, cm).total
            t_s = baselines.s_bruck("a2a", n, m, cm).total
            assert t_s / t_b >= 1.3, (m, d, t_s / t_b)
