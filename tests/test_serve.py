"""Serving driver test: batched requests produce per-request token budgets
and the greedy stream matches the reference full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import Request, serve_requests
from repro.models import forward, init_params


def test_serve_requests_greedy_consistent():
    cfg = configs.get("stablelm-3b").scaled_down()
    cfg = dataclasses.replace(cfg, dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    P, N, B = 12, 5, 3
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, P)
                    .astype(np.int32), max_new_tokens=N if i else N - 2)
            for i in range(B)]
    out = serve_requests(cfg, params, reqs, max_seq=P + N + 1,
                         progress=lambda *_: None)
    assert len(out[0]) == N - 2 and all(len(out[i]) == N for i in (1, 2))

    # greedy stream must match teacher-forced full forward
    for i in (1, 2):
        toks = np.concatenate([reqs[i].prompt, np.asarray(out[i])])
        ref = forward(cfg, params, {"tokens": jnp.asarray(toks[None])},
                      mode="train").logits
        ref_greedy = np.asarray(jnp.argmax(ref[0, P - 1:-1, :], axis=-1))
        np.testing.assert_array_equal(ref_greedy, np.asarray(out[i]))
