"""FabricSim: asynchronous per-link fabric vs the synchronized event sim.

Pins the tentpole invariants:
  - full-pause mode reproduces `collective_time_event` bit-for-bit;
  - sparse (async, per-link delta) completion <= full-pause completion for
    random schedules across n in {6, 12, 48, 96};
  - overlap credit is monotone and the duplicate-gcd boundary is free;
  - the scenario knobs (link_speed, payload_scale) validate their shapes.

The seeded-random versions always run; the hypothesis property tests run
when hypothesis is installed (CI installs it).
"""
import random

import pytest

from repro.core import (CostModel, FabricSim, PAPER_DEFAULT, Schedule,
                        collective_time, collective_time_overlap, periodic_a2a,
                        simulate_fabric, straggler_speeds)
from repro.core.bruck import schedule_length, steps_for
from repro.core.eventsim import collective_time_event, simulate_step

MB = 1024.0 ** 2


def random_schedule(rng: random.Random, kind: str, n: int, r: int = 2) -> Schedule:
    s = schedule_length(kind, n, r)
    x = tuple([0] + [rng.randint(0, 1) for _ in range(s - 1)])
    return Schedule(kind=kind, n=n, x=x, r=r)


# --- full-pause compatibility -------------------------------------------------


@pytest.mark.parametrize("n,R", [(16, 0), (16, 2), (32, 3), (6, 1)])
def test_full_pause_matches_collective_time_event_exactly(n, R):
    """Zero-overlap full-pause FabricSim == the legacy synchronized loop,
    bit-for-bit (same accumulation order)."""
    m, cm = 2 * MB, PAPER_DEFAULT
    sched = periodic_a2a(n, R)
    # the pre-FabricSim accumulation, recomputed by hand:
    steps = steps_for("a2a", n, m, sched.r)
    legacy = sched.R * cm.delta
    for st, g in zip(steps, sched.link_offsets(steps), strict=True):
        legacy += cm.alpha_s
        legacy += simulate_step(n, g, st.offset, st.nbytes, cm, 8).completion
    res = FabricSim(chunks_per_msg=8, mode="full-pause").run(sched, m, cm)
    assert res.completion == legacy
    assert collective_time_event(sched, m, cm, chunks_per_msg=8) == legacy
    assert res.reconfigs_paid == R and res.delta_stall == R * cm.delta


def test_full_pause_rejects_sparse_only_knobs():
    with pytest.raises(ValueError, match="payload_scale"):
        FabricSim(mode="full-pause", payload_scale=[1.0] * 8)
    with pytest.raises(ValueError, match="overlap"):
        FabricSim(mode="full-pause", overlap=0.5)
    with pytest.raises(ValueError, match="mode"):
        FabricSim(mode="warp")
    with pytest.raises(ValueError, match="overlap"):
        FabricSim(overlap=1.5)


# --- sparse mode: monotonicity ------------------------------------------------


@pytest.mark.parametrize("n", [6, 12, 48, 96])
def test_sparse_completion_le_full_pause_random_schedules(n):
    """Async per-link reconfiguration can only beat the global barrier +
    whole-fabric pause, for every schedule/kind/delta drawn."""
    rng = random.Random(n)
    for kind in ("a2a", "rs", "ag"):
        for _ in range(3):
            sched = random_schedule(rng, kind, n)
            m = rng.choice([0.25, 2.0]) * MB
            cm = PAPER_DEFAULT.replace(delta=rng.choice([1e-6, 1e-3, 15e-3]))
            chunks = rng.choice([1, 4])
            full = FabricSim(chunks_per_msg=chunks, mode="full-pause").run(sched, m, cm)
            sparse = FabricSim(chunks_per_msg=chunks, mode="sparse").run(sched, m, cm)
            assert sparse.completion <= full.completion * (1 + 1e-12)
            assert sparse.chunks_moved == full.chunks_moved


def test_sparse_monotone_in_overlap():
    sched = periodic_a2a(32, 3)
    m, cm = 4 * MB, PAPER_DEFAULT.replace(delta=1e-3)
    times = [FabricSim(chunks_per_msg=8, overlap=ov).run(sched, m, cm).completion
             for ov in (0.0, 0.5, 1.0)]
    assert times[0] >= times[1] >= times[2]
    # with everything hidden, all R*delta disappears from the critical path
    assert times[0] - times[2] == pytest.approx(sched.R * cm.delta, rel=0.05)


def test_sparse_straggler_slower_than_nominal():
    sched = periodic_a2a(16, 2)
    m, cm = 2 * MB, PAPER_DEFAULT
    nominal = simulate_fabric(sched, m, cm, chunks_per_msg=8)
    slow = simulate_fabric(sched, m, cm, chunks_per_msg=8,
                           link_speed=straggler_speeds(16, {8: 0.25}))
    assert slow.completion > nominal.completion


def test_sparse_payload_skew_slower_than_nominal():
    sched = periodic_a2a(16, 2)
    m, cm = 2 * MB, PAPER_DEFAULT
    skew = [1.0] * 16
    skew[3] = 4.0
    nominal = simulate_fabric(sched, m, cm, chunks_per_msg=8)
    skewed = simulate_fabric(sched, m, cm, chunks_per_msg=8, payload_scale=skew)
    assert skewed.completion > nominal.completion


# --- sparse reconfiguration accounting ----------------------------------------


def test_duplicate_gcd_boundary_is_free():
    """n=16 r=4 offsets [1,2,3,4,8,12]: segments [0],[1,2],[3..5] have link
    offsets 1,1,4 — the first reconfiguration changes no circuit."""
    sched = Schedule(kind="a2a", n=16, x=(0, 1, 0, 1, 0, 0), r=4)
    assert sched.link_offsets() == [1, 1, 1, 4, 4, 4]
    assert sched.reconfig_changed_links() == (0, 16)
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    res = FabricSim(chunks_per_msg=4).run(sched, 1 * MB, cm)
    # only the second boundary swaps: 16 port swaps, one delta each
    assert res.reconfigs_paid == 16
    assert res.delta_stall == pytest.approx(16 * cm.delta)
    bd = collective_time_overlap(sched, 1 * MB, cm, 0.0)
    assert bd.reconfig == pytest.approx(cm.delta)  # 1 of 2 boundaries charged


def test_ports_skip_unused_segment_circuits():
    """All boundaries whose segments a port has no traffic in are skipped —
    with uniform ring traffic every port serves every segment, so the paid
    swap count is exactly n per changing boundary."""
    sched = periodic_a2a(12, 2)
    cm = PAPER_DEFAULT
    res = FabricSim(chunks_per_msg=2).run(sched, 1 * MB, cm)
    changing = sum(1 for c in sched.reconfig_changed_links() if c)
    assert res.reconfigs_paid == 12 * changing


def test_delta_sparse_term():
    cm = CostModel(delta=10e-6)
    assert cm.delta_sparse(0, 0.0) == 0.0
    assert cm.delta_sparse(64, 0.0) == cm.delta
    assert cm.delta_sparse(64, 0.75) == pytest.approx(0.25 * cm.delta)
    assert cm.delta_sparse(1, 1.0) == 0.0
    with pytest.raises(ValueError, match="overlap"):
        cm.delta_sparse(4, 1.5)


def test_collective_time_overlap_degenerates_to_collective_time():
    """overlap=0 with every boundary changing == the plain analytic model."""
    sched = periodic_a2a(64, 3)
    m, cm = 4 * MB, PAPER_DEFAULT
    assert all(c == 64 for c in sched.reconfig_changed_links())
    bd = collective_time_overlap(sched, m, cm, 0.0)
    ref = collective_time(sched, m, cm)
    assert bd.total == ref.total
    assert bd.steps == ref.steps


# --- scenario-knob validation -------------------------------------------------


def test_sparse_rejects_bad_link_speed_and_scale():
    sched = periodic_a2a(16, 1)
    cm = PAPER_DEFAULT
    with pytest.raises(ValueError, match="link_speed"):
        FabricSim(link_speed=[1.0] * 8).run(sched, MB, cm)
    with pytest.raises(ValueError, match="link_speed"):
        FabricSim(link_speed=[1.0] * 15 + [0.0]).run(sched, MB, cm)
    with pytest.raises(ValueError, match="payload_scale"):
        FabricSim(payload_scale=[1.0] * 17).run(sched, MB, cm)
    with pytest.raises(ValueError, match="node"):
        straggler_speeds(8, {9: 0.5})
    with pytest.raises(ValueError, match="rate"):
        straggler_speeds(8, {2: 0.0})


# The hypothesis property versions of these invariants live in
# tests/test_fabricsim_properties.py (skipped when hypothesis is absent).
