"""Distributed-training features on an 8-device host mesh (subprocess).

Covers BRIDGE vs GSPMD gradient sync, compressed sync, 2-D (data x model)
MoE training, GPipe pipeline parallelism, and elastic checkpoint restart onto
a different mesh shape.  Details in tests/_distributed_worker.py.
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_distributed_features():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_distributed_worker.py"),
         "8"],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "ALL-OK" in proc.stdout, proc.stdout
