"""Multi-device collective validation.

The checks live in tests/_multidevice_worker.py and run in a subprocess so
the XLA host-platform device count (8) never leaks into this pytest process
(smoke tests and benches must see 1 device; see the dry-run rules).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("n", [4, 6, 8])  # 6: non-power-of-two world size
def test_collectives_vs_lax_oracles(n):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_multidevice_worker.py"), str(n)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-OK" in proc.stdout, proc.stdout
