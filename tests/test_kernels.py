"""Per-kernel validation: shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, rg_lru, wkv6
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rg_lru import ref as lru_ref
from repro.kernels.wkv6 import ref as wkv_ref

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return ({"atol": 5e-2, "rtol": 5e-2} if dtype == jnp.bfloat16
            else {"atol": 5e-5, "rtol": 5e-5})


# --- flash attention ----------------------------------------------------------

FLASH_CASES = [
    # b, hq, hkv, sq, sk, d, causal, window, block_q, block_k
    (2, 4, 2, 128, 128, 64, True, None, 64, 64),     # GQA causal
    (1, 2, 1, 100, 100, 32, True, None, 64, 64),     # ragged seq (padding)
    (1, 4, 4, 96, 96, 16, True, 32, 32, 32),         # sliding window
    (1, 4, 2, 160, 160, 32, True, 64, 64, 64),       # GQA + window
    (1, 2, 2, 64, 64, 16, False, None, 32, 32),      # bidirectional (encoder)
    (1, 8, 2, 8, 200, 32, True, None, 64, 64),       # chunked decode sq << sk
    (1, 1, 1, 64, 64, 128, True, None, 64, 64),      # MXU-aligned head dim
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, hq, hkv, sq, sk, d, causal, window, bq, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    got = flash_attention(q, k, v, causal, window, None, bq, bk)
    want = fa_ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("case", [
    # b, hq, hkv, sq, d, causal, window, block
    (1, 2, 1, 64, 32, True, None, 32),    # GQA group-sum of dK/dV
    (2, 4, 2, 96, 32, True, None, 32),
    (1, 4, 4, 80, 16, True, 32, 32),      # sliding window + ragged seq
    (1, 2, 2, 48, 16, False, None, 16),   # bidirectional
])
def test_flash_attention_grad_matches_ref(case):
    """Pallas backward kernels (kernel_bwd.py) vs jax.grad of the oracle."""
    b, hq, hkv, sq, d, causal, window, blk = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, hq, sq, d))
    k = jax.random.normal(ks[1], (b, hkv, sq, d))
    v = jax.random.normal(ks[2], (b, hkv, sq, d))
    g = jax.random.normal(ks[3], (b, hq, sq, d))
    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, causal, window, None, blk, blk) * g).sum()

    def f_ref(q, k, v):
        return (fa_ref.attention(q, k, v, causal=causal, window=window) * g).sum()
    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-4, rtol=1e-4)


def test_flash_attention_lse_output():
    from repro.kernels.flash_attention.kernel import flash_attention_fwd_lse
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 16))
    k = jax.random.normal(ks[1], (1, 2, 64, 16))
    v = jax.random.normal(ks[2], (1, 2, 64, 16))
    _, lse = flash_attention_fwd_lse(q, k, v, scale=0.25, causal=True,
                                     window=None, block_q=32, block_k=32)
    # manual lse
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * 0.25
    mask = fa_ref.attention_mask(64, 64, True, None)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    want = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# --- rg_lru ---------------------------------------------------------------------


@pytest.mark.parametrize("shape,blocks", [
    ((2, 100, 48), (32, 32)),
    ((1, 256, 128), (64, 128)),
    ((3, 17, 8), (16, 8)),          # tiny ragged
    ((1, 1, 16), (8, 16)),          # single step
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rg_lru_matches_ref(shape, blocks, dtype):
    B, T, D = shape
    bt, bd = blocks
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], shape, jnp.float32, 0.2, 0.99).astype(dtype)
    b = jax.random.normal(ks[1], shape, dtype)
    y, h = rg_lru(a, b)
    yr, hr = lru_ref.rg_lru_scan(a, b)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), **tol(dtype))


def test_rg_lru_grad():
    ks = jax.random.split(KEY, 2)
    a = jax.random.uniform(ks[0], (1, 20, 8), jnp.float32, 0.3, 0.95)
    b = jax.random.normal(ks[1], (1, 20, 8))
    g1 = jax.grad(lambda a, b: rg_lru(a, b)[0].sum(), argnums=(0, 1))(a, b)
    g2 = jax.grad(lambda a, b: lru_ref.rg_lru_scan(a, b)[0].sum(), argnums=(0, 1))(a, b)
    for x, y in zip(g1, g2, strict=True):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5, rtol=1e-5)


# --- wkv6 -------------------------------------------------------------------------


@pytest.mark.parametrize("dims", [
    # B, H, T, dk, dv, block_t
    (2, 3, 50, 16, 16, 16),
    (1, 2, 64, 32, 32, 32),
    (1, 1, 7, 8, 8, 8),
    (2, 2, 33, 64, 64, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_matches_ref(dims, dtype):
    B, H, T, dk, dv, bt = dims
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, T, dk), dtype)
    k = jax.random.normal(ks[1], (B, H, T, dk), dtype)
    v = jax.random.normal(ks[2], (B, H, T, dv), dtype)
    lw = (-jnp.exp(jax.random.normal(ks[3], (B, H, T, dk)))).astype(dtype)
    u = jax.random.normal(ks[4], (H, dk), dtype)
    y, s = wkv6(r, k, v, lw, u)
    yr, sr = wkv_ref.wkv6_scan(r, k, v, jnp.exp(lw.astype(jnp.float32)), u)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               **(tol(dtype) if dtype == jnp.bfloat16
                                  else {"atol": 5e-4, "rtol": 5e-4}))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=5e-4, rtol=5e-4)


def test_wkv6_extreme_decay_stable():
    """Chunked form must not overflow for very strong decay (log w << 0)."""
    B, H, T, dk, dv = 1, 1, 64, 16, 16
    ks = jax.random.split(KEY, 3)
    r = jax.random.normal(ks[0], (B, H, T, dk))
    k = jax.random.normal(ks[1], (B, H, T, dk))
    v = jax.random.normal(ks[2], (B, H, T, dv))
    lw = jnp.full((B, H, T, dk), -20.0)  # near-total forgetting each step
    u = jnp.ones((H, dk))
    y, s = wkv6(r, k, v, lw, u, 16)
    assert np.isfinite(np.asarray(y)).all()
    yr, _ = wkv_ref.wkv6_scan(r, k, v, jnp.exp(lw), u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4, rtol=1e-4)


# --- hypothesis sweeps -------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    @given(
        b=st.integers(1, 2), h=st.integers(1, 3),
        sq=st.integers(1, 80), d=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
    )
    @settings(max_examples=15, deadline=None)
    def test_flash_attention_property(b, h, sq, d, causal):
        ks = jax.random.split(jax.random.PRNGKey(sq * d + b), 3)
        q = jax.random.normal(ks[0], (b, h, sq, d))
        k = jax.random.normal(ks[1], (b, h, sq, d))
        v = jax.random.normal(ks[2], (b, h, sq, d))
        got = flash_attention(q, k, v, causal, None, None, 32, 32)
        want = fa_ref.attention(q, k, v, causal=causal, window=None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5, rtol=5e-5)

    @given(T=st.integers(1, 70), D=st.sampled_from([8, 24]),
           bt=st.sampled_from([8, 16, 32]))
    @settings(max_examples=15, deadline=None)
    def test_rg_lru_property(T, D, bt):
        ks = jax.random.split(jax.random.PRNGKey(T * D), 2)
        a = jax.random.uniform(ks[0], (1, T, D), jnp.float32, 0.1, 1.0)
        b = jax.random.normal(ks[1], (1, T, D))
        y, h = rg_lru(a, b)
        yr, hr = lru_ref.rg_lru_scan(a, b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5, rtol=1e-5)

except ImportError:  # pragma: no cover
    pass
