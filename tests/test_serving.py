"""Plan-serving front-end: LRU accounting, storm determinism, never worse
than cold per-event planning, and carryover-aware cache keys
(repro.workloads.serve + the Planner init_g key regression)."""
import json

import pytest

from repro.core import PAPER_DEFAULT
from repro.planner import PlanRequest, Planner
from repro.workloads import (PlanService, ServeRequest, build_request_pool,
                             mixed_trace, plan_trace, request_storm)

CM = PAPER_DEFAULT.replace(delta=15e-3)


def _events(n=12, k=3, seed=0):
    return mixed_trace(n, seed=seed).events[:k]


# --- request / plan surfaces --------------------------------------------------


def test_serve_request_round_trip_and_validation():
    req = ServeRequest(events=_events(), n=12, r=2, init_g=3)
    back = ServeRequest.from_dict(json.loads(json.dumps(req.to_dict())))
    assert back == req
    with pytest.raises(ValueError, match="at least one event"):
        ServeRequest(events=(), n=12)
    with pytest.raises(ValueError, match="at least 2 nodes"):
        ServeRequest(events=_events(), n=1)
    with pytest.raises(ValueError, match="radix"):
        ServeRequest(events=_events(), n=12, r=1)
    with pytest.raises(ValueError, match="init_g"):
        ServeRequest(events=_events(), n=12, init_g=0)


def test_service_validation():
    with pytest.raises(ValueError, match="fabric"):
        PlanService(fabric="static")
    with pytest.raises(ValueError, match="overlap"):
        PlanService(overlap=0.5)
    with pytest.raises(ValueError, match="cache_size"):
        PlanService(cache_size=-1)


def test_served_window_matches_offline_trace_dp():
    """A fresh-fabric request over a whole trace is exactly the offline
    carryover DP's problem: same schedules, same modeled total."""
    trace = mixed_trace(12, seed=1)
    offline = plan_trace(trace, CM, mode="carryover")
    service = PlanService(cm=CM)
    plan = service.serve(ServeRequest(events=trace.events, n=trace.n,
                                      r=trace.r))
    assert plan.entry_changed == 0 and plan.entry_cost == 0.0
    assert [p.schedule for p in plan.phases] == list(offline.schedules())
    assert plan.total_time == pytest.approx(offline.total_time, rel=1e-12)
    assert plan.final_g == plan.phases[-1].schedule.link_offsets()[-1]


# --- LRU accounting -----------------------------------------------------------


def test_cache_hit_miss_and_eviction_accounting():
    service = PlanService(cm=CM, cache_size=2)
    reqs = [ServeRequest(events=_events(seed=s), n=12) for s in range(3)]
    assert service.serve(reqs[0]) == service.serve(reqs[0])
    info = service.cache_info()
    assert (info.hits, info.misses, info.size) == (1, 1, 1)
    service.serve(reqs[1])
    service.serve(reqs[2])  # capacity 2: evicts reqs[0] (LRU)
    info = service.cache_info()
    assert (info.misses, info.size, info.capacity) == (3, 2, 2)
    service.serve(reqs[0])  # evicted -> miss again
    assert service.cache_info().misses == 4
    service.cache_clear()
    info = service.cache_info()
    assert (info.hits, info.misses, info.size) == (0, 0, 0)

    # cache_size=0 bypasses the LRU entirely but still serves plans
    bypass = PlanService(cm=CM, cache_size=0)
    assert bypass.serve(reqs[0]).phases
    assert bypass.cache_info().size == 0


def test_cache_key_includes_carryover_state():
    """Identical windows with different inherited link offsets are different
    planning problems — the serving LRU must never conflate them."""
    service = PlanService(cm=CM)
    events = _events()
    fresh = service.serve(ServeRequest(events=events, n=12))
    inherited = service.serve(ServeRequest(events=events, n=12, init_g=4))
    assert service.cache_info().misses == 2  # no stale hit
    assert fresh.entry_cost == 0.0
    first_g = inherited.phases[0].schedule.link_offsets()[0]
    if first_g != 4:
        assert inherited.entry_cost > 0.0
    assert inherited.total_time >= fresh.total_time


# --- degraded-mode serving: bounded retry with cache bypass -------------------


def test_retry_recovers_from_transient_verification_failure(monkeypatch):
    """A window that fails its audit once is re-planned (planner LRU
    cleared first) and served + cached; the retry shows up in cache_info."""
    from repro.analysis import Violation, raise_on_violations

    service = PlanService(cm=CM, cache_size=4, max_retries=1)
    req = ServeRequest(events=_events(), n=12)
    real = PlanService._plan_window
    calls = {"n": 0}

    def flaky(self, r):
        calls["n"] += 1
        if calls["n"] == 1:
            raise_on_violations(
                [Violation(rule="serve/entry", location="test",
                           message="injected corruption", repro="")],
                context="transient")
        return real(self, r)

    monkeypatch.setattr(PlanService, "_plan_window", flaky)
    plan = service.serve(req)
    info = service.cache_info()
    assert (info.hits, info.misses, info.retries, info.retry_failures) == \
        (0, 1, 1, 0)
    assert info.size == 1 and calls["n"] == 2
    # the retried plan was cached: a repeat is a pure hit
    assert service.serve(req) is plan
    assert service.cache_info().hits == 1 and calls["n"] == 2
    service.cache_clear()
    info = service.cache_info()
    assert (info.retries, info.retry_failures) == (0, 0)


def test_retry_budget_exhaustion_reraises(monkeypatch):
    """A persistently-corrupt window exhausts the budget: the error
    propagates, the failure is counted, nothing is cached, and the backoff
    sleeps once per retry."""
    from repro.analysis import (VerificationError, Violation,
                                raise_on_violations)

    service = PlanService(cm=CM, cache_size=4, max_retries=2,
                          retry_backoff_s=0.001)
    naps = []
    monkeypatch.setattr("repro.workloads.serve.time.sleep", naps.append)

    def dead(self, r):
        raise_on_violations(
            [Violation(rule="serve/final", location="test",
                       message="persistent corruption", repro="")],
            context="persistent")

    monkeypatch.setattr(PlanService, "_plan_window", dead)
    with pytest.raises(VerificationError, match="persistent"):
        service.serve(ServeRequest(events=_events(), n=12))
    info = service.cache_info()
    assert (info.misses, info.retries, info.retry_failures) == (1, 2, 1)
    assert info.size == 0  # the corrupt window never entered the LRU
    assert naps == [0.001, 0.002]  # exponential backoff per retry


def test_retry_zero_budget_and_validation(monkeypatch):
    from repro.analysis import (VerificationError, Violation,
                                raise_on_violations)

    with pytest.raises(ValueError, match="max_retries"):
        PlanService(cm=CM, max_retries=-1)
    with pytest.raises(ValueError, match="retry_backoff_s"):
        PlanService(cm=CM, retry_backoff_s=-0.1)

    service = PlanService(cm=CM, max_retries=0)

    def dead(self, r):
        raise_on_violations(
            [Violation(rule="serve/final", location="test",
                       message="injected", repro="")], context="no budget")

    monkeypatch.setattr(PlanService, "_plan_window", dead)
    with pytest.raises(VerificationError):
        service.serve(ServeRequest(events=_events(), n=12))
    info = service.cache_info()
    assert (info.retries, info.retry_failures) == (0, 1)


# --- storm driver -------------------------------------------------------------


def test_request_storm_deterministic_across_services():
    pool = build_request_pool(12, window=3, seed=0)
    runs = []
    for _ in range(2):
        service = PlanService(cm=CM)
        cold = request_storm(service, pool, requests=64, seed=1)
        hot = request_storm(service, pool, requests=64, seed=2)
        runs.append((cold.signature, cold.hits, cold.misses,
                     hot.signature, hot.hits, hot.misses,
                     cold.unique_windows))
    assert runs[0] == runs[1]
    # the plan sequence differs between differently-seeded storms
    assert runs[0][0] != runs[0][3]


def test_request_storm_accounting_and_validation():
    pool = build_request_pool(12, window=2, seed=3)
    service = PlanService(cm=CM)
    storm = request_storm(service, pool, requests=50, seed=4)
    assert storm.hits + storm.misses == storm.requests == 50
    # cold cache: at most one miss per drawn pool entry (fewer when distinct
    # pool entries are identical windows, e.g. repeated decode steps)
    assert storm.misses <= storm.unique_windows
    drawn_keys = {PlanService.request_key(r) for r in pool}
    assert storm.misses <= len(drawn_keys)
    assert storm.hit_rate == pytest.approx(storm.hits / 50)
    assert storm.plans_per_sec > 0
    with pytest.raises(ValueError, match="non-empty pool"):
        request_storm(service, [], requests=1)
    with pytest.raises(ValueError, match="requests"):
        request_storm(service, pool, requests=0)
    with pytest.raises(ValueError, match="hot_fraction"):
        request_storm(service, pool, hot_fraction=0.0)


# --- property: serving never loses to cold per-event planning -----------------


def test_serving_never_exceeds_cold_per_event_property():
    """For any window, the served joint plan is never worse than planning
    each event independently with full-fabric boundary swaps (the cold
    reference contains a feasible point of the window DP, and every sparse
    boundary charge is <= the full delta)."""
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings  # noqa: E402
    from hypothesis import strategies as st  # noqa: E402

    from repro.workloads import CollectiveEvent, Trace

    events_st = st.lists(
        st.builds(CollectiveEvent,
                  kind=st.sampled_from(["a2a", "rs", "ag", "ar"]),
                  m_bytes=st.floats(min_value=1e4, max_value=64e6),
                  tag=st.just("prop")),
        min_size=1, max_size=4)

    @settings(max_examples=25, deadline=None)
    @given(events=events_st, n=st.sampled_from([8, 12, 16]),
           delta=st.sampled_from([10e-6, 1e-3, 15e-3]))
    def inner(events, n, delta):
        cm = PAPER_DEFAULT.replace(delta=delta)
        service = PlanService(cm=cm, cache_size=0)
        served = service.serve(ServeRequest(events=events, n=n))
        cold = plan_trace(Trace(name="prop", n=n, events=tuple(events)),
                          cm, mode="cold")
        assert served.total_time <= cold.total_time * (1 + 1e-9)

    inner()


# --- Planner cache-key regression (carryover state) ---------------------------


def test_planner_cache_key_distinguishes_init_g():
    """Regression: before init_g entered the request (and so the LRU key),
    a plan computed for one inherited fabric state could be served for
    another, silently mispricing the entry boundary."""
    planner = Planner()
    base = {"kind": "a2a", "n": 16, "m_bytes": 4e6, "cost_model": CM,
            "fabric": "ocs"}
    fresh = planner.plan(PlanRequest(**base))
    warm = planner.plan(PlanRequest(**base, init_g=5))
    assert planner.cache_key(PlanRequest(**base)) != \
        planner.cache_key(PlanRequest(**base, init_g=5))
    assert planner.cache_info().misses == 2  # distinct problems, no stale hit
    assert warm.predicted_time > fresh.predicted_time  # entry swap is priced

    # same request again is a hit, per init_g
    planner.plan(PlanRequest(**base, init_g=5))
    assert planner.cache_info().hits == 1

    # JSON round trip preserves the carryover state
    req = PlanRequest(**base, init_g=5)
    assert PlanRequest.from_dict(req.to_dict()) == req

    with pytest.raises(ValueError, match="init_g"):
        PlanRequest(**base, init_g=0)
    with pytest.raises(ValueError, match="reconfigurable"):
        PlanRequest(kind="a2a", n=16, m_bytes=4e6, cost_model=CM,
                    fabric="static", init_g=2)


def test_planner_init_g_entry_matches_sparse_swap_cost():
    """The entry charge is exactly the sparse changed-circuit diff between
    the inherited offset and the winning schedule's first offset."""
    from repro.core import changed_links

    planner = Planner()
    base = {"kind": "rs", "n": 12, "m_bytes": 2e6, "cost_model": CM,
            "fabric": "ocs"}
    fresh = planner.plan(PlanRequest(**base))
    for g in (1, 3, 7):
        warm = planner.plan(PlanRequest(**base, init_g=g))
        first = warm.schedule.link_offsets()[0]
        entry = CM.delta_sparse(changed_links(12, g, first), 0.0)
        # the winning schedule may differ from the fresh one (entry cost can
        # flip the ranking); the modeled total is fresh-equivalent + entry
        # only when the same schedule wins
        if warm.schedule == fresh.schedule:
            assert warm.predicted_time == pytest.approx(
                fresh.predicted_time + entry, rel=1e-12)
        assert warm.predicted_time <= fresh.predicted_time + \
            CM.delta_sparse(12, 0.0) * (1 + 1e-9)
