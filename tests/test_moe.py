"""MoE dispatch unit tests: routing semantics, capacity, vmap==map."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, MoEConfig
from repro.models.moe import _capacity, init_moe, moe_ffn

KEY = jax.random.PRNGKey(11)


def make_cfg(**moe_kw):
    moe = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, group_size=16,
                    **moe_kw)
    return ArchConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      ffn="moe", moe=moe, dtype="float32")


def test_vectorized_groups_identical_to_scanned():
    """The moe-vmap perf variant must be semantics-preserving."""
    cfg_map = make_cfg()
    cfg_vmap = dataclasses.replace(
        cfg_map, moe=dataclasses.replace(cfg_map.moe, vectorize_groups=True))
    p = init_moe(cfg_map, KEY, jnp.float32)
    x = jax.random.normal(KEY, (2, 64, 16))
    y1, aux1 = moe_ffn(cfg_map, p, x)
    y2, aux2 = moe_ffn(cfg_vmap, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)


def test_dropless_when_capacity_huge():
    """With capacity >= all tokens, every token gets its top-k experts."""
    cfg = make_cfg(capacity_factor=8.0 / 2)  # C = group: dropless
    p = init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(KEY, (1, 32, 16))
    y, _ = moe_ffn(cfg, p, x)
    # manual dropless reference
    flat = x.reshape(-1, 16)
    logits = flat @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    ref = jnp.zeros_like(flat)
    for e in range(8):
        h = jax.nn.silu(flat @ p["w_gate"][e]) * (flat @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        w = jnp.where(top_i == e, top_p, 0.0).sum(-1, keepdims=True)
        ref = ref + w * ye
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 16)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens():
    """Tiny capacity: outputs must stay finite and bounded (dropped tokens
    pass through the residual with zero FFN contribution)."""
    cfg = make_cfg(capacity_factor=0.25)
    p = init_moe(cfg, KEY, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, 16))
    y, aux = moe_ffn(cfg, p, x)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_capacity_formula():
    m = MoEConfig(num_experts=8, top_k=2, d_ff_expert=4, capacity_factor=1.25)
    assert _capacity(64, m) == 20  # ceil(64*2*1.25/8)
    assert _capacity(4, m) == 4    # floor of 4
