"""JAX batch backend (`core.batchsim_jax`) vs the NumPy engine and the
scalar oracle.

Pins the PR's acceptance bar:
  - differential grid over n x r x kind x (m, delta, overlap) lanes,
    including certified and fallback lanes: ``backend="jax"`` matches the
    NumPy batch engine within 1e-6 relative (on this CPU it is bit-exact)
    and the scalar sparse oracle within 1e-9;
  - uncertified lanes in a jax-backend batch still route through the
    guarded NumPy path and, when a guard trips, the scalar oracle;
  - playback is bit-stable run-to-run;
  - the jit cache holds: repeated same-shape batches never retrace the
    kernel (recompilation count stays flat);
  - backend resolution: "auto" falls back to NumPy for small batches,
    ``backend="jax"`` demands ``certify=True``, x64 mode never leaks out
    of the playback call;
  - the planner's ``sim_backend`` knob gives backend-identical plans;
  - a jax-less install still imports the core and degrades cleanly
    (the `collectives._compat` guard).
"""
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PAPER_DEFAULT, periodic_a2a, straggler_speeds
from repro.core.batchsim import (BatchLane, batch_completion_times,
                                 batch_run)
from repro.core.bruck import schedule_length
from repro.core.schedules import Schedule

jax = pytest.importorskip("jax")

from repro.core import batchsim_jax  # noqa: E402  (needs the skip above)

MB = 1024.0 ** 2
REL_TOL = 1e-9
JAX_TOL = 1e-6  # the acceptance spec's jax-vs-numpy bar


def random_schedule(rng: random.Random, kind: str, n: int, r: int = 2) -> Schedule:
    s = schedule_length(kind, n, r)
    x = tuple([0] + [rng.randint(0, 1) for _ in range(s - 1)])
    return Schedule(kind=kind, n=n, x=x, r=r)


def scalar_completion(lane: BatchLane, cm, chunks: int) -> float:
    from repro.core import FabricSim

    sim = FabricSim(
        chunks_per_msg=chunks, overlap=lane.overlap, mode="sparse",
        link_speed=list(lane.link_speed) if lane.link_speed else None)
    eff_cm = cm if lane.delta is None else cm.replace(delta=lane.delta)
    return sim.run(lane.schedule, lane.m_bytes, eff_cm).completion


# --- differential grid: jax == numpy batch == scalar oracle -------------------


@pytest.mark.parametrize("n", [6, 12, 48, 96])
def test_differential_grid_jax_matches_numpy_and_scalar(n):
    """Seeded n x r x kind x (m, delta, overlap) grid, one wide batch per
    (n, r): the JAX backend agrees with the NumPy batch engine within 1e-6
    on every lane (certified ones bit-exactly) and with the scalar oracle
    within 1e-9."""
    rng = random.Random(7000 + n)
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    for r in (2, 3):
        lanes = []
        for kind in ("a2a", "rs", "ag"):   # same S at one (n, r): one batch
            for m_mb, delta, overlap in ((0.25, 1e-6, 0.0), (2.0, 15e-3, 0.5)):
                lanes.append(BatchLane(
                    schedule=random_schedule(rng, kind, n, r),
                    m_bytes=m_mb * MB, delta=delta, overlap=overlap))
        # one uncertified lane: a straggler breaks uniformity, so it must
        # route through the guarded NumPy path inside the jax-backend batch
        lanes.append(BatchLane(
            schedule=lanes[0].schedule, m_bytes=MB,
            link_speed=tuple(straggler_speeds(n, {n // 2: 0.3}))))
        chunks = rng.choice([1, 2, 4])
        res_np = batch_run(lanes, cm, chunks_per_msg=chunks)
        res_j = batch_run(lanes, cm, chunks_per_msg=chunks, backend="jax")
        assert res_j.backend == "jax"
        assert res_j.certified[:-1].all() and not res_j.certified[-1]
        np.testing.assert_allclose(res_j.completion, res_np.completion,
                                   rtol=JAX_TOL)
        np.testing.assert_allclose(res_j.node_done, res_np.node_done,
                                   rtol=JAX_TOL)
        np.testing.assert_allclose(res_j.step_done, res_np.step_done,
                                   rtol=JAX_TOL)
        # certified lanes are bit-exact on CPU (same float ops, same order);
        # the uncertified lane ran the identical NumPy code path
        np.testing.assert_array_equal(res_j.node_done, res_np.node_done)
        for b, lane in enumerate(lanes):
            assert res_j.completion[b] == pytest.approx(
                scalar_completion(lane, cm, chunks), rel=REL_TOL)


def test_severe_straggler_falls_back_to_oracle_under_jax_backend():
    """A guard-tripping lane inside a jax-backend batch still lands on the
    scalar oracle, exactly as under the NumPy backend."""
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [
        BatchLane(schedule=periodic_a2a(n, 2), m_bytes=2 * MB),
        BatchLane(schedule=periodic_a2a(n, 2), m_bytes=2 * MB,
                  link_speed=tuple(straggler_speeds(n, {3: 1e-4}))),
    ]
    res_j = batch_run(lanes, cm, chunks_per_msg=2, backend="jax")
    res_np = batch_run(lanes, cm, chunks_per_msg=2)
    assert res_j.certified.tolist() == [True, False]
    assert not res_j.fast_path[1]          # oracle re-run
    np.testing.assert_array_equal(res_j.node_done, res_np.node_done)
    np.testing.assert_array_equal(res_j.completion, res_np.completion)


def test_jax_playback_is_bit_stable_run_to_run():
    n = 48
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [BatchLane(schedule=periodic_a2a(n, R), m_bytes=(R + 1) * MB)
             for R in range(4)]
    runs = [batch_run(lanes, cm, chunks_per_msg=4, backend="jax")
            for _ in range(3)]
    for later in runs[1:]:
        np.testing.assert_array_equal(runs[0].node_done, later.node_done)
        np.testing.assert_array_equal(runs[0].step_done, later.step_done)
        np.testing.assert_array_equal(runs[0].completion, later.completion)


# --- jit cache ----------------------------------------------------------------


def test_recompilation_count_flat_across_same_shape_batches():
    """Same-shape batches must hit the jit cache: trace_count stays flat
    while the dispatch count keeps climbing."""
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)

    def run(seed):
        lanes = [BatchLane(schedule=periodic_a2a(n, R),
                           m_bytes=(1.0 + 0.1 * seed + 0.01 * R) * MB)
                 for R in range(4)]
        return batch_run(lanes, cm, chunks_per_msg=2, backend="jax")

    run(0)  # warm: compiles this (B, S, n, C) shape if not seen yet
    before = batchsim_jax.compile_stats()
    for seed in range(1, 4):
        run(seed)
    after = batchsim_jax.compile_stats()
    assert after["trace_count"] == before["trace_count"]
    assert after["calls"] == before["calls"] + 3


def test_x64_mode_does_not_leak_out_of_playback():
    """`enable_x64` is a context around the playback call only; other jax
    users in the process must still see default float32 semantics."""
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB)]
    res = batch_run(lanes, cm, chunks_per_msg=2, backend="jax")
    assert res.node_done.dtype == np.float64
    assert jax.numpy.zeros(1).dtype == np.float32


# --- backend resolution -------------------------------------------------------


def test_auto_backend_keeps_numpy_for_small_batches():
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB)]
    assert batch_run(lanes, cm, backend="auto").backend == "numpy"


def test_auto_backend_picks_jax_above_the_work_floor(monkeypatch):
    from repro.core import batchsim

    monkeypatch.setattr(batchsim, "_JAX_AUTO_MIN_WORK", 0.0)
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB)]
    res = batch_run(lanes, cm, chunks_per_msg=2, backend="auto")
    assert res.backend == "jax"
    ref = batch_run(lanes, cm, chunks_per_msg=2)
    np.testing.assert_array_equal(res.node_done, ref.node_done)


def test_jax_backend_requires_certify():
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB)]
    with pytest.raises(ValueError, match="certify=True"):
        batch_run(lanes, cm, backend="jax", certify=False)
    # auto quietly degrades instead of raising
    assert batch_run(lanes, cm, backend="auto",
                     certify=False).backend == "numpy"


def test_unknown_backend_rejected():
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB)]
    with pytest.raises(ValueError, match="backend"):
        batch_run(lanes, cm, backend="torch")


def test_all_uncertified_jax_batch_degrades_to_numpy():
    """backend='jax' with zero certified lanes has nothing for the kernel;
    it resolves to the NumPy engine rather than dispatching an empty call."""
    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    speed = tuple(straggler_speeds(n, {0: 0.5}))
    lanes = [BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB,
                       link_speed=speed)]
    res = batch_run(lanes, cm, backend="jax")
    assert res.backend == "numpy"
    assert not res.certified.any()


def test_partition_backends_matches_certificates():
    from repro.analysis.certifier import certify_batch, partition_backends

    n = 12
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    lanes = [
        BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB),
        BatchLane(schedule=periodic_a2a(n, 1), m_bytes=MB,
                  link_speed=tuple(straggler_speeds(n, {0: 0.5}))),
        BatchLane(schedule=periodic_a2a(n, 2), m_bytes=2 * MB),
    ]
    jidx, uidx, mask = partition_backends(lanes, cm)
    np.testing.assert_array_equal(mask, certify_batch(lanes, cm))
    assert jidx.tolist() == [0, 2] and uidx.tolist() == [1]


# --- planner integration ------------------------------------------------------


def test_planner_sim_backend_parity():
    """ocs-sim plans are identical across sim backends — same winner, same
    predicted time (the scores are the same floats)."""
    from repro.planner import Planner, PlanRequest

    cm = PAPER_DEFAULT.replace(delta=1e-3)
    req = PlanRequest(kind="a2a", n=48, m_bytes=2 * MB, cost_model=cm,
                      fabric="ocs-sim")
    res_np = Planner(cache_size=0, sim_backend="numpy").plan(req)
    res_j = Planner(cache_size=0, sim_backend="jax").plan(req)
    assert res_j.schedule.x == res_np.schedule.x
    assert res_j.predicted_time == res_np.predicted_time
    assert [a.score for a in res_j.alternatives] == \
        [a.score for a in res_np.alternatives]


def test_planner_rejects_unknown_sim_backend():
    from repro.planner import Planner

    with pytest.raises(ValueError, match="sim_backend"):
        Planner(sim_backend="cupy")


def test_batch_completion_times_backend_parity():
    n = 48
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    scheds = [periodic_a2a(n, R) for R in range(4)]
    t_np = batch_completion_times(scheds, 2 * MB, cm, chunks_per_msg=4)
    t_j = batch_completion_times(scheds, 2 * MB, cm, chunks_per_msg=4,
                                 backend="jax")
    np.testing.assert_array_equal(t_np, t_j)


# --- jax-less installs (the _compat import guard) -----------------------------


def test_core_imports_and_degrades_without_jax(tmp_path):
    """With jax unimportable, the NumPy core must import and run, 'auto'
    must resolve to numpy, and backend='jax' must raise a clear ImportError
    (the satellite fix: kernels/-style jax probes never leak into the core
    import path)."""
    (tmp_path / "jax.py").write_text("raise ImportError('jax disabled')\n")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = "\n".join([
        "import numpy as np",
        "from repro.collectives import _compat",
        "assert not _compat.HAS_JAX",
        "from repro.core import PAPER_DEFAULT, periodic_a2a",
        "from repro.core.batchsim import BatchLane, batch_run",
        "from repro.core.batchsim_jax import jax_available",
        "assert not jax_available()",
        "cm = PAPER_DEFAULT.replace(delta=1e-3)",
        "lanes = [BatchLane(schedule=periodic_a2a(8, 1), m_bytes=1e6)]",
        "res = batch_run(lanes, cm, backend='auto')",
        "assert res.backend == 'numpy' and res.fast_path.all()",
        "try:",
        "    batch_run(lanes, cm, backend='jax')",
        "except ImportError as e:",
        "    assert 'jax' in str(e)",
        "else:",
        "    raise AssertionError('backend=jax should raise without jax')",
        "try:",
        "    _compat.shard_map(lambda x: x)",
        "except ImportError:",
        "    pass",
        "else:",
        "    raise AssertionError('shard_map should raise without jax')",
        "print('ok')",
    ])
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([str(tmp_path), src])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "ok" in out.stdout
