"""Worker executed in a subprocess with XLA_FLAGS host-device-count set.

Validates every collective in repro.collectives against jax.lax oracles on a
real multi-device (host-platform) mesh.  Prints 'ALL-OK' on success.
"""
import os
import sys

N = int(sys.argv[1]) if len(sys.argv) > 1 else 8
# Drop any inherited device-count flag (e.g. from the CI matrix leg that runs
# the whole suite under 8 host devices): the last occurrence wins in XLA, and
# this worker's N must control the mesh size.
_inherited = " ".join(
    tok for tok in os.environ.get("XLA_FLAGS", "").split()
    if not tok.startswith("--xla_force_host_platform_device_count"))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N} {_inherited}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.collectives import (bridge_all_reduce, bruck_all_gather,  # noqa: E402
                               bruck_all_reduce, bruck_all_to_all,
                               bruck_reduce_scatter, compressed_all_reduce,
                               make_error_feedback_state, ring_all_gather,
                               ring_all_reduce, ring_reduce_scatter)
from repro.collectives._compat import shard_map  # noqa: E402
from repro.core import PAPER_DEFAULT, plan  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402  (AxisType compat inside)

assert jax.device_count() == N, jax.device_count()
mesh = make_mesh((N,), ("ring",))
AXIS = "ring"


def smap(f, in_specs, out_specs):
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs))


def check(name, got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol,
                               rtol=1e-5, err_msg=name)
    print(f"ok {name}")


key = jax.random.PRNGKey(0)

# ---- all-to-all --------------------------------------------------------------
x = jax.random.normal(key, (N, N, 4, 3))  # global: (devices, rows-per-device...)
oracle = smap(lambda a: jax.lax.all_to_all(a, AXIS, 0, 0), P(AXIS), P(AXIS))(
    x.reshape(N * N, 4, 3))
got = smap(lambda a: bruck_all_to_all(a, AXIS), P(AXIS), P(AXIS))(
    x.reshape(N * N, 4, 3))
check("bruck_all_to_all", got, oracle)

# ---- reduce-scatter ----------------------------------------------------------
x = jax.random.normal(key, (N, N, 6))
want_full = x.sum(axis=0)  # (N, 6) block j at device j


def rs_run(fn):
    return smap(lambda a: fn(a, AXIS)[None], P(AXIS), P(AXIS))(x.reshape(N * N, 6))


check("bruck_reduce_scatter", rs_run(bruck_reduce_scatter), want_full)
check("ring_reduce_scatter", rs_run(ring_reduce_scatter), want_full)

rs_sched = plan("rs", N, 6 * 4.0, PAPER_DEFAULT).schedule
got = smap(lambda a: bruck_reduce_scatter(a, AXIS, rs_sched)[None], P(AXIS),
           P(AXIS))(x.reshape(N * N, 6))
check("bruck_reduce_scatter(schedule)", got, want_full)

# ---- all-gather ----------------------------------------------------------------
x = jax.random.normal(key, (N, 5))
want = jnp.broadcast_to(x[None], (N, N, 5)).reshape(N * N, 5)


def ag_run(fn, *args):
    return smap(lambda a: fn(a[0], AXIS, *args), P(AXIS), P(AXIS))(x)


check("bruck_all_gather", ag_run(bruck_all_gather), want)
check("ring_all_gather", ag_run(ring_all_gather), want)
ag_sched = plan("ag", N, 5 * 4.0, PAPER_DEFAULT).schedule
check("bruck_all_gather(schedule)", ag_run(bruck_all_gather, ag_sched), want)

# ---- all-reduce -----------------------------------------------------------------
x = jax.random.normal(key, (N, 7, 11))  # deliberately not divisible by N
want = jnp.broadcast_to(x.sum(0)[None], (N, 7, 11)).reshape(N * 7, 11)


def ar_run(fn, **kw):
    return smap(lambda a: fn(a.reshape(7, 11), AXIS, **kw).reshape(7, 11),
                P(AXIS), P(AXIS))(x.reshape(N * 7, 11))


check("ring_all_reduce", ar_run(ring_all_reduce), want)
check("bruck_all_reduce", ar_run(bruck_all_reduce), want)
got = smap(lambda a: bridge_all_reduce(a.reshape(7, 11), AXIS, N).reshape(7, 11),
           P(AXIS), P(AXIS))(x.reshape(N * 7, 11))
check("bridge_all_reduce", got, want)

# ---- compressed all-reduce with error feedback ----------------------------------
g = jax.random.normal(key, (N, 33)) * 3.0
want_sum = g.sum(0)


def comp(a):
    grads = {"w": a.reshape(33)}
    ef = make_error_feedback_state(grads)
    out1, ef = compressed_all_reduce(grads, ef, AXIS)
    # second round on the same grads: error feedback corrects round-1 error
    out2, ef = compressed_all_reduce(grads, ef, AXIS)
    return jnp.stack([out1["w"], out2["w"]])


got = smap(lambda a: comp(a)[None], P(AXIS), P(AXIS))(g)
got = np.asarray(got)  # (N, 2, 33) stacked per device, all identical
err1 = np.abs(got[0, 0] - np.asarray(want_sum)).max()
rel = err1 / np.abs(np.asarray(want_sum)).max()
assert rel < 0.05, f"int8 quantization error too large: {rel}"
print(f"ok compressed_all_reduce (rel err {rel:.4f})")

# round-2 output = quantized(g + e): error feedback means avg of round1+round2
# approximates 2*sum better than 2*round1 alone
err_fb = np.abs(got[0, 0] + got[0, 1] - 2 * np.asarray(want_sum)).max()
assert err_fb <= 2 * err1 + 1e-6, (err_fb, err1)
print("ok error_feedback")

print("ALL-OK")
