"""Fault timelines, degraded-mode engine runs, and the recovery loop.

Covers the `repro.core.faults` types (validation, strict JSON, horizon
check, `world_after`), the per-kind `DegradedState` semantics surfaced by
`FabricSim.run_trace(..., faults=...)` (committed prefix, chunk
conservation, exact prefix snapshot), event-granularity recovery
(`split_events` 'ar' atomicity, `run_with_recovery` resume-vs-restart +
bit-identity for every fault kind), checkpointed playback through
`repro.checkpoint.store`, and the explorer's out-of-horizon rejection.

The hypothesis properties (timeline JSON round trip for any seeded
timeline; recovery monotone in the failure time) follow the repo's
mixed-file idiom: importorskip inside the test, seeded fallbacks elsewhere.
"""
import dataclasses
import functools
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import verify_degraded, verify_timeline
from repro.core import (ABRUPT_KINDS, FAULT_KINDS, PAPER_DEFAULT, FabricSim,
                        FaultSpec, FaultTimeline, latest_snapshot,
                        random_timeline, static_schedule, world_after)
from repro.workloads import (CollectiveEvent, Trace, mixed_trace,
                             reduced_trace, run_with_recovery, split_events)

MB = 1024.0 ** 2
CM = PAPER_DEFAULT.replace(delta=1e-3)
CHUNKS = 4


def simple_phases(n=12, k=3):
    return tuple((static_schedule("a2a", n, 2), MB) for _ in range(k))


def clean_run(phases, **kw):
    return FabricSim(mode="sparse", chunks_per_msg=CHUNKS, **kw).run_trace(
        phases, CM)


def one_fault(n, kind, time, node=None, repair_s=0.0, policy="drop"):
    node = (n if kind == "node-join" else n // 3) if node is None else node
    return FaultTimeline(n=n, policy=policy, faults=(
        FaultSpec(kind=kind, time=time, node=node, repair_s=repair_s),))


# --- spec / timeline validation ------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="meteor-strike", time=1.0, node=0)
    with pytest.raises(ValueError, match="time"):
        FaultSpec(kind="link-down", time=-1.0, node=0)
    with pytest.raises(ValueError, match="time"):
        FaultSpec(kind="link-down", time=float("nan"), node=0)
    with pytest.raises(ValueError, match="node"):
        FaultSpec(kind="link-down", time=1.0, node=-1)
    with pytest.raises(ValueError, match="repair_s"):
        FaultSpec(kind="link-down", time=1.0, node=0, repair_s=0.5)
    with pytest.raises(ValueError, match="repair_s"):
        FaultSpec(kind="link-flap", time=1.0, node=0, repair_s=-0.5)
    # repair on a flap is the one legal use
    f = FaultSpec(kind="link-flap", time=1.0, node=3, repair_s=0.5)
    assert (f.time, f.node, f.repair_s) == (1.0, 3, 0.5)


def test_timeline_validation():
    spec = FaultSpec(kind="link-down", time=1.0, node=0)
    with pytest.raises(ValueError, match="at least 2 nodes"):
        FaultTimeline(n=1, faults=(spec,))
    with pytest.raises(ValueError, match="policy"):
        FaultTimeline(n=8, faults=(spec,), policy="teleport")
    with pytest.raises(ValueError, match="at least one fault"):
        FaultTimeline(n=8, faults=())
    with pytest.raises(ValueError, match="sorted"):
        FaultTimeline(n=8, faults=(
            FaultSpec(kind="link-down", time=2.0, node=0),
            FaultSpec(kind="link-down", time=1.0, node=1)))
    with pytest.raises(ValueError, match="outside"):
        FaultTimeline(n=8, faults=(
            FaultSpec(kind="link-down", time=1.0, node=8),))
    with pytest.raises(ValueError, match="node-join joins at index"):
        FaultTimeline(n=8, faults=(
            FaultSpec(kind="node-join", time=1.0, node=3),))
    # a valid timeline passes the verifier's fault/spec + fault/order rules
    tl = one_fault(8, "node-join", 1.0)
    assert verify_timeline(tl) == []


def test_timeline_json_strict_round_trip():
    tl = FaultTimeline(n=8, policy="requeue", faults=(
        FaultSpec(kind="link-flap", time=0.5, node=2, repair_s=0.1),
        FaultSpec(kind="node-leave", time=0.75, node=5)))
    assert FaultTimeline.from_json(tl.to_json()) == tl
    d = tl.to_dict()
    d["surprise"] = 1
    with pytest.raises(ValueError, match="unknown field"):
        FaultTimeline.from_dict(d)
    with pytest.raises(ValueError, match="missing required"):
        FaultTimeline.from_dict({"n": 8})
    bad = tl.to_dict()
    bad["faults"][0]["blast_radius"] = 3
    with pytest.raises(ValueError, match="unknown field"):
        FaultTimeline.from_dict(bad)


def test_timeline_json_round_trip_property():
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings  # noqa: E402
    from hypothesis import strategies as st  # noqa: E402

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), n=st.integers(2, 64),
           count=st.integers(1, 4),
           policy=st.sampled_from(["drop", "requeue"]))
    def inner(seed, n, count, policy):
        tl = random_timeline(n, horizon_s=2.5, seed=seed, count=count,
                             policy=policy)
        again = FaultTimeline.from_json(tl.to_json())
        assert again == tl
        # and the wire format itself is stable (dict -> json -> dict)
        assert json.loads(again.to_json()) == json.loads(tl.to_json())
        assert verify_timeline(tl) == []

    inner()


def test_check_horizon():
    tl = one_fault(8, "link-down", 1.0)
    assert tl.check_horizon(2.0) is tl
    with pytest.raises(ValueError, match="horizon"):
        tl.check_horizon(1.0)  # at the horizon is already a no-op
    with pytest.raises(ValueError, match="horizon"):
        tl.check_horizon(0.5)


def test_world_after_per_kind():
    down = FaultSpec(kind="link-down", time=1.0, node=3)
    assert world_after(8, down) == ((0, 1, 2, 4, 5, 6, 7), (3,))
    leave = FaultSpec(kind="node-leave", time=1.0, node=3)
    assert world_after(8, leave) == ((0, 1, 2, 4, 5, 6, 7), ())
    join = FaultSpec(kind="node-join", time=1.0, node=8)
    assert world_after(8, join) == (tuple(range(9)), ())
    flap = FaultSpec(kind="link-flap", time=1.0, node=3, repair_s=0.2)
    assert world_after(8, flap) == (tuple(range(8)), ())


# --- per-kind DegradedState semantics ------------------------------------------


def test_abrupt_fault_aborts_in_flight_phase():
    n, phases = 12, simple_phases()
    clean = clean_run(phases)
    # strike mid-second-phase: phase 0 committed, phase 1 aborted
    t_f = 0.5 * (clean.phase_done[0] + clean.phase_done[1])
    for policy in ("drop", "requeue"):
        tl = one_fault(n, "link-down", t_f, node=3, policy=policy)
        res = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
            phases, CM, faults=tl, capture_state=True)
        ds = res.degraded
        assert ds is not None and ds.completed_phases == 1
        assert ds.aborted_phase == 1
        assert ds.resume_clock == t_f
        assert ds.survivors == tuple(i for i in range(n) if i != 3)
        assert ds.dead_ports == (3,) and ds.new_n == n - 1
        assert ds.dead_port_mask()[3] and sum(ds.dead_port_mask()) == 1
        # chunk ledger: the in-flight split follows the delivery policy
        assert ds.in_flight_chunks > 0
        assert ds.lost_chunks + ds.requeued_chunks == ds.in_flight_chunks
        if policy == "drop":
            assert ds.requeued_chunks == 0
        else:
            assert ds.lost_chunks == 0
        assert verify_degraded(ds, phases=phases,
                               chunks_per_msg=CHUNKS) == []


def test_link_flap_keeps_world_and_delays_resume():
    n, phases = 12, simple_phases()
    clean = clean_run(phases)
    t_f, repair = 0.5 * clean.completion, 0.25 * clean.completion
    tl = one_fault(n, "link-flap", t_f, node=5, repair_s=repair,
                   policy="requeue")
    ds = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, faults=tl, capture_state=True).degraded
    assert ds.new_n == n and ds.survivors == tuple(range(n))
    assert ds.dead_ports == ()
    assert ds.resume_clock == t_f + repair
    assert ds.lost_chunks == 0  # requeue policy
    assert verify_degraded(ds, phases=phases, chunks_per_msg=CHUNKS) == []


@pytest.mark.parametrize("kind,dn", [("node-leave", -1), ("node-join", +1)])
def test_graceful_fault_drains_at_boundary(kind, dn):
    n, phases = 12, simple_phases()
    clean = clean_run(phases)
    t_f = 0.5 * clean.phase_done[0]  # mid-first-phase: it drains, then stop
    tl = one_fault(n, kind, t_f)
    ds = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, faults=tl, capture_state=True).degraded
    assert ds.completed_phases == 1 and ds.aborted_phase is None
    assert ds.new_n == n + dn and ds.dead_ports == ()
    # nothing in flight: the boundary is clean, resume at its clock
    assert ds.in_flight_chunks == ds.lost_chunks == ds.requeued_chunks == 0
    assert ds.resume_clock == clean.phase_done[0] == ds.snapshot.clock
    assert verify_degraded(ds, phases=phases, chunks_per_msg=CHUNKS) == []


def test_committed_prefix_snapshot_is_exact():
    n, phases = 12, simple_phases(k=4)
    clean = clean_run(phases)
    t_f = 0.5 * (clean.phase_done[1] + clean.phase_done[2])
    tl = one_fault(n, "link-down", t_f, node=2)
    ds = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, faults=tl, capture_state=True).degraded
    assert ds.completed_phases == 2
    prefix = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases[:2], CM, capture_state=True).final_state
    assert ds.snapshot == prefix  # bit-exact, not approximately equal


def test_fault_after_completion_is_a_noop():
    n, phases = 12, simple_phases()
    clean = clean_run(phases)
    tl = one_fault(n, "link-down", 2.0 * clean.completion)
    res = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, faults=tl)
    assert res.degraded is None
    assert res.completion == clean.completion
    assert res.phase_done == clean.phase_done


# --- event-granularity recovery ------------------------------------------------


def test_split_events_ar_atomicity():
    events = (CollectiveEvent(kind="a2a", m_bytes=MB),
              CollectiveEvent(kind="ar", m_bytes=MB),
              CollectiveEvent(kind="ag", m_bytes=MB))
    trace = Trace(name="t", n=8, events=events)
    # phase widths: a2a=1, ar=2 (rs+ag), ag=1 -> 4 phases total
    committed, remaining = split_events(trace, 1)
    assert committed == events[:1] and remaining == events[1:]
    # half-committed AllReduce stays in `remaining` and re-runs in full
    committed, remaining = split_events(trace, 2)
    assert committed == events[:1] and remaining == events[1:]
    committed, remaining = split_events(trace, 3)
    assert committed == events[:2] and remaining == events[2:]
    committed, remaining = split_events(trace, 4)
    assert committed == events and remaining == ()
    with pytest.raises(ValueError, match=">= 0"):
        split_events(trace, -1)
    with pytest.raises(ValueError, match="exceeds"):
        split_events(trace, 5)


def test_reduced_trace_retargets_surviving_world():
    trace = mixed_trace(8, moe_layers=1, train_steps=1, decode_steps=2)
    clean = clean_run(_plan(trace).fabric_phases())
    tl = one_fault(8, "link-down", 0.5 * clean.completion)
    ds = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        _plan(trace).fabric_phases(), CM, faults=tl,
        capture_state=True).degraded
    reduced = reduced_trace(trace, ds)
    assert reduced.n == 7 and reduced.r == trace.r
    committed, remaining = split_events(trace, ds.completed_phases)
    assert reduced.events == remaining
    # a fully-committed trace has nothing to recover
    done = dataclasses.replace(ds, completed_phases=sum(
        2 if e.kind == "ar" else 1 for e in trace.events))
    with pytest.raises(ValueError, match="nothing left to recover"):
        reduced_trace(trace, done)


@functools.lru_cache(maxsize=None)
def _plan(trace):
    from repro.workloads import plan_trace
    return plan_trace(trace, CM, mode="carryover")


@pytest.mark.parametrize("kind", FAULT_KINDS)
def test_run_with_recovery_full_cycle(kind):
    trace = mixed_trace(8, moe_layers=1, train_steps=1, decode_steps=2)
    clean = clean_run(_plan(trace).fabric_phases())
    # abrupt kinds strike mid-run; graceful kinds must land inside the first
    # phase (later they can legally drain the whole trace -> no-op)
    t_f = (0.5 * clean.completion if kind in ABRUPT_KINDS
           else 0.5 * clean.phase_done[0])
    tl = one_fault(8, kind, t_f,
                   repair_s=0.05 * clean.completion
                   if kind == "link-flap" else 0.0,
                   policy="requeue" if kind == "link-flap" else "drop")
    rr = run_with_recovery(trace, CM, faults=tl, chunks_per_msg=CHUNKS)
    ds = rr.degraded
    assert ds.fault.kind == kind
    assert rr.recovery_plan.trace.n == ds.new_n
    # resuming from the snapshot never loses to restarting from scratch
    assert rr.recovery_ratio <= 1 + 1e-9
    assert rr.recovery_total <= rr.restart_total * (1 + 1e-9)
    # the re-plan is bit-identical to a clean reduced-world carryover plan
    assert rr.bit_identical
    assert rr.recovery_plan.schedules() == rr.clean_plan.schedules()
    # every event beyond the committed prefix was a misprediction
    assert rr.stats.mispredictions == len(trace) - len(rr.committed_events)
    assert rr.stats.replans >= 1


def test_run_with_recovery_rejects_noop_timeline():
    trace = mixed_trace(8, moe_layers=1, train_steps=1, decode_steps=2)
    tl = one_fault(8, "link-down", 1e6)
    with pytest.raises(ValueError, match="check_horizon"):
        run_with_recovery(trace, CM, faults=tl, chunks_per_msg=CHUNKS)


def test_recovery_monotone_in_failure_time_property():
    hypothesis = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings  # noqa: E402
    from hypothesis import strategies as st  # noqa: E402

    trace = mixed_trace(8, moe_layers=1, train_steps=1, decode_steps=2)
    clean = clean_run(_plan(trace).fabric_phases())

    @functools.lru_cache(maxsize=None)
    def recover(frac):
        tl = one_fault(8, "link-down", frac * clean.completion, node=3)
        return run_with_recovery(trace, CM, faults=tl,
                                 chunks_per_msg=CHUNKS)

    fracs = st.sampled_from([0.15, 0.35, 0.55, 0.75, 0.95])

    @settings(max_examples=10, deadline=None)
    @given(a=fracs, b=fracs)
    def inner(a, b):
        lo, hi = recover(min(a, b)), recover(max(a, b))
        # a later fault can only commit more, never less
        assert hi.degraded.completed_phases >= lo.degraded.completed_phases
        # and the remaining work (executed past the resume clock) shrinks
        assert (hi.recovery_total - hi.degraded.resume_clock
                <= (lo.recovery_total - lo.degraded.resume_clock) * (1 + 1e-9))
        for rr in (lo, hi):
            assert rr.recovery_ratio <= 1 + 1e-9 and rr.bit_identical

    inner()


# --- checkpointed playback (repro.checkpoint.store) ----------------------------


def test_checkpointed_trace_equals_straight_run(tmp_path):
    from repro.checkpoint import store

    phases = simple_phases(k=4)
    straight = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, capture_state=True)
    d = str(tmp_path / "ckpt")
    chk = FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, capture_state=True, checkpoint_dir=d, checkpoint_every=2)
    assert chk.completion == straight.completion
    assert chk.phase_done == straight.phase_done
    assert chk.chunks_moved == straight.chunks_moved
    assert chk.final_state == straight.final_state
    # every=2 over 4 phases -> checkpoints at boundaries 2 and 4
    assert store.latest_step(d) == 4
    assert latest_snapshot(d) == straight.final_state


def test_checkpoint_atomicity_and_gc(tmp_path):
    from repro.checkpoint import garbage_collect, latest_step, restore

    phases = simple_phases(k=4)
    d = str(tmp_path / "ckpt")
    FabricSim(mode="sparse", chunks_per_msg=CHUNKS).run_trace(
        phases, CM, checkpoint_dir=d, checkpoint_every=1)
    assert latest_step(d) == 4
    garbage_collect(d, keep=2)
    assert latest_step(d) == 4
    restore(d, 4)  # survivors restore fine
    with pytest.raises(FileNotFoundError):
        restore(d, 1)  # collected
    assert latest_snapshot(str(tmp_path / "empty")) is None


def test_checkpoint_exclusions():
    phases = simple_phases()
    tl = one_fault(12, "link-down", 1.0)
    with pytest.raises(ValueError, match="mutually exclusive"):
        FabricSim(mode="sparse").run_trace(phases, CM, faults=tl,
                                           checkpoint_dir="/tmp/nope")
    with pytest.raises(ValueError, match="full-pause"):
        FabricSim(mode="full-pause").run_trace(phases, CM,
                                               checkpoint_dir="/tmp/nope")
    with pytest.raises(ValueError, match="n=12"):
        FabricSim(mode="sparse").run_trace(simple_phases(n=8), CM, faults=tl)


# --- explorer front-end: out-of-horizon specs are rejected ---------------------


def test_explorer_rejects_out_of_horizon_faults(tmp_path):
    root = Path(__file__).resolve().parents[1]
    spec = tmp_path / "late.json"
    spec.write_text(one_fault(8, "link-down", 99.0).to_json())
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    proc = subprocess.run(
        [sys.executable, str(root / "examples" / "schedule_explorer.py"),
         "--trace", "mixed", "--n", "8", "--faults", str(spec)],
        capture_output=True, text=True, env=env)
    assert proc.returncode != 0
    assert "horizon" in proc.stderr
    # --faults without --trace is an argparse error, not a crash
    proc = subprocess.run(
        [sys.executable, str(root / "examples" / "schedule_explorer.py"),
         "--faults", str(spec)], capture_output=True, text=True, env=env)
    assert proc.returncode == 2 and "--trace" in proc.stderr
