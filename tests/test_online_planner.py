"""Online receding-horizon planner: bit-identity at W=full, bounded regret,
warm-start correctness against the executing fabric, and re-planning on
mispredicted streams (repro.workloads.online_planner)."""
import dataclasses

import pytest

from repro.core import FabricSim, PAPER_DEFAULT
from repro.workloads import (CollectiveEvent, OnlinePlanner, decode_ag_trace,
                             mixed_trace, moe_a2a_trace, plan_trace,
                             run_online)


def _cm(delta):
    return PAPER_DEFAULT.replace(delta=delta)


# --- W = full recovers the offline DP exactly ---------------------------------


@pytest.mark.parametrize("delta", [10e-6, 15e-3])
@pytest.mark.parametrize("make", [
    lambda n: mixed_trace(n, seed=0),
    lambda n: decode_ag_trace(n, decode_steps=5, seed=1, jitter=0.25),
    lambda n: moe_a2a_trace(n, layers=2, seed=2),
])
def test_full_window_bit_identical_to_offline(make, delta):
    """With W >= the stream length every window solve sees the whole stream,
    so the online planner must commit exactly the offline DP's choices —
    the assembled TracePlan is bit-identical (not just close) to
    `plan_trace(mode='carryover')` up to the mode label."""
    trace = make(12)
    cm = _cm(delta)
    offline = plan_trace(trace, cm, mode="carryover")
    online, stats = run_online(trace, cm, window=len(trace.events))
    assert dataclasses.replace(online, mode="carryover") == offline
    # one DP solve on the first observe, pure replay afterwards
    assert stats.replans == 1
    assert stats.plan_reuses == len(trace.events) - 1
    assert stats.commits == len(trace.events)
    assert stats.mispredictions == 0


@pytest.mark.parametrize("budget", [0.0, 0.02, 0.5])
def test_full_window_bit_identical_under_delta_budget(budget):
    """The trace-wide reconfiguration budget threads through the warm-started
    window DP (committed spend becomes init_spent), so W=full stays
    bit-identical to the budgeted offline plan."""
    trace = mixed_trace(12, seed=3)
    cm = _cm(15e-3)
    offline = plan_trace(trace, cm, mode="carryover", delta_budget=budget)
    online, _ = run_online(trace, cm, window=len(trace.events),
                           delta_budget=budget)
    assert dataclasses.replace(online, mode="carryover") == offline


# --- regret vs window size ----------------------------------------------------


@pytest.mark.parametrize("delta", [10e-6, 1e-3, 15e-3])
@pytest.mark.parametrize("make", [
    lambda n: mixed_trace(n, seed=0),
    lambda n: decode_ag_trace(n, decode_steps=6, seed=4, jitter=0.25),
])
def test_regret_monotone_nonincreasing_in_window(make, delta):
    """More lookahead never hurts on a correctly-predicted stream, and no
    window ever beats the offline DP (which sees strictly more)."""
    trace = make(16)
    cm = _cm(delta)
    offline = plan_trace(trace, cm, mode="carryover").total_time
    totals = []
    for w in (1, 2, 4, len(trace.events)):
        online, _ = run_online(trace, cm, window=w)
        totals.append(online.total_time)
        assert online.total_time >= offline * (1 - 1e-9)
    for wider, narrower in zip(totals[1:], totals, strict=False):
        assert wider <= narrower * (1 + 1e-9), (
            f"regret increased with a wider window: {totals}")
    assert totals[-1] == pytest.approx(offline, rel=1e-12)


# --- warm start matches the executing fabric ----------------------------------


@pytest.mark.parametrize("prefix", [1, 3, 5])
def test_committed_prefix_state_matches_fabric_execution(prefix):
    """The (link offset) state each window solve is warm-started from is the
    state the *fabric* reaches when the committed schedules actually run:
    `run_trace(..., capture_state=True)` over the committed prefix ends at
    exactly `OnlinePlanner.fabric_state`."""
    trace = mixed_trace(12, seed=5)
    cm = _cm(15e-3)
    op = OnlinePlanner(trace.n, r=trace.r, cm=cm, window=3)
    op.predict(trace.events)
    for _ in range(prefix):
        op.observe()
    partial = op.result()
    assert len(partial.trace.events) == prefix
    sim = FabricSim(mode="sparse")
    res = sim.run_trace(partial.fabric_phases(), cm, capture_state=True)
    assert res.final_state is not None
    assert res.final_state.link_offset == op.fabric_state
    # and the modeled spend the next solve budgets against is the plan's
    assert op.reconfigs_spent == partial.paid_reconfigs


# --- mispredictions -----------------------------------------------------------


def test_substituted_event_replans_suffix_from_committed_state():
    """A substitution invalidates only the un-committed suffix: from the
    misprediction on, the planner's commits equal those of a fresh planner
    warm-started at the committed (g, spent) state and given the realized
    suffix as its prediction stream."""
    trace = mixed_trace(12, seed=6)
    cm = _cm(15e-3)
    k = 4  # position of the mispredicted event
    substitute = CollectiveEvent(kind="a2a", m_bytes=3.5e6, tag="surprise")
    assert trace.events[k] != substitute

    op = OnlinePlanner(trace.n, r=trace.r, cm=cm, window=3)
    op.predict(trace.events)
    for _ in range(k):
        op.observe()
    g_k, spent_k = op.fabric_state, op.reconfigs_spent
    realized_suffix = [substitute] + list(trace.events[k + 1:])
    op.observe(substitute)
    for ev in realized_suffix[1:]:
        op.observe(ev)
    assert op.stats().mispredictions == 1

    ref = OnlinePlanner(trace.n, r=trace.r, cm=cm, window=3,
                        init_g=g_k, init_spent=spent_k)
    ref.predict(realized_suffix)
    for _ in realized_suffix:
        ref.observe()
    plan, ref_plan = op.result(), ref.result()
    assert plan.phases[-len(ref_plan.phases):] == ref_plan.phases


def test_unpredicted_arrival_and_drop_count_as_mispredictions():
    trace = decode_ag_trace(12, decode_steps=4, seed=7)
    cm = _cm(1e-3)
    op = OnlinePlanner(trace.n, cm=cm, window=2)
    # no predictions at all: every explicit observe is an unpredicted arrival
    for ev in trace.events:
        op.observe(ev)
    assert op.stats().mispredictions == len(trace.events)
    assert op.committed_events == trace.events

    op2 = OnlinePlanner(trace.n, cm=cm, window=2)
    op2.predict(trace.events)
    op2.drop_predicted(2)
    assert op2.predicted_events == trace.events[2:]
    assert op2.stats().mispredictions == 2
    with pytest.raises(ValueError, match="cannot drop"):
        op2.drop_predicted(len(trace.events))  # only len-2 remain


def test_dropped_prediction_replans_shifted_window():
    """Committing after a drop re-solves the shifted window rather than
    replaying the stale plan, and the result equals planning the surviving
    stream online from scratch."""
    trace = mixed_trace(12, seed=8)
    cm = _cm(15e-3)
    survived = trace.events[1:]
    op = OnlinePlanner(trace.n, r=trace.r, cm=cm, window=3)
    op.predict(trace.events)
    op.drop_predicted()  # events[0] never arrives
    for _ in survived:
        op.observe()
    ref = OnlinePlanner(trace.n, r=trace.r, cm=cm, window=3)
    ref.predict(survived)
    for _ in survived:
        ref.observe()
    assert op.result().phases == ref.result().phases
    assert op.stats().mispredictions == 1


# --- driver & validation ------------------------------------------------------


def test_run_online_realized_stream_shorter_than_predictions():
    trace = mixed_trace(12, seed=9)
    cm = _cm(1e-3)
    realized = list(trace.events[:3])
    plan, stats = run_online(trace, cm, window=2, realized=realized)
    assert len(plan.trace.events) == 3
    assert stats.commits == 3


def test_online_planner_validation():
    with pytest.raises(ValueError, match="at least 2 nodes"):
        OnlinePlanner(1)
    with pytest.raises(ValueError, match="radix"):
        OnlinePlanner(8, r=1)
    with pytest.raises(ValueError, match="window"):
        OnlinePlanner(8, window=0)
    with pytest.raises(ValueError, match="fabric"):
        OnlinePlanner(8, fabric="static")
    with pytest.raises(ValueError, match="overlap"):
        OnlinePlanner(8, overlap=0.5)
    with pytest.raises(ValueError, match="delta_budget"):
        OnlinePlanner(8, delta_budget=-1.0)
    with pytest.raises(ValueError, match="init_spent"):
        OnlinePlanner(8, init_spent=-1)
    op = OnlinePlanner(8, window=2)
    with pytest.raises(TypeError, match="CollectiveEvents"):
        op.predict([("a2a", 1e6)])
    with pytest.raises(ValueError, match="no predicted events"):
        op.observe()
    with pytest.raises(ValueError, match="nothing committed"):
        op.result()


def test_delta_budget_is_trace_wide_online():
    """The budget caps paid intra-collective reconfigurations across the
    whole realized stream, not per window: an online run never spends more
    than the cap the offline planner enforces."""
    trace = mixed_trace(16, seed=10)
    cm = _cm(15e-3)
    budget = cm.delta  # exactly one full-fabric-equivalent of stall
    unit = cm.delta_sparse(trace.n, 0.0)
    cap = int(budget / unit + 1e-12)
    for w in (1, 2, len(trace.events)):
        online, _ = run_online(trace, cm, window=w, delta_budget=budget)
        assert online.paid_reconfigs <= cap
