"""Regression tests for strict JSON-loader validation (repro.core.jsonio).

Corrupted or version-skewed serialized artifacts must be rejected with a
clear ValueError at the loader, not crash half-constructed deeper in."""
import pytest

from repro.core.cost_model import PAPER_DEFAULT
from repro.planner.api import PlanRequest, PlanResult
from repro.planner.planner import Planner
from repro.workloads.serve import ServeRequest
from repro.workloads.traces import CollectiveEvent, Trace, mixed_trace


@pytest.fixture(scope="module")
def plan_dict():
    res = Planner(cache_size=0).plan(PlanRequest(
        kind="a2a", n=8, m_bytes=1 << 20, cost_model=PAPER_DEFAULT))
    return res.to_dict()


def _trace_dict():
    return mixed_trace(8, moe_layers=1, decode_steps=1).to_dict()


def _serve_dict():
    return ServeRequest(
        events=(CollectiveEvent("a2a", 1 << 20, "t"),), n=8,
        init_g=2).to_dict()


def _request_dict():
    return PlanRequest(kind="a2a", n=8, m_bytes=1 << 20,
                       cost_model=PAPER_DEFAULT).to_dict()


# --- unknown fields -----------------------------------------------------------


def test_trace_rejects_unknown_field():
    d = _trace_dict()
    d["fabrics"] = "ocs"
    with pytest.raises(ValueError, match="unknown field.*fabrics"):
        Trace.from_dict(d)


def test_event_rejects_unknown_field():
    d = {"kind": "a2a", "m_bytes": 1024.0, "tags": "oops"}
    with pytest.raises(ValueError, match="unknown field.*tags"):
        CollectiveEvent.from_dict(d)


def test_plan_request_rejects_unknown_field():
    d = _request_dict()
    d["budget"] = 3
    with pytest.raises(ValueError, match="unknown field.*budget"):
        PlanRequest.from_dict(d)


def test_plan_result_rejects_unknown_field(plan_dict):
    d = dict(plan_dict)
    d["winner"] = "bruck"
    with pytest.raises(ValueError, match="unknown field.*winner"):
        PlanResult.from_dict(d)


def test_serve_request_rejects_unknown_field():
    d = _serve_dict()
    d["deadline"] = 1.0
    with pytest.raises(ValueError, match="unknown field.*deadline"):
        ServeRequest.from_dict(d)


# --- missing required fields --------------------------------------------------


@pytest.mark.parametrize("key", ["name", "n", "events"])
def test_trace_rejects_missing_required(key):
    d = _trace_dict()
    del d[key]
    with pytest.raises(ValueError, match=f"missing required.*{key}"):
        Trace.from_dict(d)


@pytest.mark.parametrize("key", ["kind", "n", "m_bytes", "cost_model"])
def test_plan_request_rejects_missing_required(key):
    d = _request_dict()
    del d[key]
    with pytest.raises(ValueError, match=f"missing required.*{key}"):
        PlanRequest.from_dict(d)


def test_plan_result_rejects_missing_breakdown(plan_dict):
    d = dict(plan_dict)
    del d["breakdown"]
    with pytest.raises(ValueError, match="missing required.*breakdown"):
        PlanResult.from_dict(d)


def test_non_mapping_payload_rejected():
    with pytest.raises(ValueError, match="must be a JSON object"):
        Trace.from_dict(["not", "a", "dict"])


# --- payload sign/finiteness --------------------------------------------------


@pytest.mark.parametrize("bad", [0, -1, -0.5, float("nan"), float("inf"),
                                 "big", None])
def test_event_rejects_bad_payload(bad):
    with pytest.raises(ValueError, match="m_bytes"):
        CollectiveEvent.from_dict({"kind": "a2a", "m_bytes": bad})


@pytest.mark.parametrize("bad", [0, -4096])
def test_plan_request_rejects_nonpositive_payload(bad):
    d = _request_dict()
    d["m_bytes"] = bad
    with pytest.raises(ValueError, match="m_bytes"):
        PlanRequest.from_dict(d)


def test_serve_request_rejects_zero_payload():
    d = _serve_dict()
    d["events"][0]["m_bytes"] = 0
    with pytest.raises(ValueError, match="m_bytes"):
        ServeRequest.from_dict(d)


# --- cross-field consistency --------------------------------------------------


def test_plan_result_rejects_mismatched_schedule_n(plan_dict):
    d = dict(plan_dict)
    d["request"] = dict(d["request"])
    d["request"]["n"] = 16  # schedule link offsets were compiled for n=8
    with pytest.raises(ValueError, match=r"n=8.*n=16|schedule length"):
        PlanResult.from_dict(d)


def test_plan_result_rejects_truncated_schedule_x(plan_dict):
    d = dict(plan_dict)
    assert d["schedule"] is not None
    d["schedule"] = dict(d["schedule"])
    d["schedule"]["x"] = d["schedule"]["x"][:-1]
    with pytest.raises(ValueError, match="schedule length"):
        PlanResult.from_dict(d)


def test_plan_result_rejects_unknown_cost_model_field(plan_dict):
    d = dict(plan_dict)
    d["request"] = dict(d["request"])
    d["request"]["cost_model"] = dict(d["request"]["cost_model"])
    d["request"]["cost_model"]["beta"] = 1e-9
    with pytest.raises(ValueError, match="unknown field.*beta"):
        PlanResult.from_dict(d)


def test_serve_request_rejects_out_of_range_init_g():
    d = _serve_dict()
    d["init_g"] = 8  # == n: not a valid link offset
    with pytest.raises(ValueError, match="init_g"):
        ServeRequest.from_dict(d)


# --- good payloads still round-trip -------------------------------------------


def test_good_roundtrips_still_work(plan_dict):
    assert Trace.from_dict(_trace_dict()).to_dict() == _trace_dict()
    assert ServeRequest.from_dict(_serve_dict()).to_dict() == _serve_dict()
    assert PlanResult.from_dict(plan_dict).to_dict() == plan_dict
