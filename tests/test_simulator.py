"""Simulator + subring + Bruck data-movement correctness tests."""
import math

import numpy as np
import pytest

from repro.core import (CostModel, PAPER_DEFAULT, Schedule, Topology,
                        ag_transmission_optimal, allreduce_time,
                        collective_time, num_steps, periodic_a2a,
                        rs_transmission_optimal, simulate_a2a_data,
                        simulate_rs_data, static_schedule, subring_topology)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


# --- Bruck data movement is schedule-independent correct ---------------------


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 5, 6, 7, 12])
def test_bruck_a2a_delivers_all_blocks(n):
    recv = simulate_a2a_data(n)
    want = np.arange(n)[:, None] * n + np.arange(n)[None, :]
    # recv[j, i] must be block i*n + j
    np.testing.assert_array_equal(recv, want.T)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32])
def test_bruck_rs_reduces_every_block(n):
    owned = simulate_rs_data(n)
    np.testing.assert_array_equal(owned, np.ones((n, n), dtype=np.int64))


# --- Subring structure (Lemma 3.2) -------------------------------------------


@pytest.mark.parametrize("n,k", [(16, 0), (16, 1), (16, 2), (64, 3), (256, 5)])
def test_subring_partition_and_minimality(n, k):
    topo = subring_topology(n, k)
    assert topo.num_subrings == 2**k
    assert topo.subring_size == n // 2**k
    members = topo.subring_members(3)
    assert members == [u for u in range(n) if u % 2**k == 3 % 2**k]
    # every future Bruck peer of u stays in u's subring
    s = num_steps(n)
    for u in (0, 3, n - 1):
        for j in range(k, s):
            peer = (u + 2**j) % n
            assert topo.subring_of(peer) == topo.subring_of(u)
    # current peer is directly adjacent (1 hop)
    assert topo.hops(5 % n, (5 + 2**k) % n) == 1


def test_unreachable_across_subrings_raises():
    topo = subring_topology(16, 2)  # 4 subrings
    with pytest.raises(ValueError):
        topo.hops(0, 1)  # node 1 is in a different subring


@pytest.mark.parametrize("n,g,off", [(64, 1, 8), (64, 4, 8), (64, 8, 32), (256, 16, 64)])
def test_congestion_equals_hops_for_uniform_traffic(n, g, off):
    topo = Topology(n=n, g=g)
    assert topo.max_link_load(off) == off // g == topo.hops(0, off)


# --- Simulator vs explicit routing -------------------------------------------


@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
@pytest.mark.parametrize("n", [16, 64, 256])
def test_simulator_validated_routing(kind, n):
    s = num_steps(n)
    for R in range(0, s, 2):
        if kind == "a2a":
            sched = periodic_a2a(n, R)
        elif kind == "rs":
            sched = rs_transmission_optimal(n, R)
        else:
            sched = ag_transmission_optimal(n, R)
        t = collective_time(sched, 2**20, PAPER_DEFAULT, validate=True)
        assert t.total > 0
        assert t.reconfig == pytest.approx(R * PAPER_DEFAULT.delta)


def test_static_bruck_hop_totals():
    """Static Bruck on a ring: total hops = n - 1 (paper: Omega(n))."""
    n = 64
    t = collective_time(static_schedule("a2a", n), 0.0,
                        CostModel(alpha_s=0, alpha_h=1.0, bandwidth=1e30, delta=0))
    assert t.hop_latency == n - 1


def test_reconfigured_steps_cut_future_hops():
    """Condition 3: one reconfiguration reduces *subsequent* step costs too."""
    n = 64
    cm = CostModel(alpha_s=0, alpha_h=1.0, bandwidth=1e30, delta=0)
    static = collective_time(static_schedule("a2a", n), 0.0, cm)
    one = collective_time(Schedule(kind="a2a", n=n, x=(0, 0, 0, 1, 0, 0)), 0.0, cm)
    # steps 3,4,5 all got cheaper, steps 0-2 unchanged
    for k in range(3):
        assert one.steps[k].hops == static.steps[k].hops
    for k in range(3, 6):
        assert one.steps[k].hops < static.steps[k].hops
    assert one.steps[3].hops == 1  # current peer direct (Condition 1)


# --- AllReduce composition ----------------------------------------------------


def test_allreduce_is_rs_plus_ag_plus_transition():
    n, m = 64, 2**20
    rs = rs_transmission_optimal(n, 1)
    ag = ag_transmission_optimal(n, 1)
    ar = allreduce_time(rs, ag, m, PAPER_DEFAULT)
    t_rs = collective_time(rs, m, PAPER_DEFAULT)
    t_ag = collective_time(ag, m, PAPER_DEFAULT)
    assert ar.total >= t_rs.total + t_ag.total  # transition delta >= 0
    assert ar.total <= t_rs.total + t_ag.total + PAPER_DEFAULT.delta + 1e-18


# --- Port-constrained networks (Section 3.7) ----------------------------------


def test_blocked_ring_distance_floor():
    n, m = 256, 2**20
    sched = periodic_a2a(n, 3)
    t_full = collective_time(sched, m, PAPER_DEFAULT, ports=2 * n)
    t_blocked = collective_time(sched, m, PAPER_DEFAULT, ports=64)  # blocks of 8
    t_static = collective_time(static_schedule("a2a", n), m, PAPER_DEFAULT)
    assert t_full.total < t_blocked.total <= t_static.total + 3 * PAPER_DEFAULT.delta
    # reconfiguration still helps in large networks (paper 3.7)
    assert t_blocked.hop_latency < t_static.hop_latency


# --- Property tests ------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(
        logn=st.integers(min_value=2, max_value=10),
        R=st.integers(min_value=0, max_value=9),
        m=st.floats(min_value=1.0, max_value=1e9),
        delta=st.floats(min_value=0.0, max_value=1e-1),
    )
    @settings(max_examples=60, deadline=None)
    def test_more_reconfigs_never_increase_commtime(logn, R, m, delta):
        """Monotonicity: at delta=0 adding a reconfiguration can't hurt; the
        delta term is exactly R*delta on top."""
        n = 2**logn
        R = min(R, num_steps(n) - 1)
        cm = PAPER_DEFAULT.replace(delta=delta)
        t = collective_time(periodic_a2a(n, R), m, cm)
        comm = t.total - t.reconfig
        if R + 1 <= num_steps(n) - 1:
            t2 = collective_time(periodic_a2a(n, R + 1), m, cm)
            comm2 = t2.total - t2.reconfig
            assert comm2 <= comm + 1e-12
        assert t.reconfig == pytest.approx(R * delta)

    @given(
        logn=st.integers(min_value=2, max_value=8),
        R=st.integers(min_value=0, max_value=7),
    )
    @settings(max_examples=40, deadline=None)
    def test_schedule_reachability_always_valid(logn, R):
        """Every synthesized schedule keeps all destinations reachable."""
        n = 2**logn
        R = min(R, num_steps(n) - 1)
        for sched in (periodic_a2a(n, R), rs_transmission_optimal(n, R),
                      ag_transmission_optimal(n, R)):
            collective_time(sched, 1.0, PAPER_DEFAULT, validate=True)

    @given(
        logn=st.integers(min_value=2, max_value=8),
        mexp=st.integers(min_value=10, max_value=28),
        dexp=st.integers(min_value=-6, max_value=-2),
    )
    @settings(max_examples=40, deadline=None)
    def test_bridge_never_loses_to_its_own_candidates(logn, mexp, dexp):
        """plan() returns the min over its candidate set (sanity invariant)."""
        from repro.core import plan, candidate_schedules
        n, m = 2**logn, float(2**mexp)
        cm = PAPER_DEFAULT.replace(delta=10.0**dexp)
        p = plan("rs", n, m, cm)
        for _, sched in candidate_schedules("rs", n, m, cm):
            assert p.predicted_time <= collective_time(sched, m, cm).total + 1e-15


# --- Section 5 multiport / mirrored extension ----------------------------------


def test_mirrored_halves_transmission_only():
    from repro.core import periodic_a2a
    n, m = 64, 8 * 2**20
    sched = periodic_a2a(n, 2)
    t1 = collective_time(sched, m, PAPER_DEFAULT)
    t2 = collective_time(sched, m, PAPER_DEFAULT, mirrored=True)
    assert t2.transmission == pytest.approx(t1.transmission / 2, rel=1e-12)
    assert t2.hop_latency == pytest.approx(t1.hop_latency, rel=1e-12)
    assert t2.startup == pytest.approx(t1.startup, rel=1e-12)
    assert t2.reconfig == pytest.approx(t1.reconfig, rel=1e-12)


# --- Section 3.1 multiport extension --------------------------------------------


def test_multiport_reduces_steps_and_time():
    from repro.core.multiport import a2a_multiport_time, num_steps_multiport
    n, m = 64, 4 * 2**20
    cm = PAPER_DEFAULT
    assert num_steps_multiport(n, 1) == 6     # radix 2 = classic Bruck
    assert num_steps_multiport(n, 3) == 3     # radix 4
    t1 = a2a_multiport_time(n, m, 1, cm)
    t3 = a2a_multiport_time(n, m, 3, cm)
    assert len(t3.steps) < len(t1.steps)
    assert t3.total < t1.total                # parallel ports help
    # single-port static multiport == classic static Bruck cost
    t_classic = collective_time(static_schedule("a2a", n), m, cm)
    assert t1.total == pytest.approx(t_classic.total, rel=1e-9)


def test_multiport_reconfiguration_amortizes():
    from repro.core.multiport import a2a_multiport_time
    n, m = 256, 16 * 2**20
    cm = PAPER_DEFAULT
    t_static = a2a_multiport_time(n, m, 3, cm, reconfigure_every=0)
    t_bridge = a2a_multiport_time(n, m, 3, cm, reconfigure_every=2)
    assert t_bridge.total < t_static.total


# --- Mixed-radix / arbitrary-n generalization ---------------------------------


@pytest.mark.parametrize("n", [2, 5, 6, 7, 12, 48])
@pytest.mark.parametrize("r", [2, 3, 4])
def test_generalized_data_movement(n, r):
    """The radix-r Bruck algorithms deliver/reduce/gather every block for
    arbitrary n — the payload-level proof behind the generalized schedules."""
    from repro.core import simulate_ag_data

    recv = simulate_a2a_data(n, r)
    want = np.arange(n)[:, None] * n + np.arange(n)[None, :]
    np.testing.assert_array_equal(recv, want.T)
    np.testing.assert_array_equal(simulate_rs_data(n, r),
                                  np.ones((n, n), dtype=np.int64))
    np.testing.assert_array_equal(simulate_ag_data(n, r),
                                  np.broadcast_to(np.arange(n), (n, n)))


@pytest.mark.parametrize("n,r,k", [(6, 2, 1), (48, 3, 2), (96, 4, 1), (384, 4, 2)])
def test_generalized_subring_partition(n, r, k):
    """Generalized Lemma 3.2: link offset g = r^k partitions into gcd(g, n)
    subrings and every later Bruck offset (a multiple of r^k) stays inside."""
    topo = subring_topology(n, k, r)
    g = r**k
    assert topo.num_subrings == math.gcd(g, n)
    assert topo.subring_size == n // math.gcd(g, n)
    s = num_steps(n, r)
    for u in (0, 3, n - 1):
        for j in range(k, s):
            for digit in range(1, r):
                off = digit * r**j
                if off >= n:
                    continue
                peer = (u + off) % n
                assert topo.subring_of(peer) == topo.subring_of(u)
    # closed-form hop count: offset / g, no wraparound
    for digit in range(1, r):
        off = digit * g
        if off < n:
            assert topo.hops(0, off % n) == digit
            assert topo.max_link_load(off) == digit


@pytest.mark.parametrize("n", [6, 12, 48, 96])
@pytest.mark.parametrize("r", [2, 3, 4])
@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_generalized_analytic_vs_eventsim(kind, n, r):
    """Acceptance: analytic and event-level completion times agree within the
    eventsim fluid-limit tolerance on the generalized (n, r) grid."""
    from repro.core import plan
    from repro.core.eventsim import collective_time_event

    m = 16 * 2**20  # transmission-dominated, as in the radix-2 eventsim tests
    p = plan(kind, n, m, PAPER_DEFAULT, r=r)
    t_analytic = collective_time(p.schedule, m, PAPER_DEFAULT, validate=True).total
    t_event = collective_time_event(p.schedule, m, PAPER_DEFAULT, chunks_per_msg=32)
    assert t_event == pytest.approx(t_analytic, rel=0.15)


@pytest.mark.parametrize("n", [6, 12, 96])
def test_generalized_bridge_beats_static_latency_bound(n):
    """Reconfiguration still pays off at arbitrary n: hop latency drops from
    Omega(n) (static, sum of all offsets/hops) toward the periodic bound."""
    cm = CostModel(alpha_s=0, alpha_h=1.0, bandwidth=1e30, delta=0)
    t_static = collective_time(static_schedule("a2a", n), 0.0, cm).total
    assert t_static >= n - 1  # static Bruck walks every offset
    from repro.core import plan
    t_bridge = plan("a2a", n, 0.0, cm).predicted_time
    assert t_bridge < t_static
