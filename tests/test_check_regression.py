"""Regression-gate tooling: check_regression schemas, coverage gate, summary.

Pins the satellite bugfix: an unrecognized baseline schema or a fresh file
whose row grid diverges from the baseline must fail loudly — silently
passing would turn the whole benchmark gate into a no-op.
"""
import json

import pytest

from benchmarks.bench_summary import headline, summarize_pair
from benchmarks.check_regression import main as check_main
from benchmarks.coverage_gate import main as coverage_main


def _write(path, rows):
    path.write_text(json.dumps({"rows": rows}))
    return str(path)


TRACE_ROW = {
    "trace": "mixed", "n": 16, "delta": 1e-3, "phases": 12,
    "free_boundaries": 11, "boundaries": 11, "carry_paid_reconfigs": 0,
    "carryover_s": 3.3e-3, "cold_fabric_s": 1.4e-2, "static_s": 3.3e-3,
    "carryover_vs_cold": 4.3, "carryover_vs_static": 1.0,
}


def test_unknown_schema_fails_loudly(tmp_path):
    base = _write(tmp_path / "b.json", [{"mystery_metric": 1.0, "n": 8}])
    fresh = _write(tmp_path / "f.json", [{"mystery_metric": 1.0, "n": 8}])
    with pytest.raises(SystemExit) as exc:
        check_main([base, fresh])
    assert exc.value.code not in (0, None)


def test_schema_mismatch_fails(tmp_path):
    base = _write(tmp_path / "b.json", [TRACE_ROW])
    fresh = _write(tmp_path / "f.json",
                   [{"wall_speedup": 5.0, "n": 96, "r": 2,
                     "relaxations_all_r": 1, "relaxations_per_r": 8,
                     "dp_calls_all_r": 1, "dp_calls_per_r": 8}])
    with pytest.raises(SystemExit) as exc:
        check_main([base, fresh])
    assert exc.value.code == 1


def test_fresh_missing_baseline_rows_fails_unless_subset_ok(tmp_path, capsys):
    other = dict(TRACE_ROW, delta=15e-3)
    base = _write(tmp_path / "b.json", [TRACE_ROW, other])
    fresh = _write(tmp_path / "f.json", [dict(TRACE_ROW)])
    with pytest.raises(SystemExit) as exc:
        check_main([base, fresh])
    assert exc.value.code == 1
    assert "missing from the fresh results" in capsys.readouterr().err
    check_main(["--subset-ok", base, fresh])  # smoke subset: no exit
    assert "# OK" in capsys.readouterr().out


def test_fresh_rows_unknown_to_baseline_fail_even_with_subset_ok(tmp_path, capsys):
    base = _write(tmp_path / "b.json", [TRACE_ROW])
    fresh = _write(tmp_path / "f.json",
                   [dict(TRACE_ROW), dict(TRACE_ROW, n=48)])
    with pytest.raises(SystemExit) as exc:
        check_main(["--subset-ok", base, fresh])
    assert exc.value.code == 1
    assert "stale baseline" in capsys.readouterr().err


def test_disjoint_grids_report_coverage_details(tmp_path, capsys):
    """matched == 0 must not swallow the per-row coverage diagnostics."""
    base = _write(tmp_path / "b.json", [TRACE_ROW])
    fresh = _write(tmp_path / "f.json", [dict(TRACE_ROW, trace="renamed")])
    with pytest.raises(SystemExit):
        check_main([base, fresh])
    err = capsys.readouterr().err
    assert "no fresh row matches the baseline grid" in err
    assert "stale baseline" in err
    assert "missing from the fresh results" in err


def test_trace_schema_gates_drift(tmp_path, capsys):
    base = _write(tmp_path / "b.json", [TRACE_ROW])
    ok = _write(tmp_path / "ok.json", [dict(TRACE_ROW)])
    check_main([base, ok])
    assert "# OK: 1 rows" in capsys.readouterr().out
    drift = _write(tmp_path / "d.json",
                   [dict(TRACE_ROW, carryover_vs_cold=3.9, free_boundaries=9)])
    with pytest.raises(SystemExit) as exc:
        check_main([base, drift])
    assert exc.value.code == 1
    err = capsys.readouterr().err
    assert "free_boundaries" in err and "carryover_vs_cold" in err


ONLINE_ROW = {
    "trace": "mixed", "n": 16, "delta": 1e-3, "window": 4, "events": 10,
    "phases": 12, "online_s": 3.3e-3, "offline_s": 3.3e-3,
    "cold_event_s": 1.4e-2, "online_vs_offline": 1.0, "cold_vs_online": 4.3,
    "replans": 7, "plan_reuses": 3, "free_boundaries": 11,
    "paid_reconfigs": 0,
}
STORM_ROW = {
    "trace": "storm", "n": 16, "delta": 1e-5, "window": 3, "pool": 54,
    "requests": 256, "cold_hits": 214, "cold_misses": 42, "hot_hits": 256,
    "hot_misses": 0, "hot_hit_rate": 1.0, "cold_plans_per_sec": 13000.0,
    "hot_plans_per_sec": 100000.0, "unique_windows": 53, "signature": "abc",
}


def test_online_schema_gates_drift_and_signature(tmp_path, capsys):
    base = _write(tmp_path / "b.json", [ONLINE_ROW, STORM_ROW])
    ok = _write(tmp_path / "ok.json",
                [dict(ONLINE_ROW),
                 dict(STORM_ROW, hot_plans_per_sec=30000.0)])  # noisy but ok
    check_main([base, ok])
    assert "# OK: 2 rows" in capsys.readouterr().out
    drift = _write(tmp_path / "d.json",
                   [dict(ONLINE_ROW, online_s=4.0e-3, replans=9),
                    dict(STORM_ROW, signature="def",
                         hot_plans_per_sec=1000.0)])
    with pytest.raises(SystemExit) as exc:
        check_main([base, drift])
    assert exc.value.code == 1
    err = capsys.readouterr().err
    assert "online_s" in err and "replans" in err
    assert "signature" in err and "hot_plans_per_sec" in err


def test_online_headline():
    assert "plans/s" in headline("online", [ONLINE_ROW, STORM_ROW])
    assert "W>=2" in headline("online", [ONLINE_ROW, STORM_ROW])


def test_bench_summary_rows(tmp_path):
    base = _write(tmp_path / "b.json", [TRACE_ROW])
    fresh = _write(tmp_path / "f.json", [dict(TRACE_ROW)])
    row, errors = summarize_pair("trace", base, fresh, subset_ok=False)
    assert "| trace |" in row and "PASS" in row and not errors
    bad = _write(tmp_path / "bad.json",
                 [dict(TRACE_ROW, carryover_vs_cold=1.0)])
    row, errors = summarize_pair("trace", base, bad, subset_ok=False)
    assert "FAIL" in row and errors
    row, errors = summarize_pair("gone", base, str(tmp_path / "none.json"),
                                 subset_ok=False)
    assert "MISSING" in row and errors
    assert headline("trace", [TRACE_ROW]).endswith("carryover win")
    # malformed fresh files render a FAIL row instead of raising (the
    # summary must appear precisely when a benchmark broke)
    broken = tmp_path / "broken.json"
    broken.write_text("{not json")
    row, errors = summarize_pair("broken", base, str(broken), subset_ok=False)
    assert "FAIL (unreadable)" in row and errors
    unknown = _write(tmp_path / "u.json", [{"mystery": 1}])
    row, errors = summarize_pair("unknown", unknown, fresh, subset_ok=False)
    assert "FAIL (unreadable)" in row and errors


COVERAGE_XML = """<?xml version="1.0" ?>
<coverage>
 <packages>
  <package name="repro.core">
   <classes>
    <class filename="repro/core/bruck.py">
     <lines><line number="1" hits="1"/><line number="2" hits="1"/>
            <line number="3" hits="0"/></lines>
    </class>
   </classes>
  </package>
  <package name="repro.planner">
   <classes>
    <class filename="repro/planner/api.py">
     <lines><line number="1" hits="1"/></lines>
    </class>
   </classes>
  </package>
  <package name="repro.workloads">
   <classes>
    <class filename="repro/workloads/traces.py">
     <lines><line number="1" hits="1"/><line number="2" hits="0"/></lines>
    </class>
   </classes>
  </package>
  <package name="repro.models">
   <classes>
    <class filename="repro/models/model.py">
     <lines><line number="1" hits="0"/></lines>
    </class>
   </classes>
  </package>
 </packages>
</coverage>
"""


def test_coverage_gate_scopes_and_threshold(tmp_path, capsys):
    xml = tmp_path / "coverage.xml"
    xml.write_text(COVERAGE_XML)
    # 4/6 covered lines in the gated packages (models/ is excluded) = 66.7%
    coverage_main([str(xml), "--min", "60"])
    out = capsys.readouterr().out
    assert "combined: 4/6" in out
    with pytest.raises(SystemExit) as exc:
        coverage_main([str(xml), "--min", "70"])
    assert exc.value.code == 1
    # a gated package with no measured lines is an error even above --min
    with pytest.raises(SystemExit):
        coverage_main([str(xml), "--min", "10",
                       "--packages", "core", "nonexistent"])
