"""Per-architecture smoke tests on reduced same-family configs (CPU).

For every assigned arch:
  1. one forward + train-step gradient: output shapes, finite loss, no NaNs;
  2. prefill + decode_step consistency: decoding token t with the cache must
     reproduce the full-forward logits at position t (cache correctness).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill)

KEY = jax.random.PRNGKey(7)


def make_batch(cfg, batch=2, seq=16, key=KEY):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)}
    b["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)
    if cfg.frontend == "patch_stub":
        b["patches"] = jax.random.normal(ks[2], (batch, cfg.frontend_seq,
                                                 cfg.d_model), jnp.float32)
    if cfg.enc_dec:
        b["frames"] = jax.random.normal(ks[2], (batch, cfg.encoder_seq,
                                                cfg.d_model), jnp.float32)
    return b


@pytest.fixture(scope="module", params=configs.ARCHS)
def arch(request):
    return request.param


def reduced(arch_name):
    return configs.get(arch_name).scaled_down()


def test_config_registry_complete():
    assert len(configs.ARCHS) == 10
    for a in configs.ARCHS:
        cfg = configs.get(a)
        assert cfg.name == a
        assert cfg.param_count() > 0


@pytest.mark.parametrize("arch_name", configs.ARCHS)
def test_forward_and_train_step(arch_name):
    cfg = reduced(arch_name)
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)

    out = forward(cfg, params, batch, mode="train")
    want_seq = batch["tokens"].shape[1] + (cfg.frontend_seq if
                                           cfg.frontend == "patch_stub" else 0)
    assert out.logits.shape == (2, want_seq, cfg.vocab_size)
    assert np.isfinite(np.asarray(out.logits, np.float32)).all()

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch_name
    flat = jax.tree.leaves(grads)
    assert flat and all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("arch_name", configs.ARCHS)
def test_prefill_decode_matches_forward(arch_name):
    cfg = reduced(arch_name)
    # f32 + no remat for tight numerics
    cfg = dataclasses.replace(cfg, dtype="float32", remat=False)
    if cfg.moe is not None:
        # capacity dropping is deliberately non-causal (GShard semantics:
        # tokens compete for expert capacity within a group) — make routing
        # dropless so prefill/decode must match the full forward exactly.
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=cfg.moe.num_experts / cfg.moe.top_k))
    params = init_params(cfg, KEY)
    batch, seq = 2, 12
    full = make_batch(cfg, batch, seq)
    if cfg.frontend == "patch_stub":
        pytest.skip("vlm prefill==forward covered via backbone archs; "
                    "patch prefix offsets positions")

    ref_logits = forward(cfg, params, full, mode="train").logits  # (B, S, V)

    prompt = {k: (v[:, :seq - 2] if k in ("tokens", "labels") else v)
              for k, v in full.items()}
    logits_p, caches = prefill(cfg, params, prompt, max_seq=seq + 4)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(ref_logits[:, seq - 3, :]),
                               atol=2e-3, rtol=2e-3)

    for t in range(seq - 2, seq):
        logits_d, caches = decode_step(cfg, params, full["tokens"][:, t:t + 1],
                                       caches)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(ref_logits[:, t, :]),
                                   atol=2e-3, rtol=2e-3,
                                   err_msg=f"{arch_name} decode step {t}")


def test_sliding_window_ring_buffer_decode():
    """Decode beyond the window: ring buffer must match full forward."""
    cfg = dataclasses.replace(reduced("gemma3-4b"), dtype="float32",
                              remat=False, window=8)
    params = init_params(cfg, KEY)
    seq = 24  # 3x window
    full = make_batch(cfg, 1, seq)
    ref_logits = forward(cfg, params, full, mode="train").logits
    prompt = {"tokens": full["tokens"][:, :seq - 4]}
    _, caches = prefill(cfg, params, prompt, max_seq=seq + 4)
    for t in range(seq - 4, seq):
        logits_d, caches = decode_step(cfg, params, full["tokens"][:, t:t + 1],
                                       caches)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(ref_logits[:, t, :]),
                                   atol=2e-3, rtol=2e-3, err_msg=f"t={t}")


def test_moe_routes_to_multiple_experts():
    cfg = reduced("qwen3-moe-235b-a22b")
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 32)
    out = forward(cfg, params, batch, mode="train")
    assert float(out.aux_loss) > 0.0  # router engaged


def test_pallas_kernel_path_matches_ref_path():
    """use_pallas=True (interpret) must agree with the pure-jnp model."""
    for arch_name in ("gemma3-4b", "rwkv6-3b", "recurrentgemma-9b"):
        cfg = dataclasses.replace(reduced(arch_name), dtype="float32",
                                  remat=False)
        params = init_params(cfg, KEY)
        batch = make_batch(cfg, 1, 16)
        ref_out = forward(cfg, params, batch).logits
        cfg_k = dataclasses.replace(cfg, use_pallas=True)
        k_out = forward(cfg_k, params, batch).logits
        np.testing.assert_allclose(np.asarray(k_out), np.asarray(ref_out),
                                   atol=5e-4, rtol=5e-4, err_msg=arch_name)


def test_param_counts_near_nameplate():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "command-r-plus-104b": (104e9, 0.25),
        "arctic-480b": (480e9, 0.25),
        "qwen3-moe-235b-a22b": (235e9, 0.30),
        "rwkv6-3b": (3e9, 0.5),
        "minicpm3-4b": (4e9, 0.6),
        "gemma3-4b": (4e9, 0.6),
        "recurrentgemma-9b": (9e9, 0.5),
    }
    for name, (target, tol) in expect.items():
        n = configs.get(name).param_count()
        assert abs(n - target) / target < tol, (name, n, target)
