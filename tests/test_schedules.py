"""Schedule-synthesis tests: Table 1 exact match, Theorems 3.2/3.3, Lemma 3.1."""
import math

import pytest

from repro.core import (CostModel, PAPER_DEFAULT, Schedule, baselines,
                        collective_time, cstar_a2a, full_cost_optimal,
                        num_steps, periodic, periodic_a2a, plan,
                        rs_transmission_optimal, ag_transmission_optimal,
                        static_schedule)


# --- Table 1 (n = 64): the paper's published schedules, exact ---------------

TABLE1 = {
    ("a2a", 1): (0, 0, 0, 1, 0, 0),
    ("rs", 1):  (0, 0, 1, 0, 0, 0),
    ("ag", 1):  (0, 0, 0, 0, 1, 0),
    ("a2a", 2): (0, 0, 1, 0, 1, 0),
    ("rs", 2):  (0, 1, 0, 1, 0, 0),
    ("ag", 2):  (0, 0, 0, 1, 0, 1),
}


@pytest.mark.parametrize("kind,R", list(TABLE1))
def test_table1_schedules(kind, R):
    n = 64
    if kind == "a2a":
        sched = periodic_a2a(n, R)
    elif kind == "rs":
        sched = rs_transmission_optimal(n, R)
    else:
        sched = ag_transmission_optimal(n, R)
    assert sched.x == TABLE1[(kind, R)], (kind, R, sched.x)


# --- Lemma 3.1 / Theorem 3.2: periodic A2A schedules -------------------------


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 128, 256, 1024])
def test_a2a_segments_balanced(n):
    s = num_steps(n)
    for R in range(s):
        lens = periodic_a2a(n, R).segment_lengths
        assert len(lens) == R + 1
        assert sum(lens) == s
        assert max(lens) - min(lens) <= 1  # Lemma 3.1


@pytest.mark.parametrize("n,R", [(64, 0), (64, 1), (64, 2), (64, 5),
                                 (256, 1), (256, 3), (4096, 2)])
def test_cstar_closed_form_matches_simulator(n, R):
    """Theorem 3.2 closed form == simulated periodic schedule when (R+1) | s."""
    s = num_steps(n)
    if s % (R + 1) != 0:
        pytest.skip("closed form exact only when (R+1) | s")
    cm = PAPER_DEFAULT
    m = 4 * 2**20
    t = collective_time(periodic_a2a(n, R), m, cm, validate=(n <= 256)).total
    assert t == pytest.approx(cstar_a2a(n, R, cm, m), rel=1e-12)


def test_a2a_periodic_beats_all_other_fixed_R_schedules():
    """Exhaustive check of Theorem 3.2 for n=64: periodic is optimal per R."""
    n, s = 64, 6
    cm = PAPER_DEFAULT.replace(delta=0.0)
    m = 1 * 2**20
    best_by_R = {}
    import itertools
    for bits in itertools.product([0, 1], repeat=s - 1):
        x = (0,) + bits
        sched = Schedule(kind="a2a", n=n, x=x)
        t = collective_time(sched, m, cm).total
        R = sum(x)
        if R not in best_by_R or t < best_by_R[R]:
            best_by_R[R] = t
    for R in range(s):
        t_periodic = collective_time(periodic_a2a(n, R), m, cm).total
        assert t_periodic == pytest.approx(best_by_R[R], rel=1e-12), R


def test_rs_dp_beats_all_other_fixed_R_schedules():
    """Exhaustive check of Theorem 3.3 for n=64 (transmission term only)."""
    import itertools
    n, s = 64, 6
    # pure-transmission cost model: alpha_s = alpha_h = 0
    cm = CostModel(alpha_s=0.0, alpha_h=0.0, bandwidth=1.0, delta=0.0)
    m = 1.0
    for R in range(s):
        t_dp = collective_time(rs_transmission_optimal(n, R), m, cm).total
        best = min(
            collective_time(Schedule(kind="rs", n=n, x=(0,) + bits), m, cm).total
            for bits in itertools.product([0, 1], repeat=s - 1)
            if sum(bits) == R
        )
        assert t_dp == pytest.approx(best, rel=1e-12), R


def test_ag_is_reversed_rs_and_same_cost():
    """Section 3.5: AG optimal schedule = reversed RS schedule, same cost."""
    n = 128
    cm = PAPER_DEFAULT
    m = 8 * 2**20
    for R in range(num_steps(n)):
        rs = rs_transmission_optimal(n, R)
        ag = ag_transmission_optimal(n, R)
        assert ag.segment_lengths == tuple(reversed(rs.segment_lengths))
        t_rs = collective_time(rs, m, cm, validate=True)
        t_ag = collective_time(ag, m, cm, validate=True)
        assert t_rs.transmission == pytest.approx(t_ag.transmission, rel=1e-12)
        assert t_rs.hop_latency == pytest.approx(t_ag.hop_latency, rel=1e-12)


def test_rs_reconfigures_earlier_than_periodic_ag_later():
    """Paper 3.4/3.5: RS shifts reconfigs early, AG late, vs periodic A2A."""
    n = 64
    for R in (1, 2):
        a2a = periodic_a2a(n, R).x
        rs = rs_transmission_optimal(n, R).x
        ag = ag_transmission_optimal(n, R).x
        first = lambda x: x.index(1)
        assert first(rs) <= first(a2a) <= first(ag)


# --- Cost scaling: Omega(n) -> O(R n^{1/(R+1)}) ------------------------------


def test_cost_scaling_theorem():
    cm = CostModel(alpha_s=0.0, alpha_h=1.0, bandwidth=1e30, delta=0.0)
    for R in (1, 2, 3):
        for n in (64, 256, 1024, 4096):
            t = collective_time(periodic_a2a(n, R), 0.0, cm).total
            bound = (R + 1) * (n ** (1 / (R + 1)))  # O(R n^{1/(R+1)})
            assert t <= bound
            t_static = collective_time(static_schedule("a2a", n), 0.0, cm).total
            assert t_static >= n - 1  # Omega(n)


# --- Optimal-R planning (Section 3.6) ----------------------------------------


def test_plan_picks_static_when_delta_huge():
    cm = PAPER_DEFAULT.replace(delta=10.0)  # 10 s reconfig: never worth it
    p = plan("a2a", 64, 1024.0, cm, paper_faithful=True)
    assert p.schedule.R == 0


def test_plan_picks_greedy_when_delta_zero():
    cm = PAPER_DEFAULT.replace(delta=0.0)
    p = plan("a2a", 64, 64 * 2**20, cm, paper_faithful=True)
    assert p.schedule.R == num_steps(64) - 1


def test_full_cost_dp_never_worse_than_paper_candidates():
    """Beyond-paper exact DP dominates both paper schedule families."""
    n = 256
    for m in (1e3, 1e6, 64e6):
        for delta in (1e-6, 1e-3, 5e-3):
            cm = PAPER_DEFAULT.replace(delta=delta)
            for kind in ("a2a", "rs", "ag"):
                t_paper = plan(kind, n, m, cm, paper_faithful=True).predicted_time
                t_full = plan(kind, n, m, cm, paper_faithful=False).predicted_time
                assert t_full <= t_paper + 1e-15


# --- Schedule object sanity ---------------------------------------------------


def test_schedule_segments_roundtrip():
    s = Schedule(kind="rs", n=64, x=(0, 1, 0, 1, 0, 0))
    assert s.segments == ((0, 0), (1, 2), (3, 5))
    assert s.segment_lengths == (1, 2, 3)
    assert Schedule.from_segments("rs", 64, [1, 2, 3]).x == s.x
    assert s.R == 2


def test_link_offsets_rs_vs_ag():
    rs = Schedule(kind="rs", n=64, x=(0, 0, 1, 0, 0, 0))
    assert rs.link_offsets() == [1, 1, 4, 4, 4, 4]
    ag = Schedule(kind="ag", n=64, x=(0, 0, 0, 0, 1, 0))
    # AG offsets: 32 16 8 4 2 1; segment [0,3] min offset 4, [4,5] min 1
    assert ag.link_offsets() == [4, 4, 4, 4, 1, 1]
