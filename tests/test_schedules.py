"""Schedule-synthesis tests: Table 1 exact match, Theorems 3.2/3.3, Lemma 3.1,
and the mixed-radix / arbitrary-n generalization."""
import pytest

from repro.core import (CostModel, PAPER_DEFAULT, Schedule,
                        ag_transmission_optimal, collective_time,
                        cstar_a2a, full_cost_optimal, num_steps, periodic,
                        periodic_a2a, plan, rs_transmission_optimal,
                        schedule_length, static_schedule, steps_for)


# --- Table 1 (n = 64): the paper's published schedules, exact ---------------

TABLE1 = {
    ("a2a", 1): (0, 0, 0, 1, 0, 0),
    ("rs", 1):  (0, 0, 1, 0, 0, 0),
    ("ag", 1):  (0, 0, 0, 0, 1, 0),
    ("a2a", 2): (0, 0, 1, 0, 1, 0),
    ("rs", 2):  (0, 1, 0, 1, 0, 0),
    ("ag", 2):  (0, 0, 0, 1, 0, 1),
}


@pytest.mark.parametrize("kind,R", list(TABLE1))
def test_table1_schedules(kind, R):
    n = 64
    if kind == "a2a":
        sched = periodic_a2a(n, R)
    elif kind == "rs":
        sched = rs_transmission_optimal(n, R)
    else:
        sched = ag_transmission_optimal(n, R)
    assert sched.x == TABLE1[(kind, R)], (kind, R, sched.x)


# --- Lemma 3.1 / Theorem 3.2: periodic A2A schedules -------------------------


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64, 128, 256, 1024])
def test_a2a_segments_balanced(n):
    s = num_steps(n)
    for R in range(s):
        lens = periodic_a2a(n, R).segment_lengths
        assert len(lens) == R + 1
        assert sum(lens) == s
        assert max(lens) - min(lens) <= 1  # Lemma 3.1


@pytest.mark.parametrize("n,R", [(64, 0), (64, 1), (64, 2), (64, 5),
                                 (256, 1), (256, 3), (4096, 2)])
def test_cstar_closed_form_matches_simulator(n, R):
    """Theorem 3.2 closed form == simulated periodic schedule when (R+1) | s."""
    s = num_steps(n)
    if s % (R + 1) != 0:
        pytest.skip("closed form exact only when (R+1) | s")
    cm = PAPER_DEFAULT
    m = 4 * 2**20
    t = collective_time(periodic_a2a(n, R), m, cm, validate=(n <= 256)).total
    assert t == pytest.approx(cstar_a2a(n, R, cm, m), rel=1e-12)


def test_a2a_periodic_beats_all_other_fixed_R_schedules():
    """Exhaustive check of Theorem 3.2 for n=64: periodic is optimal per R."""
    n, s = 64, 6
    cm = PAPER_DEFAULT.replace(delta=0.0)
    m = 1 * 2**20
    best_by_R = {}
    import itertools
    for bits in itertools.product([0, 1], repeat=s - 1):
        x = (0,) + bits
        sched = Schedule(kind="a2a", n=n, x=x)
        t = collective_time(sched, m, cm).total
        R = sum(x)
        if R not in best_by_R or t < best_by_R[R]:
            best_by_R[R] = t
    for R in range(s):
        t_periodic = collective_time(periodic_a2a(n, R), m, cm).total
        assert t_periodic == pytest.approx(best_by_R[R], rel=1e-12), R


def test_rs_dp_beats_all_other_fixed_R_schedules():
    """Exhaustive check of Theorem 3.3 for n=64 (transmission term only)."""
    import itertools
    n, s = 64, 6
    # pure-transmission cost model: alpha_s = alpha_h = 0
    cm = CostModel(alpha_s=0.0, alpha_h=0.0, bandwidth=1.0, delta=0.0)
    m = 1.0
    for R in range(s):
        t_dp = collective_time(rs_transmission_optimal(n, R), m, cm).total
        best = min(
            collective_time(Schedule(kind="rs", n=n, x=(0,) + bits), m, cm).total
            for bits in itertools.product([0, 1], repeat=s - 1)
            if sum(bits) == R
        )
        assert t_dp == pytest.approx(best, rel=1e-12), R


def test_ag_is_reversed_rs_and_same_cost():
    """Section 3.5: AG optimal schedule = reversed RS schedule, same cost."""
    n = 128
    cm = PAPER_DEFAULT
    m = 8 * 2**20
    for R in range(num_steps(n)):
        rs = rs_transmission_optimal(n, R)
        ag = ag_transmission_optimal(n, R)
        assert ag.segment_lengths == tuple(reversed(rs.segment_lengths))
        t_rs = collective_time(rs, m, cm, validate=True)
        t_ag = collective_time(ag, m, cm, validate=True)
        assert t_rs.transmission == pytest.approx(t_ag.transmission, rel=1e-12)
        assert t_rs.hop_latency == pytest.approx(t_ag.hop_latency, rel=1e-12)


def test_rs_reconfigures_earlier_than_periodic_ag_later():
    """Paper 3.4/3.5: RS shifts reconfigs early, AG late, vs periodic A2A."""
    n = 64
    for R in (1, 2):
        a2a = periodic_a2a(n, R).x
        rs = rs_transmission_optimal(n, R).x
        ag = ag_transmission_optimal(n, R).x
        def first(x):
            return x.index(1)
        assert first(rs) <= first(a2a) <= first(ag)


def test_cstar_a2a_rejects_invalid_inputs():
    """The Theorem 3.2 closed form assumes radix-2 offsets on n = 2^s nodes;
    other inputs used to silently return wrong values and now raise."""
    cm = PAPER_DEFAULT
    for n in (6, 48, 96, 384):
        with pytest.raises(ValueError):
            cstar_a2a(n, 1, cm, 1024.0)
    with pytest.raises(ValueError):
        cstar_a2a(64, -1, cm, 1024.0)
    with pytest.raises(ValueError):
        cstar_a2a(64, num_steps(64), cm, 1024.0)  # R must be < s
    assert cstar_a2a(64, 1, cm, 1024.0) > 0  # valid inputs still work


def test_link_offsets_uses_step_cache():
    """Schedule.link_offsets routes through the shared step cache instead of
    regenerating the step sequence per call."""
    from repro.core.schedules import _STEP_CACHE, _steps_cached

    _STEP_CACHE.pop(("ag", 40, 2), None)
    sched = static_schedule("ag", 40)
    first = sched.link_offsets()
    assert ("ag", 40, 2) in _STEP_CACHE
    assert _steps_cached("ag", 40, 2) is _STEP_CACHE[("ag", 40, 2)]
    assert sched.link_offsets() == first


# --- Cost scaling: Omega(n) -> O(R n^{1/(R+1)}) ------------------------------


def test_cost_scaling_theorem():
    cm = CostModel(alpha_s=0.0, alpha_h=1.0, bandwidth=1e30, delta=0.0)
    for R in (1, 2, 3):
        for n in (64, 256, 1024, 4096):
            t = collective_time(periodic_a2a(n, R), 0.0, cm).total
            bound = (R + 1) * (n ** (1 / (R + 1)))  # O(R n^{1/(R+1)})
            assert t <= bound
            t_static = collective_time(static_schedule("a2a", n), 0.0, cm).total
            assert t_static >= n - 1  # Omega(n)


# --- Optimal-R planning (Section 3.6) ----------------------------------------


def test_plan_picks_static_when_delta_huge():
    cm = PAPER_DEFAULT.replace(delta=10.0)  # 10 s reconfig: never worth it
    p = plan("a2a", 64, 1024.0, cm, paper_faithful=True)
    assert p.schedule.R == 0


def test_plan_picks_greedy_when_delta_zero():
    cm = PAPER_DEFAULT.replace(delta=0.0)
    p = plan("a2a", 64, 64 * 2**20, cm, paper_faithful=True)
    assert p.schedule.R == num_steps(64) - 1


def test_full_cost_dp_never_worse_than_paper_candidates():
    """Beyond-paper exact DP dominates both paper schedule families."""
    n = 256
    for m in (1e3, 1e6, 64e6):
        for delta in (1e-6, 1e-3, 5e-3):
            cm = PAPER_DEFAULT.replace(delta=delta)
            for kind in ("a2a", "rs", "ag"):
                t_paper = plan(kind, n, m, cm, paper_faithful=True).predicted_time
                t_full = plan(kind, n, m, cm, paper_faithful=False).predicted_time
                assert t_full <= t_paper + 1e-15


# --- Schedule object sanity ---------------------------------------------------


def test_schedule_segments_roundtrip():
    s = Schedule(kind="rs", n=64, x=(0, 1, 0, 1, 0, 0))
    assert s.segments == ((0, 0), (1, 2), (3, 5))
    assert s.segment_lengths == (1, 2, 3)
    assert Schedule.from_segments("rs", 64, [1, 2, 3]).x == s.x
    assert s.R == 2


def test_link_offsets_rs_vs_ag():
    rs = Schedule(kind="rs", n=64, x=(0, 0, 1, 0, 0, 0))
    assert rs.link_offsets() == [1, 1, 4, 4, 4, 4]
    ag = Schedule(kind="ag", n=64, x=(0, 0, 0, 0, 1, 0))
    # AG offsets: 32 16 8 4 2 1; segment [0,3] min offset 4, [4,5] min 1
    assert ag.link_offsets() == [4, 4, 4, 4, 1, 1]


# --- Mixed-radix / arbitrary-n generalization ---------------------------------

NONPOW2_NS = [6, 12, 48, 96]
RADIXES = [2, 3, 4]


@pytest.mark.parametrize("n", NONPOW2_NS)
@pytest.mark.parametrize("r", RADIXES)
@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_generalized_step_sequences(kind, n, r):
    """Step sequences are well-formed for arbitrary (n, r): offsets in [1, n),
    total payload conserved, and S identical across the three kinds."""
    m = 1.0
    steps = steps_for(kind, n, m, r)
    assert len(steps) == schedule_length(kind, n, r)
    assert len(steps) == schedule_length("a2a", n, r)  # same S for all kinds
    for st in steps:
        assert 1 <= st.offset < n
        assert st.offset == st.digit * r**st.phase
        assert st.nbytes > 0
    if kind == "a2a":
        # every block except the diagonal moves exactly once per nonzero digit
        total_blocks = sum(st.nbytes for st in steps) * n / m
        want = sum(len([k for k in range(20) if (d // r**k) % r]) for d in range(n))
        assert total_blocks == pytest.approx(want)
    else:
        # RS forwards each of the n-1 non-local blocks' partials exactly once
        # per nonzero digit of its offset; AG is the exact reverse
        rs = steps_for("rs", n, m, r)
        ag = steps_for("ag", n, m, r)
        assert [st.offset for st in ag] == [st.offset for st in reversed(rs)]
        assert [st.nbytes for st in ag] == [st.nbytes for st in reversed(rs)]


@pytest.mark.parametrize("n", NONPOW2_NS)
@pytest.mark.parametrize("r", RADIXES)
@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_generalized_schedules_reachable(kind, n, r):
    """Every synthesized schedule keeps destinations reachable: the segment
    link offset (gcd) divides every message offset in the segment."""
    from repro.core.subrings import validate_schedule_reachability

    S = schedule_length(kind, n, r)
    for R in range(0, S, max(1, S // 3)):
        for sched in (periodic(kind, n, R, r),
                      full_cost_optimal(kind, n, 2**20, PAPER_DEFAULT, R, r)):
            steps = steps_for(kind, n, 1.0, r)
            validate_schedule_reachability(
                n, [st.offset for st in steps], sched.link_offsets(steps))
            t = collective_time(sched, 2**20, PAPER_DEFAULT, validate=True)
            assert t.total > 0
            assert t.reconfig == pytest.approx(R * PAPER_DEFAULT.delta)


@pytest.mark.parametrize("n", [8, 64, 256])
def test_radix2_pow2_matches_seed_closed_forms(n):
    """No regression of paper-faithful results: for power-of-two n at r=2 the
    generalized step generator reproduces the paper's byte sequences and the
    DP segment costs reduce to 2^len - 1 / len / 2^a."""
    s = num_steps(n)
    m = 1024.0
    a2a = steps_for("a2a", n, m, 2)
    rs = steps_for("rs", n, m, 2)
    ag = steps_for("ag", n, m, 2)
    assert [st.offset for st in a2a] == [2**k for k in range(s)]
    assert [st.nbytes for st in a2a] == [m / 2] * s
    assert [st.nbytes for st in rs] == [m / 2 ** (k + 1) for k in range(s)]
    assert [st.offset for st in ag] == [2 ** (s - 1 - k) for k in range(s)]
    assert [st.nbytes for st in ag] == [m / 2 ** (s - k) for k in range(s)]


def test_radix2_nonpow2_a2a_truncated_digit_classes():
    """At non-pow2 n the digit classes shrink: n=6 sends m/2, m/3, m/3."""
    steps = steps_for("a2a", 6, 6.0, 2)
    assert [(st.offset, st.nbytes) for st in steps] == [(1, 3.0), (2, 2.0), (4, 2.0)]


@pytest.mark.parametrize("n,r", [(6, 2), (12, 3), (48, 4), (96, 3)])
def test_generalized_dp_beats_exhaustive(n, r):
    """The generalized DPs stay exact: no 0/1 schedule with the same R does
    better under the full cost model."""
    import itertools

    cm = PAPER_DEFAULT.replace(delta=0.0)
    m = 2**20
    S = schedule_length("rs", n, r)
    if S > 8:
        pytest.skip("exhaustive check only feasible for short step sequences")
    best_by_R = {}
    for bits in itertools.product([0, 1], repeat=S - 1):
        x = (0,) + bits
        sched = Schedule(kind="rs", n=n, x=x, r=r)
        t = collective_time(sched, m, cm).total
        R = sum(x)
        best_by_R[R] = min(best_by_R.get(R, float("inf")), t)
    for R in range(S):
        t_dp = collective_time(
            full_cost_optimal("rs", n, m, cm, R, r), m, cm).total
        assert t_dp == pytest.approx(best_by_R[R], rel=1e-12), (n, r, R)


@pytest.mark.parametrize("n", [6, 12, 48, 96, 384])
@pytest.mark.parametrize("r", RADIXES)
@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_plan_valid_at_acceptance_grid(kind, n, r):
    """Acceptance grid: plan() returns a valid, reachability-checked schedule
    for every kind at n in {6,12,48,96,384}, r in {2,3,4}."""
    p = plan(kind, n, 2**20, PAPER_DEFAULT, r=r)
    assert p.schedule.kind == kind and p.schedule.n == n and p.schedule.r == r
    t = collective_time(p.schedule, 2**20, PAPER_DEFAULT, validate=(n <= 96))
    assert t.total == pytest.approx(p.predicted_time, rel=1e-12)


def test_higher_radix_fewer_phases():
    """Radix r collapses the phase count to ceil(log_r n) (Section 3.1
    multiport); per-phase sub-steps multiply by at most r - 1."""
    for n in (64, 96, 384):
        assert num_steps(n, 4) <= num_steps(n, 3) <= num_steps(n, 2)
        s2 = schedule_length("a2a", n, 2)
        assert s2 == num_steps(n, 2)  # radix 2: one sub-step per phase
