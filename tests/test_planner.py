"""Unified planner tests: JSON round trip, registry plug-ins, parity with the
legacy per-R `plan()`, constraints, fabric semantics, and the all-R DP
relaxation savings."""
import pytest

from repro.core import PAPER_DEFAULT, collective_time, num_steps
from repro.core import schedules as core_schedules
from repro.planner import (Candidate, PlanRequest, PlanResult, Planner,
                           available_strategies, register_strategy,
                           unregister_strategy)

MB = 2**20

# the n x r grid of tests/test_schedules.py::test_plan_valid_at_acceptance_grid
GRID_NS = [6, 12, 48, 96, 384]
GRID_RS = [2, 3, 4]


# --- JSON (de)serialization ---------------------------------------------------


@pytest.mark.parametrize("kind,n,r", [("a2a", 64, 2), ("rs", 96, 3),
                                      ("ag", 48, 2)])
def test_plan_result_json_round_trip(kind, n, r):
    req = PlanRequest(kind=kind, n=n, m_bytes=16 * MB,
                      cost_model=PAPER_DEFAULT, r=r)
    res = Planner().plan(req)
    back = PlanResult.from_json(res.to_json())
    # bit-identical schedules and exact floats (json repr round trip)
    assert back.schedule == res.schedule
    assert back.schedule.x == res.schedule.x
    assert back.predicted_time == res.predicted_time
    assert back.breakdown == res.breakdown
    assert back.alternatives == res.alternatives
    assert back.request == res.request
    assert back == res


def test_plan_result_json_round_trip_allreduce():
    req = PlanRequest(kind="ar", n=48, m_bytes=4 * MB,
                      cost_model=PAPER_DEFAULT, fabric="ocs",
                      strategies=tuple(available_strategies()))
    res = Planner().plan(req)
    back = PlanResult.from_json(res.to_json())
    assert back.rs_schedule == res.rs_schedule
    assert back.ag_schedule == res.ag_schedule
    assert back == res
    # ring participated as an implementation-level alternative
    assert {a.impl for a in res.alternatives} == {"bruck", "ring"}


# --- Registry plug-in ---------------------------------------------------------


def test_registered_strategy_participates_in_selection():
    from repro.core import Schedule

    # a schedule no built-in family produces at n=16 (lens (3, 1))
    novel = Schedule(kind="a2a", n=16, x=(0, 0, 0, 1))

    @register_strategy("dummy-test", kinds=("a2a",), paper_faithful=False)
    def dummy(req, kind):
        yield Candidate("dummy-test", novel)

    try:
        # explicit selection: the plug-in is the only (and winning) candidate
        res = Planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=1.0,
                                         strategies=("dummy-test",)))
        assert res.strategy == "dummy-test"
        assert res.schedule == novel
        # default selection: the plug-in shows up in the alternatives table
        res = Planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=1.0))
        assert any(a.strategy == "dummy-test" for a in res.alternatives)
    finally:
        unregister_strategy("dummy-test")
    with pytest.raises(KeyError):
        Planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=1.0,
                                   strategies=("dummy-test",)))


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_strategy("periodic")(lambda req, kind: [])


# --- Parity with the legacy per-R plan() --------------------------------------


@pytest.mark.parametrize("n", GRID_NS)
@pytest.mark.parametrize("r", GRID_RS)
@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_parity_with_legacy_plan(kind, n, r):
    """The Planner never does worse than the pre-planner per-R reference on
    the full acceptance grid (tolerance covers grouped vs per-step float
    summation in the exact-dp family)."""
    m = float(MB)
    legacy = core_schedules._legacy_plan(kind, n, m, PAPER_DEFAULT, r=r)
    res = Planner().plan(PlanRequest(kind=kind, n=n, m_bytes=m,
                                     cost_model=PAPER_DEFAULT, r=r))
    assert res.predicted_time <= legacy.predicted_time * (1 + 1e-12)
    # the winner is a real schedule whose simulated time matches the claim
    t = collective_time(res.schedule, m, PAPER_DEFAULT).total
    assert t == pytest.approx(res.predicted_time, rel=1e-12)


@pytest.mark.parametrize("n", [8, 64, 256])
@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_paper_families_bit_identical_to_per_r(kind, n):
    """pow2 r=2: the all-R DP reproduces the per-R DP schedules bit-for-bit
    for the paper's families (Table 1 pinning transfers to the planner)."""
    old = core_schedules._legacy_candidate_schedules(
        kind, n, 4.0 * MB, PAPER_DEFAULT, paper_faithful=True)
    new = core_schedules.candidate_schedules(
        kind, n, 4.0 * MB, PAPER_DEFAULT, paper_faithful=True)
    assert [(nm, s.x) for nm, s in old] == [(nm, s.x) for nm, s in new]


def test_plan_shim_matches_planner():
    """core.schedules.plan is a thin shim: same winner as the Planner."""
    res = Planner().plan(PlanRequest(kind="rs", n=96, m_bytes=16.0 * MB,
                                     cost_model=PAPER_DEFAULT, r=3))
    p = core_schedules.plan("rs", 96, 16.0 * MB, PAPER_DEFAULT, r=3)
    assert p.schedule == res.schedule
    assert p.predicted_time == res.predicted_time
    assert p.strategy == res.strategy


# --- Constraints, fabric, objective -------------------------------------------


def test_max_r_constraint_caps_reconfigurations():
    cm = PAPER_DEFAULT.replace(delta=0.0)  # unconstrained optimum is R=S-1
    m = 64.0 * MB
    free = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=m,
                                      cost_model=cm))
    assert free.schedule.R == num_steps(64) - 1
    capped = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=m,
                                        cost_model=cm, max_R=2))
    assert capped.schedule.R <= 2
    assert all(a.R <= 2 for a in capped.alternatives if a.R is not None)


def test_delta_budget_constraint():
    cm = PAPER_DEFAULT  # delta = 10 us
    res = Planner().plan(PlanRequest(kind="rs", n=256, m_bytes=64.0 * MB,
                                     cost_model=cm,
                                     delta_budget=2.5 * cm.delta))
    assert res.schedule.R <= 2


def test_allreduce_cap_covers_both_phases():
    """For composite 'ar' the reconfiguration cap applies to RS + AG
    together, with the best split across the phases."""
    cm = PAPER_DEFAULT
    free = Planner().plan(PlanRequest(kind="ar", n=256, m_bytes=64.0 * MB,
                                      cost_model=cm))
    free_R = free.rs_schedule.R + free.ag_schedule.R
    assert free_R > 2  # the cap below actually binds
    for cap_kw in ({"max_R": 2}, {"delta_budget": 2.5 * cm.delta}):
        res = Planner().plan(PlanRequest(kind="ar", n=256, m_bytes=64.0 * MB,
                                         cost_model=cm, **cap_kw))
        assert res.rs_schedule.R + res.ag_schedule.R <= 2
        # + at most one topology-transition delta (not counted against cap)
        assert res.breakdown.reconfig <= 3 * cm.delta
    # capped at the unconstrained optimum's total, the split recovers it
    res = Planner().plan(PlanRequest(kind="ar", n=256, m_bytes=64.0 * MB,
                                     cost_model=cm, max_R=free_R))
    assert res.predicted_time <= free.predicted_time * (1 + 1e-12)


def test_static_fabric_only_r0():
    res = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=4.0 * MB,
                                     cost_model=PAPER_DEFAULT,
                                     fabric="static"))
    assert res.schedule.R == 0
    assert all(a.R == 0 for a in res.alternatives if a.R is not None)


def test_objective_selects_scoring():
    # transmission objective must pick a schedule whose transmission term is
    # minimal among candidates, even if its total time is not
    req_t = PlanRequest(kind="rs", n=128, m_bytes=64.0 * MB,
                        cost_model=PAPER_DEFAULT.replace(alpha_h=5e-5),
                        objective="transmission")
    res_t = Planner().plan(req_t)
    res_time = Planner().plan(PlanRequest(
        kind="rs", n=128, m_bytes=64.0 * MB,
        cost_model=PAPER_DEFAULT.replace(alpha_h=5e-5)))
    tx = res_t.breakdown.transmission + res_t.breakdown.reconfig
    tx_time = res_time.breakdown.transmission + res_time.breakdown.reconfig
    assert tx <= tx_time * (1 + 1e-12)


def test_request_validation():
    with pytest.raises(ValueError):
        PlanRequest(kind="bogus", n=8, m_bytes=1.0)
    with pytest.raises(ValueError):
        PlanRequest(kind="a2a", n=1, m_bytes=1.0)
    with pytest.raises(ValueError):
        PlanRequest(kind="a2a", n=8, m_bytes=-1.0)
    with pytest.raises(ValueError):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, fabric="wireless")
    with pytest.raises(ValueError):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, objective="vibes")
    with pytest.raises(ValueError):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, ports=0)
    with pytest.raises(ValueError):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, ports=-4)


def test_alternatives_table_has_no_duplicate_schedules():
    """Family endpoints overlap (static == periodic(R=0), every-step ==
    periodic(R=S-1)); each schedule is evaluated and listed once."""
    res = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=4.0 * MB,
                                     cost_model=PAPER_DEFAULT))
    xs = [a.x for a in res.alternatives if a.x is not None]
    assert len(xs) == len(set(xs))
    names = {a.strategy for a in res.alternatives}
    assert "static" not in names and "every-step" not in names  # deduped
    # explicitly selected, the endpoint family still plans on its own
    res = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=4.0 * MB,
                                     cost_model=PAPER_DEFAULT,
                                     strategies=("static",)))
    assert res.strategy == "static" and res.schedule.R == 0


# --- ocs-overlap fabric (sparse reconfiguration, hidden-delta credit) ---------


def test_overlap_request_validation():
    with pytest.raises(ValueError, match="overlap"):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, fabric="ocs-overlap",
                    overlap=1.5)
    with pytest.raises(ValueError, match="ocs-overlap"):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, overlap=0.5)  # fabric 'ocs'
    req = PlanRequest(kind="a2a", n=8, m_bytes=1.0, fabric="ocs-overlap",
                      overlap=0.9)
    assert req.overlap == 0.9


def test_overlap_request_json_round_trip():
    req = PlanRequest(kind="rs", n=48, m_bytes=4.0 * MB,
                      cost_model=PAPER_DEFAULT.replace(delta=1e-3),
                      fabric="ocs-overlap", overlap=0.9)
    res = Planner().plan(req)
    back = PlanResult.from_json(res.to_json())
    assert back.request.fabric == "ocs-overlap"
    assert back.request.overlap == 0.9
    assert back == res


def test_overlap_family_yields_only_on_overlap_fabric():
    # on the plain ocs fabric the family is empty -> explicit selection fails
    with pytest.raises(ValueError, match="no strategy"):
        Planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=1.0 * MB,
                                   strategies=("overlap",)))
    res = Planner().plan(PlanRequest(kind="a2a", n=16, m_bytes=1.0 * MB,
                                     fabric="ocs-overlap", overlap=0.5,
                                     strategies=("overlap",)))
    assert res.strategy.startswith("overlap[")


def test_overlap_credit_prefers_more_reconfigurations():
    """At ms-scale delta the full-pause model stays near-static, but with
    most of delta hidden, higher-R schedules win — and the hidden-delta
    breakdown is cheaper than the plain-ocs winner's."""
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    plain = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=16.0 * MB,
                                       cost_model=cm))
    hidden = Planner().plan(PlanRequest(kind="a2a", n=64, m_bytes=16.0 * MB,
                                        cost_model=cm, fabric="ocs-overlap",
                                        overlap=0.95))
    assert hidden.schedule.R > plain.schedule.R
    assert hidden.predicted_time < plain.predicted_time
    # reconfig term reflects the credit: R * delta * (1 - overlap)
    expect = hidden.schedule.R * cm.delta_sparse(64, 0.95)
    assert hidden.breakdown.reconfig == pytest.approx(expect)


def test_overlap_full_credit_reduces_to_zero_reconfig_cost():
    cm = PAPER_DEFAULT.replace(delta=15e-3)
    res = Planner().plan(PlanRequest(kind="rs", n=32, m_bytes=8.0 * MB,
                                     cost_model=cm, fabric="ocs-overlap",
                                     overlap=1.0))
    assert res.breakdown.reconfig == 0.0
    # with delta free, the planner reconfigures aggressively
    assert res.schedule.R > 0


def test_overlap_allreduce_charges_sparse_transition():
    cm = PAPER_DEFAULT.replace(delta=1e-4)
    res = Planner().plan(PlanRequest(kind="ar", n=32, m_bytes=8.0 * MB,
                                     cost_model=cm, fabric="ocs-overlap",
                                     overlap=0.75))
    from repro.core import allreduce_time_overlap

    ref = allreduce_time_overlap(res.rs_schedule, res.ag_schedule,
                                 8.0 * MB, cm, 0.75)
    assert res.predicted_time == ref.total
    # regression: 'ocs-overlap' must plan the RS/AG phases (not fall into the
    # static-fabric branch) and dominate the plain-ocs winner under the same
    # hidden-delta scoring
    assert res.rs_schedule.R + res.ag_schedule.R > 0
    plain = Planner().plan(PlanRequest(kind="ar", n=32, m_bytes=8.0 * MB,
                                       cost_model=cm))
    plain_rescored = allreduce_time_overlap(plain.rs_schedule,
                                            plain.ag_schedule,
                                            8.0 * MB, cm, 0.75)
    assert res.predicted_time <= plain_rescored.total * (1 + 1e-12)


# --- ocs-sim fabric (batched event-scored planning) ----------------------------


def test_ocs_sim_request_validation():
    with pytest.raises(ValueError, match="time"):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, fabric="ocs-sim",
                    objective="latency")
    # the event engine models a full-port OCS; a ports constraint would be
    # silently ignored, so it is rejected instead
    with pytest.raises(ValueError, match="ports"):
        PlanRequest(kind="a2a", n=8, m_bytes=1.0, fabric="ocs-sim", ports=3)
    req = PlanRequest(kind="a2a", n=8, m_bytes=1.0, fabric="ocs-sim",
                      overlap=0.75)
    assert req.overlap == 0.75


def test_ocs_sim_scores_every_candidate_with_the_simulator():
    """Every schedule alternative's score is its batched event completion,
    and the winner minimizes it."""
    from repro.core.batchsim import batch_completion_times

    cm = PAPER_DEFAULT.replace(delta=1e-3)
    planner = Planner(sim_chunks=8)
    res = planner.plan(PlanRequest(kind="a2a", n=48, m_bytes=4.0 * MB,
                                   cost_model=cm, fabric="ocs-sim"))
    scheds = [core_schedules.Schedule(kind="a2a", n=48, x=a.x)
              for a in res.alternatives]
    sim = batch_completion_times(scheds, 4.0 * MB, cm, chunks_per_msg=8)
    for a, t in zip(res.alternatives, sim, strict=True):
        assert a.score == pytest.approx(float(t), rel=1e-12)
        assert a.predicted_time == a.score
    assert res.predicted_time == res.alternatives[0].score
    assert min(a.score for a in res.alternatives) == res.predicted_time


@pytest.mark.parametrize("kind", ["a2a", "rs", "ag"])
def test_ocs_sim_never_worse_than_analytic_winner(kind):
    """Acceptance: the ocs-sim winner is never a schedule the batched
    simulator ranks worse than the analytic (ocs-overlap) winner of the
    same request."""
    from repro.core.batchsim import batch_completion_times

    cm = PAPER_DEFAULT.replace(delta=1e-3)
    planner = Planner(sim_chunks=8)
    for overlap in (0.0, 0.75):
        sim_res = planner.plan(PlanRequest(
            kind=kind, n=96, m_bytes=4.0 * MB, cost_model=cm,
            fabric="ocs-sim", overlap=overlap))
        analytic = planner.plan(PlanRequest(
            kind=kind, n=96, m_bytes=4.0 * MB, cost_model=cm,
            fabric="ocs-overlap", overlap=overlap))
        both = batch_completion_times(
            [sim_res.schedule, analytic.schedule], 4.0 * MB, cm,
            overlap=overlap, chunks_per_msg=planner.sim_chunks)
        assert both[0] <= both[1] * (1 + 1e-12)


def test_ocs_sim_allreduce_plans_phases_with_event_scores():
    cm = PAPER_DEFAULT.replace(delta=1e-3)
    planner = Planner(sim_chunks=4)
    res = planner.plan(PlanRequest(kind="ar", n=32, m_bytes=8.0 * MB,
                                   cost_model=cm, fabric="ocs-sim",
                                   overlap=0.75))
    assert res.rs_schedule is not None and res.ag_schedule is not None
    # predicted time = simulated RS + simulated AG + sparse transition
    from repro.core.batchsim import batch_completion_times

    phases = batch_completion_times([res.rs_schedule, res.ag_schedule],
                                    8.0 * MB, cm, overlap=0.75,
                                    chunks_per_msg=4)
    rs_final = res.rs_schedule.link_offsets()[-1]
    ag_first = res.ag_schedule.link_offsets()[0]
    transition = cm.delta_sparse(32 if rs_final != ag_first else 0, 0.75)
    assert res.predicted_time == pytest.approx(
        float(phases[0] + phases[1]) + transition, rel=1e-12)


def test_ocs_sim_round_trip():
    req = PlanRequest(kind="rs", n=48, m_bytes=4.0 * MB,
                      cost_model=PAPER_DEFAULT, fabric="ocs-sim")
    res = Planner().plan(req)
    back = PlanResult.from_json(res.to_json())
    assert back.request.fabric == "ocs-sim"
    assert back == res


# --- plan cache + plan_batch (the serving path) --------------------------------


def test_plan_cache_hits_on_repeated_requests():
    planner = Planner(cache_size=8)
    req = PlanRequest(kind="a2a", n=48, m_bytes=4.0 * MB,
                      cost_model=PAPER_DEFAULT)
    r1 = planner.plan(req)
    r2 = planner.plan(PlanRequest(kind="a2a", n=48, m_bytes=4.0 * MB,
                                  cost_model=PAPER_DEFAULT))
    assert r1 is r2  # equal requests share one immutable result
    info = planner.cache_info()
    assert (info.hits, info.misses, info.size) == (1, 1, 1)
    # a different request misses
    planner.plan(PlanRequest(kind="rs", n=48, m_bytes=4.0 * MB,
                             cost_model=PAPER_DEFAULT))
    assert planner.cache_info().misses == 2
    planner.cache_clear()
    assert planner.cache_info() == (0, 0, 0, 8)


def test_plan_cache_lru_eviction():
    planner = Planner(cache_size=1)
    req_a = PlanRequest(kind="a2a", n=16, m_bytes=1.0 * MB)
    req_b = PlanRequest(kind="rs", n=16, m_bytes=1.0 * MB)
    ra = planner.plan(req_a)
    planner.plan(req_b)           # evicts req_a
    assert planner.cache_info().size == 1
    assert planner.plan(req_b) is not None
    assert planner.cache_info().hits == 1
    ra2 = planner.plan(req_a)     # re-planned, not cached
    assert planner.cache_info().misses == 3
    assert ra2 == ra              # deterministic: equal even when recomputed


def test_plan_cache_disabled():
    planner = Planner(cache_size=0)
    req = PlanRequest(kind="a2a", n=16, m_bytes=1.0 * MB)
    r1, r2 = planner.plan(req), planner.plan(req)
    assert r1 == r2 and r1 is not r2
    assert planner.cache_info() == (0, 0, 0, 0)
    with pytest.raises(ValueError, match="cache_size"):
        Planner(cache_size=-1)


def test_plan_batch_dedupes_repeated_traffic():
    planner = Planner(cache_size=16)
    reqs = [PlanRequest(kind="a2a", n=32, m_bytes=2.0 * MB),
            PlanRequest(kind="rs", n=32, m_bytes=2.0 * MB),
            PlanRequest(kind="a2a", n=32, m_bytes=2.0 * MB),
            PlanRequest(kind="a2a", n=32, m_bytes=2.0 * MB)]
    results = planner.plan_batch(reqs)
    assert len(results) == 4
    assert results[0] is results[2] is results[3]
    assert results[0].schedule.kind == "a2a"
    assert results[1].schedule.kind == "rs"
    info = planner.cache_info()
    assert (info.hits, info.misses) == (2, 2)


def test_default_planner_is_shared_and_cached():
    from repro.planner import default_planner

    planner = default_planner()
    assert planner is default_planner()
    before = planner.cache_info().hits
    req = PlanRequest(kind="ag", n=24, m_bytes=1.0 * MB)
    planner.plan(req)
    planner.plan(req)
    assert planner.cache_info().hits >= before + 1
    # the legacy shim routes through the same cache
    core_schedules.plan("ag", 24, 1.0 * MB, PAPER_DEFAULT)
    core_schedules.plan("ag", 24, 1.0 * MB, PAPER_DEFAULT)
    assert planner.cache_info().hits >= before + 2


# --- All-R DP performance ------------------------------------------------------


def test_all_r_dp_relaxation_savings():
    """Acceptance: planning the full candidate set at n=384 performs >= 5x
    fewer DP cell relaxations than the legacy per-R loop."""
    m = float(MB)
    core_schedules.clear_schedule_caches()
    core_schedules.reset_dp_stats()
    for kind in ("a2a", "rs", "ag"):
        core_schedules.candidate_schedules(kind, 384, m, PAPER_DEFAULT, r=2)
    relax_all = core_schedules.dp_stats()["relaxations"]
    core_schedules.reset_dp_stats()
    for kind in ("a2a", "rs", "ag"):
        core_schedules._legacy_candidate_schedules(kind, 384, m, PAPER_DEFAULT,
                                                   r=2)
    relax_per_r = core_schedules.dp_stats()["relaxations"]
    assert relax_per_r >= 5 * relax_all, (relax_per_r, relax_all)


def test_all_r_dp_matches_capped_dp_per_r():
    """best[i][r] is cap-independent: every all-R entry equals the capped
    per-R DP bit-for-bit (integer hop objective)."""
    steps = core_schedules._steps_cached("a2a", 96, 3)
    tables = core_schedules.SegmentTables(steps)
    s = len(steps)
    all_r = core_schedules._partition_dp_all(s, tables.hop_sum)
    for R in range(s):
        cost, lens = core_schedules._partition_dp(s, R + 1, tables.hop_sum)
        assert (cost, tuple(lens)) == all_r[R]


def test_segment_tables_match_naive_costs():
    """O(1) prefix/gcd segment costs equal the O(len) closures exactly for
    integer hop sums, and to float tolerance for transmission."""
    for (n, r) in ((96, 3), (384, 2), (48, 4)):
        steps = core_schedules._steps_cached("rs", n, r)
        tables = core_schedules.SegmentTables(steps)
        hop_naive = core_schedules._hop_sum_cost(steps)
        tx_naive = core_schedules._transmission_cost(steps)
        S = len(steps)
        for a in range(S):
            for b in range(a, S):
                assert tables.gcd(a, b) == core_schedules._segment_gcd(steps, a, b)
                assert tables.hop_sum(a, b) == hop_naive(a, b)
                assert tables.tx_sum(a, b) == pytest.approx(tx_naive(a, b),
                                                            rel=1e-12)


# --- plan_gradient_sync wrapper ------------------------------------------------


def test_plan_gradient_sync_is_thin_wrapper():
    """Unchanged public behavior: same winners/alternatives as planning an
    'ar' request directly."""
    from repro.collectives import plan_gradient_sync
    from repro.planner import default_strategy_names

    cm = PAPER_DEFAULT
    for fabric in ("static", "ocs"):
        p = plan_gradient_sync(64, 4.0 * MB, cm, fabric=fabric)
        res = Planner().plan(PlanRequest(
            kind="ar", n=64, m_bytes=4.0 * MB, cost_model=cm, fabric=fabric,
            strategies=default_strategy_names() + ("ring",)))
        assert p.impl == res.impl
        assert p.predicted_time == res.predicted_time
        if p.impl == "bruck" and fabric == "ocs":
            assert p.rs_schedule == res.rs_schedule
            assert p.ag_schedule == res.ag_schedule
        else:
            assert p.rs_schedule is None and p.ag_schedule is None
    # psum fallback unchanged
    p = plan_gradient_sync(1, 4.0 * MB, cm)
    assert (p.impl, p.predicted_time, p.alternatives) == ("psum", 0.0, {})
    p = plan_gradient_sync(64, 4.0 * MB, cm, allow=())
    assert p.impl == "psum"
