"""Kernel micro-benchmarks (CPU interpret-mode wall time is NOT a TPU number;
the derived column reports the modeled VMEM working set and arithmetic
intensity that the BlockSpec tiling targets — the structural quantities the
Pallas hillclimb iterates on)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, reps=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def flash_attention_bench():
    from repro.kernels import flash_attention
    b, h, s, d = 1, 4, 256, 64
    bq = bk = 128
    k = jax.random.PRNGKey(0)
    q, kk, v = (jax.random.normal(k, (b, h, s, d)) for _ in range(3))
    us = _time(lambda q, kk, v: flash_attention(q, kk, v, True, None, None,
                                                bq, bk), q, kk, v, reps=2)
    vmem = (bq * d + 2 * bk * d + bq * d + 2 * bq) * 4
    flops = 4 * b * h * s * s * d / 2  # causal
    hbm = (3 + 1) * b * h * s * d * 4
    return {"us_per_call": us, "vmem_bytes": vmem,
            "arith_intensity": flops / hbm}


def rg_lru_bench():
    from repro.kernels import rg_lru
    B, T, D = 1, 512, 256
    k = jax.random.PRNGKey(0)
    a = jax.random.uniform(k, (B, T, D), jnp.float32, 0.5, 0.99)
    bb = jax.random.normal(k, (B, T, D))
    us = _time(lambda a, b: rg_lru(a, b)[0], a, bb, reps=2)
    bt, bd = 256, 256
    vmem = (2 * bt * bd + bt * bd + bd) * 4
    return {"us_per_call": us, "vmem_bytes": vmem,
            "hbm_bytes_per_elem": 3 * 4}  # read a,b write y


def wkv6_bench():
    from repro.kernels import wkv6
    B, H, T, dk, dv, bt = 1, 2, 256, 64, 64, 64
    k = jax.random.PRNGKey(0)
    r, kk, v = (jax.random.normal(k, (B, H, T, dk)) for _ in range(3))
    lw = -jnp.exp(jax.random.normal(k, (B, H, T, dk)))
    u = jax.random.normal(k, (H, dk))
    us = _time(lambda *a: wkv6(*a)[0], r, kk, v, lw, u, reps=1)
    vmem = (4 * bt * dk + dk * dv + bt * bt * dk) * 4
    flops = T * (2 * bt * dk + 4 * dk * dv)  # per block-row approx
    return {"us_per_call": us, "vmem_bytes": vmem, "flops_per_tok": flops / T}
