"""Static audit of the plans implied by the committed BENCH_*.json baselines.

The committed benchmark baselines pin down a grid of (workload, fabric,
cost-model) points whose plans the repo claims are correct.  This gate
re-derives every plan the baselines imply — planner candidate sets, trace
plans in all three modes, online receding-horizon plans, the serving-storm
request pool, and the batch-engine candidate lanes — and runs them through
the static verifier (`repro.analysis`) WITHOUT running a simulator.  Any
`Violation` fails the gate (exit 1), so a planner change that starts
emitting malformed schedules is caught in CI even when its modeled times
still look plausible.

Also reports the statically-certified lane fraction for the batch-engine
grid (`repro.analysis.certifier`): under the paper cost model every uniform
candidate lane must hold a fast-path certificate.

Usage:

    python -m benchmarks.verify_gate [--root DIR] [--max-pool N]

Reads whichever of BENCH_planner.json / BENCH_trace.json /
BENCH_online.json / BENCH_sim_scale.json / BENCH_faults.json /
BENCH_tenancy.json exist under --root (default: the repository root, next
to this package).  Tenancy rows embed the full shared plan artifact, which
is round-tripped and audited by the ``tenant/*`` rules.  The faults
baseline is the one exception to the no-simulator rule: re-deriving each
row's `DegradedState` requires replaying the faulted trace, after which the
``fault/*`` rules audit the degraded state and recovery plan statically.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _load_rows(root: str, name: str) -> list[dict]:
    path = os.path.join(root, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)["rows"]


def audit_planner(rows: list[dict]) -> tuple[list[str], int]:
    """Re-plan every (kind, n, r, m) point of BENCH_planner and verify."""
    from repro.analysis import verify_plan
    from repro.core import PAPER_DEFAULT
    from repro.planner import Planner, PlanRequest

    planner = Planner(cache_size=0, verify=False)  # the gate IS the verifier
    findings, audited = [], 0
    for row in rows:
        kinds = tuple(row.get("kinds") or ("a2a", "rs", "ag")) + ("ar",)
        for kind in kinds:
            req = PlanRequest(kind=kind, n=row["n"], m_bytes=row["m_bytes"],
                              cost_model=PAPER_DEFAULT, r=row["r"])
            res = planner.plan(req)
            audited += 1
            findings += [f"planner n={row['n']} r={row['r']} {kind}: {v}"
                         for v in verify_plan(res)]
    return findings, audited


def audit_trace(rows: list[dict]) -> tuple[list[str], int]:
    """Re-plan every (trace, n, delta) point in all three modes and verify."""
    from benchmarks.trace_bench import make_trace
    from repro.analysis import verify_trace_plan
    from repro.core import PAPER_DEFAULT
    from repro.workloads import plan_trace

    findings, audited = [], 0
    for key in sorted({(r["trace"], r["n"], r["delta"]) for r in rows},
                      key=str):
        name, n, delta = key
        trace = make_trace(name, n)
        cm = PAPER_DEFAULT.replace(delta=delta)
        for mode in ("static", "cold", "carryover"):
            tp = plan_trace(trace, cm, mode=mode)
            audited += 1
            findings += [f"trace={name} n={n} delta={delta} {mode}: {v}"
                         for v in verify_trace_plan(tp, cm=cm)]
    return findings, audited


def audit_online(rows: list[dict], max_pool: int) -> tuple[list[str], int]:
    """Replay every online window grid point and the storm request pool."""
    from benchmarks.online_bench import STORM_WINDOW
    from benchmarks.trace_bench import make_trace
    from repro.analysis import verify_served_plan, verify_trace_plan
    from repro.core import PAPER_DEFAULT
    from repro.workloads import PlanService, build_request_pool, run_online

    findings, audited = [], 0
    for row in rows:
        if row["trace"] == "storm":
            service = PlanService(cm=PAPER_DEFAULT, cache_size=0,
                                  verify=False)
            pool = build_request_pool(row["n"], window=row.get(
                "window", STORM_WINDOW), seed=0)[:max_pool]
            for req in pool:
                sp = service.serve(req)
                audited += 1
                findings += [f"storm n={row['n']} ({len(req.events)}ev "
                             f"init_g={req.init_g}): {v}"
                             for v in verify_served_plan(sp, PAPER_DEFAULT)]
            continue
        trace = make_trace(row["trace"], row["n"])
        cm = PAPER_DEFAULT.replace(delta=row["delta"])
        tp, _ = run_online(trace, cm, window=row["window"])
        audited += 1
        findings += [f"online trace={row['trace']} n={row['n']} "
                     f"delta={row['delta']} W={row['window']}: {v}"
                     for v in verify_trace_plan(tp, cm=cm)]
    return findings, audited


def audit_sim(rows: list[dict]) -> tuple[list[str], int, list[str]]:
    """Verify every batch-engine candidate tape; report certified fraction.

    jax / jax-scale tier rows tile a hop-capped candidate set out to a wide
    batch (`sim_bench._jax_lanes`); their lanes are reconstructed from the
    committed row's (lanes, hop_cap) and each *distinct* schedule is
    verified once — the certificate check still runs over the full tiled
    lane list, since certification is per (schedule, payload) lane.
    """
    from benchmarks.sim_bench import _candidate_lanes, _jax_lanes
    from repro.analysis import certify_batch, verify_schedule
    from repro.core import PAPER_DEFAULT

    findings, audited, certified_lines = [], 0, []
    for row in rows:
        if row["tier"] in ("jax", "jax-scale"):
            lanes = _jax_lanes(row["n"], row["m_bytes"],
                               lanes_target=row["lanes"],
                               hop_cap=row["hop_cap"])
        else:
            lanes = _candidate_lanes(row["n"], row["m_bytes"],
                                     max_lanes=row["lanes"])
        cm = PAPER_DEFAULT.replace(delta=row["delta"])
        seen = set()
        for lane in lanes:
            sched_key = (lane.schedule.kind, lane.schedule.x)
            if sched_key in seen:  # tiled jax rows repeat schedules
                continue
            seen.add(sched_key)
            audited += 1
            findings += [f"sim tier={row['tier']} n={row['n']} "
                         f"{lane.schedule.kind} x={lane.schedule.x}: {v}"
                         for v in verify_schedule(lane.schedule)]
        certified = int(certify_batch(lanes, cm).sum())
        certified_lines.append(
            f"# sim tier={row['tier']} n={row['n']}: {certified}/{len(lanes)}"
            f" lanes certified ({certified / max(len(lanes), 1):.0%})")
        if certified != len(lanes):
            findings.append(
                f"sim tier={row['tier']} n={row['n']}: only {certified}/"
                f"{len(lanes)} uniform candidate lanes certified (alpha_s > "
                f"0 regime must certify them all)")
        baseline = row.get("certified_lanes")
        if baseline is not None and certified != baseline:
            findings.append(
                f"sim tier={row['tier']} n={row['n']}: certified lanes "
                f"{certified} != committed baseline {baseline}")
    return findings, audited, certified_lines


def audit_faults(rows: list[dict]) -> tuple[list[str], int]:
    """Re-run every fault-recovery grid point and audit with fault/* rules.

    `run_with_recovery` is invoked with ``verify=False`` — the gate runs
    `verify_timeline` / `verify_degraded` / `verify_recovery` itself so a
    violation is *reported* here rather than raised mid-derivation.
    """
    from benchmarks.faults_bench import CHUNKS_PER_MSG, recovery_for
    from repro.analysis import (verify_degraded, verify_recovery,
                                verify_timeline)

    findings, audited = [], 0
    for row in rows:
        rr, faults = recovery_for(row["kind"], row["n"], row["delta"],
                                  row["fail_frac"], verify=False)
        audited += 1
        found = (verify_timeline(faults)
                 + verify_degraded(rr.degraded,
                                   phases=rr.plan.fabric_phases(),
                                   chunks_per_msg=CHUNKS_PER_MSG)
                 + verify_recovery(rr.degraded, rr.recovery_plan,
                                   clean_plan=rr.clean_plan))
        findings += [f"faults kind={row['kind']} n={row['n']} "
                     f"delta={row['delta']} frac={row['fail_frac']}: {v}"
                     for v in found]
    return findings, audited


def audit_tenancy(rows: list[dict]) -> tuple[list[str], int]:
    """Round-trip every row's embedded shared plan and audit tenant/* rules.

    The bench commits the full ``SharedPlan.to_dict()`` artifact per row, so
    the gate needs no re-planning: deserialize and hand it to
    `verify_shared_plan`, which re-derives hand-off pricing, budgets,
    completions, and the isolation bounds from the embedded request.
    """
    from repro.analysis import verify_shared_plan
    from repro.workloads import SharedPlan

    findings, audited = [], 0
    for row in rows:
        sp = SharedPlan.from_dict(row["shared_plan"])
        audited += 1
        findings += [f"tenancy sharing={row['sharing']} K={row['K']} "
                     f"n={row['n']} delta={row['delta']}: {v}"
                     for v in verify_shared_plan(sp)]
    return findings, audited


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."),
        help="directory holding the committed BENCH_*.json baselines")
    ap.add_argument("--max-pool", type=int, default=24,
                    help="cap on storm-pool requests audited per n")
    args = ap.parse_args(argv)

    findings: list[str] = []
    total = 0
    for name, audit in (("BENCH_planner.json", audit_planner),
                        ("BENCH_trace.json", audit_trace)):
        rows = _load_rows(args.root, name)
        if not rows:
            print(f"# skip {name}: not present")
            continue
        found, audited = audit(rows)
        findings += found
        total += audited
        print(f"# {name}: {audited} plans audited, {len(found)} violations")
    rows = _load_rows(args.root, "BENCH_online.json")
    if rows:
        found, audited = audit_online(rows, args.max_pool)
        findings += found
        total += audited
        print(f"# BENCH_online.json: {audited} plans audited, "
              f"{len(found)} violations")
    else:
        print("# skip BENCH_online.json: not present")
    rows = _load_rows(args.root, "BENCH_sim_scale.json")
    if rows:
        found, audited, certified_lines = audit_sim(rows)
        findings += found
        total += audited
        for line in certified_lines:
            print(line)
        print(f"# BENCH_sim_scale.json: {audited} schedules audited, "
              f"{len(found)} violations")
    else:
        print("# skip BENCH_sim_scale.json: not present")
    rows = _load_rows(args.root, "BENCH_faults.json")
    if rows:
        found, audited = audit_faults(rows)
        findings += found
        total += audited
        print(f"# BENCH_faults.json: {audited} recovery cycles audited, "
              f"{len(found)} violations")
    else:
        print("# skip BENCH_faults.json: not present")
    rows = _load_rows(args.root, "BENCH_tenancy.json")
    if rows:
        found, audited = audit_tenancy(rows)
        findings += found
        total += audited
        print(f"# BENCH_tenancy.json: {audited} shared plans audited, "
              f"{len(found)} violations")
    else:
        print("# skip BENCH_tenancy.json: not present")

    if total == 0:
        print("# FAIL: no baselines found to audit", file=sys.stderr)
        sys.exit(1)
    if findings:
        for f in findings:
            print(f"# FAIL: {f}", file=sys.stderr)
        sys.exit(1)
    print(f"# OK: {total} artifacts statically verified, zero violations")


if __name__ == "__main__":
    main()
