"""Benchmark regression gate: freshly-measured results vs committed baseline.

Usage:

    python -m benchmarks.check_regression BASELINE.json FRESH.json

The file schema is auto-detected from the row keys:

  - planner rows (``wall_speedup``, BENCH_planner.json): the relaxation
    counts are deterministic and must match the baseline exactly; the wall
    speedup is timing-noisy, so it only has to stay above ``--wall-frac``
    of the committed value (and above 1x absolutely).
  - fabric rows (``event_analytic_ratio``, BENCH_fabric_overlap.json): the
    event simulator is deterministic, so the event/analytic ratio and the
    sparse speedup must match the baseline within ``--rel-tol``.
  - sim rows (``batched_wall_s``, BENCH_sim_scale.json): the batch engine is
    deterministic, so lane counts, fast-path counts, statically-certified
    lane counts, and the completion checksum must match the baseline
    (checksum within 1e-9 relative); the scoring-tier wall speedup is
    timing-noisy and only has to stay above ``--wall-frac`` of the committed
    value (and above 1x absolutely), and certified playback must stay within
    1.25x of the guard-based (``certify=False``) wall time.  ``jax`` /
    ``jax-scale`` tier rows additionally pin the resolved backend and
    bit-stability, and the jax tier's warm speedup over the NumPy engine
    must clear both the *absolute* 3x floor of the acceptance spec and
    ``--wall-frac`` of the committed value.
  - trace rows (``carryover_s``, BENCH_trace.json): trace planning is
    deterministic, so the carryover/cold/static ratios must match the
    baseline within ``--rel-tol`` and the boundary-reuse counts exactly.
  - online rows (``window``, BENCH_online.json): online planning and the
    request storm are deterministic, so the modeled times, replan/reuse
    counts, hit accounting, and the storm's plan-sequence signature must
    match the baseline (times within ``--rel-tol``); the serving plans/sec
    is timing-noisy and only has to stay above ``--wall-frac`` of the
    committed hot-path throughput.
  - tenancy rows (``shared_s``, BENCH_tenancy.json): shared planning is
    deterministic, so phase counts and isolation ratios must match the
    baseline (ratios within ``--rel-tol``) and the shared/serialized totals
    within ``--rel-tol``; on top of the baseline comparison,
    ``shared <= serialized`` (both metrics), per-tenant
    ``isolation <= isolation_bound``, and perfect port-partition isolation
    are re-asserted as absolute floors on every fresh row.
  - faults rows (``recovery_ratio``, BENCH_faults.json): fault injection and
    recovery re-planning are deterministic, so the committed-phase counts,
    chunk ledger, and surviving world size must match the baseline exactly
    and the recovery/restart totals within ``--rel-tol``; on top of the
    baseline comparison, ``recovery_ratio <= 1`` and ``bit_identical`` are
    re-asserted as absolute floors on every fresh row.

Rows are matched on their identifying keys (n / r / delta / tier / trace).
Row coverage is strict: a fresh row whose key the baseline does not know is
an error (the baseline is stale and that row would never be gated), and a
baseline row the fresh run did not produce is an error unless
``--subset-ok`` is passed (smoke runs measure a subset of the committed
grid, but a *full* run silently dropping rows is a regression).  A file
whose rows match no known schema is an error, never a silent pass.  Exit 1
on any drift.
"""
from __future__ import annotations

import argparse
import json
import sys

#: schema name -> (detection key present in every row, identifying row keys)
SCHEMAS = {
    "faults": ("recovery_ratio", ("kind", "n", "delta", "fail_frac")),
    "planner": ("wall_speedup", ("n", "r")),
    "sim": ("batched_wall_s", ("tier", "n")),
    "trace": ("carryover_s", ("trace", "n", "delta")),
    "tenancy": ("shared_s", ("sharing", "K", "n", "delta")),
    "fabric": ("event_analytic_ratio", ("n", "r", "delta")),
    "online": ("window", ("trace", "n", "delta", "window")),
}


def _index(rows: list[dict], keys: tuple[str, ...]) -> dict:
    return {tuple(row[k] for k in keys): row for row in rows}


def check_planner(base_rows: list[dict], fresh_rows: list[dict],
                  wall_frac: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, ("n", "r"))
    for key, fresh in _index(fresh_rows, ("n", "r")).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        tag = f"planner n={key[0]} r={key[1]}"
        for field in ("relaxations_all_r", "relaxations_per_r",
                      "dp_calls_all_r", "dp_calls_per_r"):
            if fresh[field] != ref[field]:
                errors.append(f"{tag}: {field} {fresh[field]} != baseline "
                              f"{ref[field]} (DP work is deterministic)")
        floor = max(1.0, wall_frac * ref["wall_speedup"])
        if fresh["wall_speedup"] < floor:
            errors.append(f"{tag}: wall_speedup {fresh['wall_speedup']} < "
                          f"{floor:.2f} (baseline {ref['wall_speedup']}, "
                          f"frac {wall_frac})")
    return errors, matched


#: the acceptance spec's hard floor for the jax tier's warm speedup over the
#: NumPy batch engine — absolute, never scaled by --wall-frac
JAX_SPEEDUP_FLOOR = 3.0


def check_sim_jax(key, ref: dict, fresh: dict,
                  wall_frac: float) -> list[str]:
    """Gates for one jax / jax-scale tier row (vs its committed baseline)."""
    errors = []
    tag = f"sim tier={key[0]} n={key[1]}"
    for field in ("lanes", "chunks", "hop_cap", "fast_lanes",
                  "certified_lanes", "backend"):
        if fresh[field] != ref[field]:
            errors.append(f"{tag}: {field} {fresh[field]} != baseline "
                          f"{ref[field]} (jax grid is deterministic)")
    if not fresh["bit_stable"]:
        errors.append(f"{tag}: JAX playback not bit-stable run-to-run")
    drift = (abs(fresh["completion_checksum"] - ref["completion_checksum"])
             / max(abs(ref["completion_checksum"]), 1e-12))
    if drift > 1e-9:
        errors.append(f"{tag}: completion_checksum drifted {drift:.2e} "
                      f"from baseline (> 1e-9)")
    if ref.get("jax_speedup") is not None:
        if fresh["worst_rel_diff"] > 1e-6:
            errors.append(f"{tag}: jax vs numpy completion drift "
                          f"{fresh['worst_rel_diff']} > 1e-6")
        floor = max(JAX_SPEEDUP_FLOOR, wall_frac * ref["jax_speedup"])
        if fresh["jax_speedup"] < floor:
            errors.append(f"{tag}: jax_speedup {fresh['jax_speedup']} < "
                          f"{floor:.2f} (baseline {ref['jax_speedup']}, "
                          f"frac {wall_frac}, hard floor "
                          f"{JAX_SPEEDUP_FLOOR})")
    return errors


def check_sim(base_rows: list[dict], fresh_rows: list[dict],
              wall_frac: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, ("tier", "n"))
    for key, fresh in _index(fresh_rows, ("tier", "n")).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        if key[0] in ("jax", "jax-scale"):
            errors += check_sim_jax(key, ref, fresh, wall_frac)
            continue
        tag = f"sim tier={key[0]} n={key[1]}"
        for field in ("lanes", "fast_lanes", "chunks"):
            if fresh[field] != ref[field]:
                errors.append(f"{tag}: {field} {fresh[field]} != baseline "
                              f"{ref[field]} (engine grid is deterministic)")
        if "certified_lanes" in ref:  # baselines predating the certifier skip
            if fresh["certified_lanes"] != ref["certified_lanes"]:
                errors.append(f"{tag}: certified_lanes "
                              f"{fresh['certified_lanes']} != baseline "
                              f"{ref['certified_lanes']} (certificates are "
                              f"static and deterministic)")
            guard = fresh.get("guard_wall_s")
            if guard is not None and fresh["batched_wall_s"] > 1.25 * guard:
                errors.append(f"{tag}: certified playback "
                              f"{fresh['batched_wall_s']}s slower than the "
                              f"guard-based path {guard}s x 1.25")
        drift = (abs(fresh["completion_checksum"] - ref["completion_checksum"])
                 / max(abs(ref["completion_checksum"]), 1e-12))
        if drift > 1e-9:
            errors.append(f"{tag}: completion_checksum drifted {drift:.2e} "
                          f"from baseline (> 1e-9)")
        if ref["batched_speedup"] is not None:
            floor = max(1.0, wall_frac * ref["batched_speedup"])
            if fresh["batched_speedup"] < floor:
                errors.append(f"{tag}: batched_speedup "
                              f"{fresh['batched_speedup']} < {floor:.2f} "
                              f"(baseline {ref['batched_speedup']}, "
                              f"frac {wall_frac})")
    return errors, matched


def check_fabric(base_rows: list[dict], fresh_rows: list[dict],
                 rel_tol: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, ("n", "r", "delta"))
    for key, fresh in _index(fresh_rows, ("n", "r", "delta")).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        tag = f"fabric n={key[0]} r={key[1]} delta={key[2]}"
        for field in ("event_analytic_ratio", "sparse_speedup"):
            drift = abs(fresh[field] - ref[field]) / max(abs(ref[field]), 1e-12)
            if drift > rel_tol:
                errors.append(f"{tag}: {field} {fresh[field]} drifted "
                              f"{drift:.2e} from baseline {ref[field]} "
                              f"(> {rel_tol})")
    return errors, matched


def check_trace(base_rows: list[dict], fresh_rows: list[dict],
                rel_tol: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, SCHEMAS["trace"][1])
    for key, fresh in _index(fresh_rows, SCHEMAS["trace"][1]).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        tag = f"trace={key[0]} n={key[1]} delta={key[2]}"
        for field in ("phases", "free_boundaries", "boundaries",
                      "carry_paid_reconfigs"):
            if fresh[field] != ref[field]:
                errors.append(f"{tag}: {field} {fresh[field]} != baseline "
                              f"{ref[field]} (trace planning is deterministic)")
        for field in ("carryover_vs_cold", "carryover_vs_static",
                      "carryover_s"):
            drift = abs(fresh[field] - ref[field]) / max(abs(ref[field]), 1e-12)
            if drift > rel_tol:
                errors.append(f"{tag}: {field} {fresh[field]} drifted "
                              f"{drift:.2e} from baseline {ref[field]} "
                              f"(> {rel_tol})")
    return errors, matched


def check_online(base_rows: list[dict], fresh_rows: list[dict],
                 rel_tol: float, wall_frac: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, SCHEMAS["online"][1])
    for key, fresh in _index(fresh_rows, SCHEMAS["online"][1]).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        if key[0] == "storm":
            tag = f"storm n={key[1]}"
            for field in ("pool", "requests", "cold_hits", "cold_misses",
                          "hot_hits", "hot_misses", "unique_windows",
                          "signature"):
                if fresh[field] != ref[field]:
                    errors.append(f"{tag}: {field} {fresh[field]} != "
                                  f"baseline {ref[field]} (the seeded storm "
                                  f"is deterministic)")
            floor = wall_frac * ref["hot_plans_per_sec"]
            if fresh["hot_plans_per_sec"] < floor:
                errors.append(f"{tag}: hot_plans_per_sec "
                              f"{fresh['hot_plans_per_sec']} < {floor:.0f} "
                              f"(baseline {ref['hot_plans_per_sec']}, "
                              f"frac {wall_frac})")
            continue
        tag = (f"online trace={key[0]} n={key[1]} delta={key[2]} "
               f"W={key[3]}")
        for field in ("events", "phases", "replans", "plan_reuses",
                      "free_boundaries", "paid_reconfigs"):
            if fresh[field] != ref[field]:
                errors.append(f"{tag}: {field} {fresh[field]} != baseline "
                              f"{ref[field]} (online planning is "
                              f"deterministic)")
        for field in ("online_s", "offline_s", "cold_event_s",
                      "online_vs_offline", "cold_vs_online"):
            drift = abs(fresh[field] - ref[field]) / max(abs(ref[field]), 1e-12)
            if drift > rel_tol:
                errors.append(f"{tag}: {field} {fresh[field]} drifted "
                              f"{drift:.2e} from baseline {ref[field]} "
                              f"(> {rel_tol})")
    return errors, matched


def check_tenancy(base_rows: list[dict], fresh_rows: list[dict],
                  rel_tol: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, SCHEMAS["tenancy"][1])
    for key, fresh in _index(fresh_rows, SCHEMAS["tenancy"][1]).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        tag = (f"tenancy sharing={key[0]} K={key[1]} n={key[2]} "
               f"delta={key[3]}")
        if fresh["phases"] != ref["phases"]:
            errors.append(f"{tag}: phases {fresh['phases']} != baseline "
                          f"{ref['phases']} (shared planning is "
                          f"deterministic)")
        for field in ("shared_s", "weighted_s", "serialized_s",
                      "serialized_weighted_s", "win_vs_serialized",
                      "weighted_win"):
            drift = abs(fresh[field] - ref[field]) / max(abs(ref[field]), 1e-12)
            if drift > rel_tol:
                errors.append(f"{tag}: {field} {fresh[field]} drifted "
                              f"{drift:.2e} from baseline {ref[field]} "
                              f"(> {rel_tol})")
        for name, iso in fresh["isolation"].items():
            ref_iso = ref["isolation"].get(name)
            if ref_iso is None:
                errors.append(f"{tag}: tenant {name} not in the baseline "
                              f"row (tenant mix is deterministic)")
                continue
            if abs(iso - ref_iso) / max(abs(ref_iso), 1e-12) > rel_tol:
                errors.append(f"{tag}: tenant {name} isolation {iso} "
                              f"drifted from baseline {ref_iso}")
        # absolute floors, independent of the committed baseline
        if fresh["shared_s"] > fresh["serialized_s"] * (1 + 1e-9):
            errors.append(f"{tag}: shared makespan {fresh['shared_s']} > "
                          f"serialized {fresh['serialized_s']}")
        if fresh["weighted_s"] > fresh["serialized_weighted_s"] * (1 + 1e-9):
            errors.append(f"{tag}: shared weighted completion "
                          f"{fresh['weighted_s']} > serialized "
                          f"{fresh['serialized_weighted_s']}")
        for name, iso in fresh["isolation"].items():
            bound = fresh["isolation_bound"][name]
            if iso > bound * (1 + 1e-9):
                errors.append(f"{tag}: tenant {name} isolation {iso} "
                              f"exceeds its bound {bound}")
            if key[0] == "port-partition" and abs(iso - 1.0) > 1e-9:
                errors.append(f"{tag}: port-partitioned tenant {name} not "
                              f"perfectly isolated (ratio {iso})")
    return errors, matched


def check_faults(base_rows: list[dict], fresh_rows: list[dict],
                 rel_tol: float) -> tuple[list[str], int]:
    errors, matched = [], 0
    base = _index(base_rows, SCHEMAS["faults"][1])
    for key, fresh in _index(fresh_rows, SCHEMAS["faults"][1]).items():
        if key not in base:
            continue
        matched += 1
        ref = base[key]
        tag = (f"faults kind={key[0]} n={key[1]} delta={key[2]} "
               f"frac={key[3]}")
        for field in ("policy", "completed_phases", "committed_events",
                      "new_n", "committed_chunks", "lost_chunks",
                      "requeued_chunks", "mispredictions"):
            if fresh[field] != ref[field]:
                errors.append(f"{tag}: {field} {fresh[field]} != baseline "
                              f"{ref[field]} (fault injection and recovery "
                              f"re-planning are deterministic)")
        for field in ("recovery_total_s", "restart_total_s",
                      "recovery_ratio"):
            drift = abs(fresh[field] - ref[field]) / max(abs(ref[field]), 1e-12)
            if drift > rel_tol:
                errors.append(f"{tag}: {field} {fresh[field]} drifted "
                              f"{drift:.2e} from baseline {ref[field]} "
                              f"(> {rel_tol})")
        # absolute floors, independent of the committed baseline
        if fresh["recovery_ratio"] > 1 + 1e-9:
            errors.append(f"{tag}: recovery_ratio {fresh['recovery_ratio']} "
                          f"> 1 — resume-from-snapshot lost to a restart")
        if not fresh["bit_identical"]:
            errors.append(f"{tag}: recovered result no longer bit-identical "
                          f"to a clean run of the reduced world")
    return errors, matched


def detect_schema(rows: list[dict], label: str) -> str:
    """Schema of a result file, failing loudly when no known schema matches.

    Silently defaulting to some schema would make a typo'd or re-keyed
    benchmark file pass the gate without checking anything.
    """
    for name, (key, _) in SCHEMAS.items():
        if key in rows[0]:
            return name
    raise SystemExit(
        f"# FAIL: {label}: rows match no known schema (expected one of "
        f"{ {k: v[0] for k, v in SCHEMAS.items()} } in the first row; got "
        f"keys {sorted(rows[0])})")


def check_row_coverage(base_rows: list[dict], fresh_rows: list[dict],
                       keys: tuple[str, ...], subset_ok: bool) -> list[str]:
    """Fresh rows must be gate-able and (unless subset_ok) cover the baseline."""
    base = set(_index(base_rows, keys))
    fresh = set(_index(fresh_rows, keys))
    errors = []
    for key in sorted(fresh - base, key=str):
        errors.append(f"fresh row {dict(zip(keys, key, strict=True))} is not in the "
                      f"baseline grid (stale baseline: the row would never "
                      f"be gated — regenerate the committed BENCH file)")
    if not subset_ok:
        for key in sorted(base - fresh, key=str):
            errors.append(f"baseline row {dict(zip(keys, key, strict=True))} is missing "
                          f"from the fresh results (pass --subset-ok only "
                          f"for smoke runs that measure a subset)")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly measured JSON")
    ap.add_argument("--wall-frac", type=float, default=0.25,
                    help="min fraction of the baseline wall_speedup (planner)")
    ap.add_argument("--rel-tol", type=float, default=1e-6,
                    help="relative tolerance for deterministic ratios")
    ap.add_argument("--subset-ok", action="store_true",
                    help="allow the fresh run to cover only a subset of the "
                         "baseline grid (smoke tiers)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)["rows"]
    with open(args.fresh) as f:
        fresh = json.load(f)["rows"]
    if not base or not fresh:
        print("# FAIL: baseline or fresh result has no rows", file=sys.stderr)
        sys.exit(1)
    base_schema = detect_schema(base, args.baseline)
    fresh_schema = detect_schema(fresh, args.fresh)
    if fresh_schema != base_schema:
        print(f"# FAIL: baseline/fresh schema mismatch ({args.baseline} is "
              f"a {base_schema} result, {args.fresh} a {fresh_schema} "
              f"result) — check the file arguments", file=sys.stderr)
        sys.exit(1)
    errors = check_row_coverage(base, fresh, SCHEMAS[fresh_schema][1],
                                args.subset_ok)
    if fresh_schema == "planner":
        more, matched = check_planner(base, fresh, args.wall_frac)
    elif fresh_schema == "sim":
        more, matched = check_sim(base, fresh, args.wall_frac)
    elif fresh_schema == "trace":
        more, matched = check_trace(base, fresh, args.rel_tol)
    elif fresh_schema == "online":
        more, matched = check_online(base, fresh, args.rel_tol,
                                     args.wall_frac)
    elif fresh_schema == "tenancy":
        more, matched = check_tenancy(base, fresh, args.rel_tol)
    elif fresh_schema == "faults":
        more, matched = check_faults(base, fresh, args.rel_tol)
    else:
        more, matched = check_fabric(base, fresh, args.rel_tol)
    errors += more
    if matched == 0:
        errors.append("no fresh row matches the baseline grid")
    if errors:
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"# OK: {matched} rows checked against {args.baseline}")


if __name__ == "__main__":
    main()
