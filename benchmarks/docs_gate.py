"""Docs gate: dead-link check + executable doc examples.

Two checks keep the documentation honest:

1. **Link check** — every relative markdown link in README.md and
   docs/*.md must resolve to an existing file (fragments are checked
   against the target file's headings when the target is markdown).
   External http(s)/mailto links are skipped (no network in CI).
2. **Doc examples** — every fenced ```python block in
   docs/batch_engine.md is executed, in order, in one shared namespace
   (doctest-style: the doc is effectively a script split by prose).  A
   block that raises fails the gate, so the examples cannot rot.  `bash`
   blocks are never executed — large-n / CLI examples belong there.
3. **Typed-enum call sites** — no first-party call site under src/ may
   pass a bare string constant as a ``fabric=`` or ``sharing=`` keyword
   argument (AST walk, not grep: docstrings and error messages are fine).
   Bare strings still coerce at runtime with a `DeprecationWarning`, but
   new first-party code must use `repro.planner.FabricKind` /
   `repro.planner.SharingMode` so the deprecation can actually land.

Usage:

    python -m benchmarks.docs_gate [--root DIR]

Exit 1 on any dead link or failing example.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
import traceback

# [text](target) — excludes images handled the same way on purpose, and
# skips autolinks/backticks.  Good enough for the repo's plain-markdown docs.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"^```(\w*)\s*$")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")

DOC_GLOBS = ("README.md", "docs")
EXEC_DOCS = ("docs/batch_engine.md",)


def _doc_files(root: str) -> list[str]:
    files = [os.path.join(root, "README.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def _anchor(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_~]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def _anchors(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return {_anchor(m.group(1)) for line in f
                if (m := _HEADING_RE.match(line))}


def check_links(root: str) -> list[str]:
    """Return one error string per dead relative link under README/docs."""
    errors: list[str] = []
    for path in _doc_files(root):
        rel = os.path.relpath(path, root)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        # strip fenced code blocks: link-looking text inside them is code
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            target, _, frag = target.partition("#")
            if not target:        # pure in-page anchor: check this file
                if frag and _anchor(frag) not in _anchors(path):
                    errors.append(f"{rel}: dead anchor #{frag}")
                continue
            dest = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(dest):
                errors.append(f"{rel}: dead link {target}")
            elif frag and dest.endswith(".md") \
                    and _anchor(frag) not in _anchors(dest):
                errors.append(f"{rel}: dead anchor {target}#{frag}")
    return errors


def python_blocks(path: str) -> list[tuple[int, str]]:
    """(first_line_number, source) for each fenced ```python block."""
    blocks, buf, start, lang = [], [], 0, None
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            m = _FENCE_RE.match(line)
            if m and lang is None:
                lang, start, buf = m.group(1), i + 1, []
            elif line.startswith("```") and lang is not None:
                if lang == "python":
                    blocks.append((start, "".join(buf)))
                lang = None
            elif lang is not None:
                buf.append(line)
    return blocks


def run_doc_examples(root: str) -> list[str]:
    """Execute every python block of each EXEC_DOCS file; return errors."""
    errors: list[str] = []
    for rel in EXEC_DOCS:
        path = os.path.join(root, rel)
        if not os.path.exists(path):
            errors.append(f"{rel}: executable doc missing")
            continue
        blocks = python_blocks(path)
        if not blocks:
            errors.append(f"{rel}: no fenced python blocks to execute")
            continue
        ns: dict = {"__name__": f"docs_gate::{rel}"}
        for lineno, src in blocks:
            try:
                exec(compile(src, f"{rel}:{lineno}", "exec"), ns)  # noqa: S102
            except Exception:
                errors.append(f"{rel} block at line {lineno} raised:\n"
                              f"{traceback.format_exc()}")
                break  # later blocks share the namespace; don't cascade
        print(f"# {rel}: {len(blocks)} python blocks executed")
    return errors


# keyword arguments that take a _CoercibleStrEnum; bare string constants at
# first-party call sites defeat the typed API the shim is deprecating toward
_ENUM_KWARGS = {"fabric": "repro.planner.FabricKind",
                "sharing": "repro.planner.SharingMode"}


def check_enum_kwargs(root: str) -> list[str]:
    """Flag bare string constants passed as fabric=/sharing= under src/."""
    errors: list[str] = []
    for dirpath, _, files in os.walk(os.path.join(root, "src")):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read(), filename=rel)
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                for kw in node.keywords:
                    if (kw.arg in _ENUM_KWARGS
                            and isinstance(kw.value, ast.Constant)
                            and isinstance(kw.value.value, str)):
                        errors.append(
                            f"{rel}:{kw.value.lineno}: bare string "
                            f"{kw.value.value!r} passed as {kw.arg}= "
                            f"(use {_ENUM_KWARGS[kw.arg]})")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."),
        help="repository root holding README.md and docs/")
    args = ap.parse_args(argv)
    root = os.path.abspath(args.root)

    errors = check_links(root)
    print(f"# link check: {len(_doc_files(root))} files, "
          f"{len(errors)} dead links")
    errors += run_doc_examples(root)
    enum_errors = check_enum_kwargs(root)
    print(f"# enum call-site check: {len(enum_errors)} bare fabric/sharing "
          f"strings under src/")
    errors += enum_errors

    if errors:
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    print("# OK: docs gate passed")


if __name__ == "__main__":
    main()
