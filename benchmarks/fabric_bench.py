"""Fabric-level benchmark: sparse vs full-pause vs analytic completion times.

Sweeps the n x r x delta grid and, at each point, runs the same periodic
BRIDGE schedule through three evaluators:

  - ``analytic``    : the Section 2 closed-form model (`collective_time`);
  - ``full-pause``  : the synchronized event simulator (global barrier per
                      sub-step, whole-fabric delta pause) — the legacy
                      `collective_time_event` semantics;
  - ``sparse``      : `FabricSim` — asynchronous per-link fabric, delta paid
                      only on circuits that change, per-node dependencies —
                      at overlap 0 and at the headline overlap credit.

Gates (exit 1 on violation; re-run in CI against the committed baseline by
`benchmarks.check_regression`):

  - the full-pause event/analytic ratio stays within ``--tol`` of 1 (the
    fluid-limit honesty check at benchmark scale);
  - sparse completion is <= full-pause completion at every grid point;
  - at ms-scale delta the overlap run hides at least half of the nominal
    overlap credit ``overlap * R * delta`` (the expected sparse margin).

Also records two scenario rows (straggler, skewed payloads) demonstrating
the per-link knobs; these are informational, not gated.

Run via ``make fabric-bench``; results land in BENCH_fabric_overlap.json.
"""
from __future__ import annotations

import argparse
import json
import sys

MB = 1024.0 ** 2
OVERLAP = 0.75


def bench_grid(ns=(8, 16, 32, 48, 96), radices=(2, 3),
               deltas=(10e-6, 1e-3, 15e-3), m: float = 4 * MB,
               chunks: int = 16, overlap: float = OVERLAP) -> list[dict]:
    from repro.core import PAPER_DEFAULT, FabricSim, collective_time, periodic
    from repro.core.bruck import schedule_length

    rows = []
    for n in ns:
        for r in radices:
            R = min(2, schedule_length("a2a", n, r) - 1)
            sched = periodic("a2a", n, R, r)
            for delta in deltas:
                cm = PAPER_DEFAULT.replace(delta=delta)
                analytic = collective_time(sched, m, cm).total
                full = FabricSim(chunks_per_msg=chunks,
                                 mode="full-pause").run(sched, m, cm)
                sparse = FabricSim(chunks_per_msg=chunks,
                                   mode="sparse").run(sched, m, cm)
                hidden = FabricSim(chunks_per_msg=chunks, mode="sparse",
                                   overlap=overlap).run(sched, m, cm)
                rows.append({
                    "n": n, "r": r, "delta": delta, "R": R,
                    "m_bytes": m, "chunks": chunks, "overlap": overlap,
                    "analytic_s": analytic,
                    "full_pause_s": full.completion,
                    "sparse_s": sparse.completion,
                    "sparse_overlap_s": hidden.completion,
                    "event_analytic_ratio": round(full.completion / analytic, 6),
                    "sparse_speedup": round(full.completion / hidden.completion, 6),
                    # overlap credit alone: sparse at overlap=0 minus sparse at
                    # the headline overlap (the full-pause vs sparse gap also
                    # contains barrier-removal savings, which are not credit)
                    "hidden_frac": round(
                        (sparse.completion - hidden.completion) / (R * delta), 6)
                    if R else 0.0,
                })
    return rows


def bench_scenarios(n: int = 32, m: float = 4 * MB, chunks: int = 16) -> list[dict]:
    """Per-link scenario knobs on the sparse fabric (informational)."""
    from repro.core import PAPER_DEFAULT, FabricSim, periodic, straggler_speeds

    cm = PAPER_DEFAULT.replace(delta=1e-3)
    sched = periodic("a2a", n, 2)
    base = FabricSim(chunks_per_msg=chunks).run(sched, m, cm).completion
    slow = FabricSim(chunks_per_msg=chunks,
                     link_speed=straggler_speeds(n, {n // 2: 0.25}))
    skew = [1.0] * n
    skew[0] = 4.0  # one hot destination receives 4x the payload
    skewed = FabricSim(chunks_per_msg=chunks, payload_scale=skew)
    return [
        {"scenario": "nominal", "n": n, "completion_s": base},
        {"scenario": "straggler(kappa=4)", "n": n,
         "completion_s": slow.run(sched, m, cm).completion},
        {"scenario": "skew(dest0=4x)", "n": n,
         "completion_s": skewed.run(sched, m, cm).completion},
    ]


def check_gates(rows: list[dict], tol: float, min_hidden: float) -> list[str]:
    errors = []
    for row in rows:
        key = f"n={row['n']} r={row['r']} delta={row['delta']}"
        ratio = row["event_analytic_ratio"]
        if not (1 - tol) <= ratio <= (1 + tol):
            errors.append(f"{key}: event/analytic ratio {ratio} outside "
                          f"[{1 - tol}, {1 + tol}]")
        if row["sparse_s"] > row["full_pause_s"] * (1 + 1e-9):
            errors.append(f"{key}: sparse {row['sparse_s']} > full-pause "
                          f"{row['full_pause_s']}")
        if row["R"] and row["delta"] >= 1e-3 and row["hidden_frac"] < min_hidden:
            errors.append(f"{key}: hidden_frac {row['hidden_frac']} < "
                          f"{min_hidden} (overlap credit not realized)")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (subset of the full grid so the "
                         "committed baseline still covers every row)")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="full-pause event/analytic ratio tolerance")
    ap.add_argument("--min-hidden", type=float, default=0.5 * OVERLAP,
                    help="min fraction of R*delta the overlap run must hide "
                         "at ms-scale delta")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = bench_grid(ns=(8, 32), radices=(2,), deltas=(10e-6, 15e-3))
    else:
        rows = bench_grid()
    scen = bench_scenarios()
    print("n,r,delta,R,analytic_s,full_pause_s,sparse_s,sparse_overlap_s,"
          "ratio,sparse_speedup,hidden_frac")
    for row in rows:
        print(f"{row['n']},{row['r']},{row['delta']},{row['R']},"
              f"{row['analytic_s']:.6e},{row['full_pause_s']:.6e},"
              f"{row['sparse_s']:.6e},{row['sparse_overlap_s']:.6e},"
              f"{row['event_analytic_ratio']},{row['sparse_speedup']},"
              f"{row['hidden_frac']}")
    for row in scen:
        print(f"# scenario {row['scenario']}: {row['completion_s']:.6e} s")
    errors = check_gates(rows, args.tol, args.min_hidden)
    if errors:
        # gate first: never overwrite the committed baseline with violating data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "sparse vs full-pause vs analytic completion over "
                        "the n x r x delta grid (FabricSim, "
                        "BENCH_fabric_overlap baseline)",
                "overlap": OVERLAP,
            },
            "rows": rows,
            "scenarios": scen,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
