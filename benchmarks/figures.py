"""Paper-figure reproductions: one function per table/figure.

Each returns a dict of results; benchmarks/run.py prints the CSV summary and
tests/test_paper_claims.py asserts the paper's headline claims against them.
All use the analytic step-level simulator (the paper's own Section 2 cost
model) — see DESIGN.md S8 for the Astra-Sim/ns-3 -> analytic mapping.
"""
from __future__ import annotations

import itertools
import time

from repro.core import (PAPER_DEFAULT, baselines, collective_time,
                        num_steps, plan)

KB, MB = 1024.0, 1024.0 ** 2
US, MS = 1e-6, 1e-3


def _bridge(kind, n, m, cm):
    return baselines.bridge(kind, n, m, cm, paper_faithful=True).total


def table1():
    """Table 1: reconfiguration schedules for n=64, R in {1,2}."""
    from repro.core import (ag_transmission_optimal, periodic_a2a,
                            rs_transmission_optimal)
    rows = {}
    for R in (1, 2):
        rows[f"a2a_R{R}"] = periodic_a2a(64, R).x
        rows[f"rs_R{R}"] = rs_transmission_optimal(64, R).x
        rows[f"ag_R{R}"] = ag_transmission_optimal(64, R).x
    return rows


def fig1():
    """Cumulative AllReduce cost: Bruck(+subrings) vs HD, R=0,1,2, delta=0."""
    n, m = 64, 4 * MB
    cm = PAPER_DEFAULT.replace(delta=0.0)
    out = {}
    for R in (0, 1, 2):
        hd = baselines.r_hd("ar", n, m, cm, R)
        br = baselines.bridge_allreduce_fixed_R(n, m, cm, R)
        out[f"hd_R{R}"] = hd.cumulative()
        out[f"bruck_R{R}"] = br.cumulative()
        out[f"final_hd_R{R}"] = hd.total
        out[f"final_bruck_R{R}"] = br.total
    return out


def fig2():
    """Static-ring completion-time split for RING vs BRUCK (AR and A2A)."""
    n = 64
    cm = PAPER_DEFAULT
    out = {}
    for m in (16 * KB, 1 * MB, 64 * MB):
        ring_ar = baselines.ring("ar", n, m, cm)
        bruck_ar = (baselines.s_bruck("rs", n, m, cm)
                    + baselines.s_bruck("ag", n, m, cm))
        bruck_a2a = baselines.s_bruck("a2a", n, m, cm)
        for name, t in (("ring_ar", ring_ar), ("bruck_ar", bruck_ar),
                        ("bruck_a2a", bruck_a2a)):
            out[f"{name}_m{int(m / KB)}KB"] = {
                "startup": t.startup, "hops": t.hop_latency,
                "transmission": t.transmission, "total": t.total}
    return out


def fig5(n=64):
    """A2A speedups over S-BRUCK (5a) and over min(S,G)-BRUCK (5b)."""
    cm0 = PAPER_DEFAULT
    msizes = [64 * KB, 1 * MB, 16 * MB, 128 * MB]
    deltas = [1 * US, 10 * US, 100 * US, 1 * MS, 5 * MS]
    grid_s, grid_both = {}, {}
    for m, d in itertools.product(msizes, deltas):
        cm = cm0.replace(delta=d)
        t_b = _bridge("a2a", n, m, cm)
        t_s = baselines.s_bruck("a2a", n, m, cm).total
        t_g = baselines.g_bruck("a2a", n, m, cm).total
        key = f"m{m / MB:g}MB_d{d / US:g}us"
        grid_s[key] = t_s / t_b
        grid_both[key] = min(t_s, t_g) / t_b
    return {"vs_sbruck": grid_s, "vs_best": grid_both}


def fig6(n=64):
    """A2A speedup vs per-hop delay (small and large messages)."""
    out = {}
    for m in (64 * KB, 16 * MB):
        for ah in (0.1 * US, 0.5 * US, 1 * US, 2 * US):
            for d in (10 * US, 1 * MS):
                cm = PAPER_DEFAULT.replace(alpha_h=ah, delta=d)
                t_b = _bridge("a2a", n, m, cm)
                t_s = baselines.s_bruck("a2a", n, m, cm).total
                t_g = baselines.g_bruck("a2a", n, m, cm).total
                key = f"m{m / MB:g}MB_ah{ah / US:g}us_d{d / US:g}us"
                out[key] = {"vs_sbruck": t_s / t_b,
                            "vs_best": min(t_s, t_g) / t_b}
    return out


def fig7():
    """A2A speedup over S-BRUCK for n in 16..256."""
    out = {}
    for n in (16, 32, 64, 128, 256):
        for m in (1 * MB, 32 * MB):
            for d in (10 * US, 1 * MS, 5 * MS):
                cm = PAPER_DEFAULT.replace(delta=d)
                t_b = _bridge("a2a", n, m, cm)
                t_s = baselines.s_bruck("a2a", n, m, cm).total
                out[f"n{n}_m{m / MB:g}MB_d{d / US:g}us"] = t_s / t_b
    return out


def fig8():
    """Full message range, n=64, RotorNet delta=10us: Bridge & G-Bruck vs S."""
    n = 64
    cm = PAPER_DEFAULT.replace(delta=10 * US)
    out = {"bridge_vs_s": {}, "gbruck_vs_s": {}, "bridge_vs_best": {}}
    m = 1 * KB
    while m <= 256 * MB:
        t_b = _bridge("a2a", n, m, cm)
        t_s = baselines.s_bruck("a2a", n, m, cm).total
        t_g = baselines.g_bruck("a2a", n, m, cm).total
        key = f"{m / KB:g}KB"
        out["bridge_vs_s"][key] = t_s / t_b
        out["gbruck_vs_s"][key] = t_s / t_g
        out["bridge_vs_best"][key] = min(t_s, t_g) / t_b
        m *= 2
    return out


def fig9(n=64):
    """Reduce-Scatter: Bridge vs RING and vs R-HD over message size."""
    out = {"vs_ring": {}, "vs_rhd": {}}
    for m in (16 * KB, 256 * KB, 1 * MB, 16 * MB, 64 * MB, 256 * MB):
        for d in (1 * US, 10 * US, 150 * US):
            cm = PAPER_DEFAULT.replace(delta=d)
            t_b = _bridge("rs", n, m, cm)
            t_ring = baselines.ring("rs", n, m, cm).total
            t_rhd, _ = baselines.r_hd_optimal("rs", n, m, cm)
            key = f"m{m / KB:g}KB_d{d / US:g}us"
            out["vs_ring"][key] = t_ring / t_b
            out["vs_rhd"][key] = t_rhd.total / t_b
    return out


def fig10(n=64):
    """RS speedup vs per-hop delay."""
    out = {}
    for m in (256 * KB, 16 * MB):
        for ah in (0.1 * US, 1 * US, 2 * US):
            for d in (10 * US, 150 * US):
                cm = PAPER_DEFAULT.replace(alpha_h=ah, delta=d)
                t_b = _bridge("rs", n, m, cm)
                t_ring = baselines.ring("rs", n, m, cm).total
                t_rhd, _ = baselines.r_hd_optimal("rs", n, m, cm)
                out[f"m{m / KB:g}KB_ah{ah / US:g}us_d{d / US:g}us"] = {
                    "vs_ring": t_ring / t_b, "vs_rhd": t_rhd.total / t_b}
    return out


def fig11():
    """RS speedup vs network size against the best static baseline."""
    out = {}
    for n in (16, 32, 64, 128, 256):
        for m in (16 * KB, 256 * KB, 32 * MB):
            for d in (1 * US, 10 * US, 1 * MS):
                cm = PAPER_DEFAULT.replace(delta=d)
                t_b = _bridge("rs", n, m, cm)
                t_static = min(baselines.ring("rs", n, m, cm).total,
                               baselines.s_bruck("rs", n, m, cm).total)
                out[f"n{n}_m{m / KB:g}KB_d{d / US:g}us"] = t_static / t_b
    return out


def fig12(n=64):
    """All approaches vs RING, delta=10us, alpha_h=1us (AllReduce=RS here)."""
    cm = PAPER_DEFAULT.replace(delta=10 * US)
    out = {"bridge": {}, "rhd": {}, "sbruck": {}, "gbruck": {},
           "bridge_vs_best": {}}
    m = 16 * KB
    while m <= 256 * MB:
        t_ring = baselines.ring("rs", n, m, cm).total
        t_b = _bridge("rs", n, m, cm)
        t_rhd, _ = baselines.r_hd_optimal("rs", n, m, cm)
        t_s = baselines.s_bruck("rs", n, m, cm).total
        t_g = baselines.g_bruck("rs", n, m, cm).total
        key = f"{m / KB:g}KB"
        out["bridge"][key] = t_ring / t_b
        out["rhd"][key] = t_ring / t_rhd.total
        out["sbruck"][key] = t_ring / t_s
        out["gbruck"][key] = t_ring / t_g
        out["bridge_vs_best"][key] = min(t_ring, t_rhd.total, t_s, t_g) / t_b
        m *= 4
    return out


def scheduler_runtime():
    """Paper 3.4: optimal schedules computed 'within milliseconds' (n<=256)."""
    t0 = time.perf_counter()
    for n in (16, 32, 64, 128, 256):
        for kind in ("a2a", "rs", "ag"):
            plan(kind, n, 4 * MB, PAPER_DEFAULT, paper_faithful=True)
    dt = time.perf_counter() - t0
    return {"total_seconds": dt, "per_plan_ms": dt / 15 * 1e3}


def ports_extension():
    """Section 3.7: blocked rings with z < 2n ports still benefit at scale."""
    out = {}
    for n, z in ((256, 512), (256, 128), (256, 64), (64, 32)):
        cm = PAPER_DEFAULT
        m = 8 * MB
        from repro.core import periodic_a2a, static_schedule
        t_static = collective_time(static_schedule("a2a", n), m, cm).total
        best = min(collective_time(periodic_a2a(n, R), m, cm, ports=z).total
                   for R in range(num_steps(n)))
        out[f"n{n}_z{z}"] = t_static / best
    return out
