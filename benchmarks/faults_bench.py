"""Fault-recovery benchmark: resume-from-snapshot vs restart-from-scratch.

Grids `repro.workloads.run_with_recovery` over fault kind x n x delta x
failure time on the mixed workload trace.  Each row injects one fault at
``fail_frac`` of the clean run's completion, lets the engine surface the
`DegradedState` (committed-prefix snapshot, surviving world, in-flight chunk
fate), re-plans the remaining events at the surviving world size, and
compares:

  - ``recovery_total_s`` : resume clock + executed remaining-stream
                           completion at n' (resume from the snapshot);
  - ``restart_total_s``  : resume clock + the *whole* trace re-planned and
                           re-run at n' (the no-recovery baseline).

Delivery policy is exercised both ways: link-flap rows re-queue their
in-flight chunks, every other kind drops them.

Gates (exit 1 on violation; re-checked in CI against the committed baseline
by `benchmarks.check_regression`):

  - ``recovery_ratio <= 1`` on every row — resuming from the committed
    prefix never loses to restarting the whole trace (equality only when
    the fault struck before anything committed);
  - ``bit_identical`` on every row — the recovered schedules and executed
    completion exactly match a clean run of the reduced trace at n'
    (the ``fault/replan`` verifier rule, re-derived here end to end);
  - every row already passed the full ``fault/*`` verifier rules inside
    `run_with_recovery(verify=True)` — a violation raises before any JSON
    is written.

Run via ``make faults-bench``; results land in BENCH_faults.json.
"""
from __future__ import annotations

import argparse
import json
import sys

KINDS = ("link-down", "link-flap", "node-leave", "node-join")
NS = (12, 16)
DELTAS = (10e-6, 1e-3)
FAIL_FRACS = (0.25, 0.5, 0.75)
CHUNKS_PER_MSG = 8


def make_trace(n: int):
    from repro.workloads import mixed_trace

    return mixed_trace(n, moe_layers=1, train_steps=1, decode_steps=3)


def recovery_for(kind: str, n: int, delta: float, fail_frac: float, *,
                 verify: bool = True):
    """One full fault-recovery cycle for a grid point.

    Returns ``(RecoveryResult, FaultTimeline)``.  Also used by
    `benchmarks.verify_gate.audit_faults` to re-derive the committed
    baseline rows independently (with ``verify=False`` there, since the
    gate runs the ``fault/*`` rules itself and reports the findings).
    """
    from repro.core import PAPER_DEFAULT, FabricSim
    from repro.core.faults import FaultSpec, FaultTimeline
    from repro.workloads import plan_trace, run_with_recovery

    cm = PAPER_DEFAULT.replace(delta=delta)
    trace = make_trace(n)
    plan = plan_trace(trace, cm, mode="carryover")
    clean = FabricSim(mode="sparse", chunks_per_msg=CHUNKS_PER_MSG).run_trace(
        plan.fabric_phases(), cm)
    fault_time = fail_frac * clean.completion
    node = n if kind == "node-join" else n // 3
    repair = 0.05 * clean.completion if kind == "link-flap" else 0.0
    policy = "requeue" if kind == "link-flap" else "drop"
    faults = FaultTimeline(n=n, faults=(
        FaultSpec(kind=kind, time=fault_time, node=node, repair_s=repair),),
        policy=policy)
    faults.check_horizon(clean.completion)
    rr = run_with_recovery(trace, cm, faults=faults,
                           chunks_per_msg=CHUNKS_PER_MSG, verify=verify)
    return rr, faults


def bench_row(kind: str, n: int, delta: float, fail_frac: float) -> dict:
    """One fault-recovery cycle -> one benchmark row."""
    rr, faults = recovery_for(kind, n, delta, fail_frac)
    ds = rr.degraded
    return {
        "trace": "mixed", "kind": kind, "n": n, "delta": delta,
        "fail_frac": fail_frac, "policy": faults.policy,
        "fault_time_s": faults.faults[0].time,
        "completed_phases": ds.completed_phases,
        "committed_events": len(rr.committed_events),
        "new_n": ds.new_n,
        "committed_chunks": ds.committed_chunks,
        "lost_chunks": ds.lost_chunks,
        "requeued_chunks": ds.requeued_chunks,
        "recovery_total_s": rr.recovery_total,
        "restart_total_s": rr.restart_total,
        "recovery_ratio": round(rr.recovery_ratio, 6),
        "bit_identical": rr.bit_identical,
        "mispredictions": rr.stats.mispredictions,
    }


def bench_grid(kinds=KINDS, ns=NS, deltas=DELTAS,
               fail_fracs=FAIL_FRACS) -> list[dict]:
    return [bench_row(kind, n, delta, frac)
            for kind in kinds for n in ns for delta in deltas
            for frac in fail_fracs]


def check_gates(rows: list[dict]) -> list[str]:
    errors = []
    for row in rows:
        key = (f"kind={row['kind']} n={row['n']} delta={row['delta']} "
               f"frac={row['fail_frac']}")
        if row["recovery_ratio"] > 1 + 1e-9:
            errors.append(
                f"{key}: recovery ratio {row['recovery_ratio']} > 1 — "
                f"resuming from the snapshot lost to a full restart")
        if not row["bit_identical"]:
            errors.append(
                f"{key}: recovered result is not bit-identical to a clean "
                f"run of the reduced trace at n'={row['new_n']}")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="all four kinds at one mid-trace grid point (subset "
                         "of the full grid so the committed baseline still "
                         "covers every row)")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = bench_grid(ns=(12,), deltas=(1e-3,), fail_fracs=(0.5,))
    else:
        rows = bench_grid()
    print("kind,n,delta,fail_frac,completed_phases,new_n,"
          "recovery_total_s,restart_total_s,recovery_ratio,bit_identical")
    for row in rows:
        print(f"{row['kind']},{row['n']},{row['delta']},{row['fail_frac']},"
              f"{row['completed_phases']},{row['new_n']},"
              f"{row['recovery_total_s']:.6e},{row['restart_total_s']:.6e},"
              f"{row['recovery_ratio']},{row['bit_identical']}")
    errors = check_gates(rows)
    if errors:
        # gate first: never overwrite the committed baseline with violating data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "fault-recovery cycle over kind x n x delta x "
                        "failure time on the mixed trace: resume-from-"
                        "snapshot vs restart-from-scratch totals, chunk "
                        "fate, and bit-identity vs a clean reduced-world "
                        "run (repro.workloads.recovery, BENCH_faults "
                        "baseline)",
                "chunks_per_msg": CHUNKS_PER_MSG,
            },
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
