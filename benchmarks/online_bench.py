"""Online receding-horizon planning benchmark: regret vs W, serving throughput.

Sweeps workload traces over the n x delta x window grid and, at each point,
plans the stream three ways:

  - ``offline``  : the full joint DP (`plan_trace` mode='carryover') — sees
                   the whole stream, the regret reference;
  - ``online-W`` : `run_online` — a receding-horizon window of W events,
                   the window DP warm-started at the committed fabric state,
                   commit-one-advance (W = stream length recovers offline
                   exactly);
  - ``cold``     : per-event planning with full-fabric boundary swaps
                   (`plan_trace` mode='cold') — what serving without
                   carryover state costs.

Each n also gets one serving-throughput row (``trace='storm'``): a seeded
request storm (`repro.workloads.request_storm`) fired twice at a
`PlanService` — once cold (cache misses fall through to the window DP) and
once hot (repeated windows served from the LRU) — recording plans/sec for
both tiers, hit accounting, and the deterministic plan-sequence signature.

Gates (exit 1 on violation; re-checked in CI against the committed baseline
by `benchmarks.check_regression`):

  - online-W never beats the offline DP (offline sees a superset of every
    window's information);
  - online-W stays within ``--max-regret`` of offline on every W >= 2 grid
    row — the receding horizon is a bounded-regret approximation, not a
    gamble; the greedy W=1 ablation (no lookahead: it commits the locally
    cheapest schedule and can strand the fabric in a state the next event
    pays for) gets the looser ``--max-regret-greedy`` bound (measured worst
    case 1.18x at n=48, delta=1ms);
  - at ms-scale delta, online-W strictly beats cold per-event planning for
    W >= 2 (carrying fabric state across boundaries is what the online
    planner exists for);
  - the hot (cache-hit) serving path sustains at least
    ``--min-plans-per-sec`` and a >= 0.9 hit rate.

Run via ``make online-bench``; results land in BENCH_online.json.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks.trace_bench import DELTAS, TRACES, make_trace

WINDOWS = (1, 2, 4, 8)
#: serving-storm shape — identical in smoke and full runs so the hit
#: accounting and plan-sequence signature stay baseline-comparable
STORM_WINDOW = 3
STORM_REQUESTS = 256


def bench_grid(trace_names=TRACES, ns=(16, 48), deltas=DELTAS,
               windows=WINDOWS) -> list[dict]:
    from repro.core import PAPER_DEFAULT
    from repro.workloads import plan_trace, run_online

    rows = []
    for name in trace_names:
        for n in ns:
            trace = make_trace(name, n)
            for delta in deltas:
                cm = PAPER_DEFAULT.replace(delta=delta)
                offline = plan_trace(trace, cm, mode="carryover")
                cold = plan_trace(trace, cm, mode="cold")
                for window in windows:
                    online, stats = run_online(trace, cm, window=window)
                    rows.append({
                        "trace": name, "n": n, "delta": delta,
                        "window": window, "events": len(trace),
                        "phases": len(online.phases),
                        "online_s": online.total_time,
                        "offline_s": offline.total_time,
                        "cold_event_s": cold.total_time,
                        "online_vs_offline": round(
                            online.total_time / offline.total_time, 6),
                        "cold_vs_online": round(
                            cold.total_time / online.total_time, 6),
                        "replans": stats.replans,
                        "plan_reuses": stats.plan_reuses,
                        "free_boundaries": online.free_boundaries,
                        "paid_reconfigs": online.paid_reconfigs,
                    })
    return rows


def bench_storm(ns=(16, 48)) -> list[dict]:
    from repro.core import PAPER_DEFAULT
    from repro.workloads import PlanService, build_request_pool, request_storm

    rows = []
    for n in ns:
        pool = build_request_pool(n, window=STORM_WINDOW, seed=0)
        service = PlanService()
        cold = request_storm(service, pool, requests=STORM_REQUESTS, seed=1)
        hot = request_storm(service, pool, requests=STORM_REQUESTS, seed=2)
        rows.append({
            "trace": "storm", "n": n, "delta": PAPER_DEFAULT.delta,
            "window": STORM_WINDOW, "pool": len(pool),
            "requests": STORM_REQUESTS,
            "cold_hits": cold.hits, "cold_misses": cold.misses,
            "hot_hits": hot.hits, "hot_misses": hot.misses,
            "hot_hit_rate": round(hot.hit_rate, 6),
            "cold_plans_per_sec": round(cold.plans_per_sec, 1),
            "hot_plans_per_sec": round(hot.plans_per_sec, 1),
            "unique_windows": cold.unique_windows,
            "signature": hot.signature,
        })
    return rows


def check_gates(rows: list[dict], max_regret: float, max_regret_greedy: float,
                min_plans_per_sec: float) -> list[str]:
    errors = []
    for row in rows:
        if row["trace"] == "storm":
            key = f"storm n={row['n']}"
            if row["hot_plans_per_sec"] < min_plans_per_sec:
                errors.append(
                    f"{key}: hot serving path {row['hot_plans_per_sec']} "
                    f"plans/s < floor {min_plans_per_sec}")
            if row["hot_hit_rate"] < 0.9:
                errors.append(f"{key}: hot hit rate {row['hot_hit_rate']} "
                              f"< 0.9 (LRU is not serving repeated windows)")
            continue
        key = (f"trace={row['trace']} n={row['n']} delta={row['delta']} "
               f"W={row['window']}")
        if row["online_s"] < row["offline_s"] * (1 - 1e-9):
            errors.append(f"{key}: online {row['online_s']} beats the "
                          f"offline DP {row['offline_s']} (offline sees "
                          f"strictly more — the DP is broken)")
        bound = max_regret if row["window"] >= 2 else max_regret_greedy
        if row["online_s"] > row["offline_s"] * bound:
            errors.append(f"{key}: online {row['online_s']} > "
                          f"{bound}x offline {row['offline_s']}")
        if row["delta"] >= 1e-3 and row["window"] >= 2 \
                and row["cold_event_s"] <= row["online_s"] * (1 + 1e-9):
            errors.append(f"{key}: online {row['online_s']} does not beat "
                          f"cold per-event {row['cold_event_s']} at "
                          f"ms-scale delta")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (subset of the full grid so the "
                         "committed baseline still covers every row)")
    ap.add_argument("--max-regret", type=float, default=1.10,
                    help="max online/offline total-time ratio allowed on "
                         "W >= 2 grid rows (measured: W >= 2 is exact on "
                         "every grid trace)")
    ap.add_argument("--max-regret-greedy", type=float, default=1.25,
                    help="max online/offline ratio for the no-lookahead W=1 "
                         "ablation (measured worst case 1.18x on the moe/"
                         "mixed traces at n=48, delta=1ms)")
    ap.add_argument("--min-plans-per-sec", type=float, default=2000.0,
                    help="floor for the cache-hit serving path (measured "
                         ">= 50k/s locally; the floor only catches "
                         "order-of-magnitude serving regressions)")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = bench_grid(trace_names=("decode", "mixed"), ns=(16,),
                          deltas=(10e-6, 15e-3), windows=(2, 4))
        rows += bench_storm(ns=(16,))
    else:
        rows = bench_grid()
        rows += bench_storm()
    print("trace,n,delta,window,online_s,offline_s,online_vs_offline,"
          "cold_vs_online,replans/reuses")
    for row in rows:
        if row["trace"] == "storm":
            print(f"storm,{row['n']},-,{row['window']},"
                  f"hot={row['hot_plans_per_sec']}/s,"
                  f"cold={row['cold_plans_per_sec']}/s,"
                  f"hit_rate={row['hot_hit_rate']},-,-")
            continue
        print(f"{row['trace']},{row['n']},{row['delta']},{row['window']},"
              f"{row['online_s']:.6e},{row['offline_s']:.6e},"
              f"{row['online_vs_offline']},{row['cold_vs_online']},"
              f"{row['replans']}/{row['plan_reuses']}")
    errors = check_gates(rows, args.max_regret, args.max_regret_greedy,
                         args.min_plans_per_sec)
    if errors:
        # gate first: never overwrite the committed baseline with violating data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "online receding-horizon planning vs offline DP vs "
                        "cold per-event over traces x n x delta x window, "
                        "plus plan-serving storm throughput "
                        "(repro.workloads.online_planner / serve, "
                        "BENCH_online baseline)",
                "max_regret": args.max_regret,
                "max_regret_greedy": args.max_regret_greedy,
                "min_plans_per_sec": args.min_plans_per_sec,
            },
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
