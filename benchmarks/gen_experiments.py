"""Generate the data-driven sections of EXPERIMENTS.md from results/.

Usage: PYTHONPATH=src python -m benchmarks.gen_experiments > results/exp_tables.md
"""
from __future__ import annotations

import glob
import json
import os

from .roofline import derive, load_cells


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(cells):
    hdr = ("| arch | shape | mesh | compile s | HLO flops/dev | arg bytes/dev "
           "| temp bytes/dev | collective bytes/dev (AG/AR/RS/A2A/CP) |\n"
           + "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if "error" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                        f"| FAIL | - | - | - | {c['error'][:60]} |")
            continue
        mem = c.get("memory") or {}
        coll = c["collectives"]
        parts = "/".join(fmt_bytes(coll[k]["bytes"]) for k in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute"))
        cal = c.get("calibrated") or {}
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compile_seconds']} | {cal.get('flops', c['flops']):.3g} "
            f"| {fmt_bytes(mem.get('argument_size_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_bytes'))} "
            f"| {parts} |")
    return hdr + "\n".join(rows) + "\n"


def skip_table():
    from repro import configs
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    for a, s in configs.cells():
        ok, why = configs.runnable(a, s)
        if not ok:
            rows.append(f"| {a} | {s} | {why} |")
    return "\n".join(rows) + "\n"


def perf_pairs(base_dir="results/dryrun", opt_dir="results/dryrun_opt"):
    """Before/after table for every optimized variant that has a baseline."""
    base = {(c["arch"], c["shape"], c["mesh"]): c
            for c in load_cells(base_dir) if "error" not in c}
    rows = ["| cell | variant | term | before s | after s | delta |",
            "|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(opt_dir, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if "error" in c:
            continue
        key = (c["arch"], c["shape"], c["mesh"])
        if key not in base:
            continue
        b = derive(base[key])
        o = derive(c)
        for term in ("compute", "memory", "collective"):
            tb, to = b["terms_s"][term], o["terms_s"][term]
            if tb == 0 and to == 0:
                continue
            delta = (to - tb) / tb * 100 if tb else float("inf")
            mark = " **<-**" if term == b["dominant"] else ""
            rows.append(
                f"| {c['arch']} x {c['shape']} x {c['mesh']} "
                f"| {c.get('variant', '?')} | {term}{mark} "
                f"| {tb:.3e} | {to:.3e} | {delta:+.1f}% |")
    return "\n".join(rows) + "\n"


def main():
    cells = load_cells()
    print("## Dry-run record (generated)\n")
    print(dryrun_table(cells))
    print("\n### Skipped cells\n")
    print(skip_table())
    print("\n## Roofline (generated)\n")
    rows = [d for c in cells if (d := derive(c))]
    from .roofline import markdown_table
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(markdown_table(rows))
    if os.path.isdir("results/dryrun_opt"):
        print("\n## Perf before/after (generated)\n")
        print(perf_pairs())


if __name__ == "__main__":
    main()
