"""Cross-collective trace benchmark: carryover vs cold-fabric vs static.

Sweeps workload traces (MoE a2a streams, bucketed gradient AR, decode AG
bursts, and the mixed stream) over the n x delta grid and, at each point,
plans the whole trace three ways (`repro.workloads.plan_trace`):

  - ``static``    : every collective runs the R=0 ring schedule, the fabric
                    never reconfigures;
  - ``cold``      : today's per-collective planning — every boundary
                    re-establishes the next collective's initial topology
                    with a full-fabric swap;
  - ``carryover`` : the joint DP — the fabric state left by collective i is
                    the starting topology of collective i+1, boundaries pay
                    delta only on circuits that actually change.

Each row also plays the carryover plan through the batched fabric engine
(`FabricSim(mode='batched').run_trace`) and records the cold plan's
full-pause sum-of-independents execution for reference.

Gates (exit 1 on violation; re-checked in CI against the committed baseline
by `benchmarks.check_regression`):

  - carryover <= cold-fabric at every grid point (the joint DP's candidate
    set contains every cold choice with never-larger boundary charges);
  - carryover <= static at every grid point (static is a candidate);
  - at ms-scale delta the amortization win cold/carryover is at least
    ``--min-win`` (boundary reconfigurations dominate there and carryover
    aligns or reuses them).

Run via ``make trace-bench``; results land in BENCH_trace.json.
"""
from __future__ import annotations

import argparse
import json
import sys

DELTAS = (10e-6, 1e-3, 15e-3)
TRACES = ("moe", "train", "decode", "mixed")


def make_trace(name: str, n: int, seed: int = 0):
    from repro.workloads import (decode_ag_trace, mixed_trace, moe_a2a_trace,
                                 train_step_trace)

    return {
        "moe": lambda: moe_a2a_trace(n, layers=3, seed=seed),
        "train": lambda: train_step_trace(n, steps=2, buckets=2, seed=seed),
        "decode": lambda: decode_ag_trace(n, decode_steps=6, seed=seed,
                                          jitter=0.25),
        "mixed": lambda: mixed_trace(n, seed=seed),
    }[name]()


def bench_grid(trace_names=TRACES, ns=(16, 48), deltas=DELTAS,
               chunks: int = 4) -> list[dict]:
    from repro.core import PAPER_DEFAULT, FabricSim
    from repro.workloads import plan_trace

    rows = []
    for name in trace_names:
        for n in ns:
            trace = make_trace(name, n)
            for delta in deltas:
                cm = PAPER_DEFAULT.replace(delta=delta)
                static = plan_trace(trace, cm, mode="static")
                cold = plan_trace(trace, cm, mode="cold")
                carry = plan_trace(trace, cm, mode="carryover")
                sim = FabricSim(chunks_per_msg=chunks, mode="batched")
                exec_carry = sim.run_trace(carry.fabric_phases(), cm)
                base = FabricSim(chunks_per_msg=chunks, mode="full-pause")
                exec_cold = base.run_trace(cold.fabric_phases(), cm)
                rows.append({
                    "trace": name, "n": n, "delta": delta,
                    "events": len(trace), "phases": len(carry.phases),
                    "total_mb": round(trace.total_bytes() / 1024.0 ** 2, 3),
                    "static_s": static.total_time,
                    "cold_fabric_s": cold.total_time,
                    "carryover_s": carry.total_time,
                    "carryover_vs_cold": round(
                        cold.total_time / carry.total_time, 6),
                    "carryover_vs_static": round(
                        static.total_time / carry.total_time, 6),
                    "free_boundaries": carry.free_boundaries,
                    "boundaries": len(carry.boundary_cost),
                    "carry_paid_reconfigs": carry.paid_reconfigs,
                    # event-level execution (reference: batched sparse fabric
                    # for the carryover plan; legacy sum-of-independents
                    # full-pause for the cold plan)
                    "exec_carry_sparse_s": exec_carry.completion,
                    "exec_cold_fullpause_s": exec_cold.completion,
                })
    return rows


def check_gates(rows: list[dict], min_win: float) -> list[str]:
    errors = []
    for row in rows:
        key = f"trace={row['trace']} n={row['n']} delta={row['delta']}"
        if row["carryover_s"] > row["cold_fabric_s"] * (1 + 1e-9):
            errors.append(f"{key}: carryover {row['carryover_s']} > "
                          f"cold-fabric {row['cold_fabric_s']}")
        if row["carryover_s"] > row["static_s"] * (1 + 1e-9):
            errors.append(f"{key}: carryover {row['carryover_s']} > "
                          f"static {row['static_s']}")
        if row["delta"] >= 1e-3 and row["carryover_vs_cold"] < min_win:
            errors.append(f"{key}: amortization win {row['carryover_vs_cold']}"
                          f" < {min_win} at ms-scale delta")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (subset of the full grid so the "
                         "committed baseline still covers every row)")
    ap.add_argument("--min-win", type=float, default=1.15,
                    help="min cold/carryover ratio required at delta >= 1 ms "
                         "(measured floor 1.18x on the payload-dominated MoE "
                         "trace at n=48; every other row is >= 1.9x)")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = bench_grid(trace_names=("decode", "mixed"), ns=(16,),
                          deltas=(10e-6, 15e-3))
    else:
        rows = bench_grid()
    print("trace,n,delta,phases,static_s,cold_fabric_s,carryover_s,"
          "win_vs_cold,free_boundaries/boundaries")
    for row in rows:
        print(f"{row['trace']},{row['n']},{row['delta']},{row['phases']},"
              f"{row['static_s']:.6e},{row['cold_fabric_s']:.6e},"
              f"{row['carryover_s']:.6e},{row['carryover_vs_cold']},"
              f"{row['free_boundaries']}/{row['boundaries']}")
    errors = check_gates(rows, args.min_win)
    if errors:
        # gate first: never overwrite the committed baseline with violating data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "cross-collective trace planning: carryover vs "
                        "cold-fabric vs static over workload traces x n x "
                        "delta (repro.workloads, BENCH_trace baseline)",
                "min_win": args.min_win,
            },
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
