"""Batch fabric engine benchmark: scalar sparse loop vs vectorized playback.

Four measurement tiers plus the plan-serving path:

  - ``scoring`` tier (n = 96): the planner's event-scoring workload — a
    30+-candidate set (every deduped periodic / rs-early / ag-late /
    exact-dp schedule for all three collectives at one n) completion-timed
    once by the scalar per-chunk `FabricSim` loop and once by a single
    `batchsim.batch_run` call.  Gates (exit 1): batched >= ``--min-speedup``
    x faster, every lane on the vectorized fast path, every lane statically
    certified (`repro.analysis.certifier` — the row reports the certified
    fraction), certified playback no slower than the guard-based
    ``certify=False`` path, and completions equal to the scalar loop within
    1e-9 relative.
  - ``scale`` tier (n in {768, 1536}): batched-only — the scalar engine is
    not run at all at this scale (it would take minutes per grid point);
    the row records wall time and a completion checksum so regressions in
    the engine itself are caught by `benchmarks.check_regression`.
  - ``jax`` tier (n = 1536, 256 lanes): the JAX ``jit``/``vmap`` backend
    (`core.batchsim_jax`) vs the NumPy batch engine on a wide hop-capped
    certified lane set.  Gates: jax >= ``--min-jax-speedup`` x faster than
    NumPy (warm, after the one-off XLA compile the row also records), every
    completion within 1e-6 relative of the NumPy engine, and playback
    bit-stable across runs.
  - ``jax-scale`` tier (n in {8192, 32768}): JAX-only — grids the NumPy
    batch engine never runs (its per-hop dispatch alone would take minutes
    per batch); rows record wall time, bit-stability, and a completion
    checksum.
  - plan-cache serving: repeated `PlanRequest` traffic through one
    `Planner`, recording hit/miss counts and cold vs cached plan latency.

Run via ``make sim-bench``; results land in BENCH_sim_scale.json.  The CI
bench job runs ``--smoke`` (scoring + jax tiers) against the committed
baseline; the nightly workflow runs the full grid including the n >= 768
and n >= 8192 tiers.  docs/batch_engine.md turns these rows into the
backend performance model.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

MB = 1024.0 ** 2
DELTA = 1e-3


def _candidate_lanes(n: int, m: float, max_lanes: int | None = None):
    """Deduped all-kind candidate schedules at one n (shared S => one batch)."""
    from repro.core import PAPER_DEFAULT
    from repro.core import schedules as S
    from repro.core.batchsim import BatchLane

    seen, lanes = set(), []
    for kind in ("a2a", "rs", "ag"):
        for _, sched in S.candidate_schedules(kind, n, m, PAPER_DEFAULT):
            key = (sched.kind, sched.x)
            if key in seen:
                continue
            seen.add(key)
            lanes.append(BatchLane(schedule=sched, m_bytes=m))
    return lanes[:max_lanes] if max_lanes else lanes


def _jax_lanes(n: int, m: float, lanes_target: int = 256,
               hop_cap: int = 300):
    """Wide certified lane set for the jax tiers (deterministic).

    Serving-shaped workload: the deduped candidate set at one n, capped at
    ``hop_cap`` total hops per schedule (the near-static tail of the
    candidate set costs both engines minutes without changing the
    comparison), tiled with a 1% payload ramp out to ``lanes_target`` lanes.
    All lanes are uniform, so under the paper regime all are certified —
    exactly the population the JAX backend exists for.
    `benchmarks.verify_gate` reconstructs these lanes from the committed row
    (lanes / hop_cap) to re-audit their schedules.
    """
    from repro.core.batchsim import BatchLane, compile_tape

    base = [lane for lane in _candidate_lanes(n, m)
            if sum(compile_tape(lane.schedule).hops) <= hop_cap]
    if not base:
        raise ValueError(f"hop_cap={hop_cap} filtered out every candidate "
                         f"schedule at n={n}")
    lanes, rep = [], 0
    while len(lanes) < lanes_target:
        for lane in base:
            lanes.append(BatchLane(schedule=lane.schedule,
                                   m_bytes=m * (1.0 + 0.01 * rep)))
        rep += 1
    return lanes[:lanes_target]


def bench_jax(n: int = 1536, m: float = 4 * MB, chunks: int = 4,
              lanes_target: int = 256, hop_cap: int = 300) -> dict:
    """JAX vs NumPy batch engine on one wide certified batch."""
    from repro.core import PAPER_DEFAULT
    from repro.core.batchsim import batch_run
    from repro.core.batchsim_jax import compile_stats

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    lanes = _jax_lanes(n, m, lanes_target=lanes_target, hop_cap=hop_cap)

    def run(backend):
        t0 = time.perf_counter()
        res = batch_run(lanes, cm, chunks_per_msg=chunks, backend=backend)
        return res, time.perf_counter() - t0

    # warm the shared memoized layers (tapes, certificates) on a sliver so
    # neither timed engine is charged the other's cold-cache work; the XLA
    # compile itself is deliberately NOT warmed — jax_cold_wall_s records it
    batch_run(lanes[:2], cm, chunks_per_msg=chunks)
    traces0 = compile_stats()["trace_count"]
    res_np, numpy_wall = run("numpy")
    res_cold, jax_cold_wall = run("jax")      # includes per-bucket XLA compile
    res_jax, jax_wall = run("jax")            # steady state
    res_jax2, _ = run("jax")                  # run-to-run determinism probe
    import numpy as np
    worst_rel = float(np.max(np.abs(res_jax.completion - res_np.completion)
                             / np.maximum(np.abs(res_np.completion), 1e-30)))
    bit_stable = (np.array_equal(res_cold.node_done, res_jax.node_done)
                  and np.array_equal(res_jax.node_done, res_jax2.node_done)
                  and np.array_equal(res_jax.step_done, res_jax2.step_done))
    return {
        "tier": "jax", "n": n, "r": 2, "m_bytes": m, "chunks": chunks,
        "delta": DELTA, "lanes": len(lanes), "hop_cap": hop_cap,
        "backend": res_jax.backend,
        "numpy_wall_s": round(numpy_wall, 4),
        "jax_cold_wall_s": round(jax_cold_wall, 4),
        "jax_wall_s": round(jax_wall, 4),
        "jax_compiles": compile_stats()["trace_count"] - traces0,
        "jax_speedup": round(numpy_wall / max(jax_wall, 1e-9), 2),
        "fast_lanes": int(res_jax.fast_path.sum()),
        "certified_lanes": int(res_jax.certified.sum()),
        "worst_rel_diff": float(f"{worst_rel:.3e}"),
        "bit_stable": bool(bit_stable),
        "completion_checksum": float(res_jax.completion.sum()),
    }


def bench_jax_scale(n: int, m: float = 4 * MB, chunks: int = 2,
                    lanes_target: int = 64, hop_cap: int = 400) -> dict:
    """JAX-only: grids the NumPy batch engine never runs."""
    from repro.core import PAPER_DEFAULT
    from repro.core.batchsim import batch_run, clear_tape_caches

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    lanes = _jax_lanes(n, m, lanes_target=lanes_target, hop_cap=hop_cap)
    clear_tape_caches()  # first contact at this scale: include tape compile
    t0 = time.perf_counter()
    res = batch_run(lanes, cm, chunks_per_msg=chunks, backend="jax")
    jax_cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res2 = batch_run(lanes, cm, chunks_per_msg=chunks, backend="jax")
    jax_wall = time.perf_counter() - t0
    import numpy as np
    bit_stable = (np.array_equal(res.node_done, res2.node_done)
                  and np.array_equal(res.step_done, res2.step_done))
    return {
        "tier": "jax-scale", "n": n, "r": 2, "m_bytes": m, "chunks": chunks,
        "delta": DELTA, "lanes": len(lanes), "hop_cap": hop_cap,
        "backend": res.backend,
        "numpy_wall_s": None,      # deliberately never run at this scale
        "jax_cold_wall_s": round(jax_cold_wall, 4),
        "jax_wall_s": round(jax_wall, 4),
        "jax_compiles": None,      # cold/warm split already covers compiles
        "jax_speedup": None,
        "fast_lanes": int(res.fast_path.sum()),
        "certified_lanes": int(res.certified.sum()),
        "worst_rel_diff": None,
        "bit_stable": bool(bit_stable),
        "completion_checksum": float(res.completion.sum()),
    }


def bench_scoring(n: int = 96, m: float = 4 * MB, chunks: int = 8) -> dict:
    from repro.core import PAPER_DEFAULT, FabricSim
    from repro.core.batchsim import batch_run

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    lanes = _candidate_lanes(n, m)

    def run_scalar():
        return [FabricSim(chunks_per_msg=chunks, mode="sparse")
                .run(lane.schedule, m, cm).completion for lane in lanes]

    # steady-state timing: one untimed pass per engine warms every memoized
    # layer (step structure, link-offset gcds, compiled tapes, fast-path
    # certificates) so neither timed side is charged the other's cold-cache
    # work
    run_scalar()
    batch_run(lanes, cm, chunks_per_msg=chunks)
    batch_run(lanes, cm, chunks_per_msg=chunks, certify=False)
    t0 = time.perf_counter()
    scalar = run_scalar()
    scalar_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = batch_run(lanes, cm, chunks_per_msg=chunks)
    batched_wall = time.perf_counter() - t0
    # guard-based path: same batch with certificates disabled, so the
    # canonical-order guards run their per-step bookkeeping (the pre-certifier
    # behaviour); certified playback must not be slower than this
    t0 = time.perf_counter()
    batch_run(lanes, cm, chunks_per_msg=chunks, certify=False)
    guard_wall = time.perf_counter() - t0
    worst_rel = max(
        abs(float(b) - s) / max(abs(s), 1e-30)
        for b, s in zip(res.completion, scalar, strict=True))
    return {
        "tier": "scoring", "n": n, "r": 2, "m_bytes": m, "chunks": chunks,
        "delta": DELTA, "lanes": len(lanes),
        "scalar_wall_s": round(scalar_wall, 4),
        "batched_wall_s": round(batched_wall, 4),
        "guard_wall_s": round(guard_wall, 4),
        "batched_speedup": round(scalar_wall / max(batched_wall, 1e-9), 2),
        "fast_lanes": int(res.fast_path.sum()),
        "certified_lanes": int(res.certified.sum()),
        "worst_rel_diff": float(f"{worst_rel:.3e}"),
        "completion_checksum": float(res.completion.sum()),
    }


def bench_scale(n: int, m: float = 4 * MB, chunks: int = 4,
                max_lanes: int = 30) -> dict:
    """Batched-only: grids the scalar loop cannot touch in CI time."""
    from repro.core import PAPER_DEFAULT
    from repro.core.batchsim import batch_run, clear_tape_caches

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    lanes = _candidate_lanes(n, m, max_lanes=max_lanes)
    clear_tape_caches()  # first contact at this scale: include tape compile
    t0 = time.perf_counter()
    res = batch_run(lanes, cm, chunks_per_msg=chunks)
    batched_wall = time.perf_counter() - t0
    return {
        "tier": "scale", "n": n, "r": 2, "m_bytes": m, "chunks": chunks,
        "delta": DELTA, "lanes": len(lanes),
        "scalar_wall_s": None,     # deliberately never run at this scale
        "batched_wall_s": round(batched_wall, 4),
        "guard_wall_s": None,      # guard-path A/B is a scoring-tier gate
        "batched_speedup": None,
        "fast_lanes": int(res.fast_path.sum()),
        "certified_lanes": int(res.certified.sum()),
        "worst_rel_diff": None,
        "completion_checksum": float(res.completion.sum()),
    }


def bench_plan_cache(n: int = 96, repeats: int = 20) -> dict:
    """Serving path: repeated PlanRequest traffic through one Planner."""
    from repro.core import PAPER_DEFAULT
    from repro.planner import Planner, PlanRequest

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    reqs = [PlanRequest(kind=kind, n=n, m_bytes=(i + 1) * MB, cost_model=cm,
                        fabric="ocs-sim")
            for kind in ("a2a", "rs") for i in range(2)]
    planner = Planner(cache_size=64, sim_chunks=8)
    t0 = time.perf_counter()
    for req in reqs:
        planner.plan(req)
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(repeats):
        planner.plan_batch(reqs)
    warm_wall = time.perf_counter() - t0
    info = planner.cache_info()
    warm_per_plan_us = warm_wall * 1e6 / (repeats * len(reqs))
    cold_per_plan_us = cold_wall * 1e6 / len(reqs)
    return {
        "n": n, "distinct_requests": len(reqs),
        "total_plans": len(reqs) * (repeats + 1),
        "hits": info.hits, "misses": info.misses,
        "hit_rate": round(info.hits / max(1, info.hits + info.misses), 4),
        "cold_plan_us": round(cold_per_plan_us, 1),
        "cached_plan_us": round(warm_per_plan_us, 1),
        "cache_amortization": round(cold_per_plan_us
                                    / max(warm_per_plan_us, 1e-3), 1),
    }


def check_gates(rows: list[dict], cache: dict, min_speedup: float,
                min_jax_speedup: float = 3.0) -> list[str]:
    errors = []
    for row in rows:
        key = f"tier={row['tier']} n={row['n']}"
        if row["fast_lanes"] != row["lanes"]:
            errors.append(f"{key}: only {row['fast_lanes']}/{row['lanes']} "
                          f"lanes on the vectorized fast path (uniform lanes "
                          f"must never fall back)")
        if row["certified_lanes"] != row["lanes"]:
            errors.append(f"{key}: only {row['certified_lanes']}/"
                          f"{row['lanes']} lanes statically certified "
                          f"(uniform candidate lanes under alpha_s > 0 must "
                          f"all hold fast-path certificates)")
        if row["tier"] in ("jax", "jax-scale"):
            if row["backend"] != "jax":
                errors.append(f"{key}: resolved backend {row['backend']!r} "
                              f"!= 'jax' (certified lanes must have run on "
                              f"the XLA kernel)")
            if not row["bit_stable"]:
                errors.append(f"{key}: JAX playback not bit-stable "
                              f"run-to-run")
            if row["tier"] == "jax":
                if row["jax_speedup"] < min_jax_speedup:
                    errors.append(f"{key}: jax_speedup {row['jax_speedup']} "
                                  f"< {min_jax_speedup} (warm XLA playback "
                                  f"vs the NumPy batch engine)")
                if row["worst_rel_diff"] > 1e-6:
                    errors.append(f"{key}: jax vs numpy completion drift "
                                  f"{row['worst_rel_diff']} > 1e-6")
            continue
        if row["tier"] != "scoring":
            continue
        if row["batched_speedup"] < min_speedup:
            errors.append(f"{key}: batched_speedup {row['batched_speedup']} "
                          f"< {min_speedup}")
        if row["worst_rel_diff"] > 1e-9:
            errors.append(f"{key}: batched vs scalar completion drift "
                          f"{row['worst_rel_diff']} > 1e-9")
        if row["batched_wall_s"] > 1.25 * row["guard_wall_s"]:
            errors.append(f"{key}: certified playback {row['batched_wall_s']}"
                          f"s slower than the guard-based path "
                          f"{row['guard_wall_s']}s x 1.25 (the certificate "
                          f"must never cost more than the guards it waives)")
    if cache["misses"] != cache["distinct_requests"]:
        errors.append(f"plan cache: {cache['misses']} misses != "
                      f"{cache['distinct_requests']} distinct requests")
    expected_hits = cache["total_plans"] - cache["distinct_requests"]
    if cache["hits"] != expected_hits:
        errors.append(f"plan cache: {cache['hits']} hits != expected "
                      f"{expected_hits}")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="scoring + jax tiers + plan cache only (CI; the "
                         "committed baseline still covers every row produced)")
    ap.add_argument("--scale-ns", default="768,1536",
                    help="comma-separated n values for the batched-only tier")
    ap.add_argument("--jax-ns", default="8192,32768",
                    help="comma-separated n values for the jax-only tier")
    ap.add_argument("--min-speedup", type=float, default=10.0,
                    help="min batched/scalar wall ratio on the scoring tier")
    ap.add_argument("--min-jax-speedup", type=float, default=3.0,
                    help="min warm jax/numpy wall ratio on the jax tier")
    args = ap.parse_args(argv)

    from repro.core.batchsim_jax import jax_available

    rows = [bench_scoring()]
    if jax_available():
        rows.append(bench_jax())
    else:
        print("# skip jax tiers: jax is not importable", file=sys.stderr)
    if not args.smoke:
        for n in (int(v) for v in args.scale_ns.split(",")):
            rows.append(bench_scale(n))
        if jax_available():
            for spec in (v for v in args.jax_ns.split(",") if v):
                n = int(spec)
                # deeper hop budget at the top of the grid: the candidate
                # tail grows with n, and only XLA is paying for it
                rows.append(bench_jax_scale(
                    n, lanes_target=64 if n <= 8192 else 32,
                    hop_cap=400 if n <= 8192 else 600))
    cache = bench_plan_cache()

    print("tier,n,lanes,scalar_wall_s,batched_wall_s,guard_wall_s,speedup,"
          "fast_lanes,certified_lanes,worst_rel_diff")
    for row in rows:
        if row["tier"] in ("jax", "jax-scale"):
            print(f"{row['tier']},{row['n']},{row['lanes']},"
                  f"numpy={row['numpy_wall_s']},jax={row['jax_wall_s']},"
                  f"cold={row['jax_cold_wall_s']},{row['jax_speedup']},"
                  f"{row['fast_lanes']},{row['certified_lanes']},"
                  f"{row['worst_rel_diff']}")
            continue
        print(f"{row['tier']},{row['n']},{row['lanes']},"
              f"{row['scalar_wall_s']},{row['batched_wall_s']},"
              f"{row['guard_wall_s']},{row['batched_speedup']},"
              f"{row['fast_lanes']},{row['certified_lanes']},"
              f"{row['worst_rel_diff']}")
    print(f"# plan cache: {cache['hits']} hits / {cache['misses']} misses "
          f"(rate {cache['hit_rate']}), cold {cache['cold_plan_us']} us -> "
          f"cached {cache['cached_plan_us']} us "
          f"({cache['cache_amortization']}x)")

    errors = check_gates(rows, cache, args.min_speedup,
                         min_jax_speedup=args.min_jax_speedup)
    if errors:
        # gate first: never overwrite the committed baseline with bad data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "scalar sparse FabricSim vs vectorized batch engine "
                        "(core.batchsim) vs the JAX jit/vmap backend "
                        "(core.batchsim_jax) wall time, plus the LRU "
                        "plan-cache serving path (BENCH_sim_scale baseline)",
                "delta": DELTA,
            },
            "rows": rows,
            "plan_cache": cache,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
