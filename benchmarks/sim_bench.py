"""Batch fabric engine benchmark: scalar sparse loop vs vectorized playback.

Two measurement tiers plus the plan-serving path:

  - ``scoring`` tier (n = 96): the planner's event-scoring workload — a
    30+-candidate set (every deduped periodic / rs-early / ag-late /
    exact-dp schedule for all three collectives at one n) completion-timed
    once by the scalar per-chunk `FabricSim` loop and once by a single
    `batchsim.batch_run` call.  Gates (exit 1): batched >= ``--min-speedup``
    x faster, every lane on the vectorized fast path, every lane statically
    certified (`repro.analysis.certifier` — the row reports the certified
    fraction), certified playback no slower than the guard-based
    ``certify=False`` path, and completions equal to the scalar loop within
    1e-9 relative.
  - ``scale`` tier (n in {768, 1536}): batched-only — the scalar engine is
    not run at all at this scale (it would take minutes per grid point);
    the row records wall time and a completion checksum so regressions in
    the engine itself are caught by `benchmarks.check_regression`.
  - plan-cache serving: repeated `PlanRequest` traffic through one
    `Planner`, recording hit/miss counts and cold vs cached plan latency.

Run via ``make sim-bench``; results land in BENCH_sim_scale.json.  The CI
bench job runs ``--smoke`` (scoring tier only) against the committed
baseline; the nightly workflow runs the full grid including the n >= 768
tier.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

MB = 1024.0 ** 2
DELTA = 1e-3


def _candidate_lanes(n: int, m: float, max_lanes: int | None = None):
    """Deduped all-kind candidate schedules at one n (shared S => one batch)."""
    from repro.core import PAPER_DEFAULT
    from repro.core import schedules as S
    from repro.core.batchsim import BatchLane

    seen, lanes = set(), []
    for kind in ("a2a", "rs", "ag"):
        for _, sched in S.candidate_schedules(kind, n, m, PAPER_DEFAULT):
            key = (sched.kind, sched.x)
            if key in seen:
                continue
            seen.add(key)
            lanes.append(BatchLane(schedule=sched, m_bytes=m))
    return lanes[:max_lanes] if max_lanes else lanes


def bench_scoring(n: int = 96, m: float = 4 * MB, chunks: int = 8) -> dict:
    from repro.core import PAPER_DEFAULT, FabricSim
    from repro.core.batchsim import batch_run

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    lanes = _candidate_lanes(n, m)

    def run_scalar():
        return [FabricSim(chunks_per_msg=chunks, mode="sparse")
                .run(lane.schedule, m, cm).completion for lane in lanes]

    # steady-state timing: one untimed pass per engine warms every memoized
    # layer (step structure, link-offset gcds, compiled tapes, fast-path
    # certificates) so neither timed side is charged the other's cold-cache
    # work
    run_scalar()
    batch_run(lanes, cm, chunks_per_msg=chunks)
    batch_run(lanes, cm, chunks_per_msg=chunks, certify=False)
    t0 = time.perf_counter()
    scalar = run_scalar()
    scalar_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = batch_run(lanes, cm, chunks_per_msg=chunks)
    batched_wall = time.perf_counter() - t0
    # guard-based path: same batch with certificates disabled, so the
    # canonical-order guards run their per-step bookkeeping (the pre-certifier
    # behaviour); certified playback must not be slower than this
    t0 = time.perf_counter()
    batch_run(lanes, cm, chunks_per_msg=chunks, certify=False)
    guard_wall = time.perf_counter() - t0
    worst_rel = max(
        abs(float(b) - s) / max(abs(s), 1e-30)
        for b, s in zip(res.completion, scalar, strict=True))
    return {
        "tier": "scoring", "n": n, "r": 2, "m_bytes": m, "chunks": chunks,
        "delta": DELTA, "lanes": len(lanes),
        "scalar_wall_s": round(scalar_wall, 4),
        "batched_wall_s": round(batched_wall, 4),
        "guard_wall_s": round(guard_wall, 4),
        "batched_speedup": round(scalar_wall / max(batched_wall, 1e-9), 2),
        "fast_lanes": int(res.fast_path.sum()),
        "certified_lanes": int(res.certified.sum()),
        "worst_rel_diff": float(f"{worst_rel:.3e}"),
        "completion_checksum": float(res.completion.sum()),
    }


def bench_scale(n: int, m: float = 4 * MB, chunks: int = 4,
                max_lanes: int = 30) -> dict:
    """Batched-only: grids the scalar loop cannot touch in CI time."""
    from repro.core import PAPER_DEFAULT
    from repro.core.batchsim import batch_run, clear_tape_caches

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    lanes = _candidate_lanes(n, m, max_lanes=max_lanes)
    clear_tape_caches()  # first contact at this scale: include tape compile
    t0 = time.perf_counter()
    res = batch_run(lanes, cm, chunks_per_msg=chunks)
    batched_wall = time.perf_counter() - t0
    return {
        "tier": "scale", "n": n, "r": 2, "m_bytes": m, "chunks": chunks,
        "delta": DELTA, "lanes": len(lanes),
        "scalar_wall_s": None,     # deliberately never run at this scale
        "batched_wall_s": round(batched_wall, 4),
        "guard_wall_s": None,      # guard-path A/B is a scoring-tier gate
        "batched_speedup": None,
        "fast_lanes": int(res.fast_path.sum()),
        "certified_lanes": int(res.certified.sum()),
        "worst_rel_diff": None,
        "completion_checksum": float(res.completion.sum()),
    }


def bench_plan_cache(n: int = 96, repeats: int = 20) -> dict:
    """Serving path: repeated PlanRequest traffic through one Planner."""
    from repro.core import PAPER_DEFAULT
    from repro.planner import Planner, PlanRequest

    cm = PAPER_DEFAULT.replace(delta=DELTA)
    reqs = [PlanRequest(kind=kind, n=n, m_bytes=(i + 1) * MB, cost_model=cm,
                        fabric="ocs-sim")
            for kind in ("a2a", "rs") for i in range(2)]
    planner = Planner(cache_size=64, sim_chunks=8)
    t0 = time.perf_counter()
    for req in reqs:
        planner.plan(req)
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(repeats):
        planner.plan_batch(reqs)
    warm_wall = time.perf_counter() - t0
    info = planner.cache_info()
    warm_per_plan_us = warm_wall * 1e6 / (repeats * len(reqs))
    cold_per_plan_us = cold_wall * 1e6 / len(reqs)
    return {
        "n": n, "distinct_requests": len(reqs),
        "total_plans": len(reqs) * (repeats + 1),
        "hits": info.hits, "misses": info.misses,
        "hit_rate": round(info.hits / max(1, info.hits + info.misses), 4),
        "cold_plan_us": round(cold_per_plan_us, 1),
        "cached_plan_us": round(warm_per_plan_us, 1),
        "cache_amortization": round(cold_per_plan_us
                                    / max(warm_per_plan_us, 1e-3), 1),
    }


def check_gates(rows: list[dict], cache: dict, min_speedup: float) -> list[str]:
    errors = []
    for row in rows:
        key = f"tier={row['tier']} n={row['n']}"
        if row["fast_lanes"] != row["lanes"]:
            errors.append(f"{key}: only {row['fast_lanes']}/{row['lanes']} "
                          f"lanes on the vectorized fast path (uniform lanes "
                          f"must never fall back)")
        if row["certified_lanes"] != row["lanes"]:
            errors.append(f"{key}: only {row['certified_lanes']}/"
                          f"{row['lanes']} lanes statically certified "
                          f"(uniform candidate lanes under alpha_s > 0 must "
                          f"all hold fast-path certificates)")
        if row["tier"] != "scoring":
            continue
        if row["batched_speedup"] < min_speedup:
            errors.append(f"{key}: batched_speedup {row['batched_speedup']} "
                          f"< {min_speedup}")
        if row["worst_rel_diff"] > 1e-9:
            errors.append(f"{key}: batched vs scalar completion drift "
                          f"{row['worst_rel_diff']} > 1e-9")
        if row["batched_wall_s"] > 1.25 * row["guard_wall_s"]:
            errors.append(f"{key}: certified playback {row['batched_wall_s']}"
                          f"s slower than the guard-based path "
                          f"{row['guard_wall_s']}s x 1.25 (the certificate "
                          f"must never cost more than the guards it waives)")
    if cache["misses"] != cache["distinct_requests"]:
        errors.append(f"plan cache: {cache['misses']} misses != "
                      f"{cache['distinct_requests']} distinct requests")
    expected_hits = cache["total_plans"] - cache["distinct_requests"]
    if cache["hits"] != expected_hits:
        errors.append(f"plan cache: {cache['hits']} hits != expected "
                      f"{expected_hits}")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="scoring tier + plan cache only (CI; the committed "
                         "baseline still covers every row produced)")
    ap.add_argument("--scale-ns", default="768,1536",
                    help="comma-separated n values for the batched-only tier")
    ap.add_argument("--min-speedup", type=float, default=10.0,
                    help="min batched/scalar wall ratio on the scoring tier")
    args = ap.parse_args(argv)

    rows = [bench_scoring()]
    if not args.smoke:
        for n in (int(v) for v in args.scale_ns.split(",")):
            rows.append(bench_scale(n))
    cache = bench_plan_cache()

    print("tier,n,lanes,scalar_wall_s,batched_wall_s,guard_wall_s,speedup,"
          "fast_lanes,certified_lanes,worst_rel_diff")
    for row in rows:
        print(f"{row['tier']},{row['n']},{row['lanes']},"
              f"{row['scalar_wall_s']},{row['batched_wall_s']},"
              f"{row['guard_wall_s']},{row['batched_speedup']},"
              f"{row['fast_lanes']},{row['certified_lanes']},"
              f"{row['worst_rel_diff']}")
    print(f"# plan cache: {cache['hits']} hits / {cache['misses']} misses "
          f"(rate {cache['hit_rate']}), cold {cache['cold_plan_us']} us -> "
          f"cached {cache['cached_plan_us']} us "
          f"({cache['cache_amortization']}x)")

    errors = check_gates(rows, cache, args.min_speedup)
    if errors:
        # gate first: never overwrite the committed baseline with bad data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "scalar sparse FabricSim vs vectorized batch engine "
                        "(core.batchsim) wall time, plus the LRU plan-cache "
                        "serving path (BENCH_sim_scale baseline)",
                "delta": DELTA,
            },
            "rows": rows,
            "plan_cache": cache,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
