"""Benchmark harness: one function per paper table/figure, plus the
generalized n x r x m sweep.

Prints ``name,us_per_call,derived`` CSV — us_per_call is the wall time of
producing the artifact (the schedule synthesis + simulation), derived is the
figure's headline number.  Run: PYTHONPATH=src python -m benchmarks.run

Sweep mode covers the mixed-radix / arbitrary-n scenario space::

    PYTHONPATH=src python -m benchmarks.run --sweep \
        [--ns 6,12,48,96,384] [--rs 2,3,4] [--ms 1MB,16MB] \
        [--json BENCH_bridge_radix.json] [--smoke]

Each sweep row plans all three collectives at (n, r, m), records the chosen
strategy/R, the modeled speedups over static Bruck and RING, and (for small
n) an event-level cross-check ratio.
"""
from __future__ import annotations

import argparse
import json
import time


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    from . import figures
    from .collectives_lowering import lower_allreduce_variants
    from .kernels_bench import (flash_attention_bench, rg_lru_bench,
                                wkv6_bench)

    print("name,us_per_call,derived")

    out, us = _timed(figures.table1)
    _row("table1_schedules", us,
         "a2a_R1=" + "".join(map(str, out["a2a_R1"]))
         + ";rs_R1=" + "".join(map(str, out["rs_R1"]))
         + ";ag_R1=" + "".join(map(str, out["ag_R1"])))

    out, us = _timed(figures.fig1)
    _row("fig1_bruck_vs_hd_R1", us,
         f"bruck/hd_final={out['final_bruck_R1'] / out['final_hd_R1']:.3f}")
    _row("fig1_bruck_vs_hd_R2", 0.0,
         f"bruck/hd_final={out['final_bruck_R2'] / out['final_hd_R2']:.3f}")

    out, us = _timed(figures.fig2)
    big = out["bruck_a2a_m65536KB"]
    _row("fig2_cost_split", us,
         f"a2a64MB_hopfrac={big['hops'] / big['total']:.2f}"
         f"_txfrac={big['transmission'] / big['total']:.2f}")

    out, us = _timed(figures.fig5)
    _row("fig5a_a2a_vs_sbruck_max", us, f"{max(out['vs_sbruck'].values()):.2f}x")
    _row("fig5b_a2a_vs_best_max", 0.0, f"{max(out['vs_best'].values()):.2f}x")

    out, us = _timed(figures.fig6)
    _row("fig6_a2a_perhop_max_vs_best", us,
         f"{max(v['vs_best'] for v in out.values()):.2f}x")

    out, us = _timed(figures.fig7)
    _row("fig7_a2a_netsize_n256_min", us,
         f"{min(v for k, v in out.items() if k.startswith('n256')):.2f}x")
    _row("fig7_a2a_netsize_max", 0.0, f"{max(out.values()):.2f}x")

    out, us = _timed(figures.fig8)
    _row("fig8_bridge_vs_s_max", us, f"{max(out['bridge_vs_s'].values()):.2f}x")
    _row("fig8_bridge_vs_best_max", 0.0,
         f"{max(out['bridge_vs_best'].values()):.2f}x")

    out, us = _timed(figures.fig9)
    _row("fig9_rs_vs_ring_max", us, f"{max(out['vs_ring'].values()):.2f}x")
    _row("fig9_rs_vs_rhd_max", 0.0, f"{max(out['vs_rhd'].values()):.2f}x")

    out, us = _timed(figures.fig10)
    _row("fig10_rs_perhop_max_vs_ring", us,
         f"{max(v['vs_ring'] for v in out.values()):.2f}x")

    out, us = _timed(figures.fig11)
    _row("fig11_rs_netsize_max_vs_static", us, f"{max(out.values()):.2f}x")

    out, us = _timed(figures.fig12)
    _row("fig12_rs_vs_ring_max", us, f"{max(out['bridge'].values()):.2f}x")
    _row("fig12_bridge_vs_best_max", 0.0,
         f"{max(out['bridge_vs_best'].values()):.2f}x")

    out, us = _timed(figures.scheduler_runtime)
    _row("scheduler_runtime", us, f"per_plan_ms={out['per_plan_ms']:.2f}")

    out, us = _timed(figures.ports_extension)
    _row("sec3.7_ports_n256_z64", us, f"{out['n256_z64']:.2f}x")

    out, us = _timed(lambda: lower_allreduce_variants(8, 1 << 20))
    _row("allreduce_lowering_bruck_permutes", us,
         f"{out['bruck']['collective_permute']}")
    _row("allreduce_lowering_ring_permutes", 0.0,
         f"{out['ring']['collective_permute']}")

    from .straggler import straggler_amplification
    out, us = _timed(lambda: straggler_amplification(
        n=16, m=2 * 2**20, kappas=(1.0, 4.0), chunks=8))
    _row("straggler_bridge_vs_static_k4", us,
         f"{out['speedup'][4.0]:.2f}x(nominal_{out['speedup'][1.0]:.2f}x)")

    # kernel benches need a pallas-compatible jax; report rather than die
    try:
        out, us = _timed(flash_attention_bench)
        _row("kernel_flash_attention", out["us_per_call"],
             f"vmem={out['vmem_bytes']}B_ai={out['arith_intensity']:.1f}")
        out, us = _timed(rg_lru_bench)
        _row("kernel_rg_lru", out["us_per_call"], f"vmem={out['vmem_bytes']}B")
        out, us = _timed(wkv6_bench)
        _row("kernel_wkv6", out["us_per_call"], f"vmem={out['vmem_bytes']}B")
    except Exception as e:
        _row("kernel_benches", 0.0, f"unavailable({type(e).__name__})")

    # roofline summary if the dry-run artifacts exist
    try:
        from .roofline import derive, load_cells
        rows = [d for c in load_cells() if (d := derive(c))]
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            _row("roofline_cells", 0.0, f"{len(rows)}")
            _row("roofline_worst_cell", 0.0,
                 f"{worst['arch']}x{worst['shape']}x{worst['mesh']}"
                 f"={worst['roofline_fraction']:.2f}")
    except Exception as e:  # artifacts may not be generated yet
        _row("roofline_cells", 0.0, f"unavailable({type(e).__name__})")


def radix_sweep(
    ns=(6, 12, 48, 96, 384),
    radixes=(2, 3, 4),
    ms=(1 * 2**20, 16 * 2**20),
    event_check_max_n=48,
) -> dict:
    """Plan every (kind, n, r, m) cell of the generalized scenario space.

    Returns {"rows": [...], "meta": {...}} ready for JSON serialization.
    ``event_ratio`` (event-level completion / analytic completion) is
    reported for n <= event_check_max_n where the discrete-event sim is
    cheap; it must sit within the eventsim fluid-limit tolerance (±15%).
    """
    from repro.core import (PAPER_DEFAULT, baselines, clear_schedule_caches,
                            collective_time)
    from repro.core.eventsim import collective_time_event
    from repro.planner import Planner, PlanRequest

    cm = PAPER_DEFAULT
    planner = Planner()
    rows = []
    for n in ns:
        for r in radixes:
            for m in ms:
                for kind in ("a2a", "rs", "ag"):
                    # plan_us records cold *DP* cost per cell: the memoized
                    # all-R tables would otherwise make every cell after the
                    # first a warm lookup, masking DP-cost regressions vs the
                    # committed baseline.  The step-sequence cache stays warm,
                    # matching the baseline's per-R planner semantics.
                    clear_schedule_caches()
                    t0 = time.perf_counter()
                    p = planner.plan(PlanRequest(kind=kind, n=n,
                                                 m_bytes=float(m),
                                                 cost_model=cm, r=r))
                    plan_us = (time.perf_counter() - t0) * 1e6
                    t_bridge = collective_time(p.schedule, float(m), cm,
                                               validate=(n <= 96)).total
                    t_static = baselines.s_bruck(kind, n, float(m), cm, r=r).total
                    row = {
                        "kind": kind, "n": n, "r": r, "m_bytes": m,
                        "strategy": p.strategy, "R": p.schedule.R,
                        "x": list(p.schedule.x),
                        "time_s": t_bridge,
                        "speedup_vs_static": t_static / t_bridge,
                        "plan_us": round(plan_us, 1),
                    }
                    if kind in ("rs", "ag"):
                        row["speedup_vs_ring"] = (
                            baselines.ring(kind, n, float(m), cm).total / t_bridge)
                    if n <= event_check_max_n:
                        t_ev = collective_time_event(p.schedule, float(m), cm,
                                                     chunks_per_msg=32)
                        row["event_ratio"] = t_ev / t_bridge
                    rows.append(row)
    return {
        "meta": {
            "cost_model": {"alpha_s": cm.alpha_s, "alpha_h": cm.alpha_h,
                           "bandwidth": cm.bandwidth, "delta": cm.delta},
            "ns": list(ns), "radixes": list(radixes), "ms": list(ms),
        },
        "rows": rows,
    }


def _parse_sizes(spec: str) -> tuple[int, ...]:
    units = {"KB": 1024, "MB": 1024**2, "GB": 1024**3}
    out = []
    for tok in spec.split(","):
        tok = tok.strip().upper()
        for suf, mult in units.items():
            if tok.endswith(suf):
                out.append(int(float(tok[: -len(suf)]) * mult))
                break
        else:
            out.append(int(tok))
    return tuple(out)


def sweep_main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="run the n x r x m generalized sweep instead of the figures")
    ap.add_argument("--ns", default="6,12,48,96,384")
    ap.add_argument("--rs", default="2,3,4")
    ap.add_argument("--ms", default="1MB,16MB")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write sweep results to PATH as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (n=6,12 r=2,3 m=1MB) for CI rot checks")
    args = ap.parse_args(argv)
    if not args.sweep:
        main()
        return
    if args.smoke:
        ns, radixes, ms = (6, 12), (2, 3), (1 * 2**20,)
    else:
        ns = tuple(int(v) for v in args.ns.split(","))
        radixes = tuple(int(v) for v in args.rs.split(","))
        ms = _parse_sizes(args.ms)
    out = radix_sweep(ns=ns, radixes=radixes, ms=ms)
    print("kind,n,r,m_bytes,strategy,R,speedup_vs_static,event_ratio")
    for row in out["rows"]:
        print(f"{row['kind']},{row['n']},{row['r']},{row['m_bytes']},"
              f"{row['strategy']},{row['R']},{row['speedup_vs_static']:.3f},"
              f"{row.get('event_ratio', float('nan')):.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(out['rows'])} rows to {args.json}")


if __name__ == "__main__":
    sweep_main()
