"""Dry-run comparison of gradient-allreduce lowerings (the paper's technique
as it appears in the compiled artifact).

Lowers bruck / ring / psum allreduce for a gradient payload on an abstract
8-device ring (no real devices needed) and counts collective-permute ops and
moved bytes from the lowered text — this is the 'profile' the Section Perf
hillclimb reads (no wall-clock on CPU; see ROOFLINE notes in EXPERIMENTS.md).
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.collectives import bruck_all_reduce, ring_all_reduce
from repro.core import PAPER_DEFAULT, plan


def count_collectives(text: str) -> dict:
    return {
        "collective_permute": len(re.findall(r"collective_permute|collective-permute", text)),
        "all_reduce": len(re.findall(r"all_reduce|all-reduce", text)),
        "all_gather": len(re.findall(r"all_gather|all-gather", text)),
        "reduce_scatter": len(re.findall(r"reduce_scatter|reduce-scatter", text)),
    }


def lower_allreduce_variants(n: int = 8, nbytes: int = 1 << 20) -> dict:
    try:  # AxisType landed after jax 0.4.x, with a new AbstractMesh signature
        mesh = AbstractMesh((n,), ("data",),
                            axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:
        mesh = AbstractMesh((("data", n),))
    elems = nbytes // 4
    x = jax.ShapeDtypeStruct((elems,), jnp.float32)
    m = float(nbytes)
    rs = plan("rs", n, m, PAPER_DEFAULT).schedule
    ag = plan("ag", n, m, PAPER_DEFAULT).schedule

    variants = {
        "bruck": lambda v: bruck_all_reduce(v, "data"),
        "bruck_scheduled": lambda v: bruck_all_reduce(v, "data", rs, ag),
        "ring": lambda v: ring_all_reduce(v, "data"),
        "psum": lambda v: jax.lax.psum(v, "data"),
    }
    from repro.collectives._compat import shard_map

    out = {}
    for name, fn in variants.items():
        mapped = shard_map(fn, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"), check_vma=False)
        lowered = jax.jit(mapped).lower(
            jax.ShapeDtypeStruct((n * elems,), jnp.float32))
        out[name] = count_collectives(lowered.as_text())
        out[name]["steps_modeled"] = (
            2 * (n - 1) if name == "ring"
            else 2 * (n - 1).bit_length() if "bruck" in name else None)
    return out
