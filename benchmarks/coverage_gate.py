"""Coverage gate over the planning stack: core + planner + workloads.

Usage:

    python -m pytest -m "not slow" --cov=repro --cov-report=xml
    python -m benchmarks.coverage_gate coverage.xml --min 84

Parses a Cobertura ``coverage.xml`` (pytest-cov / coverage.py) and computes
line coverage restricted to the gated subpackages (`repro/core`,
`repro/planner`, `repro/workloads` by default — the pure-Python planning
stack that CI exercises deterministically; kernels/models/launch need
accelerator time and are measured but not gated).  Exits 1 when the
combined rate is below ``--min`` or a gated package has no measured lines
(e.g. a --cov target typo, which would otherwise gate nothing).
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET

DEFAULT_PACKAGES = ("core", "planner", "workloads")


def _subpackage(filename: str) -> str | None:
    """Gated-subpackage name of a coverage.xml class filename, if any.

    Filenames are relative to the measured source root, so they look like
    ``repro/core/bruck.py`` (``--cov=repro``) or ``core/bruck.py``
    (``--cov=repro.core``); both resolve to ``core``.
    """
    parts = filename.replace("\\", "/").split("/")
    if "repro" in parts:
        parts = parts[parts.index("repro") + 1:]
    return parts[0] if len(parts) > 1 else None


def package_rates(xml_path: str,
                  packages=DEFAULT_PACKAGES) -> dict[str, tuple[int, int]]:
    """(covered, total) statement lines per gated subpackage."""
    root = ET.parse(xml_path).getroot()
    rates = {pkg: (0, 0) for pkg in packages}
    for cls in root.iter("class"):
        pkg = _subpackage(cls.get("filename", ""))
        if pkg not in rates:
            continue
        covered, total = rates[pkg]
        for line in cls.iter("line"):
            total += 1
            if int(line.get("hits", "0")) > 0:
                covered += 1
        rates[pkg] = (covered, total)
    return rates


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("xml", help="Cobertura coverage.xml from pytest-cov")
    ap.add_argument("--min", type=float, required=True,
                    help="minimum combined line-coverage percent over the "
                         "gated packages")
    ap.add_argument("--packages", nargs="+", default=list(DEFAULT_PACKAGES),
                    help="repro subpackages to gate")
    args = ap.parse_args(argv)
    rates = package_rates(args.xml, tuple(args.packages))
    covered = total = 0
    failures = []
    for pkg, (c, t) in sorted(rates.items()):
        pct = 100.0 * c / t if t else 0.0
        print(f"repro/{pkg}: {c}/{t} lines ({pct:.1f}%)")
        if t == 0:
            failures.append(f"repro/{pkg} has no measured lines — wrong "
                            f"--cov target or package rename?")
        covered += c
        total += t
    combined = 100.0 * covered / total if total else 0.0
    print(f"combined: {covered}/{total} lines ({combined:.1f}%), "
          f"gate >= {args.min}%")
    if combined < args.min:
        failures.append(f"combined coverage {combined:.1f}% < {args.min}%")
    if failures:
        for f in failures:
            print(f"# FAIL: {f}", file=sys.stderr)
        sys.exit(1)
    print("# OK: coverage gate passed")


if __name__ == "__main__":
    main()
