"""Straggler study (beyond paper): BRIDGE vs static Bruck under a degraded
optical transceiver.

One node's egress runs at rate 1/kappa.  Under uniform-offset ring traffic
every message crosses the slow link with multiplicity c_k = h_k, so schedules
with smaller per-step hop counts are exposed *less*: BRIDGE's reconfigured
subrings don't just cut nominal completion time, they also shrink the
straggler amplification factor T(kappa)/T(1).

Run: PYTHONPATH=src python -m benchmarks.straggler
"""
from __future__ import annotations

from repro.core import PAPER_DEFAULT, plan, static_schedule
from repro.core.eventsim import collective_time_event

MB = 1024.0 ** 2


def straggler_amplification(n: int = 32, m: float = 8 * MB,
                            kappas=(1.0, 2.0, 4.0, 8.0),
                            chunks: int = 16) -> dict:
    cm = PAPER_DEFAULT.replace(delta=10e-6)
    sched_b = plan("a2a", n, m, cm, paper_faithful=True).schedule
    sched_s = static_schedule("a2a", n)
    out = {"bridge": {}, "static": {}, "speedup": {}}
    base = {}
    for name, sched in (("bridge", sched_b), ("static", sched_s)):
        base[name] = collective_time_event(sched, m, cm, chunks)
    for kappa in kappas:
        speed = [1.0] * n
        speed[n // 2] = 1.0 / kappa
        for name, sched in (("bridge", sched_b), ("static", sched_s)):
            t = collective_time_event(sched, m, cm, chunks, speed)
            out[name][kappa] = t / base[name]  # amplification factor
        tb = collective_time_event(sched_b, m, cm, chunks, speed)
        ts = collective_time_event(sched_s, m, cm, chunks, speed)
        out["speedup"][kappa] = ts / tb
    return out


def main():
    out = straggler_amplification()
    print("kappa, bridge T(k)/T(1), static T(k)/T(1), bridge-vs-static speedup")
    for k in out["bridge"]:
        print(f"{k:5.1f}, {out['bridge'][k]:8.3f}, {out['static'][k]:8.3f}, "
              f"{out['speedup'][k]:8.3f}")


if __name__ == "__main__":
    main()
