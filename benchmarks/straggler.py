"""Straggler study (beyond paper): BRIDGE vs static Bruck under a degraded
optical transceiver, measured on the asynchronous per-link fabric.

One node's egress runs at rate 1/kappa.  Under uniform-offset ring traffic
every message crosses the slow link with multiplicity c_k = h_k, so schedules
with smaller per-step hop counts are exposed *less*: BRIDGE's reconfigured
subrings don't just cut nominal completion time, they also shrink the
straggler amplification factor T(kappa)/T(1).

The simulation runs on `repro.core.fabricsim.FabricSim` in sparse mode
(per-link reconfiguration, per-node step dependencies), so a straggler delays
only the flows that actually cross it — the synchronized full-pause model
would smear the slowdown across the whole fabric at every step boundary.

Run: PYTHONPATH=src python -m benchmarks.straggler
"""
from __future__ import annotations

from repro.core import (FabricSim, PAPER_DEFAULT, plan, static_schedule,
                        straggler_speeds)

MB = 1024.0 ** 2


def straggler_amplification(n: int = 32, m: float = 8 * MB,
                            kappas=(1.0, 2.0, 4.0, 8.0),
                            chunks: int = 16, overlap: float = 0.0) -> dict:
    cm = PAPER_DEFAULT.replace(delta=10e-6)
    sched_b = plan("a2a", n, m, cm, paper_faithful=True).schedule
    sched_s = static_schedule("a2a", n)
    out = {"bridge": {}, "static": {}, "speedup": {}}

    def run(sched, kappa):
        speed = None if kappa == 1.0 else straggler_speeds(n, {n // 2: 1.0 / kappa})
        sim = FabricSim(chunks_per_msg=chunks, mode="sparse", overlap=overlap,
                        link_speed=speed)
        return sim.run(sched, m, cm).completion

    base = {"bridge": run(sched_b, 1.0), "static": run(sched_s, 1.0)}
    for kappa in kappas:
        tb, ts = run(sched_b, kappa), run(sched_s, kappa)
        out["bridge"][kappa] = tb / base["bridge"]  # amplification factor
        out["static"][kappa] = ts / base["static"]
        out["speedup"][kappa] = ts / tb
    return out


def main():
    out = straggler_amplification()
    print("kappa, bridge T(k)/T(1), static T(k)/T(1), bridge-vs-static speedup")
    for k in out["bridge"]:
        print(f"{k:5.1f}, {out['bridge'][k]:8.3f}, {out['static'][k]:8.3f}, "
              f"{out['speedup'][k]:8.3f}")


if __name__ == "__main__":
    main()
