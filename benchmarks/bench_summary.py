"""Markdown gate summary for benchmark runs ($GITHUB_STEP_SUMMARY).

Usage:

    python -m benchmarks.bench_summary NAME=BASELINE:FRESH [...] [--subset-ok]

For every NAME the committed baseline and the freshly measured file are
compared with the same checks `benchmarks.check_regression` gates on, and
one table row is emitted: bench name, detected schema, rows checked, the
schema's headline ratio, and gate pass/fail.  CI appends the output to the
job summary so a regression is readable without downloading artifacts; the
hard failure still comes from the `check_regression` steps (this renderer
always exits 0 so the summary is written even when a gate failed).
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.check_regression import (SCHEMAS, check_fabric, check_faults,
                                         check_online, check_planner,
                                         check_row_coverage, check_sim,
                                         check_tenancy, check_trace,
                                         detect_schema)


def headline(schema: str, rows: list[dict]) -> str:
    """One human-scale number per schema (the benchmark's headline claim)."""
    if schema == "planner":
        return f"{max(r['wall_speedup'] for r in rows):.1f}x all-R DP"
    if schema == "sim":
        scoring = [r["batched_speedup"] for r in rows
                   if r.get("batched_speedup") is not None]
        return (f"{max(scoring):.1f}x batched" if scoring else "scale tier")
    if schema == "trace":
        return (f"{max(r['carryover_vs_cold'] for r in rows):.1f}x "
                f"carryover win")
    if schema == "online":
        storm = [r["hot_plans_per_sec"] for r in rows
                 if r["trace"] == "storm"]
        worst = max((r["online_vs_offline"] for r in rows
                     if r["trace"] != "storm" and r["window"] >= 2),
                    default=None)
        head = f"W>=2 regret {worst}x" if worst is not None else "storm only"
        return (f"{head}, {max(storm) / 1e3:.0f}k plans/s"
                if storm else head)
    if schema == "tenancy":
        best = max(r["win_vs_serialized"] for r in rows)
        worst_iso = max(iso for r in rows for iso in r["isolation"].values())
        return f"{best:.1f}x vs serialized, worst isolation {worst_iso:.2f}"
    if schema == "faults":
        worst = max(r["recovery_ratio"] for r in rows)
        return (f"worst recovery ratio {worst}x, "
                f"{'all' if all(r['bit_identical'] for r in rows) else 'NOT all'}"
                f" bit-identical")
    return f"{max(r['sparse_speedup'] for r in rows):.2f}x sparse"


def summarize_pair(name: str, baseline: str, fresh: str,
                   subset_ok: bool) -> tuple[str, list[str]]:
    """One markdown table row plus the failure details (empty = pass).

    Never raises: a missing, truncated, or schema-broken file becomes a
    FAIL/MISSING row — the summary must render precisely when a benchmark
    broke (the hard gate is the separate `check_regression` step).
    """
    if not os.path.exists(fresh):
        return f"| {name} | - | - | - | MISSING (bench did not run) |", [
            f"{name}: fresh file {fresh} not found"]
    try:
        with open(baseline) as f:
            base_rows = json.load(f)["rows"]
        with open(fresh) as f:
            fresh_rows = json.load(f)["rows"]
        schema = detect_schema(base_rows, baseline)
        errors = check_row_coverage(base_rows, fresh_rows, SCHEMAS[schema][1],
                                    subset_ok)
        check = {"planner": lambda: check_planner(base_rows, fresh_rows, 0.25),
                 "sim": lambda: check_sim(base_rows, fresh_rows, 0.25),
                 "trace": lambda: check_trace(base_rows, fresh_rows, 1e-6),
                 "fabric": lambda: check_fabric(base_rows, fresh_rows, 1e-6),
                 "online": lambda: check_online(base_rows, fresh_rows,
                                                1e-6, 0.25),
                 "faults": lambda: check_faults(base_rows, fresh_rows, 1e-6),
                 "tenancy": lambda: check_tenancy(base_rows, fresh_rows, 1e-6)}
        more, matched = check[schema]()
        errors += more
        head = headline(schema, fresh_rows)
    except (SystemExit, Exception) as exc:  # malformed file / schema change
        return f"| {name} | ? | - | - | FAIL (unreadable) |", [
            f"{name}: could not compare {baseline} vs {fresh}: {exc}"]
    verdict = "PASS" if not errors else f"FAIL ({len(errors)})"
    row = (f"| {name} | {schema} | {matched} | {head} | {verdict} |")
    return row, [f"{name}: {e}" for e in errors]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pairs", nargs="+", metavar="NAME=BASELINE:FRESH")
    ap.add_argument("--subset-ok", action="store_true",
                    help="fresh files may cover a subset of the baseline grid")
    args = ap.parse_args(argv)
    lines = ["## Benchmark gates", "",
             "| bench | schema | rows | headline | gate |",
             "|---|---|---|---|---|"]
    details: list[str] = []
    for pair in args.pairs:
        name, _, files = pair.partition("=")
        baseline, _, fresh = files.partition(":")
        if not name or not baseline or not fresh:
            raise SystemExit(f"bad pair {pair!r}: want NAME=BASELINE:FRESH")
        row, errs = summarize_pair(name, baseline, fresh, args.subset_ok)
        lines.append(row)
        details += errs
    if details:
        lines += ["", "<details><summary>failures</summary>", ""]
        lines += [f"- {d}" for d in details]
        lines += ["", "</details>"]
    print("\n".join(lines))


if __name__ == "__main__":
    main()
