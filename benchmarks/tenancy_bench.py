"""Multi-tenant fabric sharing benchmark: shared planning vs serialization.

Grids K (tenant count) x n x delta x sharing mode and, at each point, plans
the tenant mix two ways through `repro.workloads.tenancy.plan_shared`:

  - ``time-slice``     : K full-fabric tenants interleave whole collectives;
                         hand-offs are carryover boundaries priced sparsely
                         on the circuits that actually change, the joint DP
                         allocates per-tenant and global reconfiguration
                         budgets and minimizes weighted completion time;
  - ``port-partition`` : K tenants own disjoint contiguous port subsets
                         sized to their worlds and run concurrently with
                         isolation ratio exactly 1.0.

Every row records the naive-serialization baseline (each tenant planned
independently, played back-to-back with a full-fabric swap per hand-off) on
both metrics, plus the per-tenant measured isolation ratio and its
structural bound.  Time-sliced rows also play the chosen interleaving
through the sparse event-level fabric engine.

Gates (exit 1 on violation; re-checked in CI against the committed baseline
by `benchmarks.check_regression`, and every row's embedded shared plan is
re-verified by `benchmarks.verify_gate`):

  - shared completion <= naive serialization on every row, both sharing
    modes and both metrics (makespan and weighted completion);
  - every tenant's measured isolation ratio is within its structural bound
    ``serialized / alone`` on every row;
  - port-partitioned rows isolate perfectly (ratio 1.0 per tenant).

Run via ``make tenancy-bench``; results land in BENCH_tenancy.json.
"""
from __future__ import annotations

import argparse
import json
import sys

DELTAS = (10e-6, 1e-3, 15e-3)
MODES = ("time-slice", "port-partition")
KS = (2, 3)


def make_tenants(K: int, n: int, sharing: str, seed: int = 0):
    """A deterministic K-tenant mix of heterogeneous workloads.

    Time-sliced tenants all span the full fabric; port-partitioned tenants
    split it into K equal contiguous shares.
    """
    from repro.workloads import (TenantSpec, decode_ag_trace, mixed_trace,
                                 moe_a2a_trace)

    world = n if sharing == "time-slice" else n // K
    gens = (
        lambda w, s: mixed_trace(w, seed=s),
        lambda w, s: decode_ag_trace(w, decode_steps=4, seed=s, jitter=0.25),
        lambda w, s: moe_a2a_trace(w, layers=2, seed=s),
    )
    weights = (2.0, 1.0, 1.5)
    share = None if sharing == "time-slice" else 1.0 / K
    return tuple(
        TenantSpec(name=f"job-{i}", trace=gens[i % len(gens)](world, seed + i),
                   weight=weights[i % len(weights)], port_share=share)
        for i in range(K))


def bench_grid(ks=KS, ns=(16, 48), deltas=DELTAS, modes=MODES,
               chunks: int = 4) -> list[dict]:
    from repro.core import PAPER_DEFAULT, FabricSim
    from repro.workloads import SharedFabricRequest, plan_shared

    rows = []
    for sharing in modes:
        for K in ks:
            for n in ns:
                if sharing == "port-partition" and n % K:
                    continue
                tenants = make_tenants(K, n, sharing)
                for delta in deltas:
                    cm = PAPER_DEFAULT.replace(delta=delta)
                    req = SharedFabricRequest(
                        tenants=tenants, n=n, cost_model=cm, sharing=sharing)
                    sp = plan_shared(req)
                    exec_s = None
                    if sharing == "time-slice":
                        sim = FabricSim(chunks_per_msg=chunks, mode="sparse")
                        exec_s = sim.run_trace(sp.fabric_phases(),
                                               cm).completion
                    rows.append({
                        "sharing": sharing, "K": K, "n": n, "delta": delta,
                        "phases": len(sp.phases),
                        "shared_s": sp.makespan_s,
                        "weighted_s": sp.weighted_completion_s,
                        "serialized_s": sp.serialized_s,
                        "serialized_weighted_s": sp.serialized_weighted_s,
                        "win_vs_serialized": round(
                            sp.serialized_s / sp.makespan_s, 6),
                        "weighted_win": round(
                            sp.serialized_weighted_s
                            / sp.weighted_completion_s, 6),
                        "isolation": {t.name: round(t.isolation, 6)
                                      for t in sp.tenants},
                        "isolation_bound": {
                            t.name: round(t.isolation_bound, 6)
                            for t in sp.tenants},
                        "exec_sparse_s": exec_s,
                        # the full artifact, re-verified by verify_gate
                        "shared_plan": sp.to_dict(),
                    })
    return rows


def check_gates(rows: list[dict]) -> list[str]:
    errors = []
    tol = 1 + 1e-9
    for row in rows:
        key = (f"sharing={row['sharing']} K={row['K']} n={row['n']} "
               f"delta={row['delta']}")
        if row["shared_s"] > row["serialized_s"] * tol:
            errors.append(f"{key}: shared makespan {row['shared_s']} > "
                          f"serialized {row['serialized_s']}")
        if row["weighted_s"] > row["serialized_weighted_s"] * tol:
            errors.append(f"{key}: shared weighted completion "
                          f"{row['weighted_s']} > serialized "
                          f"{row['serialized_weighted_s']}")
        for name, iso in row["isolation"].items():
            bound = row["isolation_bound"][name]
            if iso > bound * tol:
                errors.append(f"{key}: tenant {name} isolation {iso} "
                              f"exceeds its bound {bound}")
            if row["sharing"] == "port-partition" and abs(iso - 1.0) > 1e-9:
                errors.append(f"{key}: port-partitioned tenant {name} is "
                              f"not perfectly isolated (ratio {iso})")
    return errors


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for CI (subset of the full grid so the "
                         "committed baseline still covers every row)")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = bench_grid(ks=(2,), ns=(16,), deltas=(10e-6, 15e-3))
    else:
        rows = bench_grid()
    print("sharing,K,n,delta,phases,shared_s,serialized_s,win,weighted_win,"
          "max_isolation")
    for row in rows:
        print(f"{row['sharing']},{row['K']},{row['n']},{row['delta']},"
              f"{row['phases']},{row['shared_s']:.6e},"
              f"{row['serialized_s']:.6e},{row['win_vs_serialized']},"
              f"{row['weighted_win']},"
              f"{max(row['isolation'].values()):.4f}")
    errors = check_gates(rows)
    if errors:
        # gate first: never overwrite the committed baseline with violating data
        for e in errors:
            print(f"# FAIL: {e}", file=sys.stderr)
        sys.exit(1)
    if args.json:
        out = {
            "meta": {
                "what": "multi-tenant fabric sharing: port-partitioned and "
                        "time-sliced shared planning vs naive serialization "
                        "over K x n x delta x sharing mode "
                        "(repro.workloads.tenancy, BENCH_tenancy baseline)",
            },
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
