"""Roofline-term derivation from dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
  memory term     = HLO_bytes_per_device / HBM_bandwidth     [s]
  collective term = collective_bytes_per_device / ICI_link_bw [s]

cost_analysis()/HLO shapes are post-SPMD (per-partition), so the per-device
convention divides by *one* chip's peak — equivalent to the global
formulation HLO_total/(chips x peak).

Also derives MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per device and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundant
compute; note train steps do fwd+bwd so the ideal HLO count is ~3x the
2*N*D forward and the ratio's ceiling is ~1 by the 6ND convention, minus
remat recompute and attention FLOPs which 6ND ignores).
"""
from __future__ import annotations

import glob
import json
import os

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

TRAIN_TOKENS = {"train_4k": 4096 * 256}
SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def load_cells(directory: str = "results/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def derive(cell: dict, devices: int | None = None) -> dict | None:
    if "error" in cell:
        return None
    n_dev = devices or cell["devices"]
    cal = cell.get("calibrated")
    if cal:  # depth-calibrated costs (scan bodies are cost-counted once)
        flops = cal["flops"]
        bytes_acc = cal["bytes_accessed"]
        coll = cal["collective_bytes"]
    else:
        flops = cell.get("flops") or 0.0
        bytes_acc = cell.get("bytes_accessed") or 0.0
        coll = cell["collectives"]["total_bytes"]

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    # model flops per device (6ND for train incl. backward; 2ND for fwd-only)
    tokens = SHAPE_TOKENS.get(cell["shape"], 0)
    n_active = cell.get("active_params") or cell.get("params") or 0
    mult = 6 if cell["mode"] == "train" else 2
    model_flops_global = mult * n_active * tokens
    model_flops_dev = model_flops_global / n_dev
    useful_ratio = model_flops_dev / flops if flops else 0.0
    # ideal step time: the model's own compute, or the mandatory read set
    # (params + optimizer state + caches = per-device argument bytes),
    # whichever dominates.  Decode steps are argument-read bound by nature.
    arg_bytes = (cell.get("memory") or {}).get("argument_size_bytes") or 0
    ideal = max(model_flops_dev / PEAK_FLOPS, arg_bytes / HBM_BW)
    frac = ideal / bound if bound else 0.0

    return {
        **{k: cell[k] for k in ("arch", "shape", "mesh", "devices", "mode")},
        "terms_s": terms,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops_per_dev": model_flops_dev,
        "useful_ratio": useful_ratio,
        "roofline_fraction": frac,
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "collective_bytes": coll,
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        t = r["terms_s"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} "
            f"| {t['collective']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |")
    return hdr + "\n".join(lines) + "\n"


def main(directory: str = "results/dryrun", out: str = "results/roofline.md"):
    rows = [d for c in load_cells(directory) if (d := derive(c))]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    md = markdown_table(rows)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write(md)
    with open(out.replace(".md", ".json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(md)
    return rows


if __name__ == "__main__":
    main()
