"""Planner DP benchmark: all-R single-pass DP vs the legacy per-R loop.

Times full candidate-set generation (all three collectives, every strategy
family, every R) and counts `_partition_dp` cell relaxations for both the
current all-R implementation (`core.schedules.candidate_schedules`, one DP
table per family with O(1) segment costs) and the pre-planner per-R
reference (`core.schedules._legacy_candidate_schedules`, one capped DP per
(family, R) with O(segment) costs).

Run via ``make plan-bench``; results land in BENCH_planner.json and the CI
smoke job re-runs it on every push to catch DP-work regressions.  The
acceptance bar is relaxation_ratio >= 5 at n = 384 (also asserted in
tests/test_planner.py).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

KINDS = ("a2a", "rs", "ag")


def bench_candidate_planning(ns=(96, 384), r: int = 2, m: int = 16 * 2**20) -> dict:
    from repro.core import PAPER_DEFAULT
    from repro.core import schedules as S

    cm = PAPER_DEFAULT
    rows = []
    for n in ns:
        S.clear_schedule_caches()
        S.reset_dp_stats()
        t0 = time.perf_counter()
        for kind in KINDS:
            S.candidate_schedules(kind, n, float(m), cm, r=r)
        us_all = (time.perf_counter() - t0) * 1e6
        stats_all = S.dp_stats()

        S.reset_dp_stats()
        t0 = time.perf_counter()
        for kind in KINDS:
            S._legacy_candidate_schedules(kind, n, float(m), cm, r=r)
        us_per_r = (time.perf_counter() - t0) * 1e6
        stats_per_r = S.dp_stats()

        rows.append({
            "n": n, "r": r, "m_bytes": m, "kinds": list(KINDS),
            "relaxations_all_r": stats_all["relaxations"],
            "relaxations_per_r": stats_per_r["relaxations"],
            "relaxation_ratio": round(
                stats_per_r["relaxations"] / max(1, stats_all["relaxations"]), 2),
            "dp_calls_all_r": stats_all["dp_calls"],
            "dp_calls_per_r": stats_per_r["dp_calls"],
            "candidate_gen_us_all_r": round(us_all, 1),
            "candidate_gen_us_per_r": round(us_per_r, 1),
            "wall_speedup": round(us_per_r / max(1e-9, us_all), 2),
        })
    return {
        "meta": {
            "what": "full candidate-set planning: all-R single-pass DP vs "
                    "legacy per-R loop (DP work only; candidate evaluation "
                    "via collective_time is identical on both sides)",
            "cost_model": {"alpha_s": cm.alpha_s, "alpha_h": cm.alpha_h,
                           "bandwidth": cm.bandwidth, "delta": cm.delta},
        },
        "rows": rows,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ns", default="96,384")
    ap.add_argument("--radix", type=int, default=2)
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--min-ratio", type=float, default=5.0,
                    help="fail (exit 1) if any row's relaxation_ratio drops "
                         "below this — the DP-work regression gate run in CI")
    args = ap.parse_args(argv)
    out = bench_candidate_planning(
        ns=tuple(int(v) for v in args.ns.split(",")), r=args.radix)
    print("n,r,relax_all_r,relax_per_r,ratio,us_all_r,us_per_r,wall_speedup")
    for row in out["rows"]:
        print(f"{row['n']},{row['r']},{row['relaxations_all_r']},"
              f"{row['relaxations_per_r']},{row['relaxation_ratio']},"
              f"{row['candidate_gen_us_all_r']},{row['candidate_gen_us_per_r']},"
              f"{row['wall_speedup']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(out['rows'])} rows to {args.json}")
    bad = [r for r in out["rows"] if r["relaxation_ratio"] < args.min_ratio]
    if bad:
        print(f"# FAIL: relaxation_ratio below {args.min_ratio} at "
              f"n={[r['n'] for r in bad]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
