"""Schedule explorer: sweep the design space of Section 4 from the CLI.

Reproduces any point of Figs 5-12 on demand, e.g.:

  PYTHONPATH=src python examples/schedule_explorer.py \
      --collective rs --n 128 --m-mb 16 --delta-us 150

and the generalized scenario space beyond the paper (any n, radix r):

  PYTHONPATH=src python examples/schedule_explorer.py \
      --collective a2a --n 96 --radix 3 --m-mb 4

prints the BRIDGE plan (schedule + R), the planner's ranked alternatives
table, every baseline, and the speedups.  Planning goes through the unified
`repro.planner` API; pass --save-plan to write the lossless PlanResult JSON.

Whole-workload traces (back-to-back collectives with fabric-state carryover,
see repro/workloads/):

  PYTHONPATH=src python examples/schedule_explorer.py \
      --trace mixed --n 48 --delta-us 1000

plans the trace jointly (carryover) and prints the per-collective schedules,
boundary reuse, and the amortization win over cold-fabric re-planning.

Fault injection (add --faults to a --trace run):

  PYTHONPATH=src python examples/schedule_explorer.py \
      --trace mixed --n 48 --delta-us 1000 --faults spec.json

loads a `repro.core.faults.FaultTimeline` JSON spec, replays the planned
trace under it, and prints the degraded state (committed prefix, surviving
world, chunk fate) plus the resume-from-snapshot vs restart-from-scratch
comparison.  A spec whose fault times all fall at/after the clean run's
completion is rejected up front (ValueError): such a timeline never takes
effect and loading it is a mistake, not a degraded run.
"""
import argparse

from repro.core import PAPER_DEFAULT, baselines, collective_time
from repro.planner import PlanRequest, Planner

MB = 1024.0 ** 2


def explore_trace(args, cm):
    from repro.workloads import (decode_ag_trace, mixed_trace, moe_a2a_trace,
                                 plan_trace, train_step_trace)

    trace = {
        "moe": lambda: moe_a2a_trace(args.n, layers=3),
        "train": lambda: train_step_trace(args.n, steps=2, buckets=2),
        "decode": lambda: decode_ag_trace(args.n, decode_steps=6, jitter=0.25),
        "mixed": lambda: mixed_trace(args.n),
    }[args.trace]()
    plans = {mode: plan_trace(trace, cm, mode=mode)
             for mode in ("static", "cold", "carryover")}
    carry = plans["carryover"]
    print(f"trace {trace.name!r}: {len(trace)} events -> "
          f"{len(carry.phases)} phases at n={args.n}, "
          f"delta={args.delta_us} us\n")
    print("  carryover plan (joint DP, boundary delta only on changed circuits):")
    for i, p in enumerate(carry.phases):
        boundary = ""
        if i:
            c = carry.boundary_changed[i - 1]
            boundary = ("  boundary: free (fabric reused)" if c == 0
                        else f"  boundary: {c} circuits swap "
                             f"({carry.boundary_cost[i - 1] * 1e3:.3f} ms)")
        print(f"    [{i:2d}] {p.tag:<24s} {p.strategy:<18s} "
              f"{p.time * 1e3:9.3f} ms{boundary}")
    print(f"\n  free boundaries: {carry.free_boundaries}/"
          f"{len(carry.boundary_cost)}")
    t_carry = carry.total_time
    for mode in ("carryover", "cold", "static"):
        t = plans[mode].total_time
        print(f"  {mode:<10s} {t * 1e3:10.3f} ms   carryover win "
              f"{t / t_carry:6.2f}x")
    if args.save_plan:
        with open(args.save_plan, "w") as f:
            f.write(carry.to_json(indent=1))
        print(f"\nwrote trace plan to {args.save_plan}")
    if args.faults:
        explore_faults(args, cm, trace, carry)


def explore_faults(args, cm, trace, carry):
    from repro.core import FabricSim, FaultTimeline
    from repro.workloads import run_with_recovery

    with open(args.faults) as f:
        faults = FaultTimeline.from_json(f.read())
    clean = FabricSim(mode="sparse", chunks_per_msg=8).run_trace(
        carry.fabric_phases(), cm)
    # reject specs that never take effect before running anything
    faults.check_horizon(clean.completion)
    rr = run_with_recovery(trace, cm, faults=faults)
    ds = rr.degraded
    print(f"\n  fault: {ds.fault.kind} at node {ds.fault.node}, "
          f"t={ds.fault.time * 1e3:.3f} ms (clean completion "
          f"{clean.completion * 1e3:.3f} ms)")
    print(f"    committed: {ds.completed_phases} phases / "
          f"{len(rr.committed_events)} events; surviving world "
          f"n={ds.n} -> n'={ds.new_n}")
    print(f"    chunks: {ds.committed_chunks} committed, "
          f"{ds.lost_chunks} lost, {ds.requeued_chunks} re-queued "
          f"(policy={ds.policy})")
    print(f"    re-plan: {len(rr.recovery_plan.phases)} phases at n'="
          f"{ds.new_n}, bit-identical to clean reduced run: "
          f"{rr.bit_identical}")
    print(f"    resume from snapshot {rr.recovery_total * 1e3:10.3f} ms")
    print(f"    restart from scratch {rr.restart_total * 1e3:10.3f} ms   "
          f"recovery ratio {rr.recovery_ratio:.3f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--collective", default="a2a",
                    choices=["a2a", "rs", "ag", "ar"])
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--m-mb", type=float, default=4.0)
    ap.add_argument("--delta-us", type=float, default=10.0)
    ap.add_argument("--alpha-h-us", type=float, default=1.0)
    ap.add_argument("--ports", type=int, default=None,
                    help="OCS ports (< 2n engages the Section 3.7 model)")
    ap.add_argument("--radix", type=int, default=2,
                    help="Bruck radix r (mixed-radix generalization; 2 = paper)")
    ap.add_argument("--fabric", default="ocs",
                    choices=["ocs", "static", "ocs-overlap", "ocs-sim"],
                    help="'ocs-overlap' = sparse reconfiguration with "
                         "hidden-delta credit (see core/fabricsim.py); "
                         "'ocs-sim' = every candidate event-scored by the "
                         "vectorized batch fabric engine (core/batchsim.py)")
    ap.add_argument("--overlap", type=float, default=0.0,
                    help="fraction of delta hidden behind communication "
                         "(requires --fabric ocs-overlap or ocs-sim)")
    ap.add_argument("--max-r", type=int, default=None,
                    help="cap on reconfigurations R")
    ap.add_argument("--top", type=int, default=5,
                    help="alternatives table rows to print")
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="write the PlanResult JSON (lossless, cacheable)")
    ap.add_argument("--trace", default=None,
                    choices=["moe", "train", "decode", "mixed"],
                    help="plan a whole workload trace (carryover vs cold vs "
                         "static) instead of a single collective")
    ap.add_argument("--faults", default=None, metavar="SPEC.json",
                    help="FaultTimeline JSON to inject into the --trace run "
                         "(fault times must fall inside the clean run's "
                         "horizon)")
    args = ap.parse_args()
    if args.faults and not args.trace:
        ap.error("--faults requires --trace (faults strike a running trace)")

    n, m = args.n, args.m_mb * MB
    cm = PAPER_DEFAULT.replace(delta=args.delta_us * 1e-6,
                               alpha_h=args.alpha_h_us * 1e-6)
    if args.trace:
        explore_trace(args, cm)
        return

    hidden_fabrics = ("ocs-overlap", "ocs-sim")
    res = Planner().plan(PlanRequest(
        kind=args.collective, n=n, m_bytes=m, cost_model=cm, r=args.radix,
        fabric=args.fabric, overlap=args.overlap,
        paper_faithful=(args.fabric not in hidden_fabrics),
        max_R=args.max_r, ports=args.ports))
    t_bridge = res.predicted_time
    if args.collective == "ar":
        print(f"BRIDGE plan: {res.strategy}")
        print(f"  rs x={res.rs_schedule.x}  ag x={res.ag_schedule.x}")
    else:
        print(f"BRIDGE plan: {res.strategy}  x={res.schedule.x}")
        if args.fabric not in hidden_fabrics:
            t_bridge = collective_time(res.schedule, m, cm, ports=args.ports).total
    print(f"  completion time {t_bridge * 1e3:.3f} ms"
          + ("  (batched event simulation)" if args.fabric == "ocs-sim" else ""))

    print(f"\n  ranked alternatives (top {args.top} of {len(res.alternatives)}):")
    for alt in res.alternatives[:args.top]:
        r_str = f"R={alt.R}" if alt.R is not None else "-"
        print(f"    {alt.strategy:<22s} {alt.impl:<6s} {r_str:<6s}"
              f" {alt.predicted_time * 1e3:10.3f} ms")
    print()

    # under ocs-overlap / ocs-sim, score reconfiguring baselines with the
    # same fabric semantics so the printed speedups compare like with like
    hidden = args.fabric in hidden_fabrics
    kind = args.collective
    if kind == "ar":
        if args.fabric == "ocs-sim":
            from repro.core import batch_completion_times, static_schedule
            ts = batch_completion_times(
                [static_schedule("rs", n, args.radix),
                 static_schedule("ag", n, args.radix)], m, cm,
                overlap=args.overlap, chunks_per_msg=8)
            t_static = float(ts[0] + ts[1])
        else:
            t_static = (baselines.s_bruck("rs", n, m, cm, r=args.radix).total
                        + baselines.s_bruck("ag", n, m, cm, r=args.radix).total)
        rows = [("S-BRUCK (static)", t_static)]
    else:
        if args.fabric == "ocs-sim":
            from repro.core import (batch_completion_times,
                                    every_step_schedule, static_schedule)
            ts = batch_completion_times(
                [static_schedule(kind, n, args.radix),
                 every_step_schedule(kind, n, args.radix)], m, cm,
                overlap=args.overlap, chunks_per_msg=8)
            t_sbruck, t_gbruck = float(ts[0]), float(ts[1])
        elif hidden:
            from repro.core import collective_time_overlap, every_step_schedule
            t_sbruck = baselines.s_bruck(kind, n, m, cm, r=args.radix).total
            t_gbruck = collective_time_overlap(
                every_step_schedule(kind, n, args.radix), m, cm,
                args.overlap).total
        else:
            t_sbruck = baselines.s_bruck(kind, n, m, cm, r=args.radix).total
            t_gbruck = baselines.g_bruck(kind, n, m, cm, r=args.radix).total
        rows = [("S-BRUCK (static)", t_sbruck),
                ("G-BRUCK (every step)", t_gbruck)]
    if kind in ("rs", "ag", "ar"):
        rows.append(("RING", baselines.ring(kind, n, m, cm).total))
    if kind in ("rs", "ag") and not hidden:
        # R-HD's schedule is internal to the baseline; it cannot be re-scored
        # with the overlap credit, so skip it on the ocs-overlap fabric
        t_rhd, R = baselines.r_hd_optimal(kind, n, m, cm, r=args.radix)
        rows.append((f"R-HD (R*={R})", t_rhd.total))
    for name, t in rows:
        print(f"  {name:<22s} {t * 1e3:10.3f} ms   bridge speedup "
              f"{t / t_bridge:6.2f}x")

    if args.save_plan:
        with open(args.save_plan, "w") as f:
            f.write(res.to_json(indent=1))
        print(f"\nwrote plan to {args.save_plan}")


if __name__ == "__main__":
    main()
