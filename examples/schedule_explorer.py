"""Schedule explorer: sweep the design space of Section 4 from the CLI.

Reproduces any point of Figs 5-12 on demand, e.g.:

  PYTHONPATH=src python examples/schedule_explorer.py \
      --collective rs --n 128 --m-mb 16 --delta-us 150

and the generalized scenario space beyond the paper (any n, radix r):

  PYTHONPATH=src python examples/schedule_explorer.py \
      --collective a2a --n 96 --radix 3 --m-mb 4

prints every baseline, the BRIDGE plan (schedule + R), and the speedups.
"""
import argparse

from repro.core import (PAPER_DEFAULT, baselines, collective_time, plan)

MB = 1024.0 ** 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--collective", default="a2a", choices=["a2a", "rs", "ag"])
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--m-mb", type=float, default=4.0)
    ap.add_argument("--delta-us", type=float, default=10.0)
    ap.add_argument("--alpha-h-us", type=float, default=1.0)
    ap.add_argument("--ports", type=int, default=None,
                    help="OCS ports (< 2n engages the Section 3.7 model)")
    ap.add_argument("--radix", type=int, default=2,
                    help="Bruck radix r (mixed-radix generalization; 2 = paper)")
    args = ap.parse_args()

    n, m = args.n, args.m_mb * MB
    cm = PAPER_DEFAULT.replace(delta=args.delta_us * 1e-6,
                               alpha_h=args.alpha_h_us * 1e-6)

    p = plan(args.collective, n, m, cm, paper_faithful=True, r=args.radix)
    t_bridge = collective_time(p.schedule, m, cm, ports=args.ports).total
    print(f"BRIDGE plan: {p.strategy}  x={p.schedule.x}")
    print(f"  completion time {t_bridge * 1e3:.3f} ms\n")

    rows = [("S-BRUCK (static)",
             baselines.s_bruck(args.collective, n, m, cm, r=args.radix).total),
            ("G-BRUCK (every step)",
             baselines.g_bruck(args.collective, n, m, cm, r=args.radix).total)]
    if args.collective in ("rs", "ag"):
        rows.append(("RING", baselines.ring(args.collective, n, m, cm).total))
        t_rhd, R = baselines.r_hd_optimal(args.collective, n, m, cm,
                                          r=args.radix)
        rows.append((f"R-HD (R*={R})", t_rhd.total))
    for name, t in rows:
        print(f"  {name:<22s} {t * 1e3:10.3f} ms   bridge speedup "
              f"{t / t_bridge:6.2f}x")


if __name__ == "__main__":
    main()
