"""End-to-end training driver: train a reduced LM for a few hundred steps.

Demonstrates the full stack — model zoo config, synthetic data pipeline,
AdamW, checkpointing, and (on a multi-device host) BRIDGE gradient sync.
Loss must fall well below the uniform baseline ln(V).

Run:  PYTHONPATH=src python examples/train_lm.py [--arch stablelm-3b]
      [--steps 300] [--grad-sync bridge]
"""
import argparse
import math

from repro import configs
from repro.launch.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--grad-sync", default="gspmd",
                    choices=["gspmd", "bridge", "bridge-compressed"])
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    tc = TrainConfig(arch=args.arch, steps=args.steps,
                     batch_size=args.batch_size, seq_len=args.seq_len,
                     grad_sync=args.grad_sync,
                     checkpoint_dir=args.checkpoint_dir,
                     lr=1e-3, warmup=20)
    cfg = configs.get(args.arch).scaled_down()
    uniform = math.log(cfg.vocab_size)
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model} "
          f"V={cfg.vocab_size}); uniform-baseline loss = ln(V) = {uniform:.3f}")

    def progress(msg):
        print(msg, flush=True)

    _, _, losses = train(tc, progress=progress)
    print(f"\nfirst loss {losses[0]:.3f} -> last loss {losses[-1]:.3f} "
          f"(uniform {uniform:.3f})")
    assert losses[-1] < uniform * 0.8, "model failed to learn"
    print("OK: model learned the synthetic structure.")


if __name__ == "__main__":
    main()
