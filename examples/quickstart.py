"""Quickstart: BRIDGE schedule synthesis + cost model in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (baselines, collective_time, ocs_preset,
                        periodic_a2a, plan, rs_transmission_optimal)

MB = 1024.0 ** 2


def main():
    n = 64  # GPUs on the optical ring

    print("=== 1. The paper's Table 1: where to reconfigure ===")
    for R in (1, 2):
        a2a = periodic_a2a(n, R)
        rs = rs_transmission_optimal(n, R)
        print(f" R={R}: all-to-all {a2a.x}  (periodic)")
        print(f"       reduce-scatter {rs.x}  (early)")

    print("\n=== 2. How much does one reconfiguration buy? (A2A, 4 MB) ===")
    cm = ocs_preset("rotornet_infocus")  # 10 us reconfiguration delay
    static = collective_time(periodic_a2a(n, 0), 4 * MB, cm)
    one = collective_time(periodic_a2a(n, 1), 4 * MB, cm)
    print(f" static ring : {static.total * 1e3:8.3f} ms "
          f"(hops {static.hop_latency * 1e3:.3f} ms, "
          f"tx {static.transmission * 1e3:.3f} ms)")
    print(f" R=1 subrings: {one.total * 1e3:8.3f} ms "
          f"(incl. {one.reconfig * 1e6:.0f} us reconfig) "
          f"-> {static.total / one.total:.2f}x")

    print("\n=== 3. Optimal R, per Section 3.6 ===")
    for m in (64e3, 4 * MB, 256 * MB):
        p = plan("a2a", n, m, cm, paper_faithful=True)
        print(f" m={m / MB:8.3f} MB: {p.strategy:<16s} "
              f"t={p.predicted_time * 1e3:8.3f} ms")

    print("\n=== 4. AllReduce: BRIDGE vs the bandwidth-optimal RING ===")
    cm_ar = cm.replace(delta=150e-6)  # paper Fig. 9: delta = 0.15 ms case
    for m in (64e3, 4 * MB, 256 * MB):
        t_bridge = baselines.bridge_allreduce(n, m, cm_ar).total
        t_ring = baselines.ring("ar", n, m, cm_ar).total
        winner = "BRIDGE" if t_bridge < t_ring else "RING"
        print(f" m={m / MB:8.3f} MB: bridge {t_bridge * 1e3:8.3f} ms "
              f"ring {t_ring * 1e3:8.3f} ms -> {winner}")
    print("\n(large messages -> RING wins: exactly the paper's Fig. 9/12.)")


if __name__ == "__main__":
    main()
