"""Serving example: prefill + batched greedy decode with KV/recurrent caches.

Exercises all three cache families of the zoo:
  - sliding-window ring buffers (gemma3-4b),
  - MLA latent cache with weight-absorbed decode (minicpm3-4b),
  - O(1) recurrent state (rwkv6-3b).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-3b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import decode_step, forward, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b", choices=list(configs.ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get(args.arch).scaled_down()
    if cfg.enc_dec or cfg.frontend != "none":
        raise SystemExit("pick a text-only arch for this example")
    params = init_params(cfg, jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    max_seq = args.prompt_len + args.new_tokens + 1

    t0 = time.time()
    logits, caches = prefill(cfg, params, {"tokens": prompt}, max_seq=max_seq)
    print(f"prefill {args.prompt_len} tokens x {args.batch} seqs: "
          f"{time.time() - t0:.2f}s")

    step = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.new_tokens - 1} steps in {dt:.2f}s "
          f"({(args.new_tokens - 1) * args.batch / dt:.1f} tok/s)")
    print("generated ids (batch 0):", gen[0].tolist())

    # consistency check vs full forward (greedy path must agree)
    full = jnp.concatenate([prompt, gen], axis=1)
    ref = forward(cfg, params, {"tokens": full}, mode="train").logits
    ref_tok = jnp.argmax(ref[:, args.prompt_len - 1:-1, :], axis=-1)
    agree = float((ref_tok == gen).mean())
    print(f"greedy agreement with full forward: {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
