"""arctic-480b — dense-MoE hybrid: 128 experts top-2 + dense residual FFN.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
expert d_ff=4864, 128e top-2, dense residual d_ff=4864, vocab=32000.
Expert weights shard over the 'model' axis (EP); the dispatch all-to-all is
the paper technique's most representative binding (DESIGN.md S4).
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    ffn="moe",
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_d_ff=4864, group_size=1024),
)
