"""gemma3-4b — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, sliding window 1024, tied embeddings.
Pattern period: 5 local + 1 global covers 34 = 5*6 + 4 layers.
Counts as sub-quadratic for long_500k: decode-time global layers are O(S)
per token and the stack is dominated by the 1024-token window (DESIGN.md S4).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    tied_embeddings=True,
)
