"""rwkv6-3b — Finch: attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536,
head_dim 64 (40 heads).  Time-mix (wkv6 kernel) + channel-mix blocks;
O(1) state => runs long_500k.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    pattern=("rwkv6",),
    rwkv_head_dim=64,
)
