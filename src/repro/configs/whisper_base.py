"""whisper-base — encoder-decoder; conv audio frontend is a STUB.

[arXiv:2212.04356; unverified]  6L enc + 6L dec, d_model=512 8H (kv=8)
d_ff=2048 vocab=51865, GELU FFN.  input_specs() provides precomputed
mel-conv frame embeddings (B, 1500, d_model); decoder cross-attends with
cached K/V after prefill.  Decode shapes exercise the decoder; RoPE is used
for decoder self-attention in place of learned positions (DESIGN.md S8).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    ffn="gelu",
    enc_dec=True,
    num_encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
)
