"""Assigned-architecture registry: one module per arch, `CONFIG` in each.

Usage: repro.configs.get("rwkv6-3b") -> ArchConfig;
       repro.configs.ARCHS lists all ten assigned ids.
"""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, SHAPES, ShapeConfig

ARCHS: tuple[str, ...] = (
    "recurrentgemma-9b",
    "internvl2-26b",
    "minicpm3-4b",
    "command-r-plus-104b",
    "gemma3-4b",
    "stablelm-3b",
    "whisper-base",
    "arctic-480b",
    "qwen3-moe-235b-a22b",
    "rwkv6-3b",
)

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cells() -> list[tuple[str, str]]:
    """All 40 assigned (arch, shape) cells; skips are resolved by runnable()."""
    return [(a, s) for a in ARCHS for s in SHAPES]


def runnable(arch: str, shape: str) -> tuple[bool, str]:
    """(should_run, reason).  long_500k only for sub-quadratic archs."""
    cfg = get(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md S4)"
    return True, ""


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "get", "cells",
           "runnable"]
