"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

[arXiv:2404.16821; hf]  Backbone only: 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553.  The vision tower is a stub: input_specs() provides
precomputed patch embeddings (B, frontend_seq, d_model), projected and
prepended to the text embeddings (DESIGN.md S4).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="patch_stub",
    frontend_seq=1024,
)
