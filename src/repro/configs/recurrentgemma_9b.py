"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1:2 attn:rnn.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, local window 2048, recurrence width = d_model, tied embeddings.
Pattern period (rglru, rglru, local) covers 38 = 12*3 + 2 layers.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    rglru_width=4096,
    tied_embeddings=True,
)
