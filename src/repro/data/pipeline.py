"""Deterministic, shard-aware, resumable synthetic-LM data pipeline.

Design requirements at 1000+ nodes (DESIGN.md S5):
  - *counter-based*: batch(step, shard) is a pure function of (seed, step,
    shard), so restart/resume = "set the step counter"; no iterator state to
    checkpoint, no skew after elastic re-sharding (shards are re-derived from
    the new topology).
  - *straggler-tolerant*: shards are independent; a backup worker can
    recompute any shard's batch bit-identically.

The token stream is a noisy affine-recurrence language
    t_{i+1} = (a * t_i + c + noise) mod V
so a model can actually learn it (loss decreases in examples/train_lm.py),
while remaining fully synthetic and offline.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    noise: float = 0.05
    mult: int = 31
    add: int = 17

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))

    def batch(self, step: int, shard: int, batch_size: int) -> dict:
        """Returns {'tokens': (B, S) int32, 'labels': (B, S) int32}."""
        rng = self._rng(step, shard)
        v, s = self.vocab_size, self.seq_len
        t0 = rng.integers(0, v, size=(batch_size, 1))
        toks = [t0]
        for _ in range(s):
            nxt = (toks[-1] * self.mult + self.add) % v
            flip = rng.random((batch_size, 1)) < self.noise
            rand = rng.integers(0, v, size=(batch_size, 1))
            toks.append(np.where(flip, rand, nxt))
        seqs = np.concatenate(toks, axis=1)  # (B, S+1)
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }

    def global_batch(self, step: int, num_shards: int,
                     per_shard_batch: int) -> dict:
        """Concatenation of all shards' batches (host-side global view)."""
        parts = [self.batch(step, sh, per_shard_batch)
                 for sh in range(num_shards)]
        return {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}


def make_batch_specs(cfg, shape, dtype_tokens=np.int32):
    """ShapeDtypeStructs for one (arch, shape) cell — the dry-run inputs."""
    import jax

    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), dtype_tokens)}
        return specs
    text_S = S - (cfg.frontend_seq if cfg.frontend == "patch_stub" else 0)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, text_S), dtype_tokens),
        "labels": jax.ShapeDtypeStruct((B, text_S), dtype_tokens),
    }
    if cfg.frontend == "patch_stub":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_seq, cfg.d_model), np.float32)
    if cfg.enc_dec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), np.float32)
    return specs
