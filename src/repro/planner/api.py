"""Planner API surface: PlanRequest -> Planner -> PlanResult.

`PlanRequest` describes *what* to plan (collective kind — including the
composite AllReduce ``ar`` = RS + AG —, world size, radix, payload, cost
model, fabric, objective, constraints) and optionally *how* (an explicit
strategy subset from the registry).  `PlanResult` carries the winning
schedule(s), the full `TimeBreakdown`, a ranked table of every evaluated
alternative, and lossless JSON (de)serialization so plans can be cached on
disk and shipped as benchmark artifacts.

All floats survive the JSON round trip bit-exactly (json uses repr), and
schedules are plain (kind, n, x, r) tuples, so
``PlanResult.from_json(res.to_json())`` reconstructs bit-identical schedules.

Fabrics are selected with the typed `FabricKind` enum (re-exported here from
`core.jsonio` together with the multi-tenant `SharingMode`); bare strings
like ``fabric="ocs"`` keep working through a coercion shim but emit a
`DeprecationWarning` — new call sites should write
``fabric=FabricKind.OCS``.  JSON loaders round-trip the enums losslessly
(`to_dict` stores the plain value, `from_dict` re-coerces silently).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Literal

from repro.core.cost_model import CostModel, PAPER_DEFAULT
from repro.core.jsonio import (FabricKind, RequestBase, SharingMode,
                               cost_model_from_dict, cost_model_to_dict,
                               require_keys, require_positive_payload)
from repro.core.schedules import Schedule
from repro.core.simulator import TimeBreakdown

PlanKind = Literal["a2a", "rs", "ag", "ar"]
PLAN_KINDS = ("a2a", "rs", "ag", "ar")
#: typed fabric selector (the old ``Fabric`` string-literal alias)
Fabric = FabricKind
FABRICS = tuple(f.value for f in FabricKind)
Objective = Literal["time", "latency", "transmission"]
OBJECTIVES = ("time", "latency", "transmission")

__all__ = [
    "Candidate", "FABRICS", "Fabric", "FabricKind", "OBJECTIVES",
    "PLAN_KINDS", "PlanKind", "PlanRequest", "PlanResult",
    "RankedAlternative", "SharingMode",
]


@dataclasses.dataclass(frozen=True)
class PlanRequest(RequestBase):
    """One planning problem for the unified `Planner`.

    kind          : 'a2a' | 'rs' | 'ag' | 'ar' (composite AllReduce = RS+AG).
    n, r          : world size and Bruck radix (r=2 is the paper's pattern).
    m_bytes       : total per-node payload in bytes (the paper's m).
    cost_model    : alpha-beta-delta parameters (Section 2).
    fabric        : 'ocs' (reconfigurable, the paper's setting), 'static'
                    (no OCS: only R=0 schedules are feasible; DESIGN.md S3),
                    'ocs-overlap' (sparse reconfiguration with
                    reconfiguration/communication overlap: each boundary is
                    charged `CostModel.delta_sparse(changed, overlap)`
                    instead of a flat delta — see `core.fabricsim`), or
                    'ocs-sim' (event-scored planning: every candidate is
                    completion-timed by the vectorized batch fabric engine,
                    `core.batchsim`, in one call — stragglers, per-port
                    queueing, and pipelining that the analytic score cannot
                    see; requires objective='time').
    overlap       : fraction of delta hidden behind communication, in [0, 1];
                    only meaningful (and only allowed nonzero) for the
                    'ocs-overlap' and 'ocs-sim' fabrics.
    objective     : 'time' (total completion time, Section 3.6), 'latency'
                    (startup + hop latency + reconfig), or 'transmission'
                    (transmission + reconfig) — selects the score used to
                    rank candidates; predicted_time is always the total.
    paper_faithful: restrict to the paper's schedule families (drops the
                    beyond-paper exact-dp strategy).
    strategies    : explicit registry subset (None = all default strategies).
    max_R         : cap on reconfigurations per collective execution; for
                    the composite 'ar' the cap covers RS + AG together (the
                    best split across the phases is searched; the RS->AG
                    transition delta is topology-dependent and not counted).
    delta_budget  : cap on total reconfiguration time R * delta, seconds
                    (combined with max_R; the tighter bound wins).
    ports         : OCS port count; < 2n engages the Section 3.7 blocked-ring
                    distance floor during evaluation (analytic fabrics only;
                    rejected for 'ocs-sim', whose event engine models a
                    full-port OCS).
    init_g        : link offset the fabric was left configured at by a
                    preceding collective (windowed / carryover requests, e.g.
                    the online trace planner).  Candidates are charged the
                    sparse entry-boundary cost of swapping from ``init_g`` to
                    their first link offset, in both score and
                    predicted_time; for the composite 'ar' the entry charge
                    applies to the chosen RS schedule at the composite level.
                    Part of the request's canonical JSON, so the plan cache
                    never serves a plan computed under a different inherited
                    fabric state (requires a reconfigurable fabric).
    tenant        : identity of the tenant this plan is for (multi-tenant
                    fabric sharing, `repro.workloads.tenancy`).  Planning is
                    tenant-independent for identical geometry, but the field
                    is part of the canonical request JSON — and therefore
                    the plan-cache key — so two tenants can never share a
                    cached plan: a later tenant-specific pricing change
                    (per-tenant budgets already differ) must never be served
                    another tenant's stale entry (the same stale-hit bug
                    class `init_g` fixed for carryover state).
    """

    kind: PlanKind
    n: int
    m_bytes: float
    cost_model: CostModel = PAPER_DEFAULT
    r: int = 2
    fabric: FabricKind = FabricKind.OCS
    overlap: float = 0.0
    objective: Objective = "time"
    paper_faithful: bool = False
    strategies: tuple[str, ...] | None = None
    max_R: int | None = None
    delta_budget: float | None = None
    ports: int | None = None
    init_g: int | None = None
    tenant: str | None = None

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"kind must be one of {PLAN_KINDS}, got {self.kind!r}")
        # shared n / r / m_bytes / delta_budget / fabric (coerced, bare
        # strings warn) / overlap / init_g validation (core.jsonio)
        self._validate_base()
        if self.objective not in OBJECTIVES:
            raise ValueError(
                f"objective must be one of {OBJECTIVES}, got {self.objective!r}")
        if self.fabric == FabricKind.OCS_SIM and self.objective != "time":
            raise ValueError(
                f"fabric='ocs-sim' event-scores total completion time only; "
                f"objective must be 'time', got {self.objective!r}")
        if self.fabric == FabricKind.OCS_SIM and self.ports is not None:
            raise ValueError(
                "fabric='ocs-sim' simulates a full-port OCS (the batch "
                "engine has no Section 3.7 blocked-ring model); drop ports "
                "or use the analytic 'ocs'/'ocs-overlap' fabrics")
        if self.max_R is not None and self.max_R < 0:
            raise ValueError(f"max_R must be >= 0, got {self.max_R}")
        if self.ports is not None and self.ports < 1:
            raise ValueError(f"ports must be >= 1, got {self.ports}")
        if self.strategies is not None and not isinstance(self.strategies, tuple):
            object.__setattr__(self, "strategies", tuple(self.strategies))

    def effective_max_R(self) -> int | None:
        """Tightest reconfiguration cap implied by max_R and delta_budget."""
        caps = []
        if self.max_R is not None:
            caps.append(self.max_R)
        if self.delta_budget is not None:
            d = self.cost_model.delta
            caps.append(int(self.delta_budget / d) if d > 0 else None)
            caps = [c for c in caps if c is not None]
        return min(caps) if caps else None

    def to_dict(self) -> dict:
        return {
            "kind": self.kind, "n": self.n, "m_bytes": self.m_bytes,
            "cost_model": cost_model_to_dict(self.cost_model),
            "r": self.r, "fabric": self.fabric.value, "overlap": self.overlap,
            "objective": self.objective,
            "paper_faithful": self.paper_faithful,
            "strategies": list(self.strategies) if self.strategies is not None else None,
            "max_R": self.max_R, "delta_budget": self.delta_budget,
            "ports": self.ports, "init_g": self.init_g,
            "tenant": self.tenant,
        }

    @staticmethod
    def from_dict(d: dict) -> "PlanRequest":
        require_keys(
            d, required=("kind", "n", "m_bytes", "cost_model"),
            optional=("r", "fabric", "overlap", "objective",
                      "paper_faithful", "strategies", "max_R",
                      "delta_budget", "ports", "init_g", "tenant"),
            what="PlanRequest")
        strategies = d.get("strategies")
        return PlanRequest(
            kind=d["kind"], n=d["n"],
            m_bytes=require_positive_payload(d["m_bytes"], "PlanRequest"),
            cost_model=cost_model_from_dict(d["cost_model"], "PlanRequest"),
            r=d.get("r", 2),
            fabric=FabricKind.coerce(d.get("fabric", "ocs"), warn=False),
            overlap=d.get("overlap", 0.0),
            objective=d.get("objective", "time"),
            paper_faithful=d.get("paper_faithful", False),
            strategies=tuple(strategies) if strategies is not None else None,
            max_R=d.get("max_R"), delta_budget=d.get("delta_budget"),
            ports=d.get("ports"), init_g=d.get("init_g"),
            tenant=d.get("tenant"),
        )


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One evaluable alternative produced by a strategy.

    ``schedule`` is None for non-Bruck implementations (the ring baseline),
    in which case ``impl`` tells the planner how to cost it.
    """

    name: str
    schedule: Schedule | None = None
    impl: str = "bruck"  # 'bruck' | 'ring'


@dataclasses.dataclass(frozen=True)
class RankedAlternative:
    """One row of the PlanResult alternatives table (best score first)."""

    strategy: str               # candidate name, e.g. 'periodic(R=2)'
    impl: str                   # 'bruck' | 'ring'
    predicted_time: float       # total modeled completion time [s]
    score: float                # value of the request's objective
    R: int | None = None        # reconfiguration count (None for non-Bruck)
    x: tuple[int, ...] | None = None  # schedule bits (None for non-Bruck / ar)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["x"] = list(self.x) if self.x is not None else None
        return d

    @staticmethod
    def from_dict(d: dict) -> "RankedAlternative":
        require_keys(d, required=("strategy", "impl", "predicted_time",
                                  "score"),
                     optional=("R", "x"), what="RankedAlternative")
        x = d.get("x")
        return RankedAlternative(
            strategy=d["strategy"], impl=d["impl"],
            predicted_time=d["predicted_time"], score=d["score"],
            R=d.get("R"), x=tuple(x) if x is not None else None)


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """Outcome of one `Planner.plan` call.

    For single collectives (a2a / rs / ag) the winner is ``schedule``; for
    the composite ``ar`` the winner is the (rs_schedule, ag_schedule) pair
    (None when the ring implementation won or the fabric is static-planned
    without explicit schedules).  ``alternatives`` ranks every evaluated
    candidate by the request's objective, best first.
    """

    request: PlanRequest
    strategy: str
    impl: str
    predicted_time: float
    breakdown: TimeBreakdown
    schedule: Schedule | None = None
    rs_schedule: Schedule | None = None
    ag_schedule: Schedule | None = None
    alternatives: tuple[RankedAlternative, ...] = ()

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "request": self.request.to_dict(),
            "strategy": self.strategy,
            "impl": self.impl,
            "predicted_time": self.predicted_time,
            "breakdown": self.breakdown.to_dict(),
            "schedule": _schedule_to_dict(self.schedule),
            "rs_schedule": _schedule_to_dict(self.rs_schedule),
            "ag_schedule": _schedule_to_dict(self.ag_schedule),
            "alternatives": [a.to_dict() for a in self.alternatives],
        }

    @staticmethod
    def from_dict(d: dict) -> "PlanResult":
        require_keys(
            d, required=("request", "strategy", "impl", "predicted_time",
                         "breakdown"),
            optional=("version", "schedule", "rs_schedule", "ag_schedule",
                      "alternatives"),
            what="PlanResult")
        request = PlanRequest.from_dict(d["request"])
        schedules = {
            name: _schedule_from_dict(d.get(name))
            for name in ("schedule", "rs_schedule", "ag_schedule")
        }
        for name, sched in schedules.items():
            if sched is None:
                continue
            if sched.n != request.n or sched.r != request.r:
                raise ValueError(
                    f"PlanResult {name} is for (n={sched.n}, r={sched.r}) "
                    f"but the request is for (n={request.n}, r={request.r})")
        return PlanResult(
            request=request,
            strategy=d["strategy"],
            impl=d["impl"],
            predicted_time=d["predicted_time"],
            breakdown=TimeBreakdown.from_dict(d["breakdown"]),
            schedule=schedules["schedule"],
            rs_schedule=schedules["rs_schedule"],
            ag_schedule=schedules["ag_schedule"],
            alternatives=tuple(RankedAlternative.from_dict(a)
                               for a in d.get("alternatives", [])),
        )

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "PlanResult":
        return PlanResult.from_dict(json.loads(s))


def _schedule_to_dict(s: Schedule | None) -> dict | None:
    if s is None:
        return None
    return {"kind": s.kind, "n": s.n, "x": list(s.x), "r": s.r}


def _schedule_from_dict(d: dict | None) -> Schedule | None:
    if d is None:
        return None
    require_keys(d, required=("kind", "n", "x", "r"), what="Schedule")
    return Schedule(kind=d["kind"], n=d["n"], x=tuple(d["x"]), r=d["r"])
