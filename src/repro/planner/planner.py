"""The unified Planner: one entry point for all four collectives.

Evaluates every candidate from the selected strategy families under the
request's cost model and fabric, ranks them by the request's objective, and
returns a `PlanResult` with the winner, its full `TimeBreakdown`, and the
ranked alternatives table.

The composite AllReduce (`kind='ar'`) follows the Rabenseifner
decomposition the paper evaluates: the RS and AG phases are planned
independently (each over the schedule-producing strategies), combined by
`core.simulator.allreduce_time` (which charges the RS->AG topology
transition), and compared against implementation-level alternatives such as
the ring baseline when one is selected (ring registers with default=False;
name it in `PlanRequest.strategies`, as `plan_gradient_sync` does).
"""
from __future__ import annotations

import dataclasses

from repro.core import baselines
from repro.core.schedules import Schedule, static_schedule
from repro.core.simulator import (TimeBreakdown, allreduce_time,
                                  allreduce_time_overlap, collective_time,
                                  collective_time_overlap)

from .api import Candidate, PlanRequest, PlanResult, RankedAlternative
from .registry import select_strategies


def _objective_score(bd: TimeBreakdown, objective: str) -> float:
    if objective == "time":
        return bd.total
    if objective == "latency":
        return bd.startup + bd.hop_latency + bd.reconfig
    return bd.transmission + bd.reconfig  # "transmission"


class Planner:
    """Plans any of a2a / rs / ag / ar via the strategy registry.

    Stateless: safe to construct per call.  Candidate generation reuses the
    memoized all-R DP tables in `core.schedules`, so repeated planning at
    the same (n, r) is cheap.
    """

    def plan(self, req: PlanRequest) -> PlanResult:
        if req.kind == "ar":
            return self._plan_allreduce(req)
        return self._plan_collective(req)

    # --- single collectives --------------------------------------------------

    def _candidates(self, req: PlanRequest, kind: str):
        max_R = req.effective_max_R()
        for si in select_strategies(req, kind):
            for cand in si.fn(req, kind):
                sched = cand.schedule
                if sched is not None:
                    if max_R is not None and sched.R > max_R:
                        continue
                    if req.fabric == "static" and sched.R > 0:
                        continue  # no OCS to rewire mid-collective
                yield cand

    def _evaluate(self, req: PlanRequest, kind: str, cand: Candidate) -> TimeBreakdown:
        if cand.impl == "ring":
            return baselines.ring(kind, req.n, req.m_bytes, req.cost_model)
        assert cand.schedule is not None
        if req.fabric == "ocs-overlap":
            return collective_time_overlap(cand.schedule, req.m_bytes,
                                           req.cost_model, req.overlap,
                                           ports=req.ports)
        return collective_time(cand.schedule, req.m_bytes, req.cost_model,
                               ports=req.ports)

    def _plan_collective(self, req: PlanRequest) -> PlanResult:
        best: tuple[float, Candidate, TimeBreakdown] | None = None
        ranked: list[RankedAlternative] = []
        seen_x: set[tuple[int, ...]] = set()
        for cand in self._candidates(req, req.kind):
            # families overlap at the endpoints (static == periodic(R=0),
            # every-step == periodic(R=S-1)); evaluate each schedule once,
            # first-registered family keeps the name
            if cand.schedule is not None:
                if cand.schedule.x in seen_x:
                    continue
                seen_x.add(cand.schedule.x)
            bd = self._evaluate(req, req.kind, cand)
            score = _objective_score(bd, req.objective)
            sched = cand.schedule
            ranked.append(RankedAlternative(
                strategy=cand.name, impl=cand.impl, predicted_time=bd.total,
                score=score, R=sched.R if sched is not None else None,
                x=sched.x if sched is not None else None))
            if best is None or score < best[0]:
                best = (score, cand, bd)
        if best is None:
            raise ValueError(
                f"no strategy produced a candidate for {req.kind} "
                f"(strategies={req.strategies}, constraints may be infeasible)")
        _, cand, bd = best
        ranked.sort(key=lambda a: a.score)
        return PlanResult(
            request=req, strategy=cand.name, impl=cand.impl,
            predicted_time=bd.total, breakdown=bd, schedule=cand.schedule,
            alternatives=tuple(ranked))

    # --- composite AllReduce -------------------------------------------------

    def _allreduce_bd(self, req: PlanRequest, rs_sched: Schedule,
                      ag_sched: Schedule) -> TimeBreakdown:
        """Combined RS+AG breakdown under the request's fabric semantics."""
        if req.fabric == "ocs-overlap":
            return allreduce_time_overlap(rs_sched, ag_sched, req.m_bytes,
                                          req.cost_model, req.overlap,
                                          ports=req.ports)
        return allreduce_time(rs_sched, ag_sched, req.m_bytes,
                              req.cost_model, ports=req.ports)

    def _plan_rs_ag_phases(self, req: PlanRequest,
                           sched_names: tuple[str, ...] | None
                           ) -> tuple[PlanResult, PlanResult]:
        """Plan the RS and AG phases of an 'ar' request.

        Unconstrained, the phases are independent.  A reconfiguration cap
        (max_R / delta_budget) applies to the *whole* AllReduce, so the cap
        is split across the phases and the best split wins (cf.
        `baselines.bridge_allreduce_fixed_R`); the RS->AG transition delta
        charged by `allreduce_time` is topology-dependent and not counted
        against the cap.
        """

        def sub(kind: str, cap: int | None) -> PlanResult:
            return self._plan_collective(dataclasses.replace(
                req, kind=kind, strategies=sched_names,
                max_R=cap, delta_budget=None))

        total_cap = req.effective_max_R()
        if total_cap is None:
            return sub("rs", None), sub("ag", None)
        best: tuple[float, PlanResult, PlanResult] | None = None
        for k in range(total_cap + 1):
            rs_res = sub("rs", k)
            ag_res = sub("ag", total_cap - k)
            t = self._allreduce_bd(req, rs_res.schedule, ag_res.schedule)
            score = _objective_score(t, req.objective)
            if best is None or score < best[0]:
                best = (score, rs_res, ag_res)
        assert best is not None
        return best[1], best[2]

    def _plan_allreduce(self, req: PlanRequest) -> PlanResult:
        names = req.strategies
        sched_names = (None if names is None
                       else tuple(nm for nm in names if nm != "ring"))
        want_bruck = sched_names is None or len(sched_names) > 0
        want_ring = names is not None and "ring" in names

        evaluated: list[tuple[str, str, TimeBreakdown,
                              Schedule | None, Schedule | None]] = []
        if want_bruck:
            if req.fabric != "static":
                rs_res, ag_res = self._plan_rs_ag_phases(req, sched_names)
                rs_sched, ag_sched = rs_res.schedule, ag_res.schedule
                name = f"bruck[{rs_res.strategy} + {ag_res.strategy}]"
            else:
                # static fabric: hardware routes each Bruck offset directly;
                # cost with the R=0 model (DESIGN.md S3).
                rs_sched = static_schedule("rs", req.n, req.r)
                ag_sched = static_schedule("ag", req.n, req.r)
                name = "bruck[static]"
            assert rs_sched is not None and ag_sched is not None
            bd = self._allreduce_bd(req, rs_sched, ag_sched)
            evaluated.append((name, "bruck", bd, rs_sched, ag_sched))
        if want_ring:
            bd = baselines.ring("ar", req.n, req.m_bytes, req.cost_model)
            evaluated.append(("ring", "ring", bd, None, None))
        if not evaluated:
            raise ValueError(
                f"no strategy produced an AllReduce candidate "
                f"(strategies={req.strategies})")

        scored = [(_objective_score(e[2], req.objective), e) for e in evaluated]
        scored.sort(key=lambda p: p[0])
        _, (name, impl, bd, rs_sched, ag_sched) = scored[0]
        ranked = tuple(
            RankedAlternative(strategy=nm, impl=im, predicted_time=b.total,
                              score=sc, R=(rs.R + ag.R) if rs and ag else None)
            for sc, (nm, im, b, rs, ag) in scored)
        return PlanResult(
            request=req, strategy=name, impl=impl, predicted_time=bd.total,
            breakdown=bd, rs_schedule=rs_sched, ag_schedule=ag_sched,
            alternatives=ranked)
