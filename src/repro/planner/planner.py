"""The unified Planner: one entry point for all four collectives.

Evaluates every candidate from the selected strategy families under the
request's cost model and fabric, ranks them by the request's objective, and
returns a `PlanResult` with the winner, its full `TimeBreakdown`, and the
ranked alternatives table.

Fabrics and scoring:

  - ``ocs`` / ``static`` / ``ocs-overlap`` score analytically
    (`core.simulator`), exactly as before.
  - ``ocs-sim`` event-scores *every* candidate with the vectorized batch
    fabric engine (`core.batchsim.batch_completion_times`) in a single
    batched call — per-port queueing, chunk pipelining, and sparse
    reconfiguration stalls that the closed-form model cannot see.  The
    winner is the candidate the simulator ranks fastest, so it is never a
    schedule the simulator would rank worse than the analytic winner (which
    is always in the candidate set).  The scoring call picks the JAX
    ``jit``/``vmap`` engine automatically when jax is importable and the
    candidate set is large enough to amortize it (``sim_backend="auto"``;
    see docs/batch_engine.md), falling back to the NumPy engine otherwise —
    scores are identical either way.  ``predicted_time`` and the
    alternatives' scores are simulated completions; ``breakdown`` stays the
    analytic sparse-delta decomposition for reporting.  Non-Bruck
    implementation candidates (the ring baseline) keep their analytic score
    when explicitly selected.

Serving path: every `Planner` carries an LRU plan cache keyed by the
canonical JSON of the request (`cache_size` entries, hit/miss counters via
`cache_info`), so repeated traffic gets an amortized-O(1) answer, and
`plan_batch` plans a whole request list through the cache in one call.  Use
`default_planner()` for a process-wide shared instance (the
`core.schedules.plan` and `collectives.plan_gradient_sync` shims route
through it).  Mutating the strategy registry invalidates cached plans —
call `cache_clear()` after registering/unregistering strategies.

The composite AllReduce (`kind='ar'`) follows the Rabenseifner
decomposition the paper evaluates: the RS and AG phases are planned
independently (each over the schedule-producing strategies), combined by
`core.simulator.allreduce_time` (which charges the RS->AG topology
transition), and compared against implementation-level alternatives such as
the ring baseline when one is selected (ring registers with default=False;
name it in `PlanRequest.strategies`, as `plan_gradient_sync` does).
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import NamedTuple, Sequence

from repro.analysis import raise_on_violations, verify_plan
from repro.core import baselines
from repro.core.batchsim import batch_completion_times
from repro.core.schedules import Schedule, changed_links, static_schedule
from repro.core.simulator import (TimeBreakdown, allreduce_time,
                                  allreduce_time_overlap, collective_time,
                                  collective_time_overlap)

from .api import (Candidate, FabricKind, PlanRequest, PlanResult,
                  RankedAlternative)
from .registry import select_strategies


def _objective_score(bd: TimeBreakdown, objective: str) -> float:
    if objective == "time":
        return bd.total
    if objective == "latency":
        return bd.startup + bd.hop_latency + bd.reconfig
    return bd.transmission + bd.reconfig  # "transmission"


class PlanCacheInfo(NamedTuple):
    """Hit/miss counters of one Planner's LRU plan cache."""

    hits: int
    misses: int
    size: int
    capacity: int


class Planner:
    """Plans any of a2a / rs / ag / ar via the strategy registry.

    cache_size : LRU plan-cache capacity (0 disables caching; results are
                 immutable `PlanResult`s, safe to share between callers).
    sim_chunks : chunks per message used by the ``ocs-sim`` event scoring
                 (the batch engine's MTU-like pipelining knob).
    sim_backend: batch-engine backend for ``ocs-sim`` scoring —
                 ``"auto"`` (default: the JAX ``jit``/``vmap`` engine when
                 jax is importable and the candidate set is large enough to
                 amortize it, NumPy otherwise), ``"numpy"``, or ``"jax"``.
                 Scores are identical across backends (the JAX kernel is
                 bit-compatible on certified lanes); only wall time changes.
    verify     : statically verify every freshly-planned result
                 (`repro.analysis.verify_plan`) *before* it enters the plan
                 cache — a corrupt plan raises `VerificationError` instead
                 of being cached and served to every later hit.  Cache hits
                 are returns of already-verified objects and are not
                 re-checked, so the serving hot path is unaffected.

    Candidate generation reuses the memoized all-R DP tables in
    `core.schedules` and the compiled schedule tapes in `core.batchsim`, so
    repeated planning at the same (n, r) is cheap even on cache misses.
    """

    def __init__(self, *, cache_size: int = 128, sim_chunks: int = 8,
                 sim_backend: str = "auto", verify: bool = True):
        if cache_size < 0:
            raise ValueError(f"cache_size must be >= 0, got {cache_size}")
        if sim_backend not in ("auto", "numpy", "jax"):
            raise ValueError(
                f"sim_backend must be 'auto', 'numpy', or 'jax', "
                f"got {sim_backend!r}")
        self.cache_size = int(cache_size)
        self.sim_chunks = max(1, int(sim_chunks))
        self.sim_backend = sim_backend
        self.verify = bool(verify)
        self._cache: collections.OrderedDict[str, PlanResult] = \
            collections.OrderedDict()
        self._hits = 0
        self._misses = 0

    # --- cached serving path -------------------------------------------------

    @staticmethod
    def cache_key(req: PlanRequest) -> str:
        """Canonical JSON identity of a request (the plan-cache key).

        Includes the inherited fabric state (``init_g``): two windowed
        requests that are otherwise identical but enter from different link
        configurations are different planning problems and must never share
        a cache entry.
        """
        return json.dumps(req.to_dict(), sort_keys=True)

    def cache_info(self) -> PlanCacheInfo:
        return PlanCacheInfo(hits=self._hits, misses=self._misses,
                             size=len(self._cache), capacity=self.cache_size)

    def cache_clear(self) -> None:
        self._cache.clear()
        self._hits = 0
        self._misses = 0

    def plan(self, req: PlanRequest) -> PlanResult:
        if self.cache_size == 0:
            return self._verified(self._plan_uncached(req))
        key = self.cache_key(req)
        hit = self._cache.get(key)
        if hit is not None:
            self._hits += 1
            self._cache.move_to_end(key)
            return hit
        self._misses += 1
        # verify-before-cache: a result that fails static verification must
        # never be cached, or every later hit would serve the corruption
        res = self._verified(self._plan_uncached(req))
        self._cache[key] = res
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return res

    def _verified(self, res: PlanResult) -> PlanResult:
        if self.verify:
            raise_on_violations(
                verify_plan(res),
                context=f"plan({res.request.kind}, n={res.request.n})")
        return res

    def plan_batch(self, requests: Sequence[PlanRequest]) -> tuple[PlanResult, ...]:
        """Plan every request, deduplicating repeats through the plan cache.

        Returns results aligned with ``requests``; identical requests are
        planned once (the serving path's amortized-O(1) answer for repeated
        traffic).
        """
        return tuple(self.plan(req) for req in requests)

    def _plan_uncached(self, req: PlanRequest) -> PlanResult:
        if req.kind == "ar":
            return self._plan_allreduce(req)
        return self._plan_collective(req)

    # --- single collectives --------------------------------------------------

    def _candidates(self, req: PlanRequest, kind: str):
        max_R = req.effective_max_R()
        for si in select_strategies(req, kind):
            for cand in si.fn(req, kind):
                sched = cand.schedule
                if sched is not None:
                    if max_R is not None and sched.R > max_R:
                        continue
                    if req.fabric == FabricKind.STATIC and sched.R > 0:
                        continue  # no OCS to rewire mid-collective
                yield cand

    def _evaluate(self, req: PlanRequest, kind: str, cand: Candidate) -> TimeBreakdown:
        if cand.impl == "ring":
            return baselines.ring(kind, req.n, req.m_bytes, req.cost_model)
        assert cand.schedule is not None
        if req.fabric in (FabricKind.OCS_OVERLAP, FabricKind.OCS_SIM):
            # for ocs-sim this is the reported analytic decomposition; the
            # score itself comes from the batched event simulation
            return collective_time_overlap(cand.schedule, req.m_bytes,
                                           req.cost_model, req.overlap,
                                           ports=req.ports)
        return collective_time(cand.schedule, req.m_bytes, req.cost_model,
                               ports=req.ports)

    @staticmethod
    def _entry_cost(req: PlanRequest, sched: Schedule | None) -> float:
        """Sparse boundary cost of entering ``sched`` from the inherited
        fabric state (0 when the request carries no ``init_g``, and for the
        ring implementation, whose fixed topology the carryover model does
        not cover)."""
        if req.init_g is None or sched is None:
            return 0.0
        return req.cost_model.delta_sparse(
            changed_links(req.n, req.init_g, sched.link_offsets()[0]),
            req.overlap)

    def _sim_scores(self, req: PlanRequest,
                    cands: list[Candidate]) -> dict[int, float]:
        """Batched event scores for every schedule candidate (ocs-sim)."""
        idx = [i for i, c in enumerate(cands) if c.schedule is not None]
        if not idx:
            return {}
        completions = batch_completion_times(
            [cands[i].schedule for i in idx], req.m_bytes, req.cost_model,
            overlap=req.overlap, chunks_per_msg=self.sim_chunks,
            backend=self.sim_backend)
        return {i: float(t) for i, t in zip(idx, completions, strict=True)}

    def _plan_collective(self, req: PlanRequest) -> PlanResult:
        cands: list[Candidate] = []
        seen_x: set[tuple[int, ...]] = set()
        for cand in self._candidates(req, req.kind):
            # families overlap at the endpoints (static == periodic(R=0),
            # every-step == periodic(R=S-1)); evaluate each schedule once,
            # first-registered family keeps the name
            if cand.schedule is not None:
                if cand.schedule.x in seen_x:
                    continue
                seen_x.add(cand.schedule.x)
            cands.append(cand)
        if not cands:
            raise ValueError(
                f"no strategy produced a candidate for {req.kind} "
                f"(strategies={req.strategies}, constraints may be infeasible)")
        sim_scores = (self._sim_scores(req, cands)
                      if req.fabric == FabricKind.OCS_SIM else {})

        best: tuple[float, Candidate, TimeBreakdown, float] | None = None
        ranked: list[RankedAlternative] = []
        for i, cand in enumerate(cands):
            bd = self._evaluate(req, req.kind, cand)
            entry = self._entry_cost(req, cand.schedule)
            if i in sim_scores:
                score = predicted = sim_scores[i] + entry
            else:
                score = _objective_score(bd, req.objective) + entry
                predicted = bd.total + entry
            sched = cand.schedule
            ranked.append(RankedAlternative(
                strategy=cand.name, impl=cand.impl, predicted_time=predicted,
                score=score, R=sched.R if sched is not None else None,
                x=sched.x if sched is not None else None))
            if best is None or score < best[0]:
                best = (score, cand, bd, predicted)
        assert best is not None
        _, cand, bd, predicted = best
        ranked.sort(key=lambda a: a.score)
        return PlanResult(
            request=req, strategy=cand.name, impl=cand.impl,
            predicted_time=predicted, breakdown=bd, schedule=cand.schedule,
            alternatives=tuple(ranked))

    # --- composite AllReduce -------------------------------------------------

    def _allreduce_bd(self, req: PlanRequest, rs_sched: Schedule,
                      ag_sched: Schedule) -> TimeBreakdown:
        """Combined RS+AG breakdown under the request's fabric semantics."""
        if req.fabric in (FabricKind.OCS_OVERLAP, FabricKind.OCS_SIM):
            return allreduce_time_overlap(rs_sched, ag_sched, req.m_bytes,
                                          req.cost_model, req.overlap,
                                          ports=req.ports)
        return allreduce_time(rs_sched, ag_sched, req.m_bytes,
                              req.cost_model, ports=req.ports)

    def _allreduce_score(self, req: PlanRequest, rs_res: PlanResult,
                         ag_res: PlanResult,
                         bd: TimeBreakdown) -> float:
        """Objective score of one RS+AG split.

        Under ``ocs-sim`` the phases' predicted times are already simulated
        completions; the RS->AG topology transition is charged as a sparse
        swap exactly as `allreduce_time_overlap` does.
        """
        if req.fabric != FabricKind.OCS_SIM:
            return _objective_score(bd, req.objective)
        rs_final = rs_res.schedule.link_offsets()[-1]
        ag_first = ag_res.schedule.link_offsets()[0]
        changed = req.n if rs_final != ag_first else 0
        transition = req.cost_model.delta_sparse(changed, req.overlap)
        return rs_res.predicted_time + ag_res.predicted_time + transition

    def _plan_rs_ag_phases(self, req: PlanRequest,
                           sched_names: tuple[str, ...] | None
                           ) -> tuple[PlanResult, PlanResult]:
        """Plan the RS and AG phases of an 'ar' request.

        Unconstrained, the phases are independent.  A reconfiguration cap
        (max_R / delta_budget) applies to the *whole* AllReduce, so the cap
        is split across the phases and the best split wins (cf.
        `baselines.bridge_allreduce_fixed_R`); the RS->AG transition delta
        charged by `allreduce_time` is topology-dependent and not counted
        against the cap.
        """

        def sub(kind: str, cap: int | None) -> PlanResult:
            # init_g is stripped: the entry boundary is charged once at the
            # composite level (on the chosen RS schedule), not per phase
            return self._plan_collective(dataclasses.replace(
                req, kind=kind, strategies=sched_names,
                max_R=cap, delta_budget=None, init_g=None))

        total_cap = req.effective_max_R()
        if total_cap is None:
            return sub("rs", None), sub("ag", None)
        best: tuple[float, PlanResult, PlanResult] | None = None
        for k in range(total_cap + 1):
            rs_res = sub("rs", k)
            ag_res = sub("ag", total_cap - k)
            bd = self._allreduce_bd(req, rs_res.schedule, ag_res.schedule)
            score = self._allreduce_score(req, rs_res, ag_res, bd)
            if best is None or score < best[0]:
                best = (score, rs_res, ag_res)
        assert best is not None
        return best[1], best[2]

    def _plan_allreduce(self, req: PlanRequest) -> PlanResult:
        names = req.strategies
        sched_names = (None if names is None
                       else tuple(nm for nm in names if nm != "ring"))
        want_bruck = sched_names is None or len(sched_names) > 0
        want_ring = names is not None and "ring" in names

        evaluated: list[tuple[str, str, float, float, TimeBreakdown,
                              Schedule | None, Schedule | None]] = []
        if want_bruck:
            rs_res = ag_res = None
            if req.fabric != FabricKind.STATIC:
                rs_res, ag_res = self._plan_rs_ag_phases(req, sched_names)
                rs_sched, ag_sched = rs_res.schedule, ag_res.schedule
                name = f"bruck[{rs_res.strategy} + {ag_res.strategy}]"
            else:
                # static fabric: hardware routes each Bruck offset directly;
                # cost with the R=0 model (DESIGN.md S3).
                rs_sched = static_schedule("rs", req.n, req.r)
                ag_sched = static_schedule("ag", req.n, req.r)
                name = "bruck[static]"
            assert rs_sched is not None and ag_sched is not None
            bd = self._allreduce_bd(req, rs_sched, ag_sched)
            entry = self._entry_cost(req, rs_sched)
            if req.fabric == FabricKind.OCS_SIM:
                score = predicted = (
                    self._allreduce_score(req, rs_res, ag_res, bd) + entry)
            else:
                score = _objective_score(bd, req.objective) + entry
                predicted = bd.total + entry
            evaluated.append((name, "bruck", score, predicted, bd,
                              rs_sched, ag_sched))
        if want_ring:
            bd = baselines.ring("ar", req.n, req.m_bytes, req.cost_model)
            evaluated.append(("ring", "ring",
                              _objective_score(bd, req.objective), bd.total,
                              bd, None, None))
        if not evaluated:
            raise ValueError(
                f"no strategy produced an AllReduce candidate "
                f"(strategies={req.strategies})")

        evaluated.sort(key=lambda e: e[2])
        name, impl, _, predicted, bd, rs_sched, ag_sched = evaluated[0]
        ranked = tuple(
            RankedAlternative(strategy=nm, impl=im, predicted_time=pt,
                              score=sc, R=(rs.R + ag.R) if rs and ag else None)
            for nm, im, sc, pt, b, rs, ag in evaluated)
        return PlanResult(
            request=req, strategy=name, impl=impl, predicted_time=predicted,
            breakdown=bd, rs_schedule=rs_sched, ag_schedule=ag_sched,
            alternatives=ranked)


_DEFAULT_PLANNER: Planner | None = None


def default_planner() -> Planner:
    """Process-wide shared Planner (the cached plan-serving path)."""
    global _DEFAULT_PLANNER
    if _DEFAULT_PLANNER is None:
        _DEFAULT_PLANNER = Planner()
    return _DEFAULT_PLANNER
