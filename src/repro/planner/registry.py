"""Strategy registry: pluggable schedule families for the unified Planner.

A *strategy* is a generator of candidate schedules for one planning request:

    @register_strategy("my-family", kinds=("rs",), paper_faithful=False)
    def my_family(req: PlanRequest, kind: Collective):
        yield Candidate("my-family(R=1)", some_schedule)

New families (e.g. reconfiguration/communication-overlap or circuit-switched
variants from PAPERS.md) plug in by registering — no edits to the planner or
to `core.schedules.candidate_schedules` required.  Strategies are selected
per request: by explicit name (``PlanRequest.strategies``), else every
strategy registered with ``default=True``; a ``paper_faithful`` request
additionally drops strategies marked ``paper_faithful=False``.

Iteration order is registration order, which also breaks exact ties during
selection (first minimum wins), so built-ins register the paper's families
first.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator

from .api import Candidate, PlanRequest

#: fn(request, kind) -> iterable of Candidate, where ``kind`` is the concrete
#: sub-collective being planned ('rs'/'ag' for the two phases of an 'ar').
StrategyFn = Callable[[PlanRequest, str], Iterable[Candidate]]


@dataclasses.dataclass(frozen=True)
class StrategyInfo:
    name: str
    fn: StrategyFn
    kinds: frozenset[str]
    paper_faithful: bool  # survives a paper_faithful request
    default: bool         # selected when the request names no strategies
    doc: str = ""


_REGISTRY: dict[str, StrategyInfo] = {}


def register_strategy(name: str, *, kinds: Iterable[str] = ("a2a", "rs", "ag"),
                      paper_faithful: bool = True,
                      default: bool = True) -> Callable[[StrategyFn], StrategyFn]:
    """Decorator registering a strategy family under ``name``.

    kinds          : collectives the family can plan ('ar' only for families
                     that are implementation-level AllReduce alternatives).
    paper_faithful : keep the family when a request asks for paper-faithful
                     planning (False for beyond-paper families).
    default        : include in the candidate set when a request does not
                     name strategies explicitly.
    """

    def deco(fn: StrategyFn) -> StrategyFn:
        if name in _REGISTRY:
            raise ValueError(f"strategy {name!r} is already registered")
        doc_lines = (fn.__doc__ or "").strip().splitlines()
        _REGISTRY[name] = StrategyInfo(
            name=name, fn=fn, kinds=frozenset(kinds),
            paper_faithful=paper_faithful, default=default,
            doc=doc_lines[0] if doc_lines else "")
        return fn

    return deco


def unregister_strategy(name: str) -> None:
    """Remove a registered strategy (primarily for tests/plugins)."""
    _REGISTRY.pop(name, None)


def get_strategy(name: str) -> StrategyInfo:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; registered: {sorted(_REGISTRY)}") from None


def available_strategies() -> tuple[str, ...]:
    """All registered strategy names, in registration order."""
    return tuple(_REGISTRY)


def default_strategy_names() -> tuple[str, ...]:
    """Names selected when a request does not specify strategies."""
    return tuple(si.name for si in _REGISTRY.values() if si.default)


def select_strategies(req: PlanRequest, kind: str) -> Iterator[StrategyInfo]:
    """Strategies participating in planning ``kind`` under ``req``."""
    if req.strategies is not None:
        infos = [get_strategy(nm) for nm in req.strategies]
    else:
        infos = [si for si in _REGISTRY.values() if si.default]
    for si in infos:
        if kind not in si.kinds:
            continue
        if req.paper_faithful and not si.paper_faithful:
            continue
        yield si
