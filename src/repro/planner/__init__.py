"""Unified planning API for BRIDGE collectives (paper Sections 3.3-3.6).

One entry point for all four collectives — All-to-All, Reduce-Scatter,
AllGather, and the composite AllReduce (``ar`` = RS + AG):

    from repro.planner import FabricKind, Planner, PlanRequest

    res = Planner().plan(PlanRequest(kind="rs", n=96, m_bytes=16 * 2**20, r=3))
    res.schedule, res.predicted_time, res.breakdown, res.alternatives
    cached = PlanResult.from_json(res.to_json())   # lossless round trip

Event-scored planning and the cached serving path:

    planner = default_planner()                    # process-wide, LRU-cached
    res = planner.plan(PlanRequest(kind="a2a", n=96, m_bytes=2**24,
                                   fabric=FabricKind.OCS_SIM))  # event scores
    results = planner.plan_batch(requests)         # dedupes repeated traffic
    planner.cache_info()                           # hits / misses / size

Strategy families are pluggable via the registry (`register_strategy`);
importing this package registers the built-ins (periodic, rs-early, ag-late,
exact-dp, overlap, static, every-step, ring).  The legacy `repro.core.plan`
and `repro.collectives.plan_gradient_sync` entry points are thin shims over
this package.
"""
from . import strategies  # noqa: F401  (registers the built-in families)
from .api import (Candidate, FabricKind, PlanRequest,  # noqa: F401
                  PlanResult, RankedAlternative, SharingMode)
from .planner import PlanCacheInfo, Planner, default_planner  # noqa: F401
from .registry import (StrategyInfo, available_strategies,  # noqa: F401
                       default_strategy_names, get_strategy,
                       register_strategy, select_strategies,
                       unregister_strategy)

__all__ = [
    "Candidate", "FabricKind", "PlanRequest", "PlanResult",
    "RankedAlternative", "SharingMode",
    "PlanCacheInfo", "Planner", "default_planner",
    "StrategyInfo", "available_strategies", "default_strategy_names",
    "get_strategy", "register_strategy", "select_strategies",
    "unregister_strategy",
    "strategies",
]
