"""Built-in strategy families (paper Sections 3.3-3.6 + baselines).

Each family materializes its candidates from one all-R DP pass in
`repro.core.schedules` (`*_all` functions), so generating the full candidate
set costs one O(S^3) table per family instead of one capped DP per R.

Registration order matters for exact ties (first minimum wins) and mirrors
the legacy `candidate_schedules` priority: the paper's families first, the
beyond-paper exact DP next, then the degenerate endpoints and the ring
baseline.
"""
from __future__ import annotations

from repro.core import schedules as core_schedules
from repro.core.schedules import every_step_schedule, static_schedule

from .api import Candidate, FabricKind, PlanRequest
from .registry import register_strategy


@register_strategy("periodic")
def periodic_family(req: PlanRequest, kind: str):
    """Theorem 3.2 latency-optimal (periodic) schedules for every R; RS/AG
    share the A2A optimum (AG reversed, Sections 3.5-3.6)."""
    for R, sched in enumerate(core_schedules.periodic_all(kind, req.n, req.r)):
        yield Candidate(f"periodic(R={R})", sched)


@register_strategy("rs-early", kinds=("rs",))
def rs_early_family(req: PlanRequest, kind: str):
    """Theorem 3.3 transmission-optimal Reduce-Scatter schedules (early
    reconfigurations), every R."""
    for R, sched in enumerate(
            core_schedules.rs_transmission_optimal_all(req.n, req.r)):
        yield Candidate(f"rs-early(R={R})", sched)


@register_strategy("ag-late", kinds=("ag",))
def ag_late_family(req: PlanRequest, kind: str):
    """Section 3.5 AllGather optima: time-reversed Reduce-Scatter schedules
    (late reconfigurations), every R."""
    for R, sched in enumerate(
            core_schedules.ag_transmission_optimal_all(req.n, req.r)):
        yield Candidate(f"ag-late(R={R})", sched)


@register_strategy("exact-dp", paper_faithful=False)
def exact_dp_family(req: PlanRequest, kind: str):
    """Beyond-paper: joint latency+transmission optimum per R under the full
    cost model (dominates both paper families)."""
    scheds = core_schedules.full_cost_optimal_all(
        kind, req.n, float(req.m_bytes), req.cost_model, req.r)
    for R, sched in enumerate(scheds):
        yield Candidate(f"exact-dp(R={R})", sched)


@register_strategy("overlap", paper_faithful=False)
def overlap_family(req: PlanRequest, kind: str):
    """Sparse-reconfiguration overlap family (ocs-overlap / ocs-sim fabrics):
    re-scores the periodic and exact-dp candidate schedules under the
    hidden-delta credit `CostModel.delta_sparse(changed, overlap)` — or,
    for 'ocs-sim', under the batched event simulation.

    Per fixed R the optimal segment partition is delta-independent, so the
    candidates coincide with the periodic / exact-dp tables; what changes is
    the scoring — with most of delta hidden, higher-R schedules win at
    (delta, m) points where the full-pause model would stay static.  The
    planner evaluates *every* candidate with `collective_time_overlap`
    (or the batch engine) on these fabrics, so this family's role is to
    guarantee the schedule tables are in the candidate set even under an
    explicit ``strategies=("overlap",)`` subset."""
    if req.fabric not in (FabricKind.OCS_OVERLAP, FabricKind.OCS_SIM):
        return
    for R, sched in enumerate(core_schedules.periodic_all(kind, req.n, req.r)):
        yield Candidate(f"overlap[periodic](R={R})", sched)
    exact = core_schedules.full_cost_optimal_all(
        kind, req.n, float(req.m_bytes), req.cost_model, req.r)
    for R, sched in enumerate(exact):
        yield Candidate(f"overlap[exact-dp](R={R})", sched)


@register_strategy("static")
def static_family(req: PlanRequest, kind: str):
    """S-BRUCK endpoint: never reconfigure (the only feasible schedule on a
    static fabric)."""
    yield Candidate("static", static_schedule(kind, req.n, req.r))


@register_strategy("every-step")
def every_step_family(req: PlanRequest, kind: str):
    """G-BRUCK endpoint: reconfigure before every sub-step after the first."""
    yield Candidate("every-step", every_step_schedule(kind, req.n, req.r))


@register_strategy("ring", kinds=("rs", "ag", "ar"), default=False)
def ring_family(req: PlanRequest, kind: str):
    """Bandwidth-optimal ring baseline — an implementation-level alternative
    (no Bruck schedule), costed by `core.baselines.ring`."""
    yield Candidate("ring", None, impl="ring")
