"""Pallas TPU kernels for the framework's per-architecture compute hotspots.

The paper's contribution is communication scheduling (no single-node kernel),
so these kernels serve the model zoo, not the core technique:

  flash_attention : tiled online-softmax attention (causal / sliding-window /
                    bidirectional, GQA) — every attention arch.
  rg_lru          : RG-LRU gated linear recurrence — recurrentgemma-9b.
  wkv6            : RWKV-6 data-dependent-decay recurrence — rwkv6-3b.

Each subpackage ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with custom_vjp) and ref.py (pure-jnp
oracle).  Kernels target TPU; on CPU they run under interpret=True and are
validated against the oracle in tests/test_kernels.py.
"""
from .flash_attention.ops import flash_attention
from .rg_lru.ops import rg_lru
from .wkv6.ops import wkv6

__all__ = ["flash_attention", "rg_lru", "wkv6"]
