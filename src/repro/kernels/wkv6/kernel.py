"""RWKV-6 recurrence Pallas kernel — chunked (intra-block parallel) form.

Grid (B * H, num_t_blocks), time sequential; the head state S (dk, dv) lives
in VMEM scratch across time blocks.  Within a block of L = block_t steps the
recurrence is evaluated *without* a sequential scan via the chunked
decomposition (GLA/Mamba-2-style, adapted to RWKV-6's per-channel decay):

  c_t   = sum_{tau<=t} log w_tau                      (cumulative log-decay)
  A[t,j] = sum_d r_t[d] k_j[d] e^{c_{t-1}[d]-c_j[d]}  (j <  t, intra-block)
  A[t,t] = sum_d r_t[d] u[d] k_t[d]                   (bonus diagonal)
  y_t   = (A @ V)[t] + (r_t * e^{c_{t-1}})^T S_in     (cross-block via state)
  S_out = e^{c_{L-1}} * S_in + sum_j (k_j e^{c_{L-1}-c_j}) v_j^T

All exponents are differences of cumulative sums with the *later* index on
the left, hence <= 0: every e^{...} is in (0, 1] — numerically safe in f32
(no 1/w blowups).  The (L, L, dk) pairwise tensor stays in VMEM:
L=64, dk=64 -> 1 MB.  MXU does the A@V and r@S matmuls.

HBM traffic: one read of r/k/v/w, one write of y per element, plus the
carried state — the memory-bound optimum for this op.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.collectives._compat import pallas_compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, slast_ref, s_scr,
                 *, block_t: int):
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)    # (L, dk)
    k = k_ref[0].astype(jnp.float32)    # (L, dk)
    v = v_ref[0].astype(jnp.float32)    # (L, dv)
    lw = lw_ref[0].astype(jnp.float32)  # (L, dk) log-decay (<= 0)
    u = u_ref[0].astype(jnp.float32)    # (dk,)
    S = s_scr[...]                      # (dk, dv)
    L = block_t

    c = jnp.cumsum(lw, axis=0)          # c[t] = sum_{tau<=t} lw
    c_prev = c - lw                     # c[t-1] with c[-1] = 0

    # pairwise decay factors e^{c_prev[t] - c[j]} for j < t (exponent <= 0)
    expo = c_prev[:, None, :] - c[None, :, :]          # (L, L, dk)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)  # strict lower
    decay = jnp.exp(jnp.where(tri[:, :, None], expo, 0.0))
    A = jnp.einsum("td,jd,tjd->tj", r, k, decay,
                   preferred_element_type=jnp.float32)
    A = jnp.where(tri, A, 0.0)
    A += jnp.diag(jnp.sum(r * u[None, :] * k, axis=1))  # bonus diagonal

    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y += jax.lax.dot_general(r * jnp.exp(c_prev), S, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, :, :] = y.astype(y_ref.dtype)

    c_last = c[L - 1]                                   # (dk,)
    k_scaled = k * jnp.exp(c_last[None, :] - c)         # e^{c_last - c_j} <= 1
    S_new = jnp.exp(c_last)[:, None] * S + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(ti == nt - 1)
    def finalize():
        slast_ref[0] = S_new.astype(slast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6_fwd(r, k, v, log_w, u, *, block_t: int = 64, interpret: bool = True):
    """r,k,log_w: (B,H,T,dk); v: (B,H,T,dv); u: (H,dk) -> (y, s_last)."""
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    block_t = min(block_t, T)
    pt = (-T) % block_t
    if pt:
        # identity padding: log_w = 0 (decay 1), k = 0 (no state update)
        pad4 = ((0, 0), (0, 0), (0, pt), (0, 0))
        r = jnp.pad(r, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        log_w = jnp.pad(log_w, pad4)
    Tp = T + pt

    def fold(x):
        return x.reshape(B * H, Tp, x.shape[-1])
    rf, kf, vf, lwf = fold(r), fold(k), fold(v), fold(log_w)
    uf = jnp.broadcast_to(u[None], (B, H, dk)).reshape(B * H, dk)

    grid = (B * H, Tp // block_t)
    y, s_last = pl.pallas_call(
        functools.partial(_wkv6_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, dk), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, block_t, dk), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, block_t, dv), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, block_t, dk), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, dk), lambda bh, ti: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, dv), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, ti: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, dv), r.dtype),
            jax.ShapeDtypeStruct((B * H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="wkv6_chunked",
    )(rf, kf, vf, lwf, uf)
    return (y.reshape(B, H, Tp, dv)[:, :, :T],
            s_last.reshape(B, H, dk, dv))
