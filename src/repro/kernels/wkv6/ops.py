"""Public WKV6 op: chunked Pallas forward, reference-scan backward."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import wkv6_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def wkv6(r, k, v, log_w, u, interpret: bool = True):
    """RWKV-6 recurrence.  r,k,log_w: (B,H,T,dk); v: (B,H,T,dv); u: (H,dk).

    log_w is the log-space decay (<= 0).  Returns (y, s_last)."""
    return wkv6_fwd(r, k, v, log_w, u, interpret=interpret)


def _fwd(r, k, v, log_w, u, interpret):
    return wkv6(r, k, v, log_w, u, interpret), (r, k, v, log_w, u)


def _bwd(interpret, res, g):
    r, k, v, log_w, u = res
    _, vjp = jax.vjp(
        lambda r_, k_, v_, lw_, u_: ref.wkv6_scan(r_, k_, v_, jnp.exp(lw_), u_),
        r, k, v, log_w, u)
    return vjp(g)


wkv6.defvjp(_fwd, _bwd)
