"""Pure-jnp oracle for the RWKV-6 (Finch) recurrence with data-dependent decay.

Per head (state S in R^{dk x dv}, decay w_t in (0,1)^{dk}, bonus u in R^{dk}):

    y_t = r_t^T (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan(r, k, v, w, u, s0=None):
    """r, k, w: (B, H, T, dk); v: (B, H, T, dv); u: (H, dk).

    Returns (y: (B, H, T, dv), s_last: (B, H, dk, dv)).  w is the *decay*
    in (0, 1), i.e. exp(log_w) if the model parameterizes log-space decay.
    """
    B, H, T, dk = r.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,dk), (B,H,dk), (B,H,dv), (B,H,dk)
        kv = kt[..., :, None] * vt[..., None, :]             # (B,H,dk,dv)
        att = S + u[None, :, :, None] * kv                   # S_{t-1} + (u*k)v^T
        y = jnp.einsum("bhk,bhkv->bhv", rt, att)
        S = wt[..., :, None] * S + kv
        return S, y

    def f32(x):
        return x.astype(jnp.float32)
    xs = (f32(r).transpose(2, 0, 1, 3), f32(k).transpose(2, 0, 1, 3),
          f32(v).transpose(2, 0, 1, 3), f32(w).transpose(2, 0, 1, 3))
    s_last, ys = jax.lax.scan(step, f32(s0), xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), s_last
