"""Pure-jnp oracle for the RG-LRU gated linear recurrence (Griffin/recurrentgemma).

    h_t = a_t * h_{t-1} + b_t        (elementwise over the model dimension)

The caller supplies the input-dependent decay a_t in (0, 1) and the gated
input b_t (for Griffin: b_t = sqrt(1 - a_t^2) * i_t * x_t); the recurrence
itself is the compute hotspot the kernel accelerates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rg_lru_scan(a, b, h0=None):
    """a, b: (B, T, D); returns (y, h_last) with y[t] = h_t."""
    B, T, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    (h_last, ys) = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.astype(jnp.float32).swapaxes(0, 1), b.astype(jnp.float32).swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(a.dtype), h_last
