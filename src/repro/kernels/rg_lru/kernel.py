"""RG-LRU linear-recurrence Pallas kernel.

Grid (B, num_d_blocks, num_t_blocks); the time dimension is innermost and
sequential ("arbitrary"), the batch and feature dimensions are parallel.
The hidden state h (block_d,) lives in VMEM scratch and is carried across
time blocks — HBM traffic is exactly one read of (a, b) and one write of y
per element, the memory-bound optimum for a first-order recurrence.

Within a time block the scan is an explicit fori_loop of VPU elementwise
ops (the recurrence is data-dependent so the MXU is not involved); block_d
is a multiple of 128 for lane alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.collectives._compat import pallas_compiler_params


def _rg_lru_kernel(a_ref, b_ref, y_ref, hlast_ref, h_scr, *, block_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)  # (block_t, block_d)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def finalize():
        hlast_ref[0, :] = h.astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_d", "interpret"))
def rg_lru_fwd(a, b, *, block_t: int = 256, block_d: int = 256,
               interpret: bool = True):
    """a, b: (B, T, D) -> (y: (B, T, D), h_last: (B, D))."""
    B, T, D = a.shape
    block_t = min(block_t, T)
    block_d = min(block_d, D)
    pt, pd = (-T) % block_t, (-D) % block_d
    if pt or pd:
        # pad with a=1, b=0 (identity steps) so h_last stays correct
        a = jnp.pad(a, ((0, 0), (0, pt), (0, pd)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pt), (0, pd)))
    Tp, Dp = T + pt, D + pd

    grid = (B, Dp // block_d, Tp // block_t)
    y, h_last = pl.pallas_call(
        functools.partial(_rg_lru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ti: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Dp), a.dtype),
            jax.ShapeDtypeStruct((B, Dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rg_lru_scan",
    )(a, b)
    return y[:, :T, :D], h_last[:, :D]
