"""Public RG-LRU op: Pallas forward, reference-scan backward (custom_vjp)."""
from __future__ import annotations

import functools

import jax

from . import ref
from .kernel import rg_lru_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rg_lru(a, b, interpret: bool = True):
    """h_t = a_t * h_{t-1} + b_t over axis 1.  a, b: (B, T, D).

    Returns (y, h_last)."""
    return rg_lru_fwd(a, b, interpret=interpret)


def _fwd(a, b, interpret):
    return rg_lru(a, b, interpret), (a, b)


def _bwd(interpret, res, g):
    a, b = res
    _, vjp = jax.vjp(lambda a_, b_: ref.rg_lru_scan(a_, b_), a, b)
    return vjp(g)


rg_lru.defvjp(_fwd, _bwd)
