"""Flash-attention backward Pallas kernels (two-pass, MHA layout).

Standard flash backward with the logsumexp trick (saved from the forward):

  P_ij = exp(q_i k_j scale - L_i)
  D_i  = sum_d do_id * o_id
  dV_j = sum_i P_ij do_i
  dS_ij = P_ij * (do_i . v_j - D_i) * scale
  dQ_i = sum_j dS_ij k_j          (pass 2: k innermost, dq in scratch)
  dK_j = sum_i dS_ij q_i          (pass 1: q innermost, dk/dv in scratch)

GQA is handled by the caller (ops.py) by expanding K/V to the query heads
and group-summing dK/dV — the kernels are pure MHA.  Masking is identical to
the forward kernel (causal / sliding-window / padding), with the same
tile-level skipping, so backward FLOPs match the mask sparsity too.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.collectives._compat import pallas_compiler_params


def _mask_and_run(causal, window, off, sk, block_q, block_k, qi, ki):
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < sk
    if causal:
        mask &= k_pos <= q_pos + off
    if window is not None:
        mask &= k_pos > q_pos + off - window
    run = True
    if causal:
        run = ki * block_k <= qi * block_q + block_q - 1 + off
    if window is not None:
        run_w = ki * block_k + block_k - 1 > qi * block_q + off - window
        run = jnp.logical_and(run, run_w) if causal else run_w
    return mask, run


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                    dk_ref, dv_ref, dk_scr, dv_scr, *,
                    scale, causal, window, block_q, block_k, off, sk):
    ki = pl.program_id(1)
    qi = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    mask, run = _mask_and_run(causal, window, off, sk, block_q, block_k,
                              qi, ki)

    def compute():
        q = q_ref[0].astype(jnp.float32)      # (bq, d)
        k = k_ref[0].astype(jnp.float32)      # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)    # (bq, d)
        lse = lse_ref[0]                      # (bq,)
        dvec = dvec_ref[0]                    # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dvec[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    if isinstance(run, bool):
        compute()
    else:
        pl.when(run)(compute)

    @pl.when(qi == nq - 1)
    def finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                   dq_ref, dq_scr, *,
                   scale, causal, window, block_q, block_k, off, sk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    mask, run = _mask_and_run(causal, window, off, sk, block_q, block_k,
                              qi, ki)

    def compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0]
        dvec = dvec_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dvec[:, None]) * scale
        dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    if isinstance(run, bool):
        compute()
    else:
        pl.when(run)(compute)

    @pl.when(ki == nk - 1)
    def finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "block_q", "block_k",
                     "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, scale, causal, window,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = True):
    """MHA backward.  q,k,v,o,do: (B, H, S*, D); lse: (B, H, Sq) f32.

    Returns (dq, dk, dv) with k/v already expanded to H heads (GQA summing
    happens in ops.py)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq, pk = (-sq) % block_q, (-sk) % block_k
    def pad_q(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else x

    def pad_k(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else x
    qf = pad_q(q).reshape(b * h, sq + pq, d)
    of = pad_q(o).reshape(b * h, sq + pq, d)
    dof = pad_q(do).reshape(b * h, sq + pq, d)
    kf = pad_k(k).reshape(b * h, sk + pk, d)
    vf = pad_k(v).reshape(b * h, sk + pk, d)
    # padded queries: lse pad of +inf makes p = exp(-inf) = 0 (no gradient)
    lsef = (jnp.pad(lse, ((0, 0), (0, 0), (0, pq)), constant_values=jnp.inf)
            .reshape(b * h, sq + pq) if pq else lse.reshape(b * h, sq))
    dvec = jnp.sum(dof.astype(jnp.float32) * of.astype(jnp.float32), axis=-1)

    sq_p, sk_p = sq + pq, sk + pk
    kw = {"scale": scale, "causal": causal, "window": window,
          "block_q": block_q, "block_k": block_k, "off": sk - sq, "sk": sk}

    q_spec = pl.BlockSpec((1, block_q, d), lambda bh, a, bq: (bh, bq, 0))
    k_spec_kv = pl.BlockSpec((1, block_k, d), lambda bh, a, bq: (bh, a, 0))
    r_spec = pl.BlockSpec((1, block_q), lambda bh, a, bq: (bh, bq))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **kw),
        grid=(b * h, sk_p // block_k, sq_p // block_q),
        in_specs=[q_spec, k_spec_kv, k_spec_kv, q_spec, r_spec, r_spec],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda bh, a, bq: (bh, a, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, a, bq: (bh, a, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b * h, sk_p, d), q.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32)] * 2,
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="bridge_flash_bwd_dkv",
    )(qf, kf, vf, dof, lsef, dvec)

    q_spec2 = pl.BlockSpec((1, block_q, d), lambda bh, bq, a: (bh, bq, 0))
    k_spec2 = pl.BlockSpec((1, block_k, d), lambda bh, bq, a: (bh, a, 0))
    r_spec2 = pl.BlockSpec((1, block_q), lambda bh, bq, a: (bh, bq))
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **kw),
        grid=(b * h, sq_p // block_q, sk_p // block_k),
        in_specs=[q_spec2, k_spec2, k_spec2, q_spec2, r_spec2, r_spec2],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, bq, a: (bh, bq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="bridge_flash_bwd_dq",
    )(qf, kf, vf, dof, lsef, dvec)

    dq = dq.reshape(b, h, sq_p, d)[:, :, :sq]
    dk = dk.reshape(b, h, sk_p, d)[:, :, :sk]
    dv = dv.reshape(b, h, sk_p, d)[:, :, :sk]
    return dq, dk, dv
