"""Tiled online-softmax attention kernel (pl.pallas_call + BlockSpec).

TPU-native design (DESIGN.md Section 6):
  grid = (batch * q_heads, num_q_blocks, num_k_blocks), k innermost and
  sequential ("arbitrary"); q/k/v tiles live in VMEM via BlockSpec; the
  running max/denominator/accumulator are VMEM scratch revisited across the
  k dimension (the canonical TPU flash pattern).

  VMEM working set per program:
    q tile (block_q, d) + k/v tiles (block_k, d) + acc (block_q, d) + stats.
  With block_q = block_k = 512 and d = 128 in f32 this is ~1.3 MB << 16 MB.
  MXU alignment: block sizes are multiples of 128.

Causal/sliding-window blocks that are fully masked are skipped via pl.when
(so the kernel's FLOP count matches the mask sparsity, e.g. ~1/2 for causal,
O(window/seq) for sliding-window — this is what makes long-context local
attention linear-time on TPU).

GQA is handled by the k/v index_map (query head h reads kv head h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.collectives._compat import pallas_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, off: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions of this tile's queries/keys; ``off`` aligns the last
    # *real* query to the last real key (matching ref.attention_mask)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def masked_out() -> jax.Array:
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos + off
        if window is not None:
            mask &= k_pos > q_pos + off - window
        mask &= k_pos < sk  # key padding
        return mask

    def compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(masked_out(), s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    # Tile-level sparsity: skip fully-masked (q, k) tiles.
    run = True
    if causal:
        # tile has any k_pos <= q_pos + off  <=>  ki*bk <= qi*bq + bq-1 + off
        run = ki * block_k <= qi * block_q + block_q - 1 + off
    if window is not None:
        # tile has any k_pos > q_pos + off - window
        run_w = ki * block_k + block_k - 1 > qi * block_q + off - window
        run = jnp.logical_and(run, run_w) if causal else run_w

    if isinstance(run, bool):
        compute()
    else:
        pl.when(run)(compute)

    @pl.when(ki == nk - 1)
    def finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        if lse_ref is not None:
            lse_ref[0, :] = m_scr[...] + jnp.log(denom)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "block_q", "block_k",
                     "interpret"),
)
def flash_attention_fwd_lse(q, k, v, *, scale: float, causal: bool,
                            window: int | None, block_q: int = 512,
                            block_k: int = 512, interpret: bool = True):
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D) -> (out, logsumexp).

    out: (B, Hq, Sq, D); lse: (B, Hq, Sq) float32 (saved for the backward
    kernels)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    # pad sequence dims to block multiples (mask handles the padding keys;
    # padded queries are sliced off at the end)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk

    qf = q.reshape(b * hq, sq_p, d)
    kf = k.reshape(b * hkv, sk_p, d)
    vf = v.reshape(b * hkv, sk_p, d)

    grid = (b * hq, sq_p // block_q, sk_p // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, off=sk - sq, sk=sk)  # real dims

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
            jax.ShapeDtypeStruct((b * hq, sq_p), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="bridge_flash_attention",
    )(qf, kf, vf)

    out = out.reshape(b, hq, sq_p, d)[:, :, :sq, :]
    lse = lse.reshape(b, hq, sq_p)[:, :, :sq]
    return out, lse


def flash_attention_fwd(q, k, v, *, scale: float, causal: bool,
                        window: int | None, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True):
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    return flash_attention_fwd_lse(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)[0]
