"""Public flash-attention op: Pallas forward + Pallas backward.

Forward saves the logsumexp; backward runs the two-pass flash kernels
(kernel_bwd.py) — dK/dV with queries innermost, dQ with keys innermost —
validated against jax.grad of the pure-jnp reference in
tests/test_kernels.py.  GQA: K/V are expanded to the query heads for the
backward kernels and dK/dV group-summed here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention_fwd_lse
from .kernel_bwd import flash_attention_bwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool = True):
    """Attention with VMEM-tiled online softmax.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hkv | Hq.  Returns (B, Hq, Sq, D).
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    return flash_attention_fwd_lse(q, k, v, scale=scale, causal=causal,
                                   window=window, block_q=block_q,
                                   block_k=block_k, interpret=interpret)[0]


def _fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    out, lse = flash_attention_fwd_lse(
        q, k, v, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, scale, block_q, block_k, interpret, res, g):
    q, k, v, out, lse = res
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    hq, hkv = q.shape[1], k.shape[1]
    group = hq // hkv
    k_full = jnp.repeat(k, group, axis=1)
    v_full = jnp.repeat(v, group, axis=1)
    dq, dk_full, dv_full = flash_attention_bwd(
        q, k_full, v_full, out, lse, g, scale=scale, causal=causal,
        window=window, block_q=block_q, block_k=block_k, interpret=interpret)
    if group > 1:  # GQA: sum gradients over the query-head group
        b, _, sk, d = k.shape
        dk = dk_full.reshape(b, hkv, group, sk, d).sum(axis=2)
        dv = dv_full.reshape(b, hkv, group, sk, d).sum(axis=2)
    else:
        dk, dv = dk_full, dv_full
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)


def flash_attention_ref_bwd(q, k, v, causal=True, window=None, scale=None):
    """Reference-backward variant kept for A/B validation in tests."""
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale)
