"""Pure-jnp oracle for flash attention (causal / sliding-window / bidir, GQA)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_mask(sq: int, sk: int, causal: bool, window: int | None):
    """(sq, sk) boolean mask. Query i attends key j iff:
       causal: j <= i + (sk - sq)   (offset aligns last query to last key)
       window: i + off - window < j (sliding window of `window` keys, incl. self)
    """
    off = sk - sq
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kj <= qi + off
    if window is not None:
        mask &= kj > qi + off - window
    return mask


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hkv divides Hq (GQA).

    Returns (B, Hq, Sq, D). float32 accumulation regardless of input dtype.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = attention_mask(sq, sk, causal, window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
