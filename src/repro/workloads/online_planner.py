"""Receding-horizon online trace planning with fabric-state warm starts.

`plan_trace` is an offline DP: it assumes the whole collective stream is
known up front.  In production serving the stream is only *predicted* —
decode AllGather bursts and MoE All-to-Alls from many jobs arrive one at a
time, predictions beyond a short horizon are unreliable, and the paper's
reconfigure-or-not question becomes a decision under uncertainty.
`OnlinePlanner` is the receding-horizon answer:

  - it sees a sliding window of the next W upcoming `CollectiveEvent`s (the
    realized head plus W-1 predicted followers);
  - it runs the joint (link offset, reconfigs spent) DP over the window
    (`trace_planner.window_dp`) warm-started at the *committed* fabric
    state: the link offset the already-executed collectives left behind is
    the window's initial configuration and entering the window charges the
    sparse changed-circuit diff, exactly as `plan_trace` chains segments;
  - it commits the first event's schedule and advances;
  - it re-plans only when the horizon actually changes — a new event slides
    into the window, a predicted event is substituted by a different one, or
    a predicted event is dropped.  While the realized stream matches the
    predicted one and no new events appear, the stored window plan's suffix
    is committed as-is (so with W >= the remaining stream the planner solves
    the DP once and replays it, making the W=full case bit-identical to the
    offline `plan_trace`).

The committed prefix is never revisited: a misprediction invalidates only
the un-committed window suffix, which is re-planned from the carryover state
(g, spent) the committed prefix established — the same state
`FabricSim.run_trace(..., capture_state=True)` reaches when the committed
schedules are actually played (tests/test_online_planner.py pins this).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Iterable, Sequence

from repro.core.cost_model import CostModel, PAPER_DEFAULT
from repro.core.jsonio import FabricKind

from .trace_planner import (TRACE_FABRICS, PhaseCandidate, PhasePlan,
                            TracePlan, _finish, _phase_plan, phase_candidates,
                            window_dp)
from .traces import CollectiveEvent, Trace


@dataclasses.dataclass(frozen=True)
class OnlineStats:
    """Counters of one `OnlinePlanner` run.

    commits        : events committed (= phases committed, counting 'ar' once).
    replans        : window DP solves (1 when the window never changed shape).
    plan_reuses    : commits served from the stored window plan without a solve.
    mispredictions : substituted or dropped predicted events observed.
    """

    commits: int
    replans: int
    plan_reuses: int
    mispredictions: int


def _flatten(events: Sequence[CollectiveEvent]) -> list[tuple[str, float, str]]:
    """Flatten events to single-collective phases, `Trace.phases` semantics
    (a composite 'ar' expands to its RS + AG phases)."""
    out: list[tuple[str, float, str]] = []
    for ev in events:
        if ev.kind == "ar":
            out.append(("rs", ev.m_bytes, f"{ev.tag}:rs"))
            out.append(("ag", ev.m_bytes, f"{ev.tag}:ag"))
        else:
            out.append((ev.kind, ev.m_bytes, ev.tag))
    return out


class OnlinePlanner:
    """Receding-horizon planner over a predicted collective stream.

    n, r         : fabric world size and Bruck radix (as in `Trace`).
    window       : horizon W — how many upcoming events (realized head
                   included) each DP solve sees.  W=1 is greedy per-event
                   planning with carryover; W >= the stream length recovers
                   the offline `plan_trace` exactly.
    cm / fabric / overlap / delta_budget : as in `plan_trace`; the budget
                   caps intra-collective reconfiguration stall across the
                   *whole realized stream* (committed spend is carried into
                   every window solve, so the online planner never overspends
                   the trace-wide cap).
    init_g / init_spent : inherited fabric state to warm-start the first
                   window from (e.g. resuming after a fault); None/0 means a
                   fresh fabric, matching the offline planner.
    planner      : a `repro.planner.Planner` (defaults to the process-wide
                   `default_planner()`, sharing its plan cache).
    verify       : statically audit every window DP solution — including
                   warm-started suffix re-plans after a misprediction —
                   before any of it can be committed
                   (`repro.analysis.verify_window_choice`); a corrupt
                   candidate raises `VerificationError` instead of moving
                   the committed (g, spent) fabric-state ledger.

    Drive it with `predict` (append predicted events), `observe` (the next
    event actually arrived — commit its schedule), and `drop_predicted` (a
    predicted event will not arrive).  `result()` assembles the committed
    stream into a `TracePlan` (mode='online').
    """

    def __init__(self, n: int, *, r: int = 2, cm: CostModel = PAPER_DEFAULT,
                 window: int = 4, fabric: FabricKind = FabricKind.OCS,
                 overlap: float = 0.0, tenant: str | None = None,
                 delta_budget: float | None = None, init_g: int | None = None,
                 init_spent: int = 0, planner=None, verify: bool = True):
        if n < 2:
            raise ValueError(f"need at least 2 nodes, got n={n}")
        if r < 2:
            raise ValueError(f"radix must be >= 2, got r={r}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        fabric = FabricKind.coerce(fabric)
        if fabric not in TRACE_FABRICS:
            raise ValueError(
                f"fabric must be one of {tuple(map(str, TRACE_FABRICS))}, "
                f"got {str(fabric)!r}")
        if overlap and fabric != FabricKind.OCS_OVERLAP:
            raise ValueError(f"overlap={overlap} requires fabric='ocs-overlap'")
        if delta_budget is not None and delta_budget < 0:
            raise ValueError(f"delta_budget must be >= 0, got {delta_budget}")
        if init_spent < 0:
            raise ValueError(f"init_spent must be >= 0, got {init_spent}")
        if planner is None:
            from repro.planner import default_planner  # deferred: no cycle

            planner = default_planner()
        self.n, self.r = int(n), int(r)
        self.cm, self.fabric, self.overlap = cm, fabric, float(overlap)
        self.tenant = tenant
        self.delta_budget = delta_budget
        self.window = int(window)
        self.planner = planner
        self.verify = bool(verify)
        unit = cm.delta_sparse(n, overlap)
        self._cap: int | None = None
        if delta_budget is not None and unit > 0:
            self._cap = int(delta_budget / unit + 1e-12)
        self._g = init_g                  # fabric state after committed prefix
        self._spent = int(init_spent)     # paid intra reconfigs committed
        self._predicted: deque[CollectiveEvent] = deque()
        self._committed: list[PhasePlan] = []
        self._committed_events: list[CollectiveEvent] = []
        self._plan: list[PhaseCandidate] = []        # un-committed window plan
        self._plan_events: list[CollectiveEvent] = []  # events _plan covers
        self._commits = self._replans = 0
        self._reuses = self._mispred = 0

    # --- introspection -------------------------------------------------------

    @property
    def fabric_state(self) -> int | None:
        """Link offset the committed prefix left the fabric at (None before
        the first commit on a fresh fabric)."""
        return self._g

    @property
    def reconfigs_spent(self) -> int:
        """Paid intra-collective reconfigurations committed so far."""
        return self._spent

    @property
    def committed_events(self) -> tuple[CollectiveEvent, ...]:
        return tuple(self._committed_events)

    @property
    def predicted_events(self) -> tuple[CollectiveEvent, ...]:
        return tuple(self._predicted)

    def stats(self) -> OnlineStats:
        return OnlineStats(commits=self._commits, replans=self._replans,
                           plan_reuses=self._reuses,
                           mispredictions=self._mispred)

    # --- prediction stream ---------------------------------------------------

    def predict(self, events: Iterable[CollectiveEvent]) -> None:
        """Append predicted upcoming events to the stream (lazy: the stored
        window plan is invalidated only when a commit actually sees a
        different window)."""
        for ev in events:
            if not isinstance(ev, CollectiveEvent):
                raise TypeError(f"predict() wants CollectiveEvents, got {ev!r}")
            self._predicted.append(ev)

    def drop_predicted(self, count: int = 1) -> None:
        """The next ``count`` predicted events will not arrive (dropped /
        timed-out predictions).  The committed prefix is untouched; the next
        commit re-plans the shifted window."""
        if count < 1 or count > len(self._predicted):
            raise ValueError(
                f"cannot drop {count} of {len(self._predicted)} predicted "
                f"events")
        for _ in range(count):
            self._predicted.popleft()
        self._mispred += count

    # --- commit loop ---------------------------------------------------------

    def observe(self, event: CollectiveEvent | None = None
                ) -> tuple[PhasePlan, ...]:
        """The next collective actually arrived; commit its schedule(s).

        ``event=None`` asserts the predicted head arrived exactly as
        predicted.  Passing a different event records a substitution
        misprediction: the stored window plan is discarded and the realized
        window — the arrived event plus the surviving predictions — is
        re-planned from the committed fabric state.  Returns the committed
        phase plans (one, or the RS + AG pair for an 'ar' event).
        """
        if event is None:
            if not self._predicted:
                raise ValueError(
                    "no predicted events left; pass the realized event "
                    "explicitly (or predict() more)")
            event = self._predicted.popleft()
        elif self._predicted:
            if self._predicted[0] == event:
                self._predicted.popleft()
            else:
                self._predicted.popleft()  # substituted prediction
                self._mispred += 1
        else:
            self._mispred += 1  # unpredicted arrival
        window = [event] + list(itertools.islice(self._predicted,
                                                 self.window - 1))
        if self._plan_events != window:
            self._solve(window)
        else:
            self._reuses += 1
        committed = []
        phases = _flatten([event])
        for (kind, m, tag), cand in zip(phases, self._plan, strict=False):
            committed.append(_phase_plan(kind, m, tag, cand))
            self._g = cand.g_last
            self._spent += cand.paid
        del self._plan[:len(phases)]
        del self._plan_events[0]
        self._committed.extend(committed)
        self._committed_events.append(event)
        self._commits += 1
        return tuple(committed)

    def _solve(self, window: list[CollectiveEvent]) -> None:
        """Joint DP over the window, warm-started at the committed state."""
        phases = _flatten(window)
        cand_lists = [
            phase_candidates(kind, self.n, self.r, m, self.cm, self.fabric,
                             self.overlap, self.planner, tenant=self.tenant)
            for kind, m, _ in phases]
        self._plan = window_dp(
            self.n, cand_lists, self.cm, overlap=self.overlap,
            init_g=self._g, init_spent=self._spent, cap=self._cap,
            label=f"{len(window)}-event window")
        if self.verify:
            # audit-before-commit: the suffix re-plan is checked against the
            # committed (g, spent) ledger before any of it moves that ledger
            from repro.analysis import raise_on_violations, verify_window_choice

            raise_on_violations(
                verify_window_choice(
                    self.n, self._plan, init_spent=self._spent,
                    cap=self._cap, label=f"{len(window)}-event window"),
                context=f"online window n={self.n}")
        self._plan_events = list(window)
        self._replans += 1

    # --- results -------------------------------------------------------------

    def result(self, name: str = "online") -> TracePlan:
        """Committed stream as a `TracePlan` (mode='online').

        Boundary accounting and the total-time summation follow `_finish`
        exactly, so an online run that committed the same schedules as the
        offline DP reports bit-identical totals.  The entry boundary of a
        warm-started planner (``init_g``) is outside the committed stream
        and not included.
        """
        if not self._committed:
            raise ValueError("nothing committed yet")
        trace = Trace(name=name, n=self.n, r=self.r,
                      events=tuple(self._committed_events))
        return _finish(trace, "online", self.fabric, self.overlap,
                       self.delta_budget, self.cm, list(self._committed),
                       full_boundaries=False)


def run_online(trace: Trace, cm: CostModel = PAPER_DEFAULT, *,
               window: int = 4, fabric: str = "ocs", overlap: float = 0.0,
               delta_budget: float | None = None, planner=None,
               realized: Sequence[CollectiveEvent] | None = None
               ) -> tuple[TracePlan, OnlineStats]:
    """Drive an `OnlinePlanner` over ``trace`` and return (plan, stats).

    The trace's events are the predicted stream.  ``realized`` (default: the
    predictions come true) substitutes the actually-arriving events — same
    length or shorter; a shorter realized stream leaves the prediction tail
    unobserved.  This is the benchmark harness path (`benchmarks/
    online_bench.py`) and the regret-test entry point.
    """
    op = OnlinePlanner(trace.n, r=trace.r, cm=cm, window=window,
                       fabric=fabric, overlap=overlap,
                       delta_budget=delta_budget, planner=planner)
    op.predict(trace.events)
    if realized is None:
        for _ in trace.events:
            op.observe()
    else:
        for ev in realized:
            op.observe(ev)
    return op.result(name=trace.name), op.stats()
