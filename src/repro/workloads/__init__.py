"""Workload traces: back-to-back collectives on one reconfigurable fabric.

Real training and serving workloads do not issue one collective on a cold
fabric — MoE All-to-All, gradient AllReduce, and decode AllGather arrive
back-to-back, and the circuits left behind by one collective are the
starting topology of the next.  This package raises BRIDGE's step-level
reuse argument one level:

  - `traces`        — typed `CollectiveEvent` / `Trace` records plus
                      deterministic generators that synthesize realistic
                      streams from the model-zoo configs (MoE a2a per layer,
                      per-step gradient AR, decode AG bursts), with JSON
                      round-tripping;
  - `trace_planner` — `plan_trace` extends the exact-R DP across collective
                      boundaries: the fabric's final link offsets of
                      collective i become the initial configuration of
                      collective i+1, boundaries pay delta only on circuits
                      that actually change (`core.schedules.changed_links`),
                      and per-collective R is chosen jointly under a
                      trace-wide delta budget.

Fabric execution of a planned trace lives in `core.fabricsim.FabricSim
.run_trace` / `core.batchsim.batch_run_trace`; benchmarks/trace_bench.py
records carryover vs cold-fabric vs static on mixed traces.
"""
from .trace_planner import (PhasePlan, TRACE_PLAN_MODES, TracePlan,
                            plan_trace)
from .traces import (CollectiveEvent, Trace, approx_param_bytes,
                     concat_traces, decode_ag_trace, mixed_trace,
                     moe_a2a_trace, train_step_trace)

__all__ = [
    "CollectiveEvent", "Trace", "approx_param_bytes", "concat_traces",
    "decode_ag_trace", "mixed_trace", "moe_a2a_trace", "train_step_trace",
    "PhasePlan", "TRACE_PLAN_MODES", "TracePlan", "plan_trace",
]
