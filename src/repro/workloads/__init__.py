"""Workload traces: back-to-back collectives on one reconfigurable fabric.

Real training and serving workloads do not issue one collective on a cold
fabric — MoE All-to-All, gradient AllReduce, and decode AllGather arrive
back-to-back, and the circuits left behind by one collective are the
starting topology of the next.  This package raises BRIDGE's step-level
reuse argument one level:

  - `traces`        — typed `CollectiveEvent` / `Trace` records plus
                      deterministic generators that synthesize realistic
                      streams from the model-zoo configs (MoE a2a per layer,
                      per-step gradient AR, decode AG bursts), with JSON
                      round-tripping;
  - `trace_planner` — `plan_trace` extends the exact-R DP across collective
                      boundaries: the fabric's final link offsets of
                      collective i become the initial configuration of
                      collective i+1, boundaries pay delta only on circuits
                      that actually change (`core.schedules.changed_links`),
                      and per-collective R is chosen jointly under a
                      trace-wide delta budget.

  - `online_planner` — `OnlinePlanner` plans the same stream *online*: a
                      receding-horizon window of W upcoming events, the
                      joint DP warm-started at the committed fabric state,
                      commit-one-advance, and re-plan on mispredictions
                      (W = stream length recovers `plan_trace` exactly);
  - `serve`         — `PlanService` answers windowed plan requests through
                      a serving LRU (carryover state in the key) with
                      `request_storm` measuring plans/sec and hit rate;
  - `tenancy`       — multi-tenant fabric sharing: `plan_shared` allocates
                      one fabric across K tenants by disjoint port
                      partitions or whole-collective time slices, with
                      per-tenant SLA weights, delta budgets, and measured
                      isolation bounds;
  - `recovery`      — the failure → snapshot → re-plan → verify loop:
                      `run_with_recovery` maps a `core.faults.DegradedState`
                      back to whole events, re-plans the remainder at the
                      surviving world size (bit-identical to the offline
                      plan of the reduced trace), and measures resume-from-
                      snapshot vs restart-from-scratch.

Fabric execution of a planned trace lives in `core.fabricsim.FabricSim
.run_trace` / `core.batchsim.batch_run_trace` (now with mid-trace
snapshot/restore via `core.FabricSnapshot`); benchmarks/trace_bench.py
records carryover vs cold-fabric vs static on mixed traces and
benchmarks/online_bench.py the online-vs-offline regret and serving
throughput.
"""
from .online_planner import OnlinePlanner, OnlineStats, run_online
from .recovery import (RecoveryResult, reduced_trace, replan_after_fault,
                       run_with_recovery, split_events)
from .serve import (PlanService, ServeCacheInfo, ServeRequest, ServedPlan,
                    StormResult, build_request_pool, request_storm)
from .tenancy import (SharedFabricRequest, SharedPhase, SharedPlan,
                      TenantPlan, TenantSpec, candidate_orders, plan_shared,
                      score_shared_plans, shared_window_dp)
from .trace_planner import (PhaseCandidate, PhasePlan, TRACE_PLAN_MODES,
                            TracePlan, phase_candidates, plan_trace,
                            window_dp)
from .traces import (CollectiveEvent, Trace, approx_param_bytes,
                     concat_traces, decode_ag_trace, mixed_trace,
                     moe_a2a_trace, train_step_trace)

__all__ = [
    "CollectiveEvent", "Trace", "approx_param_bytes", "concat_traces",
    "decode_ag_trace", "mixed_trace", "moe_a2a_trace", "train_step_trace",
    "PhaseCandidate", "PhasePlan", "TRACE_PLAN_MODES", "TracePlan",
    "phase_candidates", "plan_trace", "window_dp",
    "OnlinePlanner", "OnlineStats", "run_online",
    "RecoveryResult", "reduced_trace", "replan_after_fault",
    "run_with_recovery", "split_events",
    "PlanService", "ServeCacheInfo", "ServeRequest", "ServedPlan",
    "StormResult", "build_request_pool", "request_storm",
    "SharedFabricRequest", "SharedPhase", "SharedPlan", "TenantPlan",
    "TenantSpec", "candidate_orders", "plan_shared", "score_shared_plans",
    "shared_window_dp",
]
