"""Multi-tenant fabric sharing: K concurrent jobs on one optical fabric.

BRIDGE plans one job as if it owned the whole fabric; the serving reality
the ROADMAP targets (and PCCL deploys) is one photonic circuit switch shared
by many distributed-ML jobs at once.  This module plans that sharing under
two disciplines, selected by `repro.core.jsonio.SharingMode`:

  PORT_PARTITION
      Each tenant owns a disjoint contiguous subset of the fabric's ports
      sized to its trace's world (``sum of tenant worlds <= n``) and runs
      its trace on its own sub-fabric, planned by the existing carryover DP
      at the tenant's world size.  Tenants run concurrently and never touch
      each other's circuits, so the isolation ratio is exactly 1.0 and the
      shared makespan is ``max_t C_t <= sum_t C_t`` (the serialized
      baseline) structurally.

  TIME_SLICE
      All tenants need the full fabric (``every tenant world == n``) and
      interleave *whole collectives* on it.  A tenant hand-off is just a
      carryover boundary: `core.schedules.changed_links` prices exactly the
      circuits that differ between the outgoing tenant's final link offsets
      and the incoming tenant's initial ones — a hand-off where the next
      tenant reuses the subring as-is is free.  `plan_shared` evaluates
      candidate interleavings (the request-order serialization, Smith's-rule
      weighted-shortest-block order, and round-robin over collectives) with
      a joint DP (`shared_window_dp`) whose state tracks the fabric's link
      offset plus per-tenant *and* global reconfiguration spend, minimizing
      the exact weighted completion time ``sum_t w_t * C_t``.

Both gates the tenancy bench enforces hold *structurally*, not just
empirically:

  - shared <= serialized: the naive serialization (every tenant planned
    independently, played back-to-back with a full-fabric swap at each
    hand-off) is replayed under shared accounting and kept in the candidate
    pool, and sparse hand-offs never cost more than full swaps; the
    selected plan is the weighted-best among candidates whose makespan does
    not exceed the serialized baseline.
  - per-tenant isolation bound: every tenant's shared completion is at most
    the plan's makespan, which is at most the serialized baseline — so
    ``C_t(shared) / C_t(alone)`` is bounded by
    ``serialized / C_t(alone)``, the bound `TenantPlan.isolation_bound`
    reports and `analysis.verifier` re-checks (``tenant/*`` rules).

`SharedPlan.fabric_phases()` emits the interleaved (schedule, m) tape for
`FabricSim.run_trace` (which plays foreign circuits without resetting port
state — carryover is a first-class input), and `score_shared_plans` pushes
many shared plans through `core.batchsim.batch_run_trace`, grouping lanes
by tape shape so interleavings are scored vectorized where the engine
allows and through the scalar oracle otherwise.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.core.cost_model import CostModel, PAPER_DEFAULT
from repro.core.jsonio import (FabricKind, RequestBase, SharingMode,
                               cost_model_from_dict, cost_model_to_dict,
                               require_keys)
from repro.core.schedules import changed_links

from .trace_planner import (PhaseCandidate, PhasePlan, TRACE_FABRICS,
                            TracePlan, _phase_plan, phase_candidates,
                            plan_trace)
from .traces import Trace

#: relative slack on the structural shared <= serialized comparisons
REL_TOL = 1e-9


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's job and its service-level contract.

    trace        : the tenant's collective stream (its ``trace.n`` is the
                   tenant's world size — the whole fabric under TIME_SLICE,
                   its port-partition size under PORT_PARTITION).
    weight       : SLA weight in the shared objective ``sum_t w_t * C_t``
                   (> 0; higher = finishing this tenant earlier matters
                   more).
    delta_budget : cap on this tenant's *intra-collective* reconfiguration
                   stall, seconds (None = inherit a weighted share of the
                   request's global budget, or unbounded).
    port_share   : optional fraction of the fabric's ports this tenant is
                   entitled to under PORT_PARTITION (its world must fit:
                   ``trace.n <= port_share * n``).
    """

    name: str
    trace: Trace
    weight: float = 1.0
    delta_budget: float | None = None
    port_share: float | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if not self.weight > 0:
            raise ValueError(
                f"tenant {self.name!r}: weight must be > 0, got {self.weight}")
        if self.delta_budget is not None and self.delta_budget < 0:
            raise ValueError(
                f"tenant {self.name!r}: delta_budget must be >= 0, got "
                f"{self.delta_budget}")
        if self.port_share is not None and not 0 < self.port_share <= 1:
            raise ValueError(
                f"tenant {self.name!r}: port_share must be in (0, 1], got "
                f"{self.port_share}")

    def to_dict(self) -> dict:
        return {"name": self.name, "trace": self.trace.to_dict(),
                "weight": self.weight, "delta_budget": self.delta_budget,
                "port_share": self.port_share}

    @staticmethod
    def from_dict(d: dict) -> "TenantSpec":
        require_keys(d, required=("name", "trace"),
                     optional=("weight", "delta_budget", "port_share"),
                     what="TenantSpec")
        return TenantSpec(
            name=d["name"], trace=Trace.from_dict(d["trace"]),
            weight=d.get("weight", 1.0),
            delta_budget=d.get("delta_budget"),
            port_share=d.get("port_share"))


@dataclasses.dataclass(frozen=True)
class SharedFabricRequest(RequestBase):
    """K tenants asking to share one fabric of ``n`` ports.

    sharing      : the discipline (`SharingMode`); bare strings coerce with
                   a `DeprecationWarning` like `FabricKind` everywhere else.
    fabric       : 'ocs' or 'ocs-overlap' (the analytic trace fabrics).
    delta_budget : global cap on intra-collective reconfiguration stall
                   across all tenants; tenants without their own budget
                   inherit a weight-proportional share of it.
    """

    tenants: tuple[TenantSpec, ...]
    n: int
    cost_model: CostModel = PAPER_DEFAULT
    fabric: FabricKind = FabricKind.OCS
    sharing: SharingMode = SharingMode.TIME_SLICE
    overlap: float = 0.0
    delta_budget: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "tenants", tuple(self.tenants))
        if not self.tenants:
            raise ValueError("a shared-fabric request needs at least 1 tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            dupes = sorted({x for x in names if names.count(x) > 1})
            raise ValueError(f"tenant names must be unique, got duplicates "
                             f"{dupes}")
        object.__setattr__(self, "sharing", SharingMode.coerce(self.sharing))
        self._validate_base()
        if self.fabric not in TRACE_FABRICS:
            raise ValueError(
                f"fabric must be one of {tuple(map(str, TRACE_FABRICS))}, "
                f"got {str(self.fabric)!r} (shared planning prices tenant "
                f"hand-offs analytically)")
        if self.sharing == SharingMode.TIME_SLICE:
            bad = [t.name for t in self.tenants if t.trace.n != self.n]
            if bad:
                raise ValueError(
                    f"time-sliced tenants interleave on the full fabric: "
                    f"tenant(s) {bad} have trace.n != n={self.n}")
        else:
            total = sum(t.trace.n for t in self.tenants)
            if total > self.n:
                raise ValueError(
                    f"port partition does not fit: tenant worlds sum to "
                    f"{total} > n={self.n} ports")
            for t in self.tenants:
                if (t.port_share is not None
                        and t.trace.n > t.port_share * self.n + 1e-12):
                    raise ValueError(
                        f"tenant {t.name!r} world {t.trace.n} exceeds its "
                        f"port share {t.port_share} of n={self.n} "
                        f"(= {t.port_share * self.n:.1f} ports)")

    def to_dict(self) -> dict:
        return {"tenants": [t.to_dict() for t in self.tenants],
                "n": self.n,
                "cost_model": cost_model_to_dict(self.cost_model),
                "fabric": str(self.fabric), "sharing": str(self.sharing),
                "overlap": self.overlap, "delta_budget": self.delta_budget}

    @classmethod
    def from_dict(cls, d: dict) -> "SharedFabricRequest":
        require_keys(d, required=("tenants", "n"),
                     optional=("cost_model", "fabric", "sharing", "overlap",
                               "delta_budget"),
                     what="SharedFabricRequest")
        return SharedFabricRequest(
            tenants=tuple(TenantSpec.from_dict(t) for t in d["tenants"]),
            n=d["n"],
            cost_model=(cost_model_from_dict(d["cost_model"],
                                             "SharedFabricRequest")
                        if "cost_model" in d else PAPER_DEFAULT),
            fabric=FabricKind.coerce(d.get("fabric", "ocs"), warn=False),
            sharing=SharingMode.coerce(d.get("sharing", "time-slice"),
                                       warn=False),
            overlap=d.get("overlap", 0.0),
            delta_budget=d.get("delta_budget"))

    def resolved_budgets(self) -> dict[str, float | None]:
        """Per-tenant intra-collective stall budgets, seconds.

        A tenant's own ``delta_budget`` wins; tenants without one split the
        request's global budget proportionally to SLA weight (so the global
        cap is never oversubscribed by the derived shares); with neither,
        the tenant is unbounded.
        """
        out: dict[str, float | None] = {}
        if self.delta_budget is None:
            return {t.name: t.delta_budget for t in self.tenants}
        free = [t for t in self.tenants if t.delta_budget is None]
        pool = self.delta_budget - sum(
            t.delta_budget for t in self.tenants if t.delta_budget is not None)
        pool = max(0.0, pool)
        wsum = sum(t.weight for t in free)
        for t in self.tenants:
            if t.delta_budget is not None:
                out[t.name] = t.delta_budget
            else:
                out[t.name] = pool * t.weight / wsum if wsum else 0.0
        return out


@dataclasses.dataclass(frozen=True)
class SharedPhase:
    """One planned phase of a time-sliced interleaving, tagged with its
    owning tenant; ``boundary_*`` price *entering* this phase (0 circuits /
    0 cost for the first phase on a fresh fabric)."""

    tenant: str
    plan: PhasePlan
    boundary_changed: int
    boundary_cost: float

    def to_dict(self) -> dict:
        return {"tenant": self.tenant, "plan": self.plan.to_dict(),
                "boundary_changed": self.boundary_changed,
                "boundary_cost": self.boundary_cost}

    @staticmethod
    def from_dict(d: dict) -> "SharedPhase":
        return SharedPhase(tenant=d["tenant"],
                           plan=PhasePlan.from_dict(d["plan"]),
                           boundary_changed=d["boundary_changed"],
                           boundary_cost=d["boundary_cost"])


@dataclasses.dataclass(frozen=True)
class TenantPlan:
    """One tenant's outcome inside a `SharedPlan`.

    ports           : the tenant's ``[lo, hi)`` port range under
                      PORT_PARTITION (None under TIME_SLICE).
    plan            : the tenant's own `TracePlan` under PORT_PARTITION
                      (None under TIME_SLICE, where the shared plan's
                      interleaved ``phases`` carry the schedules).
    completion_s    : when the tenant's last collective completes in the
                      shared execution.
    alone_s         : the tenant planned alone on its fabric under the same
                      budget — the isolation denominator.
    isolation       : measured ``completion_s / alone_s``.
    isolation_bound : structural worst case ``serialized_s / alone_s``
                      (shared completion never exceeds the serialized
                      baseline, so ``isolation <= isolation_bound``).
    """

    name: str
    weight: float
    delta_budget: float | None
    ports: tuple[int, int] | None
    plan: TracePlan | None
    completion_s: float
    alone_s: float
    isolation: float
    isolation_bound: float
    paid_reconfigs: int

    def to_dict(self) -> dict:
        return {"name": self.name, "weight": self.weight,
                "delta_budget": self.delta_budget,
                "ports": list(self.ports) if self.ports else None,
                "plan": self.plan.to_dict() if self.plan else None,
                "completion_s": self.completion_s, "alone_s": self.alone_s,
                "isolation": self.isolation,
                "isolation_bound": self.isolation_bound,
                "paid_reconfigs": self.paid_reconfigs}

    @staticmethod
    def from_dict(d: dict) -> "TenantPlan":
        return TenantPlan(
            name=d["name"], weight=d["weight"],
            delta_budget=d["delta_budget"],
            ports=tuple(d["ports"]) if d["ports"] else None,
            plan=TracePlan.from_dict(d["plan"]) if d["plan"] else None,
            completion_s=d["completion_s"], alone_s=d["alone_s"],
            isolation=d["isolation"], isolation_bound=d["isolation_bound"],
            paid_reconfigs=d["paid_reconfigs"])


@dataclasses.dataclass(frozen=True)
class SharedPlan:
    """Outcome of one `plan_shared` call (lossless JSON round trip).

    phases / order        : the chosen interleaving under TIME_SLICE (order
                            names the owning tenant per phase); both empty
                            under PORT_PARTITION, where each `TenantPlan`
                            carries its own `TracePlan`.
    makespan_s            : total shared execution time.
    weighted_completion_s : ``sum_t w_t * C_t``, the DP objective.
    serialized_s / serialized_weighted_s : the naive-serialization baseline
                            (independent plans back-to-back, full-fabric
                            swap per hand-off) on the same metrics — the
                            bench gates ``makespan_s <= serialized_s`` and
                            ``weighted_completion_s <= serialized_weighted_s``
                            row by row.
    """

    request: SharedFabricRequest
    order: tuple[str, ...]
    phases: tuple[SharedPhase, ...]
    tenants: tuple[TenantPlan, ...]
    makespan_s: float
    weighted_completion_s: float
    serialized_s: float
    serialized_weighted_s: float

    @property
    def sharing(self) -> SharingMode:
        return self.request.sharing

    def tenant(self, name: str) -> TenantPlan:
        for t in self.tenants:
            if t.name == name:
                return t
        raise KeyError(f"no tenant {name!r} in this shared plan")

    def fabric_phases(self) -> tuple[tuple, ...]:
        """Interleaved (schedule, m) tape for `FabricSim.run_trace` /
        `TraceLane` (TIME_SLICE only: a port partition has no single shared
        tape — each tenant's `TracePlan.fabric_phases()` plays its own
        sub-fabric)."""
        if self.sharing != SharingMode.TIME_SLICE:
            raise ValueError(
                "fabric_phases() is the time-sliced interleaved tape; "
                "port-partitioned tenants each play their own "
                "TracePlan.fabric_phases()")
        return tuple((p.plan.schedule, p.plan.m_bytes) for p in self.phases)

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "request": self.request.to_dict(),
            "order": list(self.order),
            "phases": [p.to_dict() for p in self.phases],
            "tenants": [t.to_dict() for t in self.tenants],
            "makespan_s": self.makespan_s,
            "weighted_completion_s": self.weighted_completion_s,
            "serialized_s": self.serialized_s,
            "serialized_weighted_s": self.serialized_weighted_s,
        }

    @staticmethod
    def from_dict(d: dict) -> "SharedPlan":
        return SharedPlan(
            request=SharedFabricRequest.from_dict(d["request"]),
            order=tuple(d["order"]),
            phases=tuple(SharedPhase.from_dict(p) for p in d["phases"]),
            tenants=tuple(TenantPlan.from_dict(t) for t in d["tenants"]),
            makespan_s=d["makespan_s"],
            weighted_completion_s=d["weighted_completion_s"],
            serialized_s=d["serialized_s"],
            serialized_weighted_s=d["serialized_weighted_s"])

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "SharedPlan":
        return SharedPlan.from_dict(json.loads(s))


# --- the shared joint DP ------------------------------------------------------


def shared_window_dp(n: int, items: Sequence[tuple[int, Sequence[PhaseCandidate]]],
                     cm: CostModel, *, coeffs: Sequence[float],
                     caps: Sequence[int | None], global_cap: int | None = None,
                     overlap: float = 0.0) -> list[PhaseCandidate]:
    """Joint DP over an interleaved multi-tenant phase sequence.

    ``items[p] = (owner, candidates)`` assigns phase position p to tenant
    ``owner``; ``coeffs[p]`` multiplies position p's (boundary + phase)
    cost in the objective — with ``coeffs[p] = sum of weights of tenants
    whose last phase is at position >= p`` the DP minimizes the exact
    weighted completion time ``sum_t w_t * C_t`` (each tenant's completion
    is the prefix sum through its last phase, so every position's cost is
    counted once per still-running tenant).  ``caps[t]`` bounds tenant t's
    paid intra-collective reconfigurations and ``global_cap`` the fleet's
    total, extending `trace_planner.window_dp`'s (offset, spent) state to
    (offset, per-tenant spent vector): reconfigs migrate to the tenants —
    and the collectives — that benefit, but never past a tenant's own cap.
    """
    if not items:
        raise ValueError("shared_window_dp needs at least one phase")
    if len(coeffs) != len(items):
        raise ValueError(f"need one coefficient per phase, got "
                         f"{len(coeffs)} for {len(items)} phases")
    T = len(caps)
    tracked = tuple(t for t in range(T) if caps[t] is not None)

    def spend(vec: tuple, owner: int, paid: int):
        """Update (per-tracked-tenant spent, global spent); None = over cap."""
        tenant_spent, total = vec
        total += paid
        if global_cap is not None and total > global_cap:
            return None
        if owner in tracked and paid:
            i = tracked.index(owner)
            new = tenant_spent[:i] + (tenant_spent[i] + paid,) \
                + tenant_spent[i + 1:]
            if new[i] > caps[owner]:
                return None
            tenant_spent = new
        return (tenant_spent, total)

    zero = ((0,) * len(tracked), 0)
    # state: (g_last, spend vector) -> (objective, prev state, candidate)
    layers: list[dict] = []
    cur: dict = {}
    owner0, cands0 = items[0]
    for cand in cands0:
        vec = spend(zero, owner0, cand.paid)
        if vec is None:
            continue
        obj = coeffs[0] * cand.time
        key = (cand.g_last, vec)
        if key not in cur or obj < cur[key][0]:
            cur[key] = (obj, None, cand)
    for p in range(1, len(items)):
        layers.append(cur)
        owner, cands = items[p]
        nxt: dict = {}
        for (g, vec), (obj, _, _) in cur.items():
            for cand in cands:
                vec2 = spend(vec, owner, cand.paid)
                if vec2 is None:
                    continue
                step = cm.delta_sparse(
                    changed_links(n, g, cand.g_first), overlap) + cand.time
                obj2 = obj + coeffs[p] * step
                key = (cand.g_last, vec2)
                if key not in nxt or obj2 < nxt[key][0]:
                    nxt[key] = (obj2, (g, vec), cand)
        cur = nxt
    if not cur:
        raise ValueError(
            f"per-tenant reconfiguration caps {list(caps)} (global "
            f"{global_cap}) are infeasible for the {len(items)}-phase "
            f"shared window (even R=0 schedules do not fit)")
    best_key = min(cur, key=lambda k: (cur[k][0], k))
    chosen: list[PhaseCandidate] = []
    key = best_key
    for layer in reversed(layers + [cur]):
        _, prev_key, cand = layer[key]
        chosen.append(cand)
        key = prev_key
    chosen.reverse()
    return chosen


# --- interleavings ------------------------------------------------------------


def _event_groups(trace: Trace) -> list[list[tuple[str, float, str]]]:
    """Per-event phase groups, tagged exactly like `Trace.phases()` ('ar'
    keeps its RS + AG phases adjacent)."""
    groups: list[list[tuple[str, float, str]]] = []
    for ev in trace.events:
        if ev.kind == "ar":
            groups.append([("rs", ev.m_bytes, f"{ev.tag}:rs"),
                           ("ag", ev.m_bytes, f"{ev.tag}:ag")])
        else:
            groups.append([(ev.kind, ev.m_bytes, ev.tag)])
    return groups


def candidate_orders(req: SharedFabricRequest,
                     alone_totals: Sequence[float]) -> dict[str, list[int]]:
    """Candidate interleavings, as tenant-index sequences per *collective*.

    Each entry lists which tenant issues the next whole collective (event);
    per-tenant event order is always preserved.  The pool always contains
    the request-order serialization (the shared <= serialized gate needs it
    structurally), Smith's-rule weighted-shortest-block order (optimal block
    serialization for weighted completion), and round-robin.
    """
    K = len(req.tenants)
    counts = [len(t.trace.events) for t in req.tenants]
    orders: dict[str, list[int]] = {}
    orders["serialized"] = [t for t in range(K) for _ in range(counts[t])]
    wspt = sorted(range(K), key=lambda t: (
        -req.tenants[t].weight / alone_totals[t] if alone_totals[t] > 0
        else float("-inf"), t))
    orders["wspt"] = [t for t in wspt for _ in range(counts[t])]
    rr, left = [], list(counts)
    while any(left):
        for t in range(K):
            if left[t]:
                rr.append(t)
                left[t] -= 1
    orders["round-robin"] = rr
    # de-duplicate orders that collapse to the same sequence (e.g. K=1)
    seen: dict[tuple, str] = {}
    out: dict[str, list[int]] = {}
    for name, seq in orders.items():
        key = tuple(seq)
        if key not in seen:
            seen[key] = name
            out[name] = seq
    return out


def _interleave(req: SharedFabricRequest, order: Sequence[int]):
    """Expand a per-collective tenant order into per-phase items:
    (tenant index, (kind, m, tag)) per position."""
    groups = [_event_groups(t.trace) for t in req.tenants]
    cursor = [0] * len(req.tenants)
    items: list[tuple[int, tuple[str, float, str]]] = []
    for t in order:
        for ph in groups[t][cursor[t]]:
            items.append((t, ph))
        cursor[t] += 1
    return items


def _path_metrics(req: SharedFabricRequest, items, chosen):
    """Assemble phases / completions / totals for a chosen candidate path."""
    n, cm, overlap = req.n, req.cost_model, req.overlap
    phases: list[SharedPhase] = []
    g = None
    t_acc = 0.0
    completion = {t.name: 0.0 for t in req.tenants}
    for (owner, (kind, m, tag)), cand in zip(items, chosen, strict=True):
        bc = 0 if g is None else changed_links(n, g, cand.g_first)
        cost = cm.delta_sparse(bc, overlap) if g is not None else 0.0
        t_acc += cost + cand.time
        name = req.tenants[owner].name
        completion[name] = t_acc
        phases.append(SharedPhase(
            tenant=name, plan=_phase_plan(kind, m, tag, cand),
            boundary_changed=bc, boundary_cost=cost))
        g = cand.g_last
    weighted = sum(t.weight * completion[t.name] for t in req.tenants)
    return phases, completion, t_acc, weighted


# --- plan_shared --------------------------------------------------------------


def _plan_port_partition(req: SharedFabricRequest, planner) -> SharedPlan:
    cm, overlap = req.cost_model, req.overlap
    budgets = req.resolved_budgets()
    base = 0
    tenant_plans: list[TenantPlan] = []
    swap = cm.delta_sparse(req.n, overlap)
    completions = []
    for spec in req.tenants:
        tp = plan_trace(spec.trace, cm, mode="carryover", fabric=req.fabric,
                        overlap=overlap, delta_budget=budgets[spec.name],
                        planner=planner, tenant=spec.name)
        completions.append(tp.total_time)
        tenant_plans.append((spec, (base, base + spec.trace.n), tp))
        base += spec.trace.n
    # naive serialization: one tenant at a time on the shared fabric, a
    # full-fabric swap re-establishing circuits at each hand-off
    serialized = sum(completions) + swap * (len(completions) - 1)
    acc, serialized_weighted = 0.0, 0.0
    for (spec, _, _), c in zip(tenant_plans, completions, strict=True):
        acc += (swap if acc > 0 else 0.0) + c
        serialized_weighted += spec.weight * acc
    out = []
    for (spec, ports, tp), c in zip(tenant_plans, completions, strict=True):
        out.append(TenantPlan(
            name=spec.name, weight=spec.weight,
            delta_budget=budgets[spec.name], ports=ports, plan=tp,
            completion_s=c, alone_s=c, isolation=1.0,
            isolation_bound=serialized / c if c > 0 else 1.0,
            paid_reconfigs=tp.paid_reconfigs))
    makespan = max(completions)
    weighted = sum(spec.weight * c
                   for (spec, _, _), c in zip(tenant_plans, completions,
                                              strict=True))
    return SharedPlan(
        request=req, order=(), phases=(), tenants=tuple(out),
        makespan_s=makespan, weighted_completion_s=weighted,
        serialized_s=serialized, serialized_weighted_s=serialized_weighted)


def _plan_time_slice(req: SharedFabricRequest, planner) -> SharedPlan:
    cm, n, overlap = req.cost_model, req.n, req.overlap
    budgets = req.resolved_budgets()
    unit = cm.delta_sparse(n, overlap)

    def cap_of(budget):
        if budget is None or unit <= 0:
            return None
        return int(budget / unit + 1e-12)

    caps = [cap_of(budgets[t.name]) for t in req.tenants]
    global_cap = cap_of(req.delta_budget)

    # tenant-alone plans: the isolation denominators, and the building
    # blocks of the naive serialization baseline
    alone = [plan_trace(t.trace, cm, mode="carryover", fabric=req.fabric,
                        overlap=overlap, delta_budget=budgets[t.name],
                        planner=planner, tenant=t.name)
             for t in req.tenants]
    alone_totals = [tp.total_time for tp in alone]
    swap = unit
    serialized = sum(alone_totals) + swap * (len(alone) - 1)
    acc, serialized_weighted = 0.0, 0.0
    for spec, tot in zip(req.tenants, alone_totals, strict=True):
        acc += (swap if acc > 0 else 0.0) + tot
        serialized_weighted += spec.weight * acc

    # per-tenant per-phase candidate tables (tenant-keyed in the plan cache)
    tables = []
    for spec in req.tenants:
        tables.append({})
        for kind, m, _tag in spec.trace.phases():
            if (kind, m) not in tables[-1]:
                tables[-1][(kind, m)] = phase_candidates(
                    kind, n, spec.trace.r, m, cm, req.fabric, overlap,
                    planner, tenant=spec.name)

    def coeffs_for(items):
        last = {}
        for p, (owner, _) in enumerate(items):
            last[owner] = p
        weights = [t.weight for t in req.tenants]
        out = []
        for p in range(len(items)):
            out.append(sum(w for t, w in enumerate(weights)
                           if last[t] >= p))
        return out

    # candidate paths: per order, the weighted-optimal joint DP path; plus
    # the naive serialization's own choices replayed under shared (sparse
    # hand-off) accounting, which anchors both structural gates
    paths = []
    for name, order in candidate_orders(req, alone_totals).items():
        items = _interleave(req, order)
        cand_lists = [(owner, tables[owner][(kind, m)])
                      for owner, (kind, m, _tag) in items]
        chosen = shared_window_dp(
            n, cand_lists, cm, coeffs=coeffs_for(items), caps=caps,
            global_cap=global_cap, overlap=overlap)
        paths.append((name, items, chosen))
    naive_items = _interleave(
        req, [t for t in range(len(req.tenants))
              for _ in range(len(req.tenants[t].trace.events))])
    naive_chosen = []
    for tp in alone:
        for pp, (kind, m, _tag) in zip(tp.phases, tp.trace.phases(),
                                       strict=True):
            offs = pp.schedule.link_offsets()
            naive_chosen.append(PhaseCandidate(
                strategy=pp.strategy, schedule=pp.schedule, time=pp.time,
                paid=pp.paid_reconfigs, g_first=offs[0], g_last=offs[-1]))
    naive_spent = [tp.paid_reconfigs for tp in alone]
    if global_cap is None or sum(naive_spent) <= global_cap:
        paths.append(("serialized-naive", naive_items, naive_chosen))

    scored = []
    for name, items, chosen in paths:
        phases, completion, makespan, weighted = _path_metrics(
            req, items, chosen)
        scored.append((name, items, phases, completion, makespan, weighted))
    # the selected plan must beat serialization on *both* metrics: filter to
    # makespan <= serialized (the naive replay always qualifies — sparse
    # hand-offs never exceed full swaps), then take the weighted best
    ok = [s for s in scored
          if s[4] <= serialized * (1 + REL_TOL)]
    if not ok:  # numerically impossible; keep the gate honest anyway
        ok = scored
    _, _, phases, completion, makespan, weighted = min(
        ok, key=lambda s: (s[5], s[4]))

    spent = {t.name: 0 for t in req.tenants}
    for p in phases:
        spent[p.tenant] += p.plan.paid_reconfigs
    tenants = []
    for spec, tp in zip(req.tenants, alone, strict=True):
        c, a = completion[spec.name], tp.total_time
        tenants.append(TenantPlan(
            name=spec.name, weight=spec.weight,
            delta_budget=budgets[spec.name], ports=None, plan=None,
            completion_s=c, alone_s=a,
            isolation=c / a if a > 0 else 1.0,
            isolation_bound=serialized / a if a > 0 else 1.0,
            paid_reconfigs=spent[spec.name]))
    return SharedPlan(
        request=req, order=tuple(p.tenant for p in phases),
        phases=tuple(phases), tenants=tuple(tenants),
        makespan_s=makespan, weighted_completion_s=weighted,
        serialized_s=serialized, serialized_weighted_s=serialized_weighted)


def plan_shared(req: SharedFabricRequest, planner=None) -> SharedPlan:
    """Plan K tenants sharing one fabric under ``req.sharing``.

    Guarantees (see the module docstring for why they are structural):
    ``makespan_s <= serialized_s`` and ``weighted_completion_s <=
    serialized_weighted_s``, and every tenant's ``isolation <=
    isolation_bound``.
    """
    if planner is None:
        from repro.planner import default_planner  # deferred: no cycle

        planner = default_planner()
    if req.sharing == SharingMode.PORT_PARTITION:
        return _plan_port_partition(req, planner)
    return _plan_time_slice(req, planner)


# --- batch scoring of interleavings -------------------------------------------


def score_shared_plans(plans: Sequence[SharedPlan], cm: CostModel, *,
                       chunks_per_msg: int = 32) -> list[float]:
    """Event-score many time-sliced shared plans' interleaved tapes.

    Groups the plans' tapes by (n, per-phase sub-step shape) and pushes each
    group through `core.batchsim.batch_run_trace` in one vectorized call
    (same-shape interleavings — e.g. reorderings of equal-length tenant
    blocks — batch together); odd-shaped tapes fall back to their own
    single-lane batch, which `batch_run_trace` may in turn route to the
    scalar `FabricSim.run_trace` oracle.  Returns one completion time per
    plan, in input order.
    """
    from repro.core.batchsim import TraceLane, batch_run_trace, compile_tape

    groups: dict[tuple, list[int]] = {}
    tapes = []
    for i, plan in enumerate(plans):
        phases = plan.fabric_phases()
        shape = (phases[0][0].n,
                 tuple(compile_tape(s).S for s, _ in phases))
        groups.setdefault(shape, []).append(i)
        tapes.append(phases)
    out = [0.0] * len(plans)
    for idx in groups.values():
        lanes = [TraceLane(phases=tapes[i],
                           overlap=plans[i].request.overlap) for i in idx]
        batch = batch_run_trace(lanes, cm, chunks_per_msg=chunks_per_msg)
        for j, i in enumerate(idx):
            out[i] = batch.result(j).completion
    return out
