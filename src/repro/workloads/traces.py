"""Typed collective-event traces and deterministic workload generators.

A `Trace` is a sequence of `CollectiveEvent`s (kind + payload) issued
back-to-back on one n-node reconfigurable fabric.  The generators below
synthesize realistic streams from the model-zoo configs rather than from
hand-picked payloads:

  - `moe_a2a_trace`    — per-MoE-layer dispatch + combine All-to-All (token
                         routing), payloads from (tokens/device) x d_model
                         with seeded routing-imbalance jitter
                         (`configs/qwen3_moe_235b_a22b.py`-style shapes);
  - `train_step_trace` — per-training-step bucketed gradient AllReduce,
                         payloads from an analytic parameter-count estimate
                         of the arch (the `train_lm` gradient-sync path);
  - `decode_ag_trace`  — decode-time AllGather bursts, one small
                         hidden-state gather per emitted token (the
                         `serve_decode` path);
  - `mixed_trace`      — interleaved training + serving stream for the
                         cross-collective carryover benchmark.

All generators are deterministic in ``seed`` (payload jitter comes from one
`random.Random(seed)` stream) and every record round-trips through JSON
losslessly (floats survive via repr).
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Sequence

from repro.core.jsonio import require_keys, require_positive_payload
from repro.models.config import ArchConfig

EVENT_KINDS = ("a2a", "rs", "ag", "ar")

MB = 1024.0 ** 2


@dataclasses.dataclass(frozen=True)
class CollectiveEvent:
    """One collective issued on the fabric.

    kind    : 'a2a' | 'rs' | 'ag' | 'ar' (composite AllReduce = RS then AG).
    m_bytes : total per-node payload in bytes (the paper's m).
    tag     : free-form provenance label, e.g. 'moe-a2a[L3:dispatch]'.
    """

    kind: str
    m_bytes: float
    tag: str = ""

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"kind must be one of {EVENT_KINDS}, got {self.kind!r}")
        if self.m_bytes < 0:
            raise ValueError(f"payload must be >= 0, got {self.m_bytes}")
        object.__setattr__(self, "m_bytes", float(self.m_bytes))

    def to_dict(self) -> dict:
        return {"kind": self.kind, "m_bytes": self.m_bytes, "tag": self.tag}

    @staticmethod
    def from_dict(d: dict) -> "CollectiveEvent":
        require_keys(d, required=("kind", "m_bytes"), optional=("tag",),
                     what="CollectiveEvent")
        return CollectiveEvent(
            kind=d["kind"],
            m_bytes=require_positive_payload(d["m_bytes"], "CollectiveEvent"),
            tag=d.get("tag", ""))


@dataclasses.dataclass(frozen=True)
class Trace:
    """A back-to-back collective stream on one n-node fabric.

    The Bruck radix ``r`` is shared by every event (all schedules of one
    trace run on the same fabric and planner family tables).
    """

    name: str
    n: int
    events: tuple[CollectiveEvent, ...]
    r: int = 2

    def __post_init__(self):
        if self.n < 2:
            raise ValueError(f"need at least 2 nodes, got n={self.n}")
        if self.r < 2:
            raise ValueError(f"radix must be >= 2, got r={self.r}")
        if not self.events:
            raise ValueError("a trace needs at least one event")
        object.__setattr__(self, "events", tuple(self.events))

    def __len__(self) -> int:
        return len(self.events)

    def phases(self) -> tuple[tuple[str, float, str], ...]:
        """Flatten to single-collective (kind, m_bytes, tag) phases.

        A composite 'ar' event expands to its Rabenseifner RS + AG phases;
        the RS->AG transition then becomes an ordinary carryover boundary in
        the trace planner and fabric playback.
        """
        out: list[tuple[str, float, str]] = []
        for ev in self.events:
            if ev.kind == "ar":
                out.append(("rs", ev.m_bytes, f"{ev.tag}:rs"))
                out.append(("ag", ev.m_bytes, f"{ev.tag}:ag"))
            else:
                out.append((ev.kind, ev.m_bytes, ev.tag))
        return tuple(out)

    def total_bytes(self) -> float:
        return sum(ev.m_bytes for ev in self.events)

    def to_dict(self) -> dict:
        return {"version": 1, "name": self.name, "n": self.n, "r": self.r,
                "events": [ev.to_dict() for ev in self.events]}

    @staticmethod
    def from_dict(d: dict) -> "Trace":
        require_keys(d, required=("name", "n", "events"),
                     optional=("r", "version"), what="Trace")
        return Trace(name=d["name"], n=d["n"], r=d.get("r", 2),
                     events=tuple(CollectiveEvent.from_dict(e)
                                  for e in d["events"]))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "Trace":
        return Trace.from_dict(json.loads(s))


# --- payload derivation from model configs -----------------------------------


def _arch(arch: str | ArchConfig) -> ArchConfig:
    if isinstance(arch, ArchConfig):
        return arch
    from repro import configs  # deferred: keep workloads importable standalone

    return configs.get(arch)


def approx_param_bytes(cfg: ArchConfig, dtype_bytes: int = 4) -> float:
    """Analytic parameter-footprint estimate of an arch (gradient AR payload).

    Embedding + per-layer attention and FFN weights; MoE layers count every
    expert (all-expert gradients sync in the dense data-parallel path).  An
    estimate, not a checkpoint census — trace payloads only need realistic
    magnitudes and ratios.
    """
    d = cfg.d_model
    head_dim = cfg.head_dim or d // cfg.num_heads
    attn = d * head_dim * (2 * cfg.num_heads + 2 * cfg.num_kv_heads)
    if cfg.ffn == "moe" and cfg.moe is not None:
        ffn = 3 * d * cfg.moe.d_ff_expert * cfg.moe.num_experts
        if cfg.moe.dense_residual_d_ff:
            ffn += 3 * d * cfg.moe.dense_residual_d_ff
    else:
        ffn = 3 * d * cfg.d_ff
    return float(dtype_bytes) * (cfg.vocab_size * d + cfg.num_layers * (attn + ffn))


def moe_a2a_trace(n: int, *, arch: str | ArchConfig = "qwen3-moe-235b-a22b",
                  layers: int = 4, tokens_per_device: int = 1024,
                  act_bytes: int = 2, seed: int = 0,
                  jitter: float = 0.25, name: str | None = None) -> Trace:
    """Per-MoE-layer dispatch + combine All-to-All stream.

    Every MoE layer routes each device's tokens to their experts (dispatch
    a2a) and returns the expert outputs (combine a2a); the nominal per-node
    payload is tokens_per_device x d_model x act_bytes, scaled per event by
    a seeded routing-imbalance jitter in [1 - jitter, 1 + jitter].
    """
    cfg = _arch(arch)
    if cfg.ffn != "moe" or cfg.moe is None:
        raise ValueError(f"{cfg.name} has no MoE layers (ffn={cfg.ffn!r})")
    if not 0.0 <= jitter < 1.0:
        raise ValueError(f"jitter must be in [0, 1), got {jitter}")
    layers = min(layers, cfg.num_layers)
    rng = random.Random(seed)
    nominal = tokens_per_device * cfg.d_model * act_bytes
    events = []
    for layer in range(layers):
        for stage in ("dispatch", "combine"):
            scale = 1.0 + jitter * rng.uniform(-1.0, 1.0)
            events.append(CollectiveEvent(
                kind="a2a", m_bytes=nominal * scale,
                tag=f"moe-a2a[L{layer}:{stage}]"))
    return Trace(name=name or f"moe-{cfg.name}", n=n, events=tuple(events))


def train_step_trace(n: int, *, arch: str | ArchConfig = "stablelm-3b",
                     steps: int = 2, buckets: int = 2, grad_bytes: int = 4,
                     scale_down: float = 1e-3, seed: int = 0,
                     name: str | None = None) -> Trace:
    """Per-training-step bucketed gradient AllReduce stream (`train_lm`).

    Each step emits ``buckets`` composite AR events covering the arch's
    (scaled) parameter footprint — the overlapped bucket sync of a data-
    parallel training loop.  ``scale_down`` shrinks the analytic footprint
    to benchmark-friendly payloads (the default maps a ~3B arch to a few
    tens of MB per bucket, the reduced-model regime of examples/train_lm).
    """
    if steps < 1 or buckets < 1:
        raise ValueError("need steps >= 1 and buckets >= 1")
    cfg = _arch(arch)
    del seed  # payloads are structural; accepted for interface symmetry
    per_bucket = approx_param_bytes(cfg, grad_bytes) * scale_down / buckets
    events = [
        CollectiveEvent(kind="ar", m_bytes=per_bucket,
                        tag=f"grad-ar[s{step}:b{bucket}]")
        for step in range(steps) for bucket in range(buckets)
    ]
    return Trace(name=name or f"train-{cfg.name}", n=n, events=tuple(events))


def decode_ag_trace(n: int, *, arch: str | ArchConfig = "gemma3-4b",
                    decode_steps: int = 8, batch: int = 8,
                    act_bytes: int = 2, seed: int = 0, jitter: float = 0.0,
                    name: str | None = None) -> Trace:
    """Decode-time AllGather burst (`serve_decode`): one hidden-state gather
    per emitted token across the serving group, optionally jittered to model
    ragged batches."""
    if decode_steps < 1 or batch < 1:
        raise ValueError("need decode_steps >= 1 and batch >= 1")
    if not 0.0 <= jitter < 1.0:
        raise ValueError(f"jitter must be in [0, 1), got {jitter}")
    cfg = _arch(arch)
    rng = random.Random(seed)
    nominal = batch * cfg.d_model * act_bytes
    events = []
    for step in range(decode_steps):
        scale = 1.0 + jitter * rng.uniform(-1.0, 1.0)
        events.append(CollectiveEvent(kind="ag", m_bytes=nominal * scale,
                                      tag=f"decode-ag[t{step}]"))
    return Trace(name=name or f"decode-{cfg.name}", n=n, events=tuple(events))


def mixed_trace(n: int, *, seed: int = 0, moe_layers: int = 2,
                train_steps: int = 1, decode_steps: int = 4,
                name: str = "mixed") -> Trace:
    """Interleaved training + serving stream: MoE a2a pairs, then the step's
    gradient AR buckets, then a decode AG burst — the trace-bench workload."""
    moe = moe_a2a_trace(n, layers=moe_layers, seed=seed)
    train = train_step_trace(n, steps=train_steps, seed=seed)
    decode = decode_ag_trace(n, decode_steps=decode_steps, seed=seed,
                             jitter=0.25)
    return Trace(name=name, n=n,
                 events=moe.events + train.events + decode.events)


def concat_traces(name: str, traces: Sequence[Trace]) -> Trace:
    """Concatenate traces issued on the same fabric into one stream."""
    if not traces:
        raise ValueError("need at least one trace")
    n, r = traces[0].n, traces[0].r
    for t in traces:
        if t.n != n or t.r != r:
            raise ValueError(
                f"trace {t.name!r} has (n={t.n}, r={t.r}) != ({n}, {r})")
    return Trace(name=name, n=n, r=r,
                 events=tuple(ev for t in traces for ev in t.events))
