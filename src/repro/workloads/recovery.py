"""Degraded-mode re-planning: from a `DegradedState` back to a running trace.

`FabricSim.run_trace(..., faults=...)` ends a faulted trace with a
`core.faults.DegradedState`: the committed collective prefix, its exact
`FabricSnapshot`, the surviving world, and the fate of the in-flight chunks.
This module closes the loop — failure → snapshot → re-plan → verify:

  1. `split_events` maps the committed *phase* count back to whole
     `CollectiveEvent`s (a composite 'ar' spans an RS + AG phase pair and is
     only committed when both drained — a half-committed AllReduce re-runs
     in full, recovery never trusts partially-delivered collective state).
  2. `reduced_trace` rebuilds the remaining stream at the surviving world
     size (the arbitrary-n schedule core makes shrink/grow worlds legal,
     including a node-join's n+1).
  3. `replan_after_fault` treats the failure as the ultimate misprediction:
     every event planned beyond the committed prefix is dropped, and a fresh
     `OnlinePlanner` at the reduced n re-plans the remaining stream with the
     window covering all of it — which makes the recovery plan bit-identical
     to the offline `plan_trace(mode='carryover')` of the reduced trace (the
     W-equals-stream anchor pinned by tests/test_online_planner.py).  The
     re-plan is *cold* (no ``init_g``): after an abort the parked circuits
     are untrustworthy — a dead link or a changed world — so recovery
     re-establishes topology, while the snapshot still supplies the resume
     clock and the committed accounting.
  4. `run_with_recovery` measures the payoff: resume-from-snapshot completion
     (resume clock + remaining-stream run at n') vs restart-from-scratch
     (resume clock + the *whole* trace re-planned and re-run at n'), executes
     the recovery plan and the clean reduced-world plan on a fresh fabric to
     check bit-identity, and audits everything with the ``fault/*`` verifier
     rules (`repro.analysis`).

`benchmarks/faults_bench.py` grids this over fault kind x n x delta x
failure time and gates ``recovery_ratio <= 1`` plus bit-identity on every
row (BENCH_faults.json).
"""
from __future__ import annotations

import dataclasses

from repro.core.cost_model import CostModel, PAPER_DEFAULT
from repro.core.fabricsim import FabricSim, TraceFabricResult
from repro.core.jsonio import FabricKind
from repro.core.faults import DegradedState, FaultTimeline

from .online_planner import OnlinePlanner, OnlineStats
from .trace_planner import TracePlan, plan_trace
from .traces import CollectiveEvent, Trace


def split_events(trace: Trace, completed_phases: int
                 ) -> tuple[tuple[CollectiveEvent, ...],
                            tuple[CollectiveEvent, ...]]:
    """(committed, remaining) events for a committed *phase* count.

    An event is committed only when every phase it flattens to drained
    ('ar' = its RS + AG pair); an event with any un-committed phase lands in
    ``remaining`` and re-runs in full on recovery.
    """
    if completed_phases < 0:
        raise ValueError(
            f"completed_phases must be >= 0, got {completed_phases}")
    done = 0
    committed: list[CollectiveEvent] = []
    for i, ev in enumerate(trace.events):
        width = 2 if ev.kind == "ar" else 1
        if done + width > completed_phases:
            return tuple(committed), trace.events[i:]
        committed.append(ev)
        done += width
    if completed_phases > done:
        raise ValueError(
            f"completed_phases={completed_phases} exceeds the trace's "
            f"{done} phases")
    return tuple(committed), ()


def reduced_trace(trace: Trace, degraded: DegradedState) -> Trace:
    """Remaining stream of ``trace`` re-targeted at the surviving world."""
    if degraded.n != trace.n:
        raise ValueError(
            f"degraded state is for n={degraded.n}, trace has n={trace.n}")
    _, remaining = split_events(trace, degraded.completed_phases)
    if not remaining:
        raise ValueError(
            "nothing left to recover: every event of the trace committed")
    return Trace(name=f"{trace.name}+recovery", n=degraded.new_n, r=trace.r,
                 events=remaining)


def replan_after_fault(trace: Trace, degraded: DegradedState,
                       cm: CostModel = PAPER_DEFAULT, *,
                       fabric: FabricKind = FabricKind.OCS,
                       overlap: float = 0.0,
                       delta_budget: float | None = None, planner=None,
                       verify: bool = True) -> tuple[TracePlan, OnlineStats]:
    """Re-plan the remaining stream over the surviving world.

    Every prediction beyond the committed prefix is dropped (the fault
    invalidated the world they were planned for — each drop is counted as a
    misprediction in the returned `OnlineStats`) and a fresh `OnlinePlanner`
    at the reduced n re-plans the survivors with the window spanning the
    whole remaining stream, so the recovery plan is bit-identical to the
    offline carryover plan of `reduced_trace` — the recovered result then
    matches a clean run of the reduced world exactly, which is the
    ``fault/replan`` verifier gate.
    """
    reduced = reduced_trace(trace, degraded)
    op = OnlinePlanner(reduced.n, r=reduced.r, cm=cm,
                       window=len(reduced.events), fabric=fabric,
                       overlap=overlap, delta_budget=delta_budget,
                       planner=planner, verify=verify)
    # the old-world predictions covering these events were invalidated by
    # the fault: drop them (each counts as a misprediction), then re-predict
    # the same stream on the surviving world and commit it
    op.predict(reduced.events)
    op.drop_predicted(len(reduced.events))
    op.predict(reduced.events)
    for _ in reduced.events:
        op.observe()
    return op.result(name=reduced.name), op.stats()


@dataclasses.dataclass(frozen=True)
class RecoveryResult:
    """Outcome of one `run_with_recovery` fault-recovery cycle.

    degraded         : the state the fault left the fabric in.
    plan             : the original full-trace plan (old world).
    faulted_run      : the degraded execution that surfaced ``degraded``.
    committed_events : events whose every phase drained before the fault.
    recovery_plan    : re-plan of the remaining events at the reduced n.
    clean_plan       : offline carryover plan of the same reduced trace —
                       the bit-identity reference.
    restart_plan     : the whole trace re-planned from scratch at the
                       reduced n (the no-recovery baseline).
    recovery_total   : resume clock + executed remaining-stream completion.
    restart_total    : resume clock + executed whole-trace completion.
    bit_identical    : recovery schedules == clean schedules AND the two
                       executed completions are exactly equal.
    stats            : the re-planner's counters (the dropped old-world
                       predictions show up as mispredictions).
    """

    degraded: DegradedState
    plan: TracePlan
    faulted_run: TraceFabricResult
    committed_events: tuple[CollectiveEvent, ...]
    recovery_plan: TracePlan
    clean_plan: TracePlan
    restart_plan: TracePlan
    recovery_total: float
    restart_total: float
    bit_identical: bool
    stats: OnlineStats

    @property
    def recovery_ratio(self) -> float:
        """recovery_total / restart_total — <= 1 means resuming from the
        snapshot beats restarting the whole trace (1.0 when the fault struck
        before anything committed and the two coincide)."""
        return self.recovery_total / self.restart_total


def run_with_recovery(trace: Trace, cm: CostModel = PAPER_DEFAULT, *,
                      faults: FaultTimeline,
                      fabric: FabricKind = FabricKind.OCS,
                      overlap: float = 0.0,
                      delta_budget: float | None = None, planner=None,
                      engine_mode: str = "sparse", chunks_per_msg: int = 8,
                      verify: bool = True) -> RecoveryResult:
    """Plan, fault, re-plan, and measure one full recovery cycle.

    Plays the offline carryover plan of ``trace`` under ``faults``, maps the
    surfaced `DegradedState` back to whole events, re-plans the remainder at
    the surviving world size, executes both the recovery plan and the clean
    reduced-world reference on a fresh fabric (bit-identity check), and
    compares resume-from-snapshot against restart-from-scratch.  With
    ``verify=True`` the timeline, the degraded state, and the recovery plan
    must pass the ``fault/*`` verifier rules (`repro.analysis`) — a
    violation raises instead of returning.
    """
    plan = plan_trace(trace, cm, mode="carryover", fabric=fabric,
                      overlap=overlap, delta_budget=delta_budget,
                      planner=planner)
    sim = FabricSim(mode=engine_mode, chunks_per_msg=chunks_per_msg,
                    overlap=overlap)
    faulted = sim.run_trace(plan.fabric_phases(), cm, faults=faults,
                            capture_state=True)
    if faulted.degraded is None:
        raise ValueError(
            "no fault took effect before the trace completed; "
            "FaultTimeline.check_horizon rejects such timelines up front")
    ds = faulted.degraded
    committed, _ = split_events(trace, ds.completed_phases)

    recovery_plan, stats = replan_after_fault(
        trace, ds, cm, fabric=fabric, overlap=overlap,
        delta_budget=delta_budget, planner=planner, verify=verify)
    reduced = reduced_trace(trace, ds)
    clean_plan = plan_trace(reduced, cm, mode="carryover", fabric=fabric,
                            overlap=overlap, delta_budget=delta_budget,
                            planner=planner)
    restart = Trace(name=f"{trace.name}+restart", n=ds.new_n, r=trace.r,
                    events=trace.events)
    restart_plan = plan_trace(restart, cm, mode="carryover", fabric=fabric,
                              overlap=overlap, delta_budget=delta_budget,
                              planner=planner)

    def execute(p: TracePlan) -> float:
        fresh = FabricSim(mode=engine_mode, chunks_per_msg=chunks_per_msg,
                          overlap=overlap)
        return fresh.run_trace(p.fabric_phases(), cm).completion

    recovery_done = execute(recovery_plan)
    clean_done = execute(clean_plan)
    restart_done = execute(restart_plan)
    bit_identical = (recovery_plan.schedules() == clean_plan.schedules()
                     and recovery_done == clean_done)

    result = RecoveryResult(
        degraded=ds, plan=plan, faulted_run=faulted,
        committed_events=committed, recovery_plan=recovery_plan,
        clean_plan=clean_plan, restart_plan=restart_plan,
        recovery_total=ds.resume_clock + recovery_done,
        restart_total=ds.resume_clock + restart_done,
        bit_identical=bit_identical, stats=stats)

    if verify:
        from repro.analysis import (raise_on_violations, verify_degraded,
                                    verify_recovery, verify_timeline)

        found = (verify_timeline(faults)
                 + verify_degraded(ds, phases=plan.fabric_phases(),
                                   chunks_per_msg=chunks_per_msg)
                 + verify_recovery(ds, recovery_plan, clean_plan=clean_plan))
        raise_on_violations(
            found, context=f"fault recovery n={trace.n} "
                           f"kind={ds.fault.kind}")
    return result
