"""Plan-serving front-end: windowed plan requests answered through an LRU.

The serving-fleet picture (PCCL-style): many jobs share one reconfigurable
fabric, each periodically asking "here is my visible window of upcoming
collectives and the link offset my last collective left behind — what should
I run?".  `PlanService` answers such `ServeRequest`s in two tiers:

  - cache hit : the canonical JSON of the request (events + fabric carryover
    state) indexes a serving LRU of finished `ServedPlan`s — the
    microsecond-scale path repeated traffic takes;
  - cache miss: the request falls through to the receding-horizon machinery —
    the window's phases are candidate-tabled through the shared `Planner`
    (its own LRU amortizes the per-phase tables across jobs and windows) and
    joined by `trace_planner.window_dp`, warm-started at the request's
    ``init_g`` exactly like the online planner's re-plan step.

The request key includes ``init_g`` for the same reason `Planner.cache_key`
does: two windows with identical events but different inherited link offsets
are different planning problems, and a stale hit would hand one job a plan
whose entry boundary was priced for another job's fabric state.

`request_storm` is the synthetic driver: a seeded, skew-weighted storm of
windowed requests (hot windows repeat, cold ones churn) measuring plans/sec
and hit rate, with a timing-independent signature over the served plan
sequence so determinism is testable (benchmarks/online_bench.py gates the
cache-hit throughput floor; tests/test_serving.py pins determinism and the
never-worse-than-cold property).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import random
import time
from collections import OrderedDict
from typing import NamedTuple, Sequence

from repro.core.cost_model import CostModel, PAPER_DEFAULT
from repro.core.jsonio import FabricKind, RequestBase, require_keys
from repro.core.schedules import changed_links

from .trace_planner import (TRACE_FABRICS, PhasePlan, phase_candidates,
                            window_dp)
from .traces import (CollectiveEvent, decode_ag_trace, mixed_trace,
                     moe_a2a_trace)


class ServeCacheInfo(NamedTuple):
    """Serving-LRU counters, extended with the degraded-mode retry ledger.

    hits / misses / size / capacity mirror `planner.PlanCacheInfo`;
    retries counts cache-bypass re-plans after a `VerificationError`, and
    retry_failures counts requests whose retry budget was exhausted (the
    error then propagates to the caller).
    """

    hits: int
    misses: int
    retries: int
    retry_failures: int
    size: int
    capacity: int


@dataclasses.dataclass(frozen=True)
class ServeRequest(RequestBase):
    """One job's windowed plan request.

    events : the job's visible window of upcoming collectives (>= 1).
    n, r   : fabric world size and Bruck radix.
    init_g : link offset the job's previous collective left the fabric at
             (None = fresh fabric, no entry boundary).
    tenant : requesting tenant's identity (multi-tenant serving).  Part of
             the request key: two tenants with identical windows must never
             share a cached `ServedPlan` (same stale-hit class as init_g —
             a tenant's entry may be priced for another tenant's state).
    """

    events: tuple[CollectiveEvent, ...]
    n: int
    r: int = 2
    init_g: int | None = None
    tenant: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        if not self.events:
            raise ValueError("a serve request needs at least one event")
        self._validate_base()

    def to_dict(self) -> dict:
        return {"events": [ev.to_dict() for ev in self.events],
                "n": self.n, "r": self.r, "init_g": self.init_g,
                "tenant": self.tenant}

    @classmethod
    def from_dict(cls, d: dict) -> "ServeRequest":
        require_keys(d, required=("events", "n"),
                     optional=("r", "init_g", "tenant"), what="ServeRequest")
        init_g = d.get("init_g")
        if init_g is not None and not 1 <= init_g < d["n"]:
            raise ValueError(
                f"ServeRequest init_g must be a link offset in [1, n), got "
                f"init_g={init_g} with n={d['n']}")
        return ServeRequest(
            events=tuple(CollectiveEvent.from_dict(e) for e in d["events"]),
            n=d["n"], r=d.get("r", 2), init_g=init_g,
            tenant=d.get("tenant"))


@dataclasses.dataclass(frozen=True)
class ServedPlan:
    """Outcome of one served window.

    phases        : planned single-collective phases ('ar' events expanded).
    entry_changed / entry_cost : circuits rewired (and sparse stall paid)
                    entering the window from the request's ``init_g``.
    boundary_changed / boundary_cost : per intra-window boundary, as in
                    `TracePlan`.
    total_time    : entry + phase times + boundary costs (the quantity
                    `window_dp` minimizes).
    final_g       : link offset the window leaves the fabric at (the
                    ``init_g`` of the job's next request).
    """

    request: ServeRequest
    phases: tuple[PhasePlan, ...]
    entry_changed: int
    entry_cost: float
    boundary_changed: tuple[int, ...]
    boundary_cost: tuple[float, ...]
    total_time: float
    final_g: int

    @property
    def paid_reconfigs(self) -> int:
        return sum(p.paid_reconfigs for p in self.phases)

    def to_dict(self) -> dict:
        return {
            "request": self.request.to_dict(),
            "phases": [p.to_dict() for p in self.phases],
            "entry_changed": self.entry_changed,
            "entry_cost": self.entry_cost,
            "boundary_changed": list(self.boundary_changed),
            "boundary_cost": list(self.boundary_cost),
            "total_time": self.total_time, "final_g": self.final_g,
        }


class PlanService:
    """Serving front-end over the windowed-plan LRU + window DP (see module
    docstring).

    cm / fabric / overlap : planning model shared by every served window.
    cache_size : serving-LRU capacity (entries are immutable `ServedPlan`s).
    planner    : the shared `repro.planner.Planner` the candidate tables go
                 through (defaults to the process-wide `default_planner()`).
    verify     : statically audit every freshly-planned window
                 (`repro.analysis.verify_served_plan`) before it is cached
                 or served — a corrupt window raises `VerificationError`
                 instead of becoming a production incident on every later
                 cache hit.  Hits return already-audited plans unchecked.
    max_retries / retry_backoff_s : degraded-mode serving.  A window that
                 fails its audit is re-planned up to ``max_retries`` times
                 with the shared planner LRU cleared first (cache bypass —
                 a poisoned candidate table would otherwise be replayed
                 verbatim), sleeping ``retry_backoff_s * 2**attempt`` between
                 tries; only an exhausted budget lets the
                 `VerificationError` reach the caller.  The retry ledger is
                 surfaced in `cache_info`.
    """

    def __init__(self, *, cm: CostModel = PAPER_DEFAULT,
                 fabric: FabricKind = FabricKind.OCS,
                 overlap: float = 0.0, cache_size: int = 512, planner=None,
                 verify: bool = True, max_retries: int = 1,
                 retry_backoff_s: float = 0.0):
        fabric = FabricKind.coerce(fabric)
        if fabric not in TRACE_FABRICS:
            raise ValueError(
                f"fabric must be one of {tuple(map(str, TRACE_FABRICS))}, "
                f"got {str(fabric)!r}")
        if overlap and fabric != FabricKind.OCS_OVERLAP:
            raise ValueError(f"overlap={overlap} requires fabric='ocs-overlap'")
        if cache_size < 0:
            raise ValueError(f"cache_size must be >= 0, got {cache_size}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {retry_backoff_s}")
        if planner is None:
            from repro.planner import default_planner  # deferred: no cycle

            planner = default_planner()
        self.cm, self.fabric, self.overlap = cm, fabric, float(overlap)
        self.cache_size = int(cache_size)
        self.planner = planner
        self.verify = bool(verify)
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self._cache: OrderedDict[str, ServedPlan] = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._retries = 0
        self._retry_failures = 0

    # --- cache ---------------------------------------------------------------

    @staticmethod
    def request_key(req: ServeRequest) -> str:
        """Canonical JSON identity of a request (includes ``init_g``: same
        window, different inherited fabric state -> different entry)."""
        return json.dumps(req.to_dict(), sort_keys=True)

    def cache_info(self) -> ServeCacheInfo:
        return ServeCacheInfo(hits=self._hits, misses=self._misses,
                              retries=self._retries,
                              retry_failures=self._retry_failures,
                              size=len(self._cache), capacity=self.cache_size)

    def cache_clear(self) -> None:
        self._cache.clear()
        self._hits = 0
        self._misses = 0
        self._retries = 0
        self._retry_failures = 0

    # --- serving -------------------------------------------------------------

    def serve(self, req: ServeRequest) -> ServedPlan:
        if self.cache_size == 0:
            return self._plan_with_retry(req)
        key = self.request_key(req)
        hit = self._cache.get(key)
        if hit is not None:
            self._hits += 1
            self._cache.move_to_end(key)
            return hit
        self._misses += 1
        plan = self._plan_with_retry(req)
        self._cache[key] = plan
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return plan

    def serve_batch(self, reqs: Sequence[ServeRequest]) -> tuple[ServedPlan, ...]:
        return tuple(self.serve(req) for req in reqs)

    def _plan_with_retry(self, req: ServeRequest) -> ServedPlan:
        """Degraded-mode miss path: bounded retry with cache bypass.

        A `VerificationError` from the audit marks the freshly-planned
        window corrupt; instead of failing the request outright the shared
        planner LRU is cleared (the corrupt candidate tables must not be
        replayed) and the window re-planned, up to ``max_retries`` times
        with exponential backoff.  Only an exhausted budget re-raises.
        """
        from repro.analysis import VerificationError, clear_verifier_caches

        for attempt in range(self.max_retries + 1):
            try:
                return self._plan_window(req)
            except VerificationError:
                if attempt == self.max_retries:
                    self._retry_failures += 1
                    raise
                self._retries += 1
                if self.retry_backoff_s > 0:
                    time.sleep(self.retry_backoff_s * (2.0 ** attempt))
                self.planner.cache_clear()
                clear_verifier_caches()
        raise AssertionError("unreachable: retry loop returns or raises")

    def _plan_window(self, req: ServeRequest) -> ServedPlan:
        """Cache-miss path: window DP warm-started at the request's init_g."""
        from .online_planner import _flatten
        from .trace_planner import _phase_plan

        phases = _flatten(req.events)
        cand_lists = [
            phase_candidates(kind, req.n, req.r, m, self.cm, self.fabric,
                             self.overlap, self.planner, tenant=req.tenant)
            for kind, m, _ in phases]
        chosen = window_dp(req.n, cand_lists, self.cm, overlap=self.overlap,
                           init_g=req.init_g,
                           label=f"{len(req.events)}-event serve window")
        plans = [_phase_plan(kind, m, tag, cand)
                 for (kind, m, tag), cand in zip(phases, chosen, strict=True)]
        entry_changed = (0 if req.init_g is None else
                         changed_links(req.n, req.init_g, chosen[0].g_first))
        entry_cost = self.cm.delta_sparse(entry_changed, self.overlap)
        boundary_changed, boundary_cost = [], []
        for prev, nxt in zip(chosen, chosen[1:], strict=False):
            bc = changed_links(req.n, prev.g_last, nxt.g_first)
            boundary_changed.append(bc)
            boundary_cost.append(self.cm.delta_sparse(bc, self.overlap))
        total = (entry_cost + sum(p.time for p in plans)
                 + sum(boundary_cost))
        plan = ServedPlan(
            request=req, phases=tuple(plans),
            entry_changed=entry_changed, entry_cost=entry_cost,
            boundary_changed=tuple(boundary_changed),
            boundary_cost=tuple(boundary_cost), total_time=total,
            final_g=chosen[-1].g_last)
        if self.verify:
            # audit-before-serve: runs on the cache-miss path only, so the
            # hot hit path stays microsecond-scale
            from repro.analysis import raise_on_violations, verify_served_plan

            raise_on_violations(
                verify_served_plan(plan, self.cm, self.overlap),
                context=f"serve window n={req.n} ({len(req.events)} events)")
        return plan


# --- synthetic request storm --------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StormResult:
    """Outcome of one `request_storm` run.

    signature is a sha256 over the served plan sequence (requests + chosen
    schedules + modeled totals) — independent of wall time, so two storms
    with the same seed and pool must produce equal signatures regardless of
    machine speed.
    """

    requests: int
    hits: int
    misses: int
    unique_windows: int
    wall_s: float
    plans_per_sec: float
    hit_rate: float
    signature: str


def build_request_pool(n: int, *, r: int = 2, window: int = 3, seed: int = 0
                       ) -> tuple[ServeRequest, ...]:
    """Deterministic pool of windowed requests sliced from the workload
    generators: every length-``window`` slice of a decode burst, an MoE
    layer stream, and a mixed trace, crossed with a few inherited fabric
    states (fresh, unit offset, a mid-range offset)."""
    traces = [
        decode_ag_trace(n, decode_steps=8, seed=seed, jitter=0.25),
        moe_a2a_trace(n, layers=3, seed=seed),
        mixed_trace(n, seed=seed),
    ]
    init_gs: tuple[int | None, ...] = (None, 1, max(2, n // 4))
    pool = []
    for t in traces:
        for i in range(0, max(1, len(t.events) - window + 1)):
            evs = t.events[i:i + window]
            if not evs:
                continue
            for g in init_gs:
                pool.append(ServeRequest(events=evs, n=n, r=r, init_g=g))
    return tuple(pool)


def request_storm(service: PlanService, pool: Sequence[ServeRequest], *,
                  requests: int = 512, seed: int = 0,
                  hot_fraction: float = 0.25) -> StormResult:
    """Fire a seeded storm of ``requests`` draws from ``pool`` at the service.

    Draws are skew-weighted (Zipf-like 1/(rank+1) over a seeded shuffle of
    the pool, so roughly ``hot_fraction`` of the pool serves most traffic —
    the repeated-window regime the serving LRU exists for).  Returns
    plans/sec, hit accounting deltas for this storm, and the deterministic
    plan-sequence signature.
    """
    if not pool:
        raise ValueError("request_storm needs a non-empty pool")
    if requests < 1:
        raise ValueError(f"requests must be >= 1, got {requests}")
    if not 0.0 < hot_fraction <= 1.0:
        raise ValueError(f"hot_fraction must be in (0, 1], got {hot_fraction}")
    rng = random.Random(seed)
    ranks = list(range(len(pool)))
    rng.shuffle(ranks)
    # Zipf-ish: the first ~hot_fraction of the shuffled pool gets most draws
    weights = [1.0 / (1.0 + rank / max(1.0, hot_fraction * len(pool)))
               for rank in ranks]
    order = rng.choices(range(len(pool)), weights=weights, k=requests)

    hits0, misses0 = service._hits, service._misses
    t0 = time.perf_counter()
    served = [service.serve(pool[i]) for i in order]
    wall = time.perf_counter() - t0
    hits = service._hits - hits0
    misses = service._misses - misses0

    digest = hashlib.sha256()
    for plan in served:
        digest.update(json.dumps(plan.to_dict(), sort_keys=True).encode())
    return StormResult(
        requests=requests, hits=hits, misses=misses,
        unique_windows=len(set(order)), wall_s=wall,
        plans_per_sec=requests / wall if wall > 0 else float("inf"),
        hit_rate=hits / requests, signature=digest.hexdigest())
