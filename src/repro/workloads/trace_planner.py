"""Cross-collective trace planning with fabric-state carryover.

`plan_trace` extends the per-collective exact-R planning across collective
boundaries.  The fabric's final link offsets from collective i are the
initial configuration of collective i+1, so the boundary pays delta only on
the circuits that actually change (`core.schedules.changed_links`) — and a
boundary where collective i ends on exactly the offsets collective i+1
starts with is free.  Three planning modes:

  - ``carryover`` : joint DP over the whole trace.  Every phase contributes
                    its full all-R candidate table (the planner's ranked
                    alternatives, themselves products of the exact segment-
                    partition DPs), the DP state is (final link offset,
                    reconfigurations spent), transitions charge the sparse
                    boundary cost, and a trace-wide ``delta_budget`` caps
                    the total intra-collective reconfiguration stall
                    *jointly* — R migrates to the collectives that benefit
                    instead of being rationed per collective.
  - ``cold``      : today's per-collective view.  Each phase is planned
                    independently (a ``delta_budget`` is split evenly across
                    phases — the greedy allocation), and every boundary
                    re-establishes the next phase's initial topology with a
                    full-fabric swap (all n circuits, one effective delta).
  - ``static``    : every phase runs the static (R=0, ring) schedule; the
                    fabric never reconfigures and all boundaries are free.

The carryover candidate set contains every cold choice and its boundary
charges are never larger, so ``carryover <= cold`` holds pointwise — the
trace-bench gate.  A composite 'ar' event is flattened to its RS + AG
phases first, so the RS->AG transition is just another carryover boundary.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.core.cost_model import CostModel, PAPER_DEFAULT
from repro.core.jsonio import FabricKind
from repro.core.schedules import Schedule, changed_links, static_schedule
from repro.core.simulator import collective_time, collective_time_overlap

from .traces import Trace

TRACE_PLAN_MODES = ("carryover", "cold", "static")
#: fabrics a trace/window DP can price analytically (enum members; bare
#: strings compare equal, so legacy membership checks keep working)
TRACE_FABRICS = (FabricKind.OCS, FabricKind.OCS_OVERLAP)


@dataclasses.dataclass(frozen=True)
class PhasePlan:
    """One planned single-collective phase of a trace."""

    kind: str
    m_bytes: float
    tag: str
    strategy: str
    schedule: Schedule
    time: float            # modeled completion time, boundary cost excluded
    paid_reconfigs: int    # intra-collective boundaries that rewire circuits

    def to_dict(self) -> dict:
        return {
            "kind": self.kind, "m_bytes": self.m_bytes, "tag": self.tag,
            "strategy": self.strategy,
            "schedule": {"kind": self.schedule.kind, "n": self.schedule.n,
                         "x": list(self.schedule.x), "r": self.schedule.r},
            "time": self.time, "paid_reconfigs": self.paid_reconfigs,
        }

    @staticmethod
    def from_dict(d: dict) -> "PhasePlan":
        s = d["schedule"]
        return PhasePlan(
            kind=d["kind"], m_bytes=d["m_bytes"], tag=d["tag"],
            strategy=d["strategy"],
            schedule=Schedule(kind=s["kind"], n=s["n"], x=tuple(s["x"]),
                              r=s["r"]),
            time=d["time"], paid_reconfigs=d["paid_reconfigs"])


@dataclasses.dataclass(frozen=True)
class TracePlan:
    """Outcome of one `plan_trace` call (lossless JSON round trip)."""

    trace: Trace
    mode: str
    fabric: FabricKind
    overlap: float
    delta_budget: float | None
    phases: tuple[PhasePlan, ...]
    boundary_changed: tuple[int, ...]  # circuits rewired per phase boundary
    boundary_cost: tuple[float, ...]   # effective stall charged per boundary
    total_time: float

    @property
    def phase_time(self) -> float:
        return sum(p.time for p in self.phases)

    @property
    def boundary_time(self) -> float:
        return sum(self.boundary_cost)

    @property
    def free_boundaries(self) -> int:
        """Boundaries where the next collective reuses the fabric as-is."""
        return sum(1 for c in self.boundary_changed if c == 0)

    @property
    def paid_reconfigs(self) -> int:
        return sum(p.paid_reconfigs for p in self.phases)

    def schedules(self) -> tuple[Schedule, ...]:
        return tuple(p.schedule for p in self.phases)

    def fabric_phases(self) -> tuple[tuple[Schedule, float], ...]:
        """(schedule, m) pairs for `FabricSim.run_trace` / `batch_run_trace`."""
        return tuple((p.schedule, p.m_bytes) for p in self.phases)

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "trace": self.trace.to_dict(),
            "mode": self.mode, "fabric": str(self.fabric),
            "overlap": self.overlap, "delta_budget": self.delta_budget,
            "phases": [p.to_dict() for p in self.phases],
            "boundary_changed": list(self.boundary_changed),
            "boundary_cost": list(self.boundary_cost),
            "total_time": self.total_time,
        }

    @staticmethod
    def from_dict(d: dict) -> "TracePlan":
        return TracePlan(
            trace=Trace.from_dict(d["trace"]),
            mode=d["mode"], fabric=FabricKind.coerce(d["fabric"], warn=False),
            overlap=d["overlap"],
            delta_budget=d["delta_budget"],
            phases=tuple(PhasePlan.from_dict(p) for p in d["phases"]),
            boundary_changed=tuple(d["boundary_changed"]),
            boundary_cost=tuple(d["boundary_cost"]),
            total_time=d["total_time"])

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "TracePlan":
        return TracePlan.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class PhaseCandidate:
    """One evaluable schedule for one phase of the joint DP.

    time is the phase's modeled completion (boundary costs excluded); paid
    counts its intra-collective circuit-rewiring boundaries; g_first / g_last
    are the link offsets the schedule starts and ends on — the DP charges
    `CostModel.delta_sparse(changed_links(n, g, g_first), overlap)` to enter
    the candidate from a fabric left at offset g.
    """

    strategy: str
    schedule: Schedule
    time: float
    paid: int
    g_first: int
    g_last: int


def _phase_time(sched: Schedule, m: float, cm: CostModel, fabric: str,
                overlap: float) -> float:
    if fabric == "ocs-overlap":
        return collective_time_overlap(sched, m, cm, overlap).total
    return collective_time(sched, m, cm).total


def phase_candidates(kind: str, n: int, r: int, m: float, cm: CostModel,
                     fabric: FabricKind, overlap: float,
                     planner, tenant: str | None = None
                     ) -> list[PhaseCandidate]:
    """Full all-R candidate table of one phase, from the planner's ranked
    alternatives (ring-impl rows carry no schedule and are skipped).  Goes
    through the planner's plan cache, so repeated (kind, m) phases — and the
    online planner's re-plans over a shifted window — pay for the table once.
    ``tenant`` tags the underlying `PlanRequest` (and therefore the plan-
    cache key) with the requesting tenant's identity, so multi-tenant
    serving never shares cached tables across tenants.
    """
    from repro.planner import PlanRequest  # deferred: planner imports core

    res = planner.plan(PlanRequest(kind=kind, n=n, m_bytes=m, cost_model=cm,
                                   r=r, fabric=FabricKind.coerce(fabric),
                                   overlap=overlap, tenant=tenant))
    out = []
    for alt in res.alternatives:
        if alt.x is None:
            continue
        sched = Schedule(kind=kind, n=n, x=tuple(alt.x), r=r)
        offs = sched.link_offsets()
        out.append(PhaseCandidate(
            strategy=alt.strategy, schedule=sched, time=alt.predicted_time,
            paid=sum(1 for c in sched.reconfig_changed_links() if c),
            g_first=offs[0], g_last=offs[-1]))
    return out


def _phase_plan(kind: str, m: float, tag: str,
                cand: PhaseCandidate) -> PhasePlan:
    return PhasePlan(kind=kind, m_bytes=m, tag=tag, strategy=cand.strategy,
                     schedule=cand.schedule, time=cand.time,
                     paid_reconfigs=cand.paid)


def window_dp(n: int, cand_lists: Sequence[Sequence[PhaseCandidate]],
              cm: CostModel, *, overlap: float = 0.0,
              init_g: int | None = None, init_spent: int = 0,
              cap: int | None = None,
              label: str = "window") -> list[PhaseCandidate]:
    """Joint (link offset, reconfigs spent) DP over a window of phases.

    The carryover DP of `plan_trace`, factored out so the receding-horizon
    online planner can warm-start it mid-trace: ``init_g`` is the link offset
    the fabric was left at by already-committed collectives (None = fresh
    fabric, no entry boundary), ``init_spent`` the paid intra-collective
    reconfigurations already committed against the trace-wide cap, and
    ``cap`` the absolute cap itself (None = unbounded).  Entering the first
    window phase from ``init_g`` charges the sparse changed-circuit diff
    exactly like any later boundary.  Returns the chosen candidate per phase
    (ties broken identically to `plan_trace`: strict improvement only, final
    state broken by smallest (total, key)).
    """
    if not cand_lists:
        raise ValueError("window_dp needs at least one phase")
    # state: (final link offset, paid intra reconfigs so far) ->
    #        (best total, predecessor state, winning candidate)
    layers: list[dict] = []
    cur: dict = {}
    for cand in cand_lists[0]:
        spent = init_spent + cand.paid
        if cap is not None and spent > cap:
            continue
        t = cand.time
        if init_g is not None:
            t = cm.delta_sparse(
                changed_links(n, init_g, cand.g_first), overlap) + cand.time
        key = (cand.g_last, spent)
        if key not in cur or t < cur[key][0]:
            cur[key] = (t, None, cand)
    for p in range(1, len(cand_lists)):
        layers.append(cur)
        nxt: dict = {}
        for (g, spent), (total, _, _) in cur.items():
            for cand in cand_lists[p]:
                spent2 = spent + cand.paid
                if cap is not None and spent2 > cap:
                    continue
                t2 = (total + cm.delta_sparse(
                    changed_links(n, g, cand.g_first), overlap) + cand.time)
                key = (cand.g_last, spent2)
                if key not in nxt or t2 < nxt[key][0]:
                    nxt[key] = (t2, (g, spent), cand)
        cur = nxt
    if not cur:
        raise ValueError(
            f"reconfiguration cap {cap} is infeasible for the "
            f"{len(cand_lists)}-phase {label} with {init_spent} already "
            f"spent (even R=0 schedules do not fit)")

    best_key = min(cur, key=lambda k: (cur[k][0], k))
    chosen: list[PhaseCandidate] = []
    key = best_key
    for layer in reversed(layers + [cur]):
        total, prev_key, cand = layer[key]
        chosen.append(cand)
        key = prev_key
    chosen.reverse()
    return chosen


def _finish(trace: Trace, mode: str, fabric: str, overlap: float,
            delta_budget: float | None, cm: CostModel,
            phases: list[PhasePlan], full_boundaries: bool) -> TracePlan:
    """Assemble boundary accounting + totals for a chosen phase sequence."""
    n = trace.n
    boundary_changed, boundary_cost = [], []
    for prev, nxt in zip(phases, phases[1:], strict=False):
        if full_boundaries:
            # cold fabric: the next phase's initial topology is always
            # re-established with a full-fabric swap
            bc = n
        else:
            bc = changed_links(n, prev.schedule.link_offsets()[-1],
                               nxt.schedule.link_offsets()[0])
        boundary_changed.append(bc)
        boundary_cost.append(cm.delta_sparse(bc, overlap))
    total = sum(p.time for p in phases) + sum(boundary_cost)
    return TracePlan(
        trace=trace, mode=mode, fabric=fabric, overlap=overlap,
        delta_budget=delta_budget, phases=tuple(phases),
        boundary_changed=tuple(boundary_changed),
        boundary_cost=tuple(boundary_cost), total_time=total)


def plan_trace(trace: Trace, cm: CostModel = PAPER_DEFAULT, *,
               mode: str = "carryover", fabric: FabricKind = FabricKind.OCS,
               overlap: float = 0.0, delta_budget: float | None = None,
               planner=None, tenant: str | None = None) -> TracePlan:
    """Plan every collective of ``trace`` under one of the three modes.

    fabric       : 'ocs' (flat delta per intra-collective reconfiguration)
                   or 'ocs-overlap' (sparse hidden-delta credit, see
                   `core.simulator.collective_time_overlap`); boundaries are
                   always charged sparsely except in ``cold`` mode.
    delta_budget : cap on total *intra-collective* reconfiguration stall
                   across the whole trace, seconds.  ``carryover`` spends it
                   jointly (the DP's second state dimension); ``cold``
                   rations it evenly across phases.  Boundary swaps are the
                   carryover surcharge and are not counted against it.
    planner      : a `repro.planner.Planner` (defaults to the process-wide
                   `default_planner()`, sharing its plan cache).
    tenant       : requesting tenant's identity; tags every underlying
                   `PlanRequest` so the shared plan cache is tenant-keyed.
    """
    if mode not in TRACE_PLAN_MODES:
        raise ValueError(f"mode must be one of {TRACE_PLAN_MODES}, got {mode!r}")
    fabric = FabricKind.coerce(fabric)
    if fabric not in TRACE_FABRICS:
        raise ValueError(
            f"fabric must be one of {tuple(map(str, TRACE_FABRICS))}, "
            f"got {str(fabric)!r} (event-level scoring of a planned trace "
            f"goes through FabricSim.run_trace)")
    if overlap and fabric != "ocs-overlap":
        raise ValueError(f"overlap={overlap} requires fabric='ocs-overlap'")
    if delta_budget is not None and delta_budget < 0:
        raise ValueError(f"delta_budget must be >= 0, got {delta_budget}")
    if planner is None:
        from repro.planner import default_planner  # deferred: no cycle

        planner = default_planner()
    n, r = trace.n, trace.r
    phases = trace.phases()

    if mode == "static":
        plans = []
        for kind, m, tag in phases:
            sched = static_schedule(kind, n, r)
            plans.append(PhasePlan(
                kind=kind, m_bytes=m, tag=tag, strategy="static",
                schedule=sched,
                time=_phase_time(sched, m, cm, fabric, overlap),
                paid_reconfigs=0))
        return _finish(trace, mode, fabric, overlap, delta_budget, cm, plans,
                       full_boundaries=False)

    if mode == "cold":
        from repro.planner import PlanRequest  # deferred: no cycle

        per_phase_budget = (None if delta_budget is None
                            else delta_budget / len(phases))
        plans = []
        for kind, m, tag in phases:
            res = planner.plan(PlanRequest(
                kind=kind, n=n, m_bytes=m, cost_model=cm, r=r, fabric=fabric,
                overlap=overlap, delta_budget=per_phase_budget,
                tenant=tenant))
            sched = res.schedule
            assert sched is not None
            plans.append(PhasePlan(
                kind=kind, m_bytes=m, tag=tag, strategy=res.strategy,
                schedule=sched, time=res.predicted_time,
                paid_reconfigs=sum(
                    1 for c in sched.reconfig_changed_links() if c)))
        return _finish(trace, mode, fabric, overlap, delta_budget, cm, plans,
                       full_boundaries=True)

    # --- carryover: joint DP across collective boundaries ---------------------
    unit = cm.delta_sparse(n, overlap)  # effective stall of one paid swap
    cap: int | None = None
    if delta_budget is not None and unit > 0:
        cap = int(delta_budget / unit + 1e-12)
    cand_lists = [phase_candidates(kind, n, r, m, cm, fabric, overlap, planner,
                                   tenant=tenant)
                  for kind, m, _ in phases]
    chosen = window_dp(n, cand_lists, cm, overlap=overlap, cap=cap,
                       label=f"trace {trace.name!r}")
    plans = [_phase_plan(kind, m, tag, cand)
             for (kind, m, tag), cand in zip(phases, chosen, strict=True)]
    return _finish(trace, mode, fabric, overlap, delta_budget, cm, plans,
                   full_boundaries=False)
