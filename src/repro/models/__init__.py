"""Model zoo: 10 assigned architectures over a shared functional substrate."""
from .config import ArchConfig, MLAConfig, MoEConfig, SHAPES, ShapeConfig
from .model import (decode_step, forward, init_caches, init_params, loss_fn,
                    prefill, segments)

__all__ = [
    "SHAPES", "ArchConfig", "MLAConfig", "MoEConfig", "ShapeConfig",
    "decode_step", "forward", "init_caches", "init_params", "loss_fn",
    "prefill", "segments",
]
