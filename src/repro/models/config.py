"""Architecture configuration schema for the model zoo.

Every assigned architecture is expressed as an `ArchConfig`; layer stacking is
described by a repeating `pattern` of block kinds so heterogeneous stacks
(gemma3's 5 local : 1 global, recurrentgemma's 2 RG-LRU : 1 local-attn) scan
cleanly (see models/model.py).

Block kinds:
  "attn"   : global attention (GQA + RoPE)
  "local"  : sliding-window attention (window = cfg.window)
  "mla"    : multi-head latent attention (DeepSeek/MiniCPM3 style)
  "rglru"  : Griffin RG-LRU recurrent block
  "rwkv6"  : RWKV-6 time-mix block (paired with RWKV channel-mix FFN)

FFN kinds (per block, fixed per arch): "swiglu", "gelu" (whisper), "moe".
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual_d_ff: int = 0   # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    group_size: int = 1024          # tokens per dispatch group (memory knob)
    vectorize_groups: bool = False  # vmap groups (parallel, data-sharded)
    # instead of lax.map (sequential — one group per step starves all but one
    # data shard and forces giant all-gathers; see EXPERIMENTS.md #Perf)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: tuple[str, ...] = ("attn",)      # cycled over layers
    ffn: str = "swiglu"
    head_dim: int | None = None               # default d_model // num_heads
    window: int = 1024                        # sliding-window size for "local"
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    rope_theta: float = 10000.0
    tied_embeddings: bool = False
    # encoder-decoder (whisper): encoder layers use bidirectional attention
    enc_dec: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500                    # whisper 30s @ 50 Hz after conv
    # modality frontend stub: precomputed embeddings prepended to the text
    frontend: Literal["none", "patch_stub", "audio_stub"] = "none"
    frontend_seq: int = 0                      # patches per sample (vlm)
    # state sizes for recurrent blocks
    rglru_width: int | None = None             # default d_model
    conv_kernel: int = 4
    rwkv_head_dim: int = 64
    # runtime knobs
    dtype: str = "bfloat16"
    use_pallas: bool = False                   # kernels (interpret on CPU)
    remat: bool = True
    remat_policy: str = "full"                 # full | dots | none
    unroll_layers: bool = False                # Python loop instead of scan
    # (dry-run cost calibration: XLA cost analysis counts scan bodies once,
    # so per-layer costs are measured on small unrolled variants)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0

    # --- derived -------------------------------------------------------------

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally over the full sequence, or the
        arch is recurrent — the `long_500k` eligibility rule (DESIGN.md S4).
        gemma3 counts: 5:1 local:global is dominated by the local window and
        decode-time global attention is O(S) per token."""
        kinds = set(self.layer_kinds)
        if kinds <= {"rglru", "rwkv6", "local"}:
            return True
        if self.name.startswith("gemma3"):
            return True
        return False

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tied_embeddings else 2)
        hd = self.head_dim
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
            elif kind == "mla":
                m = self.mla
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                total += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_head
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += self.num_heads * m.v_head_dim * d
            elif kind == "rglru":
                w = self.rglru_width or d
                total += 2 * d * w + w * self.conv_kernel + 2 * w + w * d  # proj+conv+gates+out
            elif kind == "rwkv6":
                total += 4 * d * d + d * self.rwkv_head_dim  # r,k,v,o (+decay lora approx)
            # FFN
            if self.ffn == "moe":
                assert self.moe is not None
                total += self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                total += d * self.moe.num_experts  # router
                if self.moe.dense_residual_d_ff:
                    total += 3 * d * self.moe.dense_residual_d_ff
            elif self.ffn == "swiglu":
                total += 3 * d * self.d_ff
            else:  # gelu
                total += 2 * d * self.d_ff
        if self.enc_dec:
            # encoder blocks + cross attention (rough)
            total += self.num_encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            total += self.num_layers * 4 * d * d  # cross-attn per decoder layer
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        if self.ffn != "moe":
            return self.param_count()
        assert self.moe is not None
        full = self.param_count()
        per_ff = 3 * self.d_model * self.moe.d_ff_expert
        expert_all = self.num_layers * self.moe.num_experts * per_ff
        expert_active = self.num_layers * self.moe.top_k * per_ff
        return full - expert_all + expert_active

    def scaled_down(self, max_layers: int = 4, max_d: int = 128,
                    max_vocab: int = 512, max_experts: int = 8) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        d = min(self.d_model, max_d)
        heads = max(1, min(self.num_heads, d // 32))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        layers = min(self.num_layers, max_layers)
        # keep the pattern period intact when possible so heterogeneity is
        # exercised (e.g. gemma3 local:global, griffin 2:1)
        if len(self.pattern) > 1:
            layers = max(layers, min(self.num_layers, len(self.pattern)))
        moe = None
        if self.moe is not None:
            moe = MoEConfig(
                num_experts=min(self.moe.num_experts, max_experts),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, max_d * 2),
                dense_residual_d_ff=min(self.moe.dense_residual_d_ff, max_d * 2)
                if self.moe.dense_residual_d_ff else 0,
                group_size=64,
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=max_d // 2, kv_lora_rank=max_d // 4,
                            qk_nope_head_dim=16, qk_rope_head_dim=8,
                            v_head_dim=16)
        return dataclasses.replace(
            self,
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=None if self.mla is None else self.head_dim,
            d_ff=min(self.d_ff, 2 * d),
            vocab_size=min(self.vocab_size, max_vocab),
            window=min(self.window, 32),
            moe=moe,
            mla=mla,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 24),
            frontend_seq=min(self.frontend_seq, 16),
            rglru_width=min(self.rglru_width, d) if self.rglru_width else None,
            rwkv_head_dim=min(self.rwkv_head_dim, 16),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
