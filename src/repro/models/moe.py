"""Mixture-of-Experts FFN: top-k routing + capacity-bounded einsum dispatch.

GShard-style dense dispatch, restructured for memory: tokens are processed in
groups of `group_size` (scanned), so the transient one-hot dispatch tensor is
(group, k, E, C) with C = ceil(group * k * cf / E) — small enough to live in
VMEM-scale working sets at any model size (the knob is per-arch config).

Expert parallelism: expert-indexed weights (E, d, ff) shard over the 'model'
mesh axis; the dispatch/combine einsums then lower to exactly the All-to-All
the paper optimizes (benchmarked via the BRIDGE planner; see DESIGN.md S4 and
the qwen3/arctic roofline rows).

Arctic-style dense residual: an always-on SwiGLU FFN added in parallel with
the routed experts (cfg.moe.dense_residual_d_ff > 0).

Returns an auxiliary load-balancing loss (Switch-style) accumulated by the
caller.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import layers
from .config import ArchConfig, MoEConfig


def init_moe(cfg: ArchConfig, key, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    s_in, s_ff = d ** -0.5, m.d_ff_expert ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, m.num_experts)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (m.num_experts, d, m.d_ff_expert))
                   * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (m.num_experts, d, m.d_ff_expert))
                 * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (m.num_experts, m.d_ff_expert, d))
                   * s_ff).astype(dtype),
    }
    if m.dense_residual_d_ff:
        p["dense"] = layers.init_swiglu(ks[4], d, m.dense_residual_d_ff, dtype)
    return p


def _capacity(group: int, m: MoEConfig) -> int:
    c = int(math.ceil(group * m.top_k * m.capacity_factor / m.num_experts))
    return max(4, c)


def _moe_group(p, xg, m: MoEConfig):
    """xg: (G, d) one token group.  Returns (yg, aux_loss_terms)."""
    G, d = xg.shape
    E, k = m.num_experts, m.top_k
    C = _capacity(G, m)

    logits = layers.dot(xg, p["router"])                  # (G, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                # (G, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, token-major order
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.int32)        # (G, k, E)
    oh_flat = oh.reshape(G * k, E)
    pos = jnp.cumsum(oh_flat, axis=0) - 1                 # (G*k, E)
    pos = jnp.sum(pos * oh_flat, axis=-1)                 # (G*k,)
    keep = (pos < C).astype(xg.dtype).reshape(G, k)

    # dispatch: (G, k, E, C) one-hot — combine/dispatch in one tensor
    disp = (jax.nn.one_hot(top_i, E, dtype=xg.dtype)
            * keep[..., None])[..., None] * jax.nn.one_hot(
                pos.reshape(G, k), C, dtype=xg.dtype)[:, :, None, :]
    xe = jnp.einsum("gd,gkec->ecd", xg, disp)             # (E, C, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"],
                   preferred_element_type=jnp.float32)
    ye = jnp.einsum("ecf,efd->ecd", (jax.nn.silu(h) * u).astype(xg.dtype),
                    p["w_down"], preferred_element_type=jnp.float32).astype(xg.dtype)

    combine = disp * top_p.astype(xg.dtype)[..., None, None]
    yg = jnp.einsum("ecd,gkec->gd", ye, combine)          # (G, d)

    # Switch aux loss terms: fraction routed per expert x mean router prob
    frac = oh.astype(jnp.float32).sum(axis=(0, 1)) / (G * k)
    mean_p = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_p)
    return yg, aux


def moe_ffn(cfg: ArchConfig, p, x):
    """x: (B, S, d).  Returns (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    gs = min(m.group_size, flat.shape[0])
    pad = (-flat.shape[0]) % gs
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, d), flat.dtype)])
    groups = flat.reshape(-1, gs, d)

    run = functools.partial(_moe_group, p, m=m)
    if groups.shape[0] == 1:
        y, aux = run(groups[0])
        y, aux = y[None], aux[None] if aux.ndim else aux[None]
    elif m.vectorize_groups:
        # all groups in parallel: the group dim inherits the token (data)
        # sharding, so dispatch/expert compute stays shard-local
        y, aux = jax.vmap(run)(groups)
    else:
        y, aux = jax.lax.map(run, groups)                 # scan over groups
    y = y.reshape(-1, d)
    if pad:
        y = y[:-pad]
    y = y.reshape(b, s, d)
    if "dense" in p:  # Arctic dense residual
        y = y + layers.swiglu(p["dense"], x)
    return y, jnp.mean(aux)
