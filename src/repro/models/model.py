"""Model assembly: pattern-segment layer scanning, enc-dec, train/serve steps.

The layer stack is grouped into *segments*: maximal runs of whole pattern
periods plus a remainder.  Each segment scans (`jax.lax.scan`) over its
repetitions with per-period block params stacked on a leading axis — compile
time is O(pattern length), not O(num_layers), which keeps the 512-device
dry-run of 94-layer models tractable.  Blocks are rematerialized
(jax.checkpoint) in training mode.

Caches thread through the same scan as per-segment stacked pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import attention, layers, moe, recurrent
from .config import ArchConfig
from .sharding import shard

PyTree = Any


# --- layer segmentation ----------------------------------------------------------


def segments(cfg: ArchConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(period kinds, repetitions)] covering cfg.num_layers."""
    p = len(cfg.pattern)
    full, rem = divmod(cfg.num_layers, p)
    out = []
    if full:
        out.append((tuple(cfg.pattern), full))
    if rem:
        out.append((tuple(cfg.pattern[:rem]), 1))
    return out


# --- per-block init / apply --------------------------------------------------------


def _init_ffn(cfg: ArchConfig, key, dtype):
    if cfg.ffn == "moe":
        return moe.init_moe(cfg, key, dtype)
    if cfg.ffn == "gelu":
        return layers.init_gelu_mlp(key, cfg.d_model, cfg.d_ff, dtype)
    return layers.init_swiglu(key, cfg.d_model, cfg.d_ff, dtype)


def _apply_ffn(cfg: ArchConfig, p, x):
    if cfg.ffn == "moe":
        return moe.moe_ffn(cfg, p, x)
    if cfg.ffn == "gelu":
        return layers.gelu_mlp(p, x), jnp.zeros((), jnp.float32)
    return layers.swiglu(p, x), jnp.zeros((), jnp.float32)


def init_block(cfg: ArchConfig, key, kind: str, dtype, with_cross: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": layers.init_rmsnorm(cfg.d_model, dtype)}
    if kind in ("attn", "local"):
        p["mix"] = attention.init_attention(cfg, k1, dtype)
    elif kind == "mla":
        p["mix"] = attention.init_mla(cfg, k1, dtype)
    elif kind == "rglru":
        p["mix"] = recurrent.init_rglru(cfg, k1, dtype)
    elif kind == "rwkv6":
        p["mix"] = recurrent.init_rwkv6(cfg, k1, dtype)
    else:
        raise ValueError(kind)
    p["norm2"] = layers.init_rmsnorm(cfg.d_model, dtype)
    if kind == "rwkv6":
        p["ffn"] = recurrent.init_rwkv_cmix(cfg, k2, dtype)
    else:
        p["ffn"] = _init_ffn(cfg, k2, dtype)
    if with_cross:
        p["cross"] = attention.init_cross_attention(cfg, k3, dtype)
        p["norm_cross"] = layers.init_rmsnorm(cfg.d_model, dtype)
    return p


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_seq: int,
                     dtype, with_cross: bool = False, enc_seq: int = 0):
    if kind in ("attn", "local"):
        c = {"mix": attention.init_attn_cache(cfg, batch, max_seq, kind, dtype)}
    elif kind == "mla":
        c = {"mix": attention.init_mla_cache(cfg, batch, max_seq, dtype)}
    elif kind == "rglru":
        c = {"mix": recurrent.init_rglru_state(cfg, batch, dtype)}
    elif kind == "rwkv6":
        c = {"mix": recurrent.init_rwkv6_state(cfg, batch, dtype),
             "cmix": jnp.zeros((batch, cfg.d_model), dtype)}
    else:
        raise ValueError(kind)
    if with_cross:
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        c["cross_k"] = jnp.zeros((batch, hkv, enc_seq, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, hkv, enc_seq, hd), dtype)
    return c


def apply_block(cfg: ArchConfig, p, kind: str, x, positions, *, cache=None,
                enc_out=None, bidirectional: bool = False):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(p["norm1"], x)
    mix_cache = None if cache is None else cache["mix"]
    if kind in ("attn", "local"):
        y, new_mix = attention.attention_block(
            cfg, p["mix"], h, positions, kind=kind, cache=mix_cache,
            bidirectional=bidirectional)
    elif kind == "mla":
        y, new_mix = attention.mla_block(cfg, p["mix"], h, positions,
                                         cache=mix_cache)
    elif kind == "rglru":
        y, new_mix = recurrent.rglru_block(cfg, p["mix"], h, state=mix_cache)
    elif kind == "rwkv6":
        y, new_mix = recurrent.rwkv6_block(cfg, p["mix"], h, state=mix_cache)
    else:
        raise ValueError(kind)
    x = x + y

    if "cross" in p:
        hc = layers.rmsnorm(p["norm_cross"], x)
        if enc_out is not None:  # train / prefill: fresh encoder output
            enc_kv = attention.encode_cross_kv(cfg, p["cross"], enc_out)
        else:  # decode: cached cross K/V
            enc_kv = (cache["cross_k"], cache["cross_v"])
        x = x + attention.cross_attention_block(cfg, p["cross"], hc, enc_kv)

    h = layers.rmsnorm(p["norm2"], x)
    if kind == "rwkv6":
        cmix_state = None if cache is None else cache["cmix"]
        y, new_cmix = recurrent.rwkv_cmix(cfg, p["ffn"], h, state=cmix_state)
    else:
        y, ffn_aux = _apply_ffn(cfg, p["ffn"], h)
        aux += ffn_aux
    x = shard(x + y, "act")

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["mix"] = new_mix
        if kind == "rwkv6":
            new_cache["cmix"] = new_cmix
    return x, new_cache, aux


# --- stack init ---------------------------------------------------------------------


def _stack_init(fn, key, reps: int):
    keys = jax.random.split(key, reps)
    return jax.vmap(fn)(keys)


def init_params(cfg: ArchConfig, key) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: dict = {}
    params["embed"] = layers.init_embedding(keys[0], cfg.vocab_size,
                                            cfg.d_model, dtype)
    if not cfg.tied_embeddings:
        params["unembed"] = layers.init_unembed(keys[1], cfg.d_model,
                                                cfg.vocab_size, dtype)
    params["final_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)

    with_cross = cfg.enc_dec
    segs = []
    seg_key = keys[2]
    for kinds, reps in segments(cfg):
        seg_key, k = jax.random.split(seg_key)
        per_pos = []
        for _pos, kind in enumerate(kinds):
            k, kk = jax.random.split(k)
            per_pos.append(_stack_init(
                lambda kk_, kind_=kind: init_block(cfg, kk_, kind_, dtype,
                                                   with_cross=with_cross),
                kk, reps))
        segs.append(per_pos)
    params["decoder"] = segs

    if cfg.enc_dec:
        enc_segs = []
        k = keys[3]
        n_enc = cfg.num_encoder_layers
        enc_segs.append([_stack_init(
            lambda kk_: init_block(cfg, kk_, "attn", dtype), k, n_enc)])
        params["encoder"] = enc_segs
    if cfg.frontend == "patch_stub":
        params["patch_proj"] = layers.init_linear(keys[4], cfg.d_model,
                                                  cfg.d_model, dtype)
    return params


def init_caches(cfg: ArchConfig, batch: int, max_seq: int) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    segs = []
    for kinds, reps in segments(cfg):
        per_pos = []
        for kind in kinds:
            one = init_block_cache(cfg, kind, batch, max_seq, dtype,
                                   with_cross=cfg.enc_dec,
                                   enc_seq=cfg.encoder_seq)
            per_pos.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), one))
        segs.append(per_pos)
    return segs


# --- stack apply --------------------------------------------------------------------


def _run_segments(cfg: ArchConfig, segs_params, segs_caches, x, positions, *,
                  enc_out=None, bidirectional=False, mode="train"):
    """Returns (x, new_caches, total_aux)."""
    seg_list = segments(cfg)
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = [] if segs_caches is not None else None

    for si, (kinds, reps) in enumerate(seg_list):
        per_pos_params = segs_params[si]
        per_pos_caches = segs_caches[si] if segs_caches is not None else None

        def body(carry, per_rep):
            xx = carry
            p_list, c_list = per_rep
            aux_sum = jnp.zeros((), jnp.float32)
            new_c = []
            for pos, kind in enumerate(kinds):
                cache_i = c_list[pos] if c_list is not None else None
                xx, nc, aux = apply_block(
                    cfg, p_list[pos], kind, xx, positions, cache=cache_i,
                    enc_out=enc_out, bidirectional=bidirectional)
                new_c.append(nc)
                aux_sum = aux_sum + aux
            return xx, (new_c if c_list is not None else None, aux_sum)

        if cfg.remat and mode == "train" and cfg.remat_policy != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            body_fn = jax.checkpoint(body, policy=policy)
        else:
            body_fn = body
        if cfg.unroll_layers:
            # Python-unrolled variant (dry-run cost calibration; see config)
            auxs = jnp.zeros((), jnp.float32)
            ncs_list = []
            for r in range(reps):
                def take(t, r=r):
                    return jax.tree.map(lambda a: a[r], t)
                c_r = take(per_pos_caches) if per_pos_caches is not None else None
                x, (nc, aux) = body_fn(x, (take(per_pos_params), c_r))
                auxs += aux
                if per_pos_caches is not None:
                    ncs_list.append(nc)
            if per_pos_caches is not None:
                new_caches.append(jax.tree.map(
                    lambda *xs: jnp.stack(xs), *ncs_list))
            total_aux += auxs
            continue
        if per_pos_caches is None:
            # scan only over params; caches absent
            x, (_, auxs) = jax.lax.scan(
                lambda c, p: body_fn(c, (p, None)), x, per_pos_params)
        else:
            x, (ncs, auxs) = jax.lax.scan(body_fn, x,
                                          (per_pos_params, per_pos_caches))
            new_caches.append(ncs)
        total_aux += jnp.sum(auxs)
    return x, new_caches, total_aux


# --- embedding / frontends ------------------------------------------------------------


def _embed_inputs(cfg: ArchConfig, params, batch: dict):
    """Returns (x, positions)."""
    tok = batch["tokens"]
    x = layers.embed(params["embed"], tok) * (cfg.d_model ** 0.5)
    x = x.astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "patch_stub" and "patches" in batch:
        px = layers.linear(params["patch_proj"], batch["patches"])
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return shard(x, "act"), positions


def _encode(cfg: ArchConfig, params, frames):
    """Whisper encoder on precomputed conv-frontend frames (B, S_enc, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + layers.sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _, _ = _run_segments(cfg, params["encoder"], None, x, positions,
                            bidirectional=True, mode="encode")
    return layers.rmsnorm(params["final_norm"], x)


# --- public entry points ----------------------------------------------------------------


@dataclasses.dataclass
class ModelOutput:
    logits: jax.Array
    caches: PyTree | None
    aux_loss: jax.Array


def forward(cfg: ArchConfig, params, batch: dict, *, caches=None,
            mode: str = "train") -> ModelOutput:
    """batch: tokens (B, S) [+ patches (B,P,d) | frames (B,S_enc,d)]."""
    enc_out = None
    if cfg.enc_dec and mode != "decode":
        enc_out = _encode(cfg, params, batch["frames"])
    x, positions = _embed_inputs(cfg, params, batch)
    if caches is not None and mode == "decode":
        # single-token step: positions come from the cache pointer
        pos0 = _cache_pos(cfg, caches)
        positions = jnp.broadcast_to(pos0[None, None], x.shape[:2]).astype(jnp.int32)
    x, new_caches, aux = _run_segments(cfg, params["decoder"], caches, x,
                                       positions, enc_out=enc_out, mode=mode)
    x = layers.rmsnorm(params["final_norm"], x)
    head = params["embed"] if cfg.tied_embeddings else params["unembed"]
    logits = shard(layers.unembed(head, x), "logits")
    return ModelOutput(logits=logits, caches=new_caches, aux_loss=aux)


def _cache_pos(cfg: ArchConfig, caches):
    """Current decode position from the first attention cache found.

    Pure-recurrent stacks (rwkv6) have no positional cache — and no use for
    positions (token-shift only) — so 0 is returned."""
    for seg in caches:
        for c in seg:
            if isinstance(c, dict) and isinstance(c.get("mix"), dict) \
                    and "pos" in c["mix"]:
                return c["mix"]["pos"][0]  # leading axis = scan reps
    return jnp.zeros((), jnp.int32)


def loss_fn(cfg: ArchConfig, params, batch, aux_weight: float = 0.01):
    out = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    logits = out.logits[:, -labels.shape[1]:, :].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = nll + aux_weight * out.aux_loss
    return loss, {"nll": nll, "aux": out.aux_loss}


def prefill(cfg: ArchConfig, params, batch, max_seq: int):
    """Run the prompt, build caches.  Returns (last-token logits, caches)."""
    b = batch["tokens"].shape[0]
    caches = init_caches(cfg, b, max_seq)
    out = forward(cfg, params, batch, caches=caches, mode="prefill")
    caches = out.caches
    if cfg.enc_dec:  # stash cross-attention K/V once
        caches = _fill_cross_kv(cfg, params, caches, batch)
    return out.logits[:, -1, :], caches


def _fill_cross_kv(cfg, params, caches, batch):
    enc_out = _encode(cfg, params, batch["frames"])
    new = []
    for si, (kinds, _reps) in enumerate(segments(cfg)):
        per_pos = []
        for pos in range(len(kinds)):
            c = caches[si][pos]
            p_stack = params["decoder"][si][pos]

            def kv_of(p_one):
                return attention.encode_cross_kv(cfg, p_one["cross"], enc_out)

            k, v = jax.vmap(kv_of)(p_stack)
            c = dict(c)
            c["cross_k"], c["cross_v"] = k, v
            per_pos.append(c)
        new.append(per_pos)
    return new


def decode_step(cfg: ArchConfig, params, token, caches):
    """token: (B, 1) int32.  Returns (logits (B, vocab), caches')."""
    out = forward(cfg, params, {"tokens": token}, caches=caches, mode="decode")
    return out.logits[:, -1, :], out.caches
