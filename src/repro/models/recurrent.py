"""Recurrent blocks: Griffin RG-LRU (recurrentgemma) and RWKV-6 time-mix.

State protocol mirrors attention caches:
  prefill: block(x full seq)          -> (y, state)
  decode : block(x one token, state)  -> (y, state')

RG-LRU block (Griffin, arXiv:2402.19427):
  u = W_gate x ; v = W_in x ; v <- causal conv1d(v, k=4)
  r = sigmoid(W_a v); i = sigmoid(W_x v)
  log a_t = -c * softplus(Lambda) * r_t           (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * v_t)   [rg_lru kernel]
  y = W_out (gelu(u) * h)
  State: (h_last (B, W), conv tail (B, k-1, W)).

RWKV-6 block (Finch, arXiv:2404.05892), time-mix + channel-mix pair:
  token-shift interpolation, data-dependent decay via a small LoRA,
  wkv6 recurrence kernel, per-head group-norm, gated output.
  State: (last token (B, d), wkv state (B, H, dk, dv)); channel-mix keeps its
  own last-token shift state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rg_lru import ref as lru_ref
from repro.kernels.rg_lru.ops import rg_lru
from repro.kernels.wkv6 import ref as wkv_ref
from repro.kernels.wkv6.ops import wkv6

from . import layers
from .config import ArchConfig

_C_RGLRU = 8.0


# --- Griffin RG-LRU ------------------------------------------------------------


def init_rglru(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    # Lambda init so that a = sigmoid(Lambda) in (0.9, 0.999) (paper init)
    lam = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    return {
        "w_gate": (jax.random.normal(ks[0], (d, w)) * s).astype(dtype),
        "w_in": (jax.random.normal(ks[1], (d, w)) * s).astype(dtype),
        "conv": (jax.random.normal(ks[2], (cfg.conv_kernel, w)) * 0.1).astype(dtype),
        "w_a": (jax.random.normal(ks[3], (w, w)) * w ** -0.5).astype(dtype),
        "w_x": (jax.random.normal(ks[4], (w, w)) * w ** -0.5).astype(dtype),
        "lambda": jnp.log(lam / (1 - lam)),  # logit so sigmoid(Lambda)=a
        "w_out": (jax.random.normal(ks[6], (w, d)) * w ** -0.5).astype(dtype),
    }


def init_rglru_state(cfg: ArchConfig, batch: int, dtype):
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv_tail": jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype),
    }


def _causal_conv(p, v, tail):
    """v: (B, S, W); tail: (B, k-1, W) inputs preceding v. Returns same-shape."""
    kk = p["conv"].shape[0]
    ext = jnp.concatenate([tail, v], axis=1)
    out = sum(ext[:, i:i + v.shape[1], :] * p["conv"][kk - 1 - i][None, None, :]
              for i in range(kk))
    return out.astype(v.dtype), ext[:, -(kk - 1):, :]


def rglru_block(cfg: ArchConfig, p, x, *, state=None):
    b, s, d = x.shape
    u = layers.dot(x, p["w_gate"]).astype(x.dtype)
    v = layers.dot(x, p["w_in"]).astype(x.dtype)
    tail = state["conv_tail"] if state is not None else \
        jnp.zeros((b, cfg.conv_kernel - 1, v.shape[-1]), v.dtype)
    v, new_tail = _causal_conv(p, v, tail)

    r = jax.nn.sigmoid(layers.dot(v, p["w_a"]))
    i = jax.nn.sigmoid(layers.dot(v, p["w_x"]))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lambda"])[None, None, :] * r
    a = jnp.exp(log_a)
    gated = (i * v.astype(jnp.float32))
    binp = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    h0 = state["h"] if state is not None else None
    if cfg.use_pallas and s > 1 and h0 is None:
        y, h_last = rg_lru(a.astype(x.dtype), binp.astype(x.dtype))
        y = y.astype(jnp.float32)
    else:
        y, h_last = lru_ref.rg_lru_scan(a, binp, h0)
    out = layers.dot(jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
                     * y.astype(x.dtype), p["w_out"]).astype(x.dtype)
    new_state = {"h": h_last, "conv_tail": new_tail}
    return out, new_state


# --- RWKV-6 ---------------------------------------------------------------------


def init_rwkv6(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    lora = max(32, d // 64)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(jnp.float32),
        "w_r": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "w_k": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "w_g": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "w_o": (jax.random.normal(ks[5], (d, d)) * s).astype(dtype),
        "decay_base": jnp.full((d,), -2.0, jnp.float32),
        "decay_A": (jax.random.normal(ks[6], (d, lora)) * s).astype(dtype),
        "decay_B": (jax.random.normal(ks[7], (lora, d)) * lora ** -0.5).astype(dtype),
        "bonus_u": (jax.random.normal(ks[8], (H, hd)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((H, hd), jnp.float32),
        "ln_bias": jnp.zeros((H, hd), jnp.float32),
    }


def init_rwkv6_state(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {
        "last": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def _token_shift(x, last):
    """shifted[t] = x[t-1]; shifted[0] = last (previous chunk's final token)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _group_norm(p, y):
    """y: (B, H, T, hd) per-head layernorm."""
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    return yn * p["ln_scale"][None, :, None, :] + p["ln_bias"][None, :, None, :]


def rwkv6_block(cfg: ArchConfig, p, x, *, state=None):
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    last = state["last"] if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, last)

    def mix(i):
        return (x + (xs - x) * p["mu"][i][None, None, :]).astype(x.dtype)

    r = layers.dot(mix(0), p["w_r"]).astype(x.dtype)
    k = layers.dot(mix(1), p["w_k"]).astype(x.dtype)
    v = layers.dot(mix(2), p["w_v"]).astype(x.dtype)
    g = layers.dot(mix(3), p["w_g"])
    dec = layers.dot(jnp.tanh(layers.dot(mix(4), p["decay_A"])).astype(x.dtype),
                     p["decay_B"])
    log_w = -jnp.exp(p["decay_base"][None, None, :] + dec)   # (B,S,d) <= 0

    def split(t):
        return t.reshape(b, s, H, hd).transpose(0, 2, 1, 3)
    rh, kh, vh, lwh = split(r), split(k), split(v), split(log_w.astype(x.dtype))

    s0 = state["wkv"] if state is not None else None
    if cfg.use_pallas and s > 1 and s0 is None:
        y, s_last = wkv6(rh, kh, vh, lwh, p["bonus_u"].astype(x.dtype))
        y = y.astype(jnp.float32)
    else:
        y, s_last = wkv_ref.wkv6_scan(rh, kh, vh,
                                      jnp.exp(lwh.astype(jnp.float32)),
                                      p["bonus_u"], s0)
    y = _group_norm(p, y.astype(jnp.float32))
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    out = layers.dot((jax.nn.silu(g) * y).astype(x.dtype), p["w_o"]).astype(x.dtype)
    new_state = {"last": x[:, -1, :], "wkv": s_last}
    return out, new_state


# --- RWKV channel mix ------------------------------------------------------------


def init_rwkv_cmix(cfg: ArchConfig, key, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": (jax.random.uniform(ks[2], (2, d)) * 0.5 + 0.25).astype(jnp.float32),
        "w_k": (jax.random.normal(ks[0], (d, dff)) * d ** -0.5).astype(dtype),
        "w_v": (jax.random.normal(ks[1], (dff, d)) * dff ** -0.5).astype(dtype),
        "w_r": (jax.random.normal(ks[2], (d, d)) * d ** -0.5).astype(dtype),
    }


def rwkv_cmix(cfg: ArchConfig, p, x, *, state=None):
    b, s, d = x.shape
    last = state if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, last)
    def mix(i):
        return (x + (xs - x) * p["mu"][i][None, None, :]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(layers.dot(mix(0), p["w_k"]))).astype(x.dtype)
    r = jax.nn.sigmoid(layers.dot(mix(1), p["w_r"]))
    out = (r * layers.dot(k, p["w_v"])).astype(x.dtype)
    return out, x[:, -1, :]
