"""Activation-sharding constraint hooks.

The model code is mesh-agnostic; the launcher installs a rule table mapping
logical names -> PartitionSpec, and `shard(x, name)` applies
with_sharding_constraint only when rules are installed (no-op on CPU tests).
"""
from __future__ import annotations

import contextlib
import threading

import jax

_STATE = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, rules: dict):
    """rules: logical name -> jax.sharding.PartitionSpec."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def shard(x, name: str):
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.get(name)
    if spec is None:
        return x
    # drop spec axes that don't divide the array (replicate those dims)
    fixed = []
    for dim, ax in enumerate(spec):
        if ax is None or dim >= x.ndim:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in mesh.shape for a in axes):
            fixed.append(None)  # axis absent from this mesh: replicate
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if x.shape[dim] % size == 0 else None)
    spec = jax.sharding.PartitionSpec(*fixed[:x.ndim])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
