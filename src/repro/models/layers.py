"""Functional building blocks (no framework dependency: params are pytrees).

Initializers return nested dicts of jnp arrays; apply functions are pure.
All matmuls accumulate in float32 (`preferred_element_type`) regardless of the
parameter dtype so bf16 training is numerically sane.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dot(x, w):
    return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


# --- norms --------------------------------------------------------------------


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --- FFN ------------------------------------------------------------------------


def init_swiglu(key, d, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_ff = d ** -0.5, d_ff ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * s_ff).astype(dtype),
    }


def swiglu(p, x):
    g = dot(x, p["w_gate"])
    u = dot(x, p["w_up"])
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return dot(h, p["w_down"]).astype(x.dtype)


def init_gelu_mlp(key, d, d_ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": (jax.random.normal(k1, (d, d_ff)) * d ** -0.5).astype(dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d)) * d_ff ** -0.5).astype(dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu(dot(x, p["w_in"]) + p["b_in"].astype(jnp.float32))
    return (dot(h.astype(x.dtype), p["w_out"])
            + p["b_out"].astype(jnp.float32)).astype(x.dtype)


# --- embeddings / head -----------------------------------------------------------


def init_embedding(key, vocab, d, dtype):
    return {"table": (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Logits; when tied, p is the embedding table."""
    return dot(x, p["table"].T) if "table" in p else dot(x, p["w"])


def init_unembed(key, d, vocab, dtype):
    return {"w": (jax.random.normal(key, (d, vocab)) * d ** -0.5).astype(dtype)}


# --- rotary position embedding ----------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (S, d)


# --- misc --------------------------------------------------------------------------


def init_linear(key, d_in, d_out, dtype, bias=False):
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * d_in ** -0.5).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = dot(x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)
