"""Attention blocks: GQA (+RoPE), sliding-window, MLA, cross-attention.

All blocks share the cache protocol:
  prefill : attn(x full seq)            -> (y, cache)
  decode  : attn(x one token, cache)    -> (y, cache')

Cache layout (global attention):  k/v (B, Hkv, S_max, hd), filled up to `pos`.
Sliding-window layers keep a ring buffer of `window` slots plus an absolute-
position array for mask reconstruction — the long_500k decode memory story
(window-bounded cache) lives here.

MLA (MiniCPM3/DeepSeek): the cache stores the *latent* c_kv (B, S, r_kv) and
the shared rope key (B, S, d_rope); decode uses the weight-absorption trick
(q_nope folded through W_uk, output through W_uv) so per-step compute touches
only rank-r tensors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref as attn_ref
from repro.kernels.flash_attention.ops import flash_attention

from . import layers
from .config import ArchConfig


# --- shared scaled-dot-product helpers ------------------------------------------


def _sdpa(cfg: ArchConfig, q, k, v, causal, window):
    if cfg.use_pallas:
        return flash_attention(q, k, v, causal, window)
    return attn_ref.attention(q, k, v, causal=causal, window=window)


def _decode_attend(q, k_cache, v_cache, slot_pos, q_pos, window):
    """q: (B, Hq, 1, hd); caches (B, Hkv, S, hd); slot_pos (S,) absolute
    positions per slot (-1 = empty).  Returns (B, Hq, 1, hd)."""
    b, hq, _, hd = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    qg = qf.reshape(b, hkv, group, hd)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, kf)
    valid = (slot_pos >= 0) & (slot_pos <= q_pos)
    if window is not None:
        valid &= slot_pos > q_pos - window
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
    return out.reshape(b, hq, 1, hd).astype(q.dtype)


# --- GQA attention (global or sliding window) ------------------------------------


def init_attention(cfg: ArchConfig, key, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, hq * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (hq * hd, d)) * (hq * hd) ** -0.5).astype(dtype),
    }


def init_attn_cache(cfg: ArchConfig, batch: int, max_seq: int, kind: str, dtype):
    s_cache = min(max_seq, cfg.window) if kind == "local" else max_seq
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, s_cache, hd), dtype),
        "v": jnp.zeros((batch, hkv, s_cache, hd), dtype),
        "slot_pos": jnp.full((s_cache,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)


def attention_block(cfg: ArchConfig, p, x, positions, *, kind: str,
                    cache=None, bidirectional: bool = False):
    """x: (B, S, d).  Returns (y, new_cache)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    window = cfg.window if kind == "local" else None
    q = _split_heads(layers.dot(x, p["wq"]).astype(x.dtype), hq, hd)
    k = _split_heads(layers.dot(x, p["wk"]).astype(x.dtype), hkv, hd)
    v = _split_heads(layers.dot(x, p["wv"]).astype(x.dtype), hkv, hd)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)

    if cache is None:  # training / plain forward
        out = _sdpa(cfg, q, k, v, not bidirectional, window)
        new_cache = None
    elif s > 1:  # prefill: full attention, then stash the tail of k/v
        out = _sdpa(cfg, q, k, v, not bidirectional, window)
        s_cache = cache["k"].shape[2]
        keep = min(s, s_cache)
        new_cache = dict(cache)
        if keep == s:  # whole prefix fits: position p lives at slot p
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k, (0, 0, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v, (0, 0, 0, 0))
            slot = jnp.full((s_cache,), -1, jnp.int32)
            slot = jax.lax.dynamic_update_slice(
                slot, jnp.arange(s, dtype=jnp.int32), (0,))
        else:  # ring buffer: slot t must hold the position p = t (mod s_cache)
            # from the kept tail [s - s_cache, s); decode continues at
            # slot = pos % s_cache without re-shuffling.
            tail_k, tail_v = k[:, :, s - keep:, :], v[:, :, s - keep:, :]
            idx = (jnp.arange(s_cache) - s) % s_cache  # tail-relative index
            new_cache["k"] = jnp.take(tail_k, idx, axis=2)
            new_cache["v"] = jnp.take(tail_v, idx, axis=2)
            slot = (s - keep) + idx.astype(jnp.int32)  # absolute positions
        new_cache["slot_pos"] = slot
        new_cache["pos"] = jnp.asarray(s, jnp.int32)
    else:  # decode: one token
        s_cache = cache["k"].shape[2]
        pos = cache["pos"]
        slot = pos % s_cache  # ring buffer (== pos for global caches)
        k_new = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
        v_new = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None], (slot,))
        out = _decode_attend(q, k_new, v_new, slot_pos, pos, window)
        new_cache = {"k": k_new, "v": v_new, "slot_pos": slot_pos,
                     "pos": pos + 1}

    y = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return layers.dot(y, p["wo"]).astype(x.dtype), new_cache


# --- MLA (multi-head latent attention) ---------------------------------------------


def init_mla(cfg: ArchConfig, key, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_dq": (jax.random.normal(ks[0], (d, m.q_lora_rank)) * s).astype(dtype),
        "w_uq": (jax.random.normal(ks[1], (m.q_lora_rank, h * qk_head))
                 * m.q_lora_rank ** -0.5).astype(dtype),
        "w_dkv": (jax.random.normal(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim))
                  * s).astype(dtype),
        "w_uk": (jax.random.normal(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim))
                 * m.kv_lora_rank ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[4], (m.kv_lora_rank, h * m.v_head_dim))
                 * m.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[5], (h * m.v_head_dim, d))
               * (h * m.v_head_dim) ** -0.5).astype(dtype),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_block(cfg: ArchConfig, p, x, positions, *, cache=None, kind="mla"):
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    cq = layers.dot(x, p["w_dq"]).astype(x.dtype)                  # (B,S,rq)
    q = layers.dot(cq, p["w_uq"]).astype(x.dtype)
    q = q.reshape(b, s, h, nope + rope_d).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = layers.dot(x, p["w_dkv"]).astype(x.dtype)                # (B,S,rkv+rope)
    c_kv, k_rope = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    k_rope = layers.apply_rope(k_rope[:, None], positions, cfg.rope_theta)[:, 0]

    def expand_kv(c):
        k_n = layers.dot(c, p["w_uk"]).astype(x.dtype)
        k_n = k_n.reshape(b, -1, h, nope).transpose(0, 2, 1, 3)
        v = layers.dot(c, p["w_uv"]).astype(x.dtype)
        v = v.reshape(b, -1, h, vd).transpose(0, 2, 1, 3)
        return k_n, v

    if cache is None or s > 1:  # train / prefill: expand latents, full attn
        k_n, v = expand_kv(c_kv)
        k_r = jnp.broadcast_to(k_rope[:, None], (b, h, s, rope_d))
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate([k_n, k_r], axis=-1)
        # pad v to q head_dim for the shared kernel, slice after
        scale = (nope + rope_d) ** -0.5
        if cfg.use_pallas and vd == nope + rope_d:
            out = flash_attention(q_full, k_full, v, True, None, scale)
        else:
            out = attn_ref.attention(q_full, k_full, v, causal=True,
                                     window=None, scale=scale)
        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["c_kv"] = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv, (0, 0, 0))
            new_cache["k_rope"] = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope, (0, 0, 0))
            new_cache["pos"] = jnp.asarray(s, jnp.int32)
    else:  # decode with weight absorption: attend in latent space
        pos = cache["pos"]
        c_all = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, pos, 0))
        kr_all = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, pos, 0))
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, nope)
        # absorb: q_abs[b,h,r] = sum_n q_nope[b,h,n] * w_uk[r,h,n]
        q_abs = jnp.einsum("bhln,rhn->bhlr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))                # (B,H,1,rkv)
        scores = jnp.einsum("bhlr,bsr->bhls", q_abs,
                            c_all.astype(jnp.float32))
        scores += jnp.einsum("bhld,bsd->bhls", q_rope.astype(jnp.float32),
                             kr_all.astype(jnp.float32))
        scores *= (nope + rope_d) ** -0.5
        spos = jnp.arange(c_all.shape[1])
        scores = jnp.where((spos <= pos)[None, None, None, :], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1)
        lat = jnp.einsum("bhls,bsr->bhlr", w, c_all.astype(jnp.float32))
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, vd)
        out = jnp.einsum("bhlr,rhv->bhlv", lat,
                         w_uv.astype(jnp.float32)).astype(x.dtype)
        new_cache = {"c_kv": c_all, "k_rope": kr_all, "pos": pos + 1}

    y = out.transpose(0, 2, 1, 3).reshape(b, s, h * vd)
    return layers.dot(y, p["wo"]).astype(x.dtype), new_cache


# --- cross attention (whisper decoder) ------------------------------------------------


def init_cross_attention(cfg: ArchConfig, key, dtype):
    return init_attention(cfg, key, dtype)


def cross_attention_block(cfg: ArchConfig, p, x, enc_kv, *, cache=None):
    """enc_kv: (k, v) each (B, Hkv, S_enc, hd), precomputed at prefill."""
    b, s, d = x.shape
    hq, hd = cfg.num_heads, cfg.head_dim
    q = _split_heads(layers.dot(x, p["wq"]).astype(x.dtype), hq, hd)
    k, v = enc_kv
    out = _sdpa(cfg, q, k, v, False, None)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return layers.dot(y, p["wo"]).astype(x.dtype)


def encode_cross_kv(cfg: ArchConfig, p, enc_out):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = _split_heads(layers.dot(enc_out, p["wk"]).astype(enc_out.dtype), hkv, hd)
    v = _split_heads(layers.dot(enc_out, p["wv"]).astype(enc_out.dtype), hkv, hd)
    return k, v
