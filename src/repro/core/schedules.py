"""BRIDGE reconfiguration-schedule synthesis (paper Sections 3.3-3.6),
generalized to arbitrary world sizes n and radix r.

A schedule for an S-sub-step Bruck collective is x in {0,1}^S, x_k = 1
meaning the OCS is reconfigured immediately before sub-step k.  x_0 = 0
always: the initial topology is established before the collective starts
(the physical ring for All-to-All / Reduce-Scatter; the first segment's
subring for AllGather, paper Section 3.5) and is therefore free.

Equivalently a schedule is a partition of the sub-steps 0..S-1 into R+1
contiguous *segments*; the topology is reconfigured at each segment boundary
and *reused* within a segment.  The OCS link offset of a segment is the
greatest common divisor of the Bruck message offsets inside it, so that
every step in the segment stays inside its subring (generalized Lemma 3.2:
a destination is reachable iff the message offset is divisible by the link
offset).  For radix 2 the offsets in a segment are successive powers of two
and the gcd is the smallest offset — exactly the paper's rule.

  - All-to-All:      optimal segments are balanced (Lemma 3.1 / Theorem 3.2)
                     => periodic reconfigurations.
  - Reduce-Scatter:  transmission-optimal segments are found by an interval
                     partition DP (the paper's ILP, Theorem 3.3) => early.
  - AllGather:       the time-reverse of Reduce-Scatter => late (Section 3.5).
  - Optimal R:       argmin over 0 <= R < S of modeled completion time (3.6).

All DPs below score segments with the *actual* per-sub-step hop counts and
send volumes from `bruck.steps_for`, so they remain exact for non-power-of-
two n and radix r > 2 where the paper's closed forms (2^len - 1, len / 2^a)
no longer apply.  For power-of-two n at radix 2 the synthesized schedules
are bit-identical to the paper's Table 1 (tested).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Literal, Sequence

from .bruck import Collective, Step, num_steps, schedule_length, steps_for
from .cost_model import CostModel


def _segment_gcd(steps: Sequence[Step], a: int, b: int) -> int:
    """Link offset of segment [a, b]: gcd of its message offsets."""
    g = 0
    for j in range(a, b + 1):
        g = math.gcd(g, steps[j].offset)
    return g


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Reconfiguration schedule for one collective execution.

    ``r`` is the Bruck radix the sub-step sequence was generated with
    (r = 2 is the paper's pattern; r > 2 is the multiport/radix-r
    generalization of Section 3.1).
    """

    kind: Collective
    n: int
    x: tuple[int, ...]
    r: int = 2

    def __post_init__(self):
        s = schedule_length(self.kind, self.n, self.r)
        if len(self.x) != s:
            raise ValueError(
                f"schedule length {len(self.x)} != S={s} (n={self.n}, r={self.r})")
        if any(v not in (0, 1) for v in self.x):
            raise ValueError("x must be 0/1")
        if self.x and self.x[0] != 0:
            raise ValueError("x_0 must be 0: initial topology is pre-established")

    @property
    def R(self) -> int:
        return sum(self.x)

    @property
    def segments(self) -> tuple[tuple[int, int], ...]:
        """Inclusive (first_step, last_step) per reconfiguration period."""
        s = len(self.x)
        bounds = [k for k in range(s) if self.x[k] == 1] + [s]
        segs, a = [], 0
        for b in bounds:
            segs.append((a, b - 1))
            a = b
        return tuple(segs)

    def link_offsets(self, steps: Sequence[Step] | None = None) -> list[int]:
        """OCS link offset in force during each sub-step."""
        steps = steps if steps is not None else steps_for(self.kind, self.n, 1.0, self.r)
        out = [0] * len(self.x)
        for a, b in self.segments:
            g = _segment_gcd(steps, a, b)
            for j in range(a, b + 1):
                out[j] = g
        return out

    @staticmethod
    def from_segments(kind: Collective, n: int, lengths: Sequence[int],
                      r: int = 2) -> "Schedule":
        s = schedule_length(kind, n, r)
        if sum(lengths) != s or any(l <= 0 for l in lengths):
            raise ValueError(f"segment lengths {lengths} must be positive and sum to {s}")
        x = [0] * s
        pos = 0
        for l in lengths[:-1]:
            pos += l
            x[pos] = 1
        return Schedule(kind=kind, n=n, x=tuple(x), r=r)

    @property
    def segment_lengths(self) -> tuple[int, ...]:
        return tuple(b - a + 1 for a, b in self.segments)


def static_schedule(kind: Collective, n: int, r: int = 2) -> Schedule:
    return Schedule(kind=kind, n=n, x=tuple([0] * schedule_length(kind, n, r)), r=r)


def every_step_schedule(kind: Collective, n: int, r: int = 2) -> Schedule:
    """Greedy (G-BRUCK-like): reconfigure before every sub-step after the first."""
    s = schedule_length(kind, n, r)
    return Schedule(kind=kind, n=n, x=tuple([0] + [1] * (s - 1)), r=r)


# --- Generic segment-partition DP -------------------------------------------


def _partition_dp(
    s: int, num_segments: int, seg_cost: Callable[[int, int], float]
) -> tuple[float, list[int]]:
    """Minimize sum of seg_cost(a, b) over partitions of 0..s-1 into exactly
    ``num_segments`` contiguous segments.  Returns (cost, segment lengths).

    Ties are broken toward lexicographically-smallest segment-length tuples,
    which matches the paper's Table 1 presentation.
    """
    if not (1 <= num_segments <= s):
        raise ValueError(f"need 1 <= segments={num_segments} <= s={s}")
    INF = float("inf")
    # best[i][r] = (cost, lengths) covering steps 0..i-1 with r segments
    best: list[list[tuple[float, tuple[int, ...]]]] = [
        [(INF, ())] * (num_segments + 1) for _ in range(s + 1)
    ]
    best[0][0] = (0.0, ())
    for i in range(1, s + 1):
        for r in range(1, min(i, num_segments) + 1):
            cand = (INF, ())
            for a in range(r - 1, i):  # previous boundary
                prev_cost, prev_lens = best[a][r - 1]
                if prev_cost == INF:
                    continue
                c = prev_cost + seg_cost(a, i - 1)
                key = (c, prev_lens + (i - a,))
                if key < cand:
                    cand = key
            best[i][r] = cand
    cost, lens = best[s][num_segments]
    if cost == float("inf"):
        raise RuntimeError("infeasible partition")
    return cost, list(lens)


# --- Paper-faithful schedules ------------------------------------------------


def _hop_sum_cost(steps: Sequence[Step]) -> Callable[[int, int], float]:
    """Total hop count of a segment: sum of offset / gcd over its sub-steps.

    For radix-2 power-of-two A2A this is 2^len - 1, the paper's Lemma 3.1
    objective; for general (n, r) it is the exact per-segment hop latency.
    """

    def seg_cost(a: int, b: int) -> float:
        g = _segment_gcd(steps, a, b)
        return float(sum(steps[j].offset // g for j in range(a, b + 1)))

    return seg_cost


def _transmission_cost(steps: Sequence[Step]) -> Callable[[int, int], float]:
    """Transmission term of a segment: sum of nbytes * congestion, with
    congestion = hops = offset / gcd (uniform-offset ring traffic).

    For radix-2 power-of-two RS this is len / 2^{a+1} (the paper's Theorem
    3.3 objective up to a constant factor); exact for general (n, r).
    """

    def seg_cost(a: int, b: int) -> float:
        g = _segment_gcd(steps, a, b)
        return sum(steps[j].nbytes * (steps[j].offset // g) for j in range(a, b + 1))

    return seg_cost


def periodic_a2a(n: int, R: int, r: int = 2) -> Schedule:
    """Theorem 3.2: optimal All-to-All schedule, periodic for radix 2
    (balanced segments by Lemma 3.1).

    Computed by the exact DP on the hop-sum objective (2^len - 1 in the
    radix-2 case); for radix 2 the result always has segment lengths
    differing by at most one.
    """
    steps = a2a_steps_cached(n, r)
    _, lens = _partition_dp(len(steps), R + 1, _hop_sum_cost(steps))
    if r == 2:
        assert max(lens) - min(lens) <= 1, "Lemma 3.1 violated"
    return Schedule.from_segments("a2a", n, lens, r)


def rs_transmission_optimal(n: int, R: int, r: int = 2) -> Schedule:
    """Theorem 3.3: transmission-optimal Reduce-Scatter schedule.

    The paper's ILP minimizes sum over periods [a,b] of (b - a + 1) / 2^a;
    the DP below minimizes the exact per-segment transmission (identical up
    to a constant factor for radix-2 power-of-two n, exact otherwise) as an
    interval-partition DP (schedules are parameter-free).
    """
    steps = _steps_cached("rs", n, r)
    _, lens = _partition_dp(len(steps), R + 1, _transmission_cost(steps))
    return Schedule.from_segments("rs", n, lens, r)


def ag_transmission_optimal(n: int, R: int, r: int = 2) -> Schedule:
    """Section 3.5: AllGather optimum is the reversed Reduce-Scatter schedule."""
    rs = rs_transmission_optimal(n, R, r)
    return Schedule.from_segments("ag", n, list(reversed(rs.segment_lengths)), r)


def periodic(kind: Collective, n: int, R: int, r: int = 2) -> Schedule:
    """Latency-optimal (periodic) schedule for any of the three collectives.

    For A2A this is Theorem 3.2; for RS/AG the paper notes the latency-optimal
    case is identical to All-to-All (Section 3.6 / Section 5).
    """
    lens = periodic_a2a(n, R, r).segment_lengths
    if kind == "ag":
        lens = tuple(reversed(lens))
    return Schedule.from_segments(kind, n, list(lens), r)


def cstar_a2a(n: int, R: int, cm: CostModel, m: float) -> float:
    """Closed-form optimal A2A cost (Theorem 3.2; radix 2, power-of-two n),
    exact when (R+1) | s.

    C* = s*alpha_s + (R+1) * c * (n^{1/(R+1)} - 1) + R*delta,  c = alpha_h + beta*m/2.
    """
    s = num_steps(n)
    c = cm.alpha_h + cm.beta * m / 2.0
    return s * cm.alpha_s + (R + 1) * c * (n ** (1.0 / (R + 1)) - 1.0) + R * cm.delta


# --- Step-sequence cache (schedule synthesis calls these in tight loops) -----

_STEP_CACHE: dict[tuple[str, int, int], tuple[Step, ...]] = {}


def _steps_cached(kind: Collective, n: int, r: int) -> tuple[Step, ...]:
    key = (kind, n, r)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = tuple(steps_for(kind, n, 1.0, r))
    return _STEP_CACHE[key]


def a2a_steps_cached(n: int, r: int) -> tuple[Step, ...]:
    return _steps_cached("a2a", n, r)


# --- Exact full-cost schedules (beyond paper: joint latency+transmission DP) --


def _segment_cost_exact(kind: Collective, steps: Sequence[Step], cm: CostModel) -> Callable:
    def seg_cost(a: int, b: int) -> float:
        g = _segment_gcd(steps, a, b)
        t = 0.0
        for j in range(a, b + 1):
            h = steps[j].offset // g
            t += cm.step_cost(hops=h, nbytes=steps[j].nbytes, congestion=h)
        return t

    return seg_cost


def full_cost_optimal(kind: Collective, n: int, m: float, cm: CostModel,
                      R: int, r: int = 2) -> Schedule:
    """Exact minimum-completion-time schedule for fixed R under the full model.

    Beyond-paper: jointly minimizes latency + transmission (+ the fixed R*delta)
    instead of picking the better of the latency-only and transmission-only
    optima (paper Section 3.6).
    """
    steps = steps_for(kind, n, m, r)
    _, lens = _partition_dp(len(steps), R + 1, _segment_cost_exact(kind, steps, cm))
    return Schedule.from_segments(kind, n, lens, r)


# --- Optimal number of reconfigurations (Section 3.6) -------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    schedule: Schedule
    predicted_time: float
    strategy: str  # which candidate family won


def candidate_schedules(
    kind: Collective, n: int, m: float, cm: CostModel,
    paper_faithful: bool = False, r: int = 2
) -> list[tuple[str, Schedule]]:
    s = schedule_length(kind, n, r)
    cands: list[tuple[str, Schedule]] = []
    for R in range(0, s):
        cands.append((f"periodic(R={R})", periodic(kind, n, R, r)))
        if kind == "rs":
            cands.append((f"rs-early(R={R})", rs_transmission_optimal(n, R, r)))
        elif kind == "ag":
            cands.append((f"ag-late(R={R})", ag_transmission_optimal(n, R, r)))
        if not paper_faithful:
            cands.append((f"exact-dp(R={R})", full_cost_optimal(kind, n, m, cm, R, r)))
    return cands


def plan(
    kind: Collective, n: int, m: float, cm: CostModel,
    paper_faithful: bool = False, r: int = 2
) -> Plan:
    """Pick the schedule (incl. R, Section 3.6) minimizing modeled completion time."""
    from .simulator import collective_time  # local import to avoid cycle

    best: Plan | None = None
    for name, sched in candidate_schedules(kind, n, m, cm, paper_faithful, r):
        t = collective_time(sched, m, cm).total
        if best is None or t < best.predicted_time:
            best = Plan(schedule=sched, predicted_time=t, strategy=name)
    assert best is not None
    return best
