"""BRIDGE reconfiguration-schedule synthesis (paper Sections 3.3-3.6),
generalized to arbitrary world sizes n and radix r.

A schedule for an S-sub-step Bruck collective is x in {0,1}^S, x_k = 1
meaning the OCS is reconfigured immediately before sub-step k.  x_0 = 0
always: the initial topology is established before the collective starts
(the physical ring for All-to-All / Reduce-Scatter; the first segment's
subring for AllGather, paper Section 3.5) and is therefore free.

Equivalently a schedule is a partition of the sub-steps 0..S-1 into R+1
contiguous *segments*; the topology is reconfigured at each segment boundary
and *reused* within a segment.  The OCS link offset of a segment is the
greatest common divisor of the Bruck message offsets inside it, so that
every step in the segment stays inside its subring (generalized Lemma 3.2:
a destination is reachable iff the message offset is divisible by the link
offset).  For radix 2 the offsets in a segment are successive powers of two
and the gcd is the smallest offset — exactly the paper's rule.

  - All-to-All:      optimal segments are balanced (Lemma 3.1 / Theorem 3.2)
                     => periodic reconfigurations.
  - Reduce-Scatter:  transmission-optimal segments are found by an interval
                     partition DP (the paper's ILP, Theorem 3.3) => early.
  - AllGather:       the time-reverse of Reduce-Scatter => late (Section 3.5).
  - Optimal R:       argmin over 0 <= R < S of modeled completion time (3.6).

All DPs below score segments with the *actual* per-sub-step hop counts and
send volumes from `bruck.steps_for`, so they remain exact for non-power-of-
two n and radix r > 2 where the paper's closed forms (2^len - 1, len / 2^a)
no longer apply.  For power-of-two n at radix 2 the synthesized schedules
are bit-identical to the paper's Table 1 (tested).

One DP table pass fills the optimum for *every* segment count at once
(`best[i][r]` is already computed for all r), and `SegmentTables` makes the
per-segment cost O(1) via prefix sums plus a dense interval-gcd table, so a
full candidate set over all R costs one O(S^3) DP per strategy family
instead of S separate capped DPs (~S/4 x fewer cell relaxations; counted by
`dp_stats` and pinned in BENCH_planner.json).

Planning entry point: `repro.planner` (PlanRequest -> Planner -> PlanResult).
The module-level `plan` / `candidate_schedules` here are kept as thin
deprecated shims over it.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Sequence

from .bruck import (Collective, Step, is_pow2, num_steps, schedule_length,
                    steps_for)
from .cost_model import CostModel


def _segment_gcd(steps: Sequence[Step], a: int, b: int) -> int:
    """Link offset of segment [a, b]: gcd of its message offsets."""
    g = 0
    for j in range(a, b + 1):
        g = math.gcd(g, steps[j].offset)
    return g


def changed_links(n: int, prev: int | Sequence[int],
                  nxt: int | Sequence[int]) -> int:
    """Egress circuits that physically differ between two link configurations.

    ``prev`` and ``nxt`` each describe the configured circuit of every node's
    optical egress port, either as one uniform subring link offset (an int:
    node u targets (u + g) mod n) or as a per-node offset sequence of length
    n.  Returns how many of the n egress circuits target a different node
    under ``nxt`` than under ``prev`` — the circuits an OCS must rewire to
    move between the configurations; everything else keeps carrying traffic.

    This is the free-function generalization of
    `Schedule.reconfig_changed_links` (which diffs consecutive segments of a
    single schedule): it applies to *any* boundary between two link states,
    in particular the boundary between back-to-back collectives in a workload
    trace, where the fabric's final offsets from collective i are the initial
    configuration of collective i+1 (`repro.workloads.trace_planner`).
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")

    def norm(name: str, v) -> tuple[int, ...]:
        if isinstance(v, int):
            return (v % n,) * n
        v = tuple(int(g) % n for g in v)
        if len(v) != n:
            raise ValueError(f"{name} has {len(v)} per-node offsets != n={n}")
        return v

    if isinstance(prev, int) and isinstance(nxt, int):
        return 0 if prev % n == nxt % n else n
    return sum(1 for a, b in zip(norm("prev", prev), norm("nxt", nxt),
                                 strict=True) if a != b)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Reconfiguration schedule for one collective execution.

    ``r`` is the Bruck radix the sub-step sequence was generated with
    (r = 2 is the paper's pattern; r > 2 is the multiport/radix-r
    generalization of Section 3.1).
    """

    kind: Collective
    n: int
    x: tuple[int, ...]
    r: int = 2

    def __post_init__(self):
        s = schedule_length(self.kind, self.n, self.r)
        if len(self.x) != s:
            raise ValueError(
                f"schedule length {len(self.x)} != S={s} (n={self.n}, r={self.r})")
        if any(v not in (0, 1) for v in self.x):
            raise ValueError("x must be 0/1")
        if self.x and self.x[0] != 0:
            raise ValueError("x_0 must be 0: initial topology is pre-established")

    @property
    def R(self) -> int:
        return sum(self.x)

    @property
    def segments(self) -> tuple[tuple[int, int], ...]:
        """Inclusive (first_step, last_step) per reconfiguration period."""
        s = len(self.x)
        bounds = [k for k in range(s) if self.x[k] == 1] + [s]
        segs, a = [], 0
        for b in bounds:
            segs.append((a, b - 1))
            a = b
        return tuple(segs)

    def link_offsets(self, steps: Sequence[Step] | None = None) -> list[int]:
        """OCS link offset in force during each sub-step.

        The offsets depend only on (kind, n, x, r) — never on the payload —
        so the default path is memoized per schedule (`_link_offsets_cached`);
        a fresh list is returned either way.
        """
        if steps is None:
            return list(_link_offsets_cached(self))
        out = [0] * len(self.x)
        for a, b in self.segments:
            g = _segment_gcd(steps, a, b)
            for j in range(a, b + 1):
                out[j] = g
        return out

    def reconfig_changed_links(self, steps: Sequence[Step] | None = None) -> tuple[int, ...]:
        """Circuits that physically change at each reconfiguration point.

        Entry i corresponds to the i-th set bit of ``x`` (the boundary before
        segment i+1) and is the number of egress circuits whose target
        differs between the adjacent segments' link offsets.  Under
        uniform-offset subrings every node's egress retargets when the
        offset changes, so each entry is ``n`` (all circuits) or ``0`` (the
        boundary reuses the same offset — possible for duplicate-gcd
        segments, e.g. at radix r > 2).  FabricSim and the overlap-aware
        analytic model charge delta only where an entry is nonzero.
        """
        if steps is None:
            return _changed_links_cached(self)
        gs = [_segment_gcd(steps, a, b) for a, b in self.segments]
        return tuple(changed_links(self.n, gs[i - 1], gs[i])
                     for i in range(1, len(gs)))

    @staticmethod
    def from_segments(kind: Collective, n: int, lengths: Sequence[int],
                      r: int = 2) -> "Schedule":
        s = schedule_length(kind, n, r)
        if sum(lengths) != s or any(seg_len <= 0 for seg_len in lengths):
            raise ValueError(f"segment lengths {lengths} must be positive and sum to {s}")
        x = [0] * s
        pos = 0
        for seg_len in lengths[:-1]:
            pos += seg_len
            x[pos] = 1
        return Schedule(kind=kind, n=n, x=tuple(x), r=r)

    @property
    def segment_lengths(self) -> tuple[int, ...]:
        return tuple(b - a + 1 for a, b in self.segments)


def static_schedule(kind: Collective, n: int, r: int = 2) -> Schedule:
    return Schedule(kind=kind, n=n, x=tuple([0] * schedule_length(kind, n, r)), r=r)


def every_step_schedule(kind: Collective, n: int, r: int = 2) -> Schedule:
    """Greedy (G-BRUCK-like): reconfigure before every sub-step after the first."""
    s = schedule_length(kind, n, r)
    return Schedule(kind=kind, n=n, x=tuple([0] + [1] * (s - 1)), r=r)


# --- Generic segment-partition DP -------------------------------------------

#: Cumulative DP work counters since the last `reset_dp_stats()`.
#: ``relaxations`` counts inner-loop cell relaxations (one candidate previous
#: boundary examined); ``dp_calls`` counts DP table constructions.  The
#: planner benchmark (benchmarks/planner_bench.py) uses these to certify the
#: all-R single-pass speedup recorded in BENCH_planner.json.
_DP_STATS = {"dp_calls": 0, "relaxations": 0}


def dp_stats() -> dict:
    """Snapshot of the DP work counters (see `reset_dp_stats`)."""
    return dict(_DP_STATS)


def reset_dp_stats() -> None:
    _DP_STATS["dp_calls"] = 0
    _DP_STATS["relaxations"] = 0


def _dp_table(
    s: int, max_segments: int, seg_cost: Callable[[int, int], float]
) -> list[list[tuple[float, tuple[int, ...]]]]:
    """Fill best[i][r] = (cost, lengths) covering steps 0..i-1 with exactly r
    segments, for every r <= max_segments — the all-R workhorse.

    Ties are broken toward lexicographically-smallest segment-length tuples,
    which matches the paper's Table 1 presentation.
    """
    INF = float("inf")
    best: list[list[tuple[float, tuple[int, ...]]]] = [
        [(INF, ())] * (max_segments + 1) for _ in range(s + 1)
    ]
    best[0][0] = (0.0, ())
    relaxations = 0
    for i in range(1, s + 1):
        for r in range(1, min(i, max_segments) + 1):
            cand = (INF, ())
            for a in range(r - 1, i):  # previous boundary
                prev_cost, prev_lens = best[a][r - 1]
                if prev_cost == INF:
                    continue
                relaxations += 1
                c = prev_cost + seg_cost(a, i - 1)
                key = (c, prev_lens + (i - a,))
                if key < cand:
                    cand = key
            best[i][r] = cand
    _DP_STATS["dp_calls"] += 1
    _DP_STATS["relaxations"] += relaxations
    return best


def _partition_dp(
    s: int, num_segments: int, seg_cost: Callable[[int, int], float]
) -> tuple[float, list[int]]:
    """Minimize sum of seg_cost(a, b) over partitions of 0..s-1 into exactly
    ``num_segments`` contiguous segments.  Returns (cost, segment lengths).

    Single-R entry point (the legacy per-R reference path runs this once per
    R); `_partition_dp_all` extracts every segment count from one table.
    """
    if not (1 <= num_segments <= s):
        raise ValueError(f"need 1 <= segments={num_segments} <= s={s}")
    cost, lens = _dp_table(s, num_segments, seg_cost)[s][num_segments]
    if cost == float("inf"):
        raise RuntimeError("infeasible partition")
    return cost, list(lens)


def _partition_dp_all(
    s: int, seg_cost: Callable[[int, int], float]
) -> list[tuple[float, tuple[int, ...]]]:
    """One DP pass, optima for *every* number of segments 1..s.

    Returns a list indexed by R = num_segments - 1 of (cost, lengths); entry
    R is bit-identical to `_partition_dp(s, R + 1, seg_cost)` because
    best[i][r] never depends on the segment-count cap.
    """
    best = _dp_table(s, s, seg_cost)
    return [best[s][r] for r in range(1, s + 1)]


class SegmentTables:
    """O(1) segment costs for a fixed step sequence.

    Precomputes an O(S^2) dense interval-gcd table plus prefix sums of the
    message offsets and of nbytes * offset.  Because the segment link offset
    g = gcd(offsets in [a, b]) divides every offset in the segment,

        sum_j offset_j // g  == (sum_j offset_j) // g          (hops)
        sum_j nbytes_j * (offset_j // g) == (sum_j nbytes_j * offset_j) / g

    so both DP objectives reduce to one prefix-sum subtraction and one
    division — the per-relaxation cost drops from O(segment length) to O(1).
    """

    __slots__ = ("_gcd", "_off", "_woff")

    def __init__(self, steps: Sequence[Step]):
        S = len(steps)
        offsets = [st.offset for st in steps]
        self._gcd: list[list[int]] = []
        for a in range(S):
            g, row = 0, []
            for b in range(a, S):
                g = math.gcd(g, offsets[b])
                row.append(g)
            self._gcd.append(row)
        self._off = [0] * (S + 1)
        self._woff = [0.0] * (S + 1)
        for j, st in enumerate(steps):
            self._off[j + 1] = self._off[j] + st.offset
            self._woff[j + 1] = self._woff[j] + st.nbytes * st.offset

    def gcd(self, a: int, b: int) -> int:
        """Link offset (gcd of message offsets) of segment [a, b]."""
        return self._gcd[a][b - a]

    def hop_sum(self, a: int, b: int) -> int:
        """Total hop count of segment [a, b] (Lemma 3.1 objective)."""
        return (self._off[b + 1] - self._off[a]) // self.gcd(a, b)

    def tx_sum(self, a: int, b: int) -> float:
        """Transmission term sum(nbytes * hops) of segment [a, b] (Thm 3.3)."""
        return (self._woff[b + 1] - self._woff[a]) / self.gcd(a, b)

    def exact_cost(self, cm: CostModel) -> Callable[[int, int], float]:
        """Full-model segment cost: startup + hop latency + transmission."""
        alpha_s, alpha_h, beta = cm.alpha_s, cm.alpha_h, cm.beta

        def seg_cost(a: int, b: int) -> float:
            return ((b - a + 1) * alpha_s + alpha_h * self.hop_sum(a, b)
                    + beta * self.tx_sum(a, b))

        return seg_cost


# --- Legacy O(segment-length) cost closures ----------------------------------
#
# Kept as the per-R reference implementation: `_legacy_candidate_schedules`
# below reproduces the pre-planner behavior (one capped DP per (family, R),
# per-step summation order) for the parity tests and the before/after
# comparison in benchmarks/planner_bench.py.


def _hop_sum_cost(steps: Sequence[Step]) -> Callable[[int, int], float]:
    """Total hop count of a segment: sum of offset / gcd over its sub-steps.

    For radix-2 power-of-two A2A this is 2^len - 1, the paper's Lemma 3.1
    objective; for general (n, r) it is the exact per-segment hop latency.
    """

    def seg_cost(a: int, b: int) -> float:
        g = _segment_gcd(steps, a, b)
        return float(sum(steps[j].offset // g for j in range(a, b + 1)))

    return seg_cost


def _transmission_cost(steps: Sequence[Step]) -> Callable[[int, int], float]:
    """Transmission term of a segment: sum of nbytes * congestion, with
    congestion = hops = offset / gcd (uniform-offset ring traffic).

    For radix-2 power-of-two RS this is len / 2^{a+1} (the paper's Theorem
    3.3 objective up to a constant factor); exact for general (n, r).
    """

    def seg_cost(a: int, b: int) -> float:
        g = _segment_gcd(steps, a, b)
        return sum(steps[j].nbytes * (steps[j].offset // g) for j in range(a, b + 1))

    return seg_cost


def _segment_cost_exact(kind: Collective, steps: Sequence[Step], cm: CostModel) -> Callable:
    def seg_cost(a: int, b: int) -> float:
        g = _segment_gcd(steps, a, b)
        t = 0.0
        for j in range(a, b + 1):
            h = steps[j].offset // g
            t += cm.step_cost(hops=h, nbytes=steps[j].nbytes, congestion=h)
        return t

    return seg_cost


# --- Step-sequence cache (schedule synthesis calls these in tight loops) -----

_STEP_CACHE: dict[tuple[str, int, int], tuple[Step, ...]] = {}


def _steps_cached(kind: Collective, n: int, r: int) -> tuple[Step, ...]:
    key = (kind, n, r)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = tuple(steps_for(kind, n, 1.0, r))
    return _STEP_CACHE[key]


@functools.lru_cache(maxsize=4096)
def _link_offsets_cached(schedule: "Schedule") -> tuple[int, ...]:
    """Per-sub-step link offsets of a schedule, memoized per Schedule.

    Schedules are small frozen dataclasses, so the hash is cheap and the
    cache lets every evaluator (analytic, event, fabric, batch) reuse the
    segment-gcd work instead of recomputing it per run.
    """
    steps = _steps_cached(schedule.kind, schedule.n, schedule.r)
    out = [0] * len(schedule.x)
    for a, b in schedule.segments:
        g = _segment_gcd(steps, a, b)
        for j in range(a, b + 1):
            out[j] = g
    return tuple(out)


@functools.lru_cache(maxsize=4096)
def _changed_links_cached(schedule: "Schedule") -> tuple[int, ...]:
    """Changed circuits per reconfiguration boundary, memoized per Schedule."""
    steps = _steps_cached(schedule.kind, schedule.n, schedule.r)
    gs = [_segment_gcd(steps, a, b) for a, b in schedule.segments]
    return tuple(changed_links(schedule.n, gs[i - 1], gs[i])
                 for i in range(1, len(gs)))


# --- Paper-faithful schedule families, all R in one DP pass -------------------


@functools.lru_cache(maxsize=None)
def periodic_a2a_all(n: int, r: int = 2) -> tuple[Schedule, ...]:
    """Theorem 3.2 optimal All-to-All schedules for every R at once.

    Entry R of the returned tuple is the hop-sum-optimal schedule with R
    reconfigurations (balanced segments for radix 2, Lemma 3.1), extracted
    from a single all-R DP table.
    """
    steps = _steps_cached("a2a", n, r)
    tables = SegmentTables(steps)
    return tuple(
        Schedule.from_segments("a2a", n, list(lens), r)
        for _, lens in _partition_dp_all(len(steps), tables.hop_sum))


@functools.lru_cache(maxsize=None)
def rs_transmission_optimal_all(n: int, r: int = 2) -> tuple[Schedule, ...]:
    """Theorem 3.3 transmission-optimal Reduce-Scatter schedules, all R."""
    steps = _steps_cached("rs", n, r)
    tables = SegmentTables(steps)
    return tuple(
        Schedule.from_segments("rs", n, list(lens), r)
        for _, lens in _partition_dp_all(len(steps), tables.tx_sum))


def ag_transmission_optimal_all(n: int, r: int = 2) -> tuple[Schedule, ...]:
    """Section 3.5: AllGather optima = reversed Reduce-Scatter schedules."""
    return tuple(
        Schedule.from_segments("ag", n, list(reversed(rs.segment_lengths)), r)
        for rs in rs_transmission_optimal_all(n, r))


@functools.lru_cache(maxsize=512)
def full_cost_optimal_all(kind: Collective, n: int, m: float, cm: CostModel,
                          r: int = 2) -> tuple[Schedule, ...]:
    """Exact minimum-completion-time schedules for every fixed R at once.

    Beyond-paper: jointly minimizes latency + transmission (+ the fixed
    R*delta) instead of picking the better of the latency-only and
    transmission-only optima (paper Section 3.6).
    """
    steps = tuple(steps_for(kind, n, m, r))
    tables = SegmentTables(steps)
    return tuple(
        Schedule.from_segments(kind, n, list(lens), r)
        for _, lens in _partition_dp_all(len(steps), tables.exact_cost(cm)))


def periodic_all(kind: Collective, n: int, r: int = 2) -> tuple[Schedule, ...]:
    """Latency-optimal (periodic) schedules for any collective, all R.

    For A2A this is Theorem 3.2; for RS/AG the paper notes the latency-optimal
    case is identical to All-to-All (Section 3.6 / Section 5), with AG's
    segments reversed to match its descending offsets.
    """
    base = periodic_a2a_all(n, r)
    if kind == "a2a":
        return base
    out = []
    for sched in base:
        lens = sched.segment_lengths
        if kind == "ag":
            lens = tuple(reversed(lens))
        out.append(Schedule.from_segments(kind, n, list(lens), r))
    return tuple(out)


def clear_schedule_caches() -> None:
    """Drop the memoized all-R DP results (used by benchmarks for cold runs)."""
    periodic_a2a_all.cache_clear()
    rs_transmission_optimal_all.cache_clear()
    full_cost_optimal_all.cache_clear()


def _check_R(R: int, s: int) -> None:
    if not (0 <= R < s):
        raise ValueError(f"need 0 <= R={R} < S={s}")


def periodic_a2a(n: int, R: int, r: int = 2) -> Schedule:
    """Theorem 3.2: optimal All-to-All schedule, periodic for radix 2
    (balanced segments by Lemma 3.1).

    Computed by the exact DP on the hop-sum objective (2^len - 1 in the
    radix-2 case); for radix 2 the result always has segment lengths
    differing by at most one.
    """
    scheds = periodic_a2a_all(n, r)
    _check_R(R, len(scheds))
    sched = scheds[R]
    if r == 2:
        lens = sched.segment_lengths
        assert max(lens) - min(lens) <= 1, "Lemma 3.1 violated"
    return sched


def rs_transmission_optimal(n: int, R: int, r: int = 2) -> Schedule:
    """Theorem 3.3: transmission-optimal Reduce-Scatter schedule.

    The paper's ILP minimizes sum over periods [a,b] of (b - a + 1) / 2^a;
    the DP minimizes the exact per-segment transmission (identical up to a
    constant factor for radix-2 power-of-two n, exact otherwise) as an
    interval-partition DP (schedules are parameter-free).
    """
    scheds = rs_transmission_optimal_all(n, r)
    _check_R(R, len(scheds))
    return scheds[R]


def ag_transmission_optimal(n: int, R: int, r: int = 2) -> Schedule:
    """Section 3.5: AllGather optimum is the reversed Reduce-Scatter schedule."""
    scheds = ag_transmission_optimal_all(n, r)
    _check_R(R, len(scheds))
    return scheds[R]


def periodic(kind: Collective, n: int, R: int, r: int = 2) -> Schedule:
    """Latency-optimal (periodic) schedule for any of the three collectives."""
    scheds = periodic_all(kind, n, r)
    _check_R(R, len(scheds))
    return scheds[R]


def full_cost_optimal(kind: Collective, n: int, m: float, cm: CostModel,
                      R: int, r: int = 2) -> Schedule:
    """Exact minimum-completion-time schedule for fixed R under the full model."""
    scheds = full_cost_optimal_all(kind, n, float(m), cm, r)
    _check_R(R, len(scheds))
    return scheds[R]


def cstar_a2a(n: int, R: int, cm: CostModel, m: float) -> float:
    """Closed-form optimal A2A cost (Theorem 3.2; radix 2, power-of-two n),
    exact when (R+1) | s.

    C* = s*alpha_s + (R+1) * c * (n^{1/(R+1)} - 1) + R*delta,  c = alpha_h + beta*m/2.

    The derivation assumes offsets 2^k on n = 2^s nodes; anything else would
    silently return a wrong value, so non-power-of-two n is rejected (use the
    exact DPs above for general n / radix).
    """
    if not is_pow2(n) or n < 2:
        raise ValueError(
            f"cstar_a2a closed form holds only for power-of-two n >= 2 at "
            f"radix 2, got n={n}; use the DP schedules for general (n, r)")
    s = num_steps(n)
    _check_R(R, s)
    c = cm.alpha_h + cm.beta * m / 2.0
    return s * cm.alpha_s + (R + 1) * c * (n ** (1.0 / (R + 1)) - 1.0) + R * cm.delta


# --- Optimal number of reconfigurations (Section 3.6) -------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    schedule: Schedule
    predicted_time: float
    strategy: str  # which candidate family won


def candidate_schedules(
    kind: Collective, n: int, m: float, cm: CostModel,
    paper_faithful: bool = False, r: int = 2
) -> list[tuple[str, Schedule]]:
    """The per-R candidate set of paper Section 3.6, in the legacy (R-major)
    order.  Each strategy family is materialized by one all-R DP pass."""
    periodic_scheds = periodic_all(kind, n, r)
    tx_scheds: tuple[Schedule, ...] = ()
    if kind == "rs":
        tx_scheds = rs_transmission_optimal_all(n, r)
    elif kind == "ag":
        tx_scheds = ag_transmission_optimal_all(n, r)
    exact_scheds: tuple[Schedule, ...] = ()
    if not paper_faithful:
        exact_scheds = full_cost_optimal_all(kind, n, float(m), cm, r)
    cands: list[tuple[str, Schedule]] = []
    for R in range(len(periodic_scheds)):
        cands.append((f"periodic(R={R})", periodic_scheds[R]))
        if kind == "rs":
            cands.append((f"rs-early(R={R})", tx_scheds[R]))
        elif kind == "ag":
            cands.append((f"ag-late(R={R})", tx_scheds[R]))
        if not paper_faithful:
            cands.append((f"exact-dp(R={R})", exact_scheds[R]))
    return cands


def plan(
    kind: Collective, n: int, m: float, cm: CostModel,
    paper_faithful: bool = False, r: int = 2
) -> Plan:
    """Pick the schedule (incl. R, Section 3.6) minimizing modeled completion
    time.

    .. deprecated::
        Thin shim over `repro.planner.Planner`, the single planning entry
        point for all four collectives; use it directly for alternatives
        tables, constraints, fabric/objective selection, and serialization.
        Routes through `default_planner()` so repeated calls hit the shared
        LRU plan cache.  Emits a `DeprecationWarning`; removal path is
        documented in the README ("Deprecated entry points").
    """
    import warnings

    from repro.planner import PlanRequest, default_planner  # local: no cycle

    warnings.warn(
        "core.schedules.plan is deprecated; construct a PlanRequest and call "
        "repro.planner.Planner.plan (see README 'Deprecated entry points' "
        "for the removal path)", DeprecationWarning, stacklevel=2)
    res = default_planner().plan(PlanRequest(
        kind=kind, n=n, m_bytes=float(m), cost_model=cm, r=r,
        paper_faithful=paper_faithful))
    assert res.schedule is not None
    return Plan(schedule=res.schedule, predicted_time=res.predicted_time,
                strategy=res.strategy)


# --- Pre-planner per-R reference implementation ------------------------------
#
# The exact legacy behavior (one capped `_partition_dp` per (family, R), no
# all-R sharing, per-step summation order).  Used by tests/test_planner.py to
# certify parity and by benchmarks/planner_bench.py as the "before" side of
# the DP-relaxation comparison.  Not part of the public API.


def _legacy_candidate_schedules(
    kind: Collective, n: int, m: float, cm: CostModel,
    paper_faithful: bool = False, r: int = 2
) -> list[tuple[str, Schedule]]:
    s = schedule_length(kind, n, r)
    a2a_steps_ = _steps_cached("a2a", n, r)
    rs_steps_ = _steps_cached("rs", n, r)
    cands: list[tuple[str, Schedule]] = []
    for R in range(0, s):
        _, lens = _partition_dp(s, R + 1, _hop_sum_cost(a2a_steps_))
        if kind == "ag":
            lens = list(reversed(lens))
        cands.append((f"periodic(R={R})", Schedule.from_segments(kind, n, lens, r)))
        if kind in ("rs", "ag"):
            _, lens = _partition_dp(s, R + 1, _transmission_cost(rs_steps_))
            if kind == "rs":
                cands.append((f"rs-early(R={R})",
                              Schedule.from_segments("rs", n, lens, r)))
            else:
                cands.append((f"ag-late(R={R})",
                              Schedule.from_segments("ag", n, list(reversed(lens)), r)))
        if not paper_faithful:
            steps_m = steps_for(kind, n, m, r)
            _, lens = _partition_dp(s, R + 1, _segment_cost_exact(kind, steps_m, cm))
            cands.append((f"exact-dp(R={R})",
                          Schedule.from_segments(kind, n, lens, r)))
    return cands


def _legacy_plan(
    kind: Collective, n: int, m: float, cm: CostModel,
    paper_faithful: bool = False, r: int = 2
) -> Plan:
    from .simulator import collective_time  # local import to avoid cycle

    best: Plan | None = None
    for name, sched in _legacy_candidate_schedules(kind, n, m, cm,
                                                   paper_faithful, r):
        t = collective_time(sched, m, cm).total
        if best is None or t < best.predicted_time:
            best = Plan(schedule=sched, predicted_time=t, strategy=name)
    assert best is not None
    return best
