"""BRIDGE reconfiguration-schedule synthesis (paper Sections 3.3-3.6).

A schedule for an s-step Bruck collective is x in {0,1}^s, x_k = 1 meaning the
OCS is reconfigured immediately before step k.  x_0 = 0 always: the initial
topology is established before the collective starts (the physical ring for
All-to-All / Reduce-Scatter; the first segment's subring for AllGather,
paper Section 3.5) and is therefore free.

Equivalently a schedule is a partition of the steps 0..s-1 into R+1 contiguous
*segments*; the topology is reconfigured at each segment boundary and *reused*
within a segment.  The OCS link offset of a segment is the smallest Bruck
message offset inside it (= first step's offset for A2A/RS whose offsets
double; = last step's offset for AG whose offsets halve), so that every step
in the segment stays inside its subring (Lemma 3.2).

  - All-to-All:      optimal segments are balanced (Lemma 3.1 / Theorem 3.2)
                     => periodic reconfigurations.
  - Reduce-Scatter:  transmission-optimal segments are found by an interval
                     partition DP (the paper's ILP, Theorem 3.3) => early.
  - AllGather:       the time-reverse of Reduce-Scatter => late (Section 3.5).
  - Optimal R:       argmin over 0 <= R < s of modeled completion time (3.6).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Literal, Sequence

from .bruck import Collective, Step, num_steps, steps_for
from .cost_model import CostModel


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Reconfiguration schedule for one collective execution."""

    kind: Collective
    n: int
    x: tuple[int, ...]

    def __post_init__(self):
        s = num_steps(self.n)
        if len(self.x) != s:
            raise ValueError(f"schedule length {len(self.x)} != s={s}")
        if any(v not in (0, 1) for v in self.x):
            raise ValueError("x must be 0/1")
        if self.x and self.x[0] != 0:
            raise ValueError("x_0 must be 0: initial topology is pre-established")

    @property
    def R(self) -> int:
        return sum(self.x)

    @property
    def segments(self) -> tuple[tuple[int, int], ...]:
        """Inclusive (first_step, last_step) per reconfiguration period."""
        s = len(self.x)
        bounds = [k for k in range(s) if self.x[k] == 1] + [s]
        segs, a = [], 0
        for b in bounds:
            segs.append((a, b - 1))
            a = b
        return tuple(segs)

    def link_offsets(self, steps: Sequence[Step] | None = None) -> list[int]:
        """OCS link offset in force during each step."""
        steps = steps if steps is not None else steps_for(self.kind, self.n, 1.0)
        out = [0] * len(self.x)
        for a, b in self.segments:
            g = min(steps[j].offset for j in range(a, b + 1))
            for j in range(a, b + 1):
                out[j] = g
        return out

    @staticmethod
    def from_segments(kind: Collective, n: int, lengths: Sequence[int]) -> "Schedule":
        s = num_steps(n)
        if sum(lengths) != s or any(l <= 0 for l in lengths):
            raise ValueError(f"segment lengths {lengths} must be positive and sum to {s}")
        x = [0] * s
        pos = 0
        for l in lengths[:-1]:
            pos += l
            x[pos] = 1
        return Schedule(kind=kind, n=n, x=tuple(x))

    @property
    def segment_lengths(self) -> tuple[int, ...]:
        return tuple(b - a + 1 for a, b in self.segments)


def static_schedule(kind: Collective, n: int) -> Schedule:
    return Schedule(kind=kind, n=n, x=tuple([0] * num_steps(n)))


def every_step_schedule(kind: Collective, n: int) -> Schedule:
    """Greedy (G-BRUCK-like): reconfigure before every step after the first."""
    s = num_steps(n)
    return Schedule(kind=kind, n=n, x=tuple([0] + [1] * (s - 1)))


# --- Generic segment-partition DP -------------------------------------------


def _partition_dp(
    s: int, num_segments: int, seg_cost: Callable[[int, int], float]
) -> tuple[float, list[int]]:
    """Minimize sum of seg_cost(a, b) over partitions of 0..s-1 into exactly
    ``num_segments`` contiguous segments.  Returns (cost, segment lengths).

    Ties are broken toward lexicographically-smallest segment-length tuples,
    which matches the paper's Table 1 presentation.
    """
    if not (1 <= num_segments <= s):
        raise ValueError(f"need 1 <= segments={num_segments} <= s={s}")
    INF = float("inf")
    # best[i][r] = (cost, lengths) covering steps 0..i-1 with r segments
    best: list[list[tuple[float, tuple[int, ...]]]] = [
        [(INF, ())] * (num_segments + 1) for _ in range(s + 1)
    ]
    best[0][0] = (0.0, ())
    for i in range(1, s + 1):
        for r in range(1, min(i, num_segments) + 1):
            cand = (INF, ())
            for a in range(r - 1, i):  # previous boundary
                prev_cost, prev_lens = best[a][r - 1]
                if prev_cost == INF:
                    continue
                c = prev_cost + seg_cost(a, i - 1)
                key = (c, prev_lens + (i - a,))
                if key < cand:
                    cand = key
            best[i][r] = cand
    cost, lens = best[s][num_segments]
    if cost == float("inf"):
        raise RuntimeError("infeasible partition")
    return cost, list(lens)


# --- Paper-faithful schedules ------------------------------------------------


def periodic_a2a(n: int, R: int) -> Schedule:
    """Theorem 3.2: optimal All-to-All schedule is periodic (balanced segments).

    Computed by the exact DP on the A2A objective sum(2^len - 1); by Lemma 3.1
    the result always has segment lengths differing by at most one.
    """
    s = num_steps(n)
    _, lens = _partition_dp(s, R + 1, lambda a, b: float(2 ** (b - a + 1) - 1))
    assert max(lens) - min(lens) <= 1, "Lemma 3.1 violated"
    return Schedule.from_segments("a2a", n, lens)


def rs_transmission_optimal(n: int, R: int) -> Schedule:
    """Theorem 3.3: transmission-optimal Reduce-Scatter schedule.

    Minimizes sum over periods [a,b] of (b - a + 1) / 2^a — the paper's ILP,
    solved exactly as an interval-partition DP (schedules are parameter-free).
    """
    s = num_steps(n)
    _, lens = _partition_dp(s, R + 1, lambda a, b: (b - a + 1) / 2.0**a)
    return Schedule.from_segments("rs", n, lens)


def ag_transmission_optimal(n: int, R: int) -> Schedule:
    """Section 3.5: AllGather optimum is the reversed Reduce-Scatter schedule."""
    rs = rs_transmission_optimal(n, R)
    return Schedule.from_segments("ag", n, list(reversed(rs.segment_lengths)))


def periodic(kind: Collective, n: int, R: int) -> Schedule:
    """Latency-optimal (periodic) schedule for any of the three collectives.

    For A2A this is Theorem 3.2; for RS/AG the paper notes the latency-optimal
    case is identical to All-to-All (Section 3.6 / Section 5).
    """
    lens = periodic_a2a(n, R).segment_lengths
    if kind == "ag":
        lens = tuple(reversed(lens))
    return Schedule.from_segments(kind, n, list(lens))


def cstar_a2a(n: int, R: int, cm: CostModel, m: float) -> float:
    """Closed-form optimal A2A cost (Theorem 3.2), exact when (R+1) | s.

    C* = s*alpha_s + (R+1) * c * (n^{1/(R+1)} - 1) + R*delta,  c = alpha_h + beta*m/2.
    """
    s = num_steps(n)
    c = cm.alpha_h + cm.beta * m / 2.0
    return s * cm.alpha_s + (R + 1) * c * (n ** (1.0 / (R + 1)) - 1.0) + R * cm.delta


# --- Exact full-cost schedules (beyond paper: joint latency+transmission DP) --


def _segment_cost_exact(kind: Collective, steps: Sequence[Step], cm: CostModel) -> Callable:
    def seg_cost(a: int, b: int) -> float:
        g = min(steps[j].offset for j in range(a, b + 1))
        t = 0.0
        for j in range(a, b + 1):
            h = steps[j].offset // g
            t += cm.step_cost(hops=h, nbytes=steps[j].nbytes, congestion=h)
        return t

    return seg_cost


def full_cost_optimal(kind: Collective, n: int, m: float, cm: CostModel, R: int) -> Schedule:
    """Exact minimum-completion-time schedule for fixed R under the full model.

    Beyond-paper: jointly minimizes latency + transmission (+ the fixed R*delta)
    instead of picking the better of the latency-only and transmission-only
    optima (paper Section 3.6).
    """
    steps = steps_for(kind, n, m)
    _, lens = _partition_dp(len(steps), R + 1, _segment_cost_exact(kind, steps, cm))
    return Schedule.from_segments(kind, n, lens)


# --- Optimal number of reconfigurations (Section 3.6) -------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    schedule: Schedule
    predicted_time: float
    strategy: str  # which candidate family won


def candidate_schedules(
    kind: Collective, n: int, m: float, cm: CostModel, paper_faithful: bool = False
) -> list[tuple[str, Schedule]]:
    s = num_steps(n)
    cands: list[tuple[str, Schedule]] = []
    for R in range(0, s):
        cands.append((f"periodic(R={R})", periodic(kind, n, R)))
        if kind == "rs":
            cands.append((f"rs-early(R={R})", rs_transmission_optimal(n, R)))
        elif kind == "ag":
            cands.append((f"ag-late(R={R})", ag_transmission_optimal(n, R)))
        if not paper_faithful:
            cands.append((f"exact-dp(R={R})", full_cost_optimal(kind, n, m, cm, R)))
    return cands


def plan(
    kind: Collective, n: int, m: float, cm: CostModel, paper_faithful: bool = False
) -> Plan:
    """Pick the schedule (incl. R, Section 3.6) minimizing modeled completion time."""
    from .simulator import collective_time  # local import to avoid cycle

    best: Plan | None = None
    for name, sched in candidate_schedules(kind, n, m, cm, paper_faithful):
        t = collective_time(sched, m, cm).total
        if best is None or t < best.predicted_time:
            best = Plan(schedule=sched, predicted_time=t, strategy=name)
    assert best is not None
    return best
