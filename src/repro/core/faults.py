"""Typed fault timelines and degraded fabric state.

Every run of the fabric simulators today finishes on the topology it
started with.  BRIDGE's premise — circuits are *reused* across future
steps — makes that assumption load-bearing: a single failed port or link
invalidates not just the current step but every downstream segment that
counted on the subring.  This module is the typed producer of that
situation:

  - `FaultSpec`     : one fault — a permanent link/port failure
    (``link-down``), a transient flap with a repair time (``link-flap``),
    a graceful departure (``node-leave``), or a node joining the world
    (``node-join``) — at an arbitrary time into a trace.
  - `FaultTimeline` : a time-sorted sequence of faults against one world
    size, with a delivery policy for in-flight chunks and a strict JSON
    round trip (`core.jsonio` loaders: unknown keys, bad kinds, and
    out-of-range nodes fail at the parse boundary).
  - `DegradedState` : what the engines surface when a fault takes effect —
    the surviving members and link offset, the dead-port mask, the
    committed-prefix `FabricSnapshot`, and the in-flight chunks lost or
    re-queued per the timeline's delivery policy.  This is the input to
    the recovery loop in `repro.workloads.recovery`.

Fault semantics (phase granularity — a collective aborts or drains as a
unit, mirroring how real collectives abort-and-restart on member failure):

  - *abrupt* faults (``link-down``, ``link-flap``) strike at their event
    time: phases fully drained before the fault are committed, the phase
    in flight is aborted, and its already-serviced chunks are lost or
    re-queued per the delivery policy.  ``link-down`` removes the node
    from the world (its egress circuit is dead); ``link-flap`` keeps the
    world intact but delays resumption by ``repair_s``.
  - *graceful* faults (``node-leave``, ``node-join``) take effect at the
    first collective boundary at/after their time: the in-flight phase
    drains, nothing is lost, and the world shrinks/grows at the boundary.

A timeline may hold several faults; one engine run acts on the *earliest*
fault that takes effect before the clean run completes (recovery re-plans
the remainder, after which the residual timeline can be applied to the
recovered run).  Faults at/after trace completion are no-ops.
"""
from __future__ import annotations

import dataclasses
import json
import math
import random

from .batchsim import FabricSnapshot
from .jsonio import require_keys

FAULT_KINDS = ("link-down", "link-flap", "node-leave", "node-join")
#: kinds that abort the in-flight phase at their event time
ABRUPT_KINDS = ("link-down", "link-flap")
#: what happens to the aborted phase's already-serviced chunks
DELIVERY_POLICIES = ("drop", "requeue")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault event (see module docstring for the kind semantics).

    time     : seconds into the trace at which the fault occurs.
    node     : affected node/port.  For ``node-join`` it is the index the
               joining node takes (always the current world size n — rings
               grow at the end).
    repair_s : ``link-flap`` only — time until the flapped link carries
               traffic again; resumption waits it out.
    """

    kind: str
    time: float
    node: int
    repair_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}")
        object.__setattr__(self, "time", float(self.time))
        object.__setattr__(self, "repair_s", float(self.repair_s))
        if not math.isfinite(self.time) or self.time < 0:
            raise ValueError(
                f"fault time must be finite and >= 0, got {self.time}")
        if int(self.node) != self.node or self.node < 0:
            raise ValueError(f"fault node must be an int >= 0, got {self.node}")
        object.__setattr__(self, "node", int(self.node))
        if not math.isfinite(self.repair_s) or self.repair_s < 0:
            raise ValueError(
                f"repair_s must be finite and >= 0, got {self.repair_s}")
        if self.repair_s > 0 and self.kind != "link-flap":
            raise ValueError(
                f"repair_s only applies to link-flap faults, got "
                f"repair_s={self.repair_s} for {self.kind!r}")

    def to_dict(self) -> dict:
        d = {"kind": self.kind, "time": self.time, "node": self.node}
        if self.repair_s:
            d["repair_s"] = self.repair_s
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        require_keys(d, required=("kind", "time", "node"),
                     optional=("repair_s",), what="FaultSpec")
        return cls(kind=d["kind"], time=d["time"], node=d["node"],
                   repair_s=d.get("repair_s", 0.0))


@dataclasses.dataclass(frozen=True)
class FaultTimeline:
    """Time-sorted fault sequence against one world size (strict JSON).

    policy : delivery policy for the aborted phase's in-flight chunks —
             ``"drop"`` (lost; the aborted event re-runs in full on
             recovery) or ``"requeue"`` (accounted as re-queued; the
             aborted event still re-runs in full, recovery never trusts
             partially-delivered collective state).
    """

    n: int
    faults: tuple[FaultSpec, ...]
    policy: str = "drop"

    def __post_init__(self):
        if self.n < 2:
            raise ValueError(f"need at least 2 nodes, got n={self.n}")
        if self.policy not in DELIVERY_POLICIES:
            raise ValueError(
                f"policy must be one of {DELIVERY_POLICIES}, got "
                f"{self.policy!r}")
        object.__setattr__(self, "faults", tuple(self.faults))
        if not self.faults:
            raise ValueError("a fault timeline needs at least one fault")
        for a, b in zip(self.faults, self.faults[1:], strict=False):
            if b.time < a.time:
                raise ValueError(
                    f"faults must be sorted by time, got {b.time} after "
                    f"{a.time}")
        for f in self.faults:
            if f.kind == "node-join":
                if f.node != self.n:
                    raise ValueError(
                        f"node-join joins at index n={self.n}, got node="
                        f"{f.node}")
            elif not 0 <= f.node < self.n:
                raise ValueError(
                    f"fault node {f.node} outside [0, {self.n})")

    def to_dict(self) -> dict:
        return {"n": self.n, "policy": self.policy,
                "faults": [f.to_dict() for f in self.faults]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultTimeline":
        require_keys(d, required=("n", "faults"), optional=("policy",),
                     what="FaultTimeline")
        return cls(n=d["n"],
                   faults=tuple(FaultSpec.from_dict(f) for f in d["faults"]),
                   policy=d.get("policy", "drop"))

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "FaultTimeline":
        return cls.from_dict(json.loads(s))

    def check_horizon(self, horizon_s: float) -> "FaultTimeline":
        """Reject fault times at/after the trace horizon (they are no-ops —
        loading such a spec is a mistake, not a degraded run)."""
        for f in self.faults:
            if f.time >= horizon_s:
                raise ValueError(
                    f"fault time {f.time} is outside the trace horizon "
                    f"{horizon_s:.6g}s (the fault would never take effect)")
        return self


def random_timeline(n: int, *, horizon_s: float, seed: int = 0,
                    kinds: tuple[str, ...] = FAULT_KINDS, count: int = 1,
                    policy: str = "drop") -> FaultTimeline:
    """Seeded random timeline: ``count`` faults uniform over the horizon."""
    if horizon_s <= 0:
        raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
    rng = random.Random(seed)
    faults = []
    for _ in range(count):
        kind = rng.choice(list(kinds))
        faults.append(FaultSpec(
            kind=kind, time=rng.uniform(0.0, horizon_s) * (1 - 1e-12),
            node=n if kind == "node-join" else rng.randrange(n),
            repair_s=(rng.uniform(0.0, 0.1) * horizon_s
                      if kind == "link-flap" else 0.0)))
    faults.sort(key=lambda f: f.time)
    return FaultTimeline(n=n, faults=tuple(faults), policy=policy)


def world_after(n: int, fault: FaultSpec) -> tuple[tuple[int, ...],
                                                   tuple[int, ...]]:
    """(survivors, dead_ports) after ``fault`` strikes an n-node world.

    Survivors are old-world member indices (``node-join`` appends index n);
    dead_ports are ports whose egress circuit can never carry traffic again
    (``link-down`` only — a repaired flap leaves no dead circuit).
    """
    if fault.kind in ("link-down", "node-leave"):
        survivors = tuple(i for i in range(n) if i != fault.node)
        dead = (fault.node,) if fault.kind == "link-down" else ()
        return survivors, dead
    if fault.kind == "node-join":
        return tuple(range(n + 1)), ()
    return tuple(range(n)), ()  # link-flap: world intact after repair


@dataclasses.dataclass(frozen=True)
class DegradedState:
    """Fabric state surfaced by a run that a fault cut short.

    fault            : the fault that took effect (earliest effective one).
    policy           : delivery policy applied to the in-flight chunks.
    n                : world size the run started with.
    survivors        : member indices after the fault (`world_after`).
    dead_ports       : ports whose circuit is permanently dead.
    completed_phases : trace phases fully drained before the fault took
                       effect (committed — recovery never re-runs them).
    aborted_phase    : index of the phase cut mid-flight (abrupt faults;
                       ``None`` for graceful faults, which drain it).
    resume_clock     : earliest time recovery work can start — the fault
                       time (+ ``repair_s`` for a flap) for abrupt faults,
                       the drained boundary's clock for graceful ones.
    snapshot         : exact committed-prefix `FabricSnapshot` (old world;
                       ``None`` when the fault struck before any boundary).
    committed_chunks : chunk services belonging to committed phases.
    in_flight_chunks : aborted-phase services started before the fault.
    lost_chunks /
    requeued_chunks  : the in-flight split per ``policy`` (drop → all lost,
                       requeue → all re-queued; they always sum to
                       ``in_flight_chunks``, and the aborted event re-runs
                       in full on recovery either way).
    """

    fault: FaultSpec
    policy: str
    n: int
    survivors: tuple[int, ...]
    dead_ports: tuple[int, ...]
    completed_phases: int
    aborted_phase: int | None
    resume_clock: float
    snapshot: FabricSnapshot | None
    committed_chunks: int
    in_flight_chunks: int
    lost_chunks: int
    requeued_chunks: int

    @property
    def new_n(self) -> int:
        """World size the recovery plan targets."""
        return len(self.survivors)

    @property
    def link_offset(self) -> int | None:
        """Surviving link offset (the circuit the committed prefix parked
        every port on), or ``None`` when nothing committed."""
        return None if self.snapshot is None else self.snapshot.link_offset

    def dead_port_mask(self) -> tuple[bool, ...]:
        """Length-n mask: True where the port's circuit is dead."""
        dead = set(self.dead_ports)
        return tuple(i in dead for i in range(self.n))


# --- checkpoint helpers (FabricSnapshot <-> array tree) ------------------------


def snapshot_to_tree(snap: FabricSnapshot) -> dict:
    """`FabricSnapshot` as a flat array tree for `repro.checkpoint.store`."""
    import numpy as np

    return {
        "n": np.array(snap.n, dtype=np.int64),
        "link_offset": np.array(snap.link_offset, dtype=np.int64),
        "node_ready": np.array(snap.node_ready, dtype=np.float64),
        "port_free": np.array(snap.port_free, dtype=np.float64),
        "chunks_moved": np.array(snap.chunks_moved, dtype=np.int64),
        "reconfigs_paid": np.array(snap.reconfigs_paid, dtype=np.int64),
        "delta_stall": np.array(snap.delta_stall, dtype=np.float64),
    }


def tree_to_snapshot(tree: dict) -> FabricSnapshot:
    """Inverse of `snapshot_to_tree` (accepts `store.restore` output, whose
    keys carry the pytree path prefix)."""
    def leaf(name):
        for k, v in tree.items():
            if k.strip("'[]\"") == name or k.endswith(f"'{name}']"):
                return v
        raise KeyError(f"checkpoint tree missing {name!r}")

    return FabricSnapshot(
        n=int(leaf("n")), link_offset=int(leaf("link_offset")),
        node_ready=tuple(float(t) for t in leaf("node_ready")),
        port_free=tuple(float(t) for t in leaf("port_free")),
        chunks_moved=int(leaf("chunks_moved")),
        reconfigs_paid=int(leaf("reconfigs_paid")),
        delta_stall=float(leaf("delta_stall")))


def latest_snapshot(directory: str) -> FabricSnapshot | None:
    """Newest checkpointed `FabricSnapshot` under ``directory`` (written by
    `FabricSim.run_trace(..., checkpoint_dir=...)`), or None if empty."""
    from repro.checkpoint import store  # deferred: store imports jax

    step = store.latest_step(directory)
    if step is None:
        return None
    return tree_to_snapshot(store.restore(directory, step))
