"""Shared strict-JSON-loader helpers.

Every ``from_dict`` loader in the repo (traces, plan requests/results, serve
requests) validates its payload through these before constructing objects:
unknown fields and missing required fields fail *at the loader* with a
`ValueError` naming the offending keys, instead of deferring to an obscure
KeyError/TypeError deep inside a constructor — a corrupted or
version-skewed cached artifact should be rejected at the trust boundary it
crosses, not half-loaded.
"""
from __future__ import annotations

from typing import Mapping, Sequence


def require_keys(d: Mapping, *, required: Sequence[str],
                 optional: Sequence[str] = (), what: str = "object") -> None:
    """Reject payloads with missing required or unknown keys."""
    if not isinstance(d, Mapping):
        raise ValueError(f"{what} payload must be a JSON object, got "
                         f"{type(d).__name__}")
    missing = [k for k in required if k not in d]
    if missing:
        raise ValueError(f"{what} payload is missing required "
                         f"field(s) {missing}")
    allowed = set(required) | set(optional)
    unknown = sorted(k for k in d if k not in allowed)
    if unknown:
        raise ValueError(
            f"{what} payload has unknown field(s) {unknown}; expected a "
            f"subset of {sorted(allowed)}")


def require_positive_payload(m_bytes, what: str = "object") -> float:
    """Serialized payloads must be strictly positive finite byte counts.

    (In-memory zero-byte events are legal — e.g. a padding phase — but a
    stored/shipped plan with m_bytes <= 0 is a corrupt artifact.)
    """
    try:
        m = float(m_bytes)
    except (TypeError, ValueError):
        raise ValueError(
            f"{what} payload m_bytes must be a number, got {m_bytes!r}"
        ) from None
    if not m > 0.0 or m != m or m == float("inf"):
        raise ValueError(
            f"{what} payload m_bytes must be > 0 and finite, got {m_bytes!r}")
    return m
