"""Shared strict-JSON-loader helpers and the typed request vocabulary.

Every ``from_dict`` loader in the repo (traces, plan requests/results, serve
requests, shared-fabric requests) validates its payload through these before
constructing objects: unknown fields and missing required fields fail *at
the loader* with a `ValueError` naming the offending keys, instead of
deferring to an obscure KeyError/TypeError deep inside a constructor — a
corrupted or version-skewed cached artifact should be rejected at the trust
boundary it crosses, not half-loaded.

This module is also the home of the request vocabulary shared by every
request dataclass in the repo (`repro.planner.api.PlanRequest`,
`repro.workloads.serve.ServeRequest`,
`repro.workloads.tenancy.SharedFabricRequest`):

  - `FabricKind`  : the typed fabric selector that replaced the string
                    literals ``"static" | "ocs" | "ocs-overlap" | "ocs-sim"``
                    (bare strings still coerce, with a `DeprecationWarning`);
  - `SharingMode` : how K tenants share one fabric (`repro.workloads
                    .tenancy`): disjoint port partitions or whole-collective
                    time slices;
  - `RequestBase` : the validated base every request dataclass mixes in —
                    the n / r / m_bytes / CostModel / fabric / budget
                    validators and the CostModel (de)serialization are
                    defined once here, not re-grown per request type.

Both enums are ``str`` subclasses, so existing comparisons against the
literal values (``req.fabric == "ocs"``, membership in tuples of strings)
and ``json.dumps`` keep working unchanged; loaders round-trip them
losslessly (`to_dict` emits the plain value, `from_dict` re-coerces without
a warning — a stored artifact is canonical serialization, not deprecated
call-site usage).
"""
from __future__ import annotations

import dataclasses
import enum
import json
import warnings
from typing import TYPE_CHECKING, Mapping, Sequence

if TYPE_CHECKING:  # annotation-only: no import cycle with cost_model
    from .cost_model import CostModel


class _CoercibleStrEnum(str, enum.Enum):
    """str-valued enum with a deprecation-warning coercion shim."""

    # keep the *value* as the str()/f-string rendering on every Python
    # version (3.11 changed mixin-enum __str__/__format__ semantics)
    __str__ = str.__str__
    __format__ = str.__format__

    @classmethod
    def _noun(cls) -> str:
        """Human name used in validation messages (e.g. 'fabric')."""
        return cls.__name__

    @classmethod
    def coerce(cls, value, *, warn: bool = True):
        """Coerce ``value`` (member or bare string) to a member.

        Bare strings are accepted for compatibility but emit a
        `DeprecationWarning` unless ``warn=False`` (JSON loaders pass
        ``warn=False``: a stored artifact's string is the canonical
        serialization, not a deprecated call site).
        """
        if isinstance(value, cls):
            return value
        try:
            member = cls(value)
        except ValueError:
            raise ValueError(
                f"{cls._noun()} must be one of "
                f"{tuple(m.value for m in cls)}, got {value!r} "
                f"(pass a {cls.__name__} member)") from None
        if warn:
            warnings.warn(
                f"passing the bare string {value!r} is deprecated; pass "
                f"{cls.__name__}.{member.name} (from repro.planner.api)",
                DeprecationWarning, stacklevel=3)
        return member


class FabricKind(_CoercibleStrEnum):
    """Which fabric model a request is planned against.

    STATIC      : no OCS — only R=0 schedules are feasible.
    OCS         : reconfigurable fabric, flat delta per reconfiguration
                  (the paper's setting).
    OCS_OVERLAP : sparse reconfiguration with reconfiguration/communication
                  overlap (`CostModel.delta_sparse` per boundary).
    OCS_SIM     : event-scored planning through the vectorized batch fabric
                  engine (`core.batchsim`).
    """

    STATIC = "static"
    OCS = "ocs"
    OCS_OVERLAP = "ocs-overlap"
    OCS_SIM = "ocs-sim"

    @classmethod
    def _noun(cls) -> str:
        return "fabric"


class SharingMode(_CoercibleStrEnum):
    """How K concurrent tenants share one optical fabric.

    PORT_PARTITION : each tenant owns a disjoint subset of the fabric's
                     ports and runs its trace on its own sub-fabric; no
                     cross-tenant interference (isolation ratio 1.0).
    TIME_SLICE     : tenants interleave whole collectives on the full
                     fabric; tenant hand-offs are carryover boundaries
                     priced sparsely on the circuits that actually change.
    """

    PORT_PARTITION = "port-partition"
    TIME_SLICE = "time-slice"

    @classmethod
    def _noun(cls) -> str:
        return "sharing mode"


def require_keys(d: Mapping, *, required: Sequence[str],
                 optional: Sequence[str] = (), what: str = "object") -> None:
    """Reject payloads with missing required or unknown keys."""
    if not isinstance(d, Mapping):
        raise ValueError(f"{what} payload must be a JSON object, got "
                         f"{type(d).__name__}")
    missing = [k for k in required if k not in d]
    if missing:
        raise ValueError(f"{what} payload is missing required "
                         f"field(s) {missing}")
    allowed = set(required) | set(optional)
    unknown = sorted(k for k in d if k not in allowed)
    if unknown:
        raise ValueError(
            f"{what} payload has unknown field(s) {unknown}; expected a "
            f"subset of {sorted(allowed)}")


def validate_world(n: int, what: str = "request") -> int:
    """World sizes are >= 2 everywhere a collective is planned."""
    if n < 2:
        raise ValueError(f"{what}: need at least 2 nodes, got n={n}")
    return int(n)


def validate_radix(r: int, what: str = "request") -> int:
    if r < 2:
        raise ValueError(f"{what}: radix must be >= 2, got r={r}")
    return int(r)


def validate_payload_nonneg(m_bytes, what: str = "request") -> float:
    """In-memory payloads may be zero (padding phases); negatives never."""
    m = float(m_bytes)
    if m < 0:
        raise ValueError(f"{what}: payload must be >= 0, got m_bytes={m_bytes}")
    return m


def validate_budget(delta_budget, what: str = "request"):
    if delta_budget is not None and delta_budget < 0:
        raise ValueError(
            f"{what}: delta_budget must be >= 0, got {delta_budget}")
    return delta_budget


def validate_overlap(overlap: float, fabric, what: str = "request") -> float:
    """Overlap is a [0, 1] fraction, meaningful only on overlap fabrics."""
    if not 0.0 <= overlap <= 1.0:
        raise ValueError(f"{what}: overlap must be in [0, 1], got {overlap}")
    if overlap > 0.0 and fabric not in (FabricKind.OCS_OVERLAP,
                                        FabricKind.OCS_SIM):
        raise ValueError(
            f"{what}: overlap={overlap} requires fabric="
            f"'ocs-overlap' or 'ocs-sim', got fabric={str(fabric)!r}")
    return float(overlap)


def validate_init_g(init_g, fabric=None, what: str = "request"):
    """Inherited link offsets are positive, and need a reconfigurable fabric."""
    if init_g is None:
        return None
    if fabric is not None and fabric == FabricKind.STATIC:
        raise ValueError(
            f"{what}: init_g (inherited fabric state) requires a "
            f"reconfigurable fabric; a static fabric has no circuits to "
            f"carry over")
    if init_g < 1:
        raise ValueError(
            f"{what}: init_g must be a positive link offset, got {init_g}")
    return int(init_g)


def cost_model_to_dict(cm: "CostModel") -> dict:
    return {"alpha_s": cm.alpha_s, "alpha_h": cm.alpha_h,
            "bandwidth": cm.bandwidth, "delta": cm.delta}


def cost_model_from_dict(d: dict, what: str = "request") -> "CostModel":
    from .cost_model import CostModel  # deferred: jsonio must stay leaf-like

    require_keys(d, required=("alpha_s", "alpha_h", "bandwidth", "delta"),
                 what=f"{what}.cost_model")
    return CostModel(**d)


class RequestBase:
    """Validated base mixed into every request dataclass in the repo.

    Centralizes what `PlanRequest`, `ServeRequest`, and
    `SharedFabricRequest` used to each re-implement: the n / r / payload /
    budget / fabric / overlap validators (the ``validate_*`` helpers above)
    and the JSON envelope (`to_json` / `from_json` over the subclass's
    `to_dict` / `from_dict`).  Subclasses stay plain frozen dataclasses —
    the base deliberately declares no fields, so each request keeps its
    established field order and positional-construction compatibility.
    """

    def to_dict(self) -> dict:  # pragma: no cover - subclasses override
        raise NotImplementedError

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str):
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_dict(cls, d: dict):  # pragma: no cover - subclasses override
        raise NotImplementedError

    def _coerce_fabric(self, field: str = "fabric") -> None:
        """Coerce a dataclass fabric field in place (bare strings warn)."""
        value = getattr(self, field)
        object.__setattr__(self, field, FabricKind.coerce(value))

    def _validate_base(self) -> None:
        """Validate whichever of the shared fields this request declares."""
        what = type(self).__name__
        fields = {f.name for f in dataclasses.fields(self)}
        if "n" in fields:
            validate_world(self.n, what)
        if "r" in fields:
            validate_radix(self.r, what)
        if "m_bytes" in fields:
            object.__setattr__(
                self, "m_bytes", validate_payload_nonneg(self.m_bytes, what))
        if "delta_budget" in fields:
            validate_budget(self.delta_budget, what)
        if "fabric" in fields:
            self._coerce_fabric()
            if "overlap" in fields:
                validate_overlap(self.overlap, self.fabric, what)
            if "init_g" in fields:
                validate_init_g(self.init_g, self.fabric, what)
        elif "init_g" in fields:
            validate_init_g(self.init_g, None, what)


def require_positive_payload(m_bytes, what: str = "object") -> float:
    """Serialized payloads must be strictly positive finite byte counts.

    (In-memory zero-byte events are legal — e.g. a padding phase — but a
    stored/shipped plan with m_bytes <= 0 is a corrupt artifact.)
    """
    try:
        m = float(m_bytes)
    except (TypeError, ValueError):
        raise ValueError(
            f"{what} payload m_bytes must be a number, got {m_bytes!r}"
        ) from None
    if not m > 0.0 or m != m or m == float("inf"):
        raise ValueError(
            f"{what} payload m_bytes must be > 0 and finite, got {m_bytes!r}")
    return m
