"""Step-level completion-time simulator on evolving subring topologies.

Evaluates the paper's topology-aware alpha-beta-delta cost model (Section 2)
for a Bruck collective under a BRIDGE reconfiguration schedule by *explicitly*
walking the OCS topology of every step: hop counts come from routing on the
link graph and congestion from per-link flow loads (`validate=True`), or from
the equivalent closed forms h_k = c_k = msg_offset / link_offset (default;
asserted equal in tests).

This is the reproduction-level stand-in for the paper's Astra-Sim + ns-3
setup: the paper's own analysis (Sections 3.3-3.5) is derived in exactly this
cost model, so every theorem is checkable bit-for-bit (see tests/).
"""
from __future__ import annotations

import dataclasses

from .bruck import steps_for
from .cost_model import CostModel
from .schedules import Schedule
from .subrings import BlockedRing, Topology


@dataclasses.dataclass(frozen=True)
class StepCost:
    index: int
    hops: int
    congestion: float
    nbytes: float
    reconfigured: bool
    time: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "StepCost":
        return StepCost(**d)


@dataclasses.dataclass(frozen=True)
class TimeBreakdown:
    """Completion time split into the cost model's four terms."""

    startup: float
    hop_latency: float
    transmission: float
    reconfig: float
    steps: tuple[StepCost, ...] = ()

    @property
    def total(self) -> float:
        return self.startup + self.hop_latency + self.transmission + self.reconfig

    def __add__(self, other: "TimeBreakdown") -> "TimeBreakdown":
        return TimeBreakdown(
            startup=self.startup + other.startup,
            hop_latency=self.hop_latency + other.hop_latency,
            transmission=self.transmission + other.transmission,
            reconfig=self.reconfig + other.reconfig,
            steps=self.steps + other.steps,
        )

    def cumulative(self) -> list[float]:
        out, t = [], 0.0
        for sc in self.steps:
            t += sc.time
            out.append(t)
        return out

    def to_dict(self) -> dict:
        """Lossless plain-data form (floats survive JSON bit-exactly)."""
        return {
            "startup": self.startup,
            "hop_latency": self.hop_latency,
            "transmission": self.transmission,
            "reconfig": self.reconfig,
            "steps": [sc.to_dict() for sc in self.steps],
        }

    @staticmethod
    def from_dict(d: dict) -> "TimeBreakdown":
        return TimeBreakdown(
            startup=d["startup"],
            hop_latency=d["hop_latency"],
            transmission=d["transmission"],
            reconfig=d["reconfig"],
            steps=tuple(StepCost.from_dict(sc) for sc in d.get("steps", [])),
        )


def collective_time(
    schedule: Schedule,
    m: float,
    cm: CostModel,
    *,
    ports: int | None = None,
    validate: bool = False,
    mirrored: bool = False,
) -> TimeBreakdown:
    """Completion time of a Bruck collective under a reconfiguration schedule.

    ports: if set and < 2n, apply the Section 3.7 blocked-ring distance floor.
    validate: recompute hops/congestion by explicit routing on the topology.
    mirrored: paper Section 5 multiport extension — OCS circuits are
      bidirectional and Bruck uses each link in only one direction, so a
      mirrored copy of the collective runs concurrently on the reverse
      direction carrying half the payload: transmission halves, latency
      unchanged (applies equally to RING/HD/S-/G-BRUCK, so relative speedups
      are preserved).
    """
    n, kind = schedule.n, schedule.kind
    steps = steps_for(kind, n, m / 2 if mirrored else m, schedule.r)
    link = schedule.link_offsets(steps)
    blocked = BlockedRing(n=n, ports=ports) if ports is not None and ports < 2 * n else None

    startup = hop_lat = tx = 0.0
    per_step: list[StepCost] = []
    for st, g in zip(steps, link, strict=True):
        if st.offset % g:
            raise ValueError(f"invalid schedule: step {st.index} unreachable (offset "
                             f"{st.offset}, link {g})")
        if blocked is not None:
            h = blocked.effective_hops(st.offset, g)
        else:
            h = st.offset // g
        c = float(h)  # uniform-offset ring traffic: congestion == hops
        if validate and blocked is None:
            topo = Topology(n=n, g=g)
            h_routed = topo.hops(0, st.offset % n)
            c_routed = topo.max_link_load(st.offset)
            assert h_routed == h and c_routed == h, (h, h_routed, c_routed)
        t = cm.step_cost(hops=h, nbytes=st.nbytes, congestion=c)
        startup += cm.alpha_s
        hop_lat += h * cm.alpha_h
        tx += st.nbytes * c * cm.beta
        per_step.append(StepCost(st.index, h, c, st.nbytes, False, t))

    # mark reconfigured steps & charge delta
    recon_steps = [k for k, xk in enumerate(schedule.x) if xk]
    per_step = [
        dataclasses.replace(sc, reconfigured=(sc.index in recon_steps),
                            time=sc.time + (cm.delta if sc.index in recon_steps else 0.0))
        for sc in per_step
    ]
    return TimeBreakdown(
        startup=startup,
        hop_latency=hop_lat,
        transmission=tx,
        reconfig=schedule.R * cm.delta,
        steps=tuple(per_step),
    )


def collective_time_overlap(
    schedule: Schedule,
    m: float,
    cm: CostModel,
    overlap: float,
    *,
    ports: int | None = None,
) -> TimeBreakdown:
    """Analytic completion time with sparse-reconfiguration overlap credit.

    Identical to `collective_time` except for the reconfiguration term: each
    reconfiguration point is charged `CostModel.delta_sparse(changed,
    overlap)` — zero when the boundary reuses the previous segment's link
    offset, and `delta * (1 - overlap)` otherwise — instead of a flat
    delta.  This is the analytic counterpart of `fabricsim.FabricSim`'s
    per-link swap accounting, used by the planner's ``ocs-overlap`` fabric.
    """
    bd = collective_time(schedule, m, cm, ports=ports)
    changed = schedule.reconfig_changed_links()
    recon_steps = [sc.index for sc in bd.steps if sc.reconfigured]
    if len(recon_steps) != len(changed):
        raise RuntimeError(
            f"reconfigured step count {len(recon_steps)} != "
            f"boundary count {len(changed)}")
    sparse_by_step = {k: cm.delta_sparse(c, overlap)
                      for k, c in zip(recon_steps, changed, strict=True)}
    new_steps = tuple(
        dataclasses.replace(sc, time=sc.time - cm.delta + sparse_by_step[sc.index])
        if sc.reconfigured else sc
        for sc in bd.steps)
    return dataclasses.replace(bd, reconfig=sum(sparse_by_step.values()),
                               steps=new_steps)


def allreduce_time(
    rs_schedule: Schedule,
    ag_schedule: Schedule,
    m: float,
    cm: CostModel,
    *,
    ports: int | None = None,
) -> TimeBreakdown:
    """AllReduce via Rabenseifner decomposition: RS phase then AG phase.

    Charges one extra reconfiguration if the AG phase's initial topology
    differs from the RS phase's final topology (the paper's evaluation reports
    RS alone; we account for the transition explicitly, see DESIGN.md S8).
    """
    if rs_schedule.kind != "rs" or ag_schedule.kind != "ag":
        raise ValueError("expected an rs schedule and an ag schedule")
    if rs_schedule.n != ag_schedule.n:
        raise ValueError("mismatched n")
    t_rs = collective_time(rs_schedule, m, cm, ports=ports)
    t_ag = collective_time(ag_schedule, m, cm, ports=ports)
    rs_final = rs_schedule.link_offsets()[-1]
    ag_first = ag_schedule.link_offsets()[0]
    transition = cm.delta if rs_final != ag_first else 0.0
    return t_rs + t_ag + TimeBreakdown(0.0, 0.0, 0.0, transition)


def allreduce_time_overlap(
    rs_schedule: Schedule,
    ag_schedule: Schedule,
    m: float,
    cm: CostModel,
    overlap: float,
    *,
    ports: int | None = None,
) -> TimeBreakdown:
    """`allreduce_time` under the sparse-reconfiguration overlap credit.

    Both phases are scored with `collective_time_overlap`, and the RS->AG
    topology transition (when the AG phase's initial link offset differs
    from the RS phase's final one) is likewise a sparse swap of every
    circuit, charged `delta_sparse(n, overlap)`.
    """
    if rs_schedule.kind != "rs" or ag_schedule.kind != "ag":
        raise ValueError("expected an rs schedule and an ag schedule")
    if rs_schedule.n != ag_schedule.n:
        raise ValueError("mismatched n")
    t_rs = collective_time_overlap(rs_schedule, m, cm, overlap, ports=ports)
    t_ag = collective_time_overlap(ag_schedule, m, cm, overlap, ports=ports)
    rs_final = rs_schedule.link_offsets()[-1]
    ag_first = ag_schedule.link_offsets()[0]
    changed = rs_schedule.n if rs_final != ag_first else 0
    transition = cm.delta_sparse(changed, overlap)
    return t_rs + t_ag + TimeBreakdown(0.0, 0.0, 0.0, transition)
