"""BRIDGE core: reconfiguration-schedule synthesis for collective communication.

Paper: "BRIDGE: Optimizing Collective Communication Schedules in Reconfigurable
Networks with Reusable Subrings" (Juerss & Schmid, 2026).
"""
from . import baselines
from .batchsim import (BatchFabricResult, BatchLane, BatchTraceResult,
                       FabricSnapshot, ScheduleTape, TraceLane,
                       batch_completion_times, batch_run, batch_run_trace,
                       clear_tape_caches, compile_tape)
from .bruck import (Collective, Step, a2a_steps, ag_steps, is_pow2, num_steps,
                    rs_steps, schedule_length, simulate_a2a_data,
                    simulate_ag_data, simulate_rs_data, step_counts, steps_for)
from .cost_model import (CostModel, OCS_TECHNOLOGIES, PAPER_DEFAULT, TPU_V5E,
                         gbps, ocs_ports, ocs_preset)
from .fabricsim import (FabricResult, FabricSim, TraceFabricResult,
                        simulate_fabric, simulate_trace, straggler_speeds,
                        trace_boundary_changed)
from .faults import (ABRUPT_KINDS, DELIVERY_POLICIES, FAULT_KINDS,
                     DegradedState, FaultSpec, FaultTimeline, latest_snapshot,
                     random_timeline, snapshot_to_tree, tree_to_snapshot,
                     world_after)
from .schedules import (Plan, Schedule, SegmentTables, ag_transmission_optimal,
                        ag_transmission_optimal_all, candidate_schedules,
                        changed_links, clear_schedule_caches, cstar_a2a,
                        dp_stats, every_step_schedule, full_cost_optimal,
                        full_cost_optimal_all, periodic, periodic_a2a,
                        periodic_a2a_all, periodic_all, plan, reset_dp_stats,
                        rs_transmission_optimal, rs_transmission_optimal_all,
                        static_schedule)
from .simulator import (StepCost, TimeBreakdown, allreduce_time,
                        allreduce_time_overlap, collective_time,
                        collective_time_overlap)
from .subrings import BlockedRing, Topology, ring, subring_topology

__all__ = [
    "Collective", "Step", "a2a_steps", "ag_steps", "is_pow2", "num_steps",
    "rs_steps", "schedule_length", "simulate_a2a_data", "simulate_ag_data",
    "simulate_rs_data", "step_counts", "steps_for",
    "BatchFabricResult", "BatchLane", "BatchTraceResult", "FabricSnapshot",
    "ScheduleTape", "TraceLane", "batch_completion_times", "batch_run",
    "batch_run_trace", "clear_tape_caches", "compile_tape",
    "OCS_TECHNOLOGIES", "PAPER_DEFAULT", "TPU_V5E", "CostModel", "gbps",
    "ocs_ports", "ocs_preset",
    "Plan", "Schedule", "SegmentTables", "ag_transmission_optimal",
    "ag_transmission_optimal_all", "candidate_schedules", "changed_links",
    "clear_schedule_caches", "cstar_a2a", "dp_stats", "every_step_schedule",
    "full_cost_optimal", "full_cost_optimal_all", "periodic", "periodic_a2a",
    "periodic_a2a_all", "periodic_all", "plan", "reset_dp_stats",
    "rs_transmission_optimal", "rs_transmission_optimal_all",
    "static_schedule",
    "FabricResult", "FabricSim", "TraceFabricResult", "simulate_fabric",
    "simulate_trace", "trace_boundary_changed", "straggler_speeds",
    "ABRUPT_KINDS", "DELIVERY_POLICIES", "FAULT_KINDS", "DegradedState",
    "FaultSpec", "FaultTimeline", "latest_snapshot", "random_timeline",
    "snapshot_to_tree", "tree_to_snapshot", "world_after",
    "StepCost", "TimeBreakdown", "allreduce_time", "allreduce_time_overlap",
    "collective_time", "collective_time_overlap",
    "BlockedRing", "Topology", "ring", "subring_topology", "baselines",
]
