"""JAX ``jit``/``vmap`` backend for certified tape playback.

`batchsim._play` is a NumPy loop nest: Python iterates steps and hop streams,
NumPy vectorizes the ``[B, n, C]`` grid inside each hop.  At n in the
thousands the per-hop Python dispatch and the guards' bookkeeping dominate;
this module lowers the *certified* subset of that playback to XLA:

  - the `ScheduleTape` stacks (``counts``/``g_step``/``hops``/``changed``)
    become device arrays with static shapes per ``(n, C)`` bucket,
  - the per-lane step loop becomes a `lax.scan` over S steps (carry: the
    per-port busy-until vector ``F`` and last-receive vector ``recv``),
  - the hop streams become a `lax.while_loop`, chunks an inner `lax.scan`,
  - `jax.vmap` maps the lane over the batch axis and `jax.jit` compiles the
    whole playback once per distinct ``(B, S, n, C)`` shape.

Soundness gate.  The kernel has *no* canonical-order guards and *no* skew
knobs — it is only called for lanes holding a static fast-path certificate
(`repro.analysis.certifier`), which proves the guards could not have tripped
and implies the lane is uniform (no ``link_speed`` / ``payload_scale``).
Uncertified lanes never reach this module: `batchsim.batch_run` keeps routing
them through the guarded NumPy playback with the scalar-oracle fallback.

Exactness.  Everything runs in float64 (`jax.experimental.enable_x64` is
entered around each playback call, so the x64 mode never leaks into other
jax users in the process) and the kernel performs the same float ops in the
same order as `_play`: service ``f = max(f, arrival) + tau`` per chunk,
``tau = (nb / C) * beta``, gather by ``(port - g) % n``, ``+ alpha_h`` per
hop, ``+ alpha_s`` per injection, ``delta_eff`` charged at rewiring
boundaries.  On CPU the result is bit-identical to the NumPy engine, and
deterministic run-to-run (the differential suite pins both).

Hop bucketing.  ``vmap`` runs every lane through the *longest* lane's
``while_loop`` trip count, so one 2000-hop static-schedule lane would drag a
whole batch of ~50-hop lanes through 40x the work.  `play_certified` sorts
lanes by total hops and splits the batch into a few contiguous buckets, each
jitted at its own shape — measured ~4x over the unbucketed call on wide
candidate sets, at the cost of at most `max_buckets` compilations per
``(n, C)``.

Importing this module never requires jax (`repro.collectives._compat`
guards the probe); `jax_available()` tells callers whether the backend can
actually run.  See docs/batch_engine.md for the full performance model.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.collectives._compat import HAS_JAX, require_jax
from .cost_model import CostModel

# trace_count increments only when XLA traces (= compiles) the kernel for a
# new shape; calls counts every playback dispatch.  The jit-cache test pins
# trace_count flat across repeated same-shape batches.
_STATS = {"trace_count": 0, "calls": 0}


def jax_available() -> bool:
    """True when the jax import probe succeeded (backend can run)."""
    return HAS_JAX


def compile_stats() -> dict:
    """Snapshot of {'trace_count', 'calls'} — kernel (re)compilations vs
    playback dispatches since import / `reset_compile_stats`."""
    return dict(_STATS)


def reset_compile_stats() -> None:
    _STATS["trace_count"] = 0
    _STATS["calls"] = 0


@functools.lru_cache(maxsize=1)
def _kernel():
    """Build (once) the jitted, vmapped playback kernel.

    Deferred so importing this module never touches jax; the first certified
    playback pays the closure construction, every later call reuses the same
    jit object and therefore XLA's per-shape compile cache.
    """
    jax = require_jax("the JAX batch backend (backend='jax')")
    import jax.numpy as jnp
    from jax import lax

    @functools.partial(jax.jit, static_argnames=("n", "C"))
    def play(nb, g, h, changed, delta_eff, alpha_s, alpha_h, beta, n, C):
        # Python side effect: fires at trace time only, so this counts XLA
        # compilations, not dispatches
        _STATS["trace_count"] += 1
        ports = jnp.arange(n)

        def lane(nb_l, g_l, h_l, ch_l, de_l):
            def step(carry, xs):
                F, recv = carry
                nbk, gk, hk, chk = xs
                # rewiring boundary: every port stalls delta_eff (k=0 never
                # charges — the host zeroes changed[:, 0])
                F = F + jnp.where(chk, de_l, 0.0)
                inj = recv + alpha_s          # recv is 0 at k=0 -> alpha_s
                tau = (nbk / C) * beta        # uniform: no speed/scale skew
                idx = (ports - gk) % n
                arr = jnp.broadcast_to(inj[None, :], (C, n))

                def cond(st):
                    return st[0] < hk

                def hop(st):
                    j, arr_h, F_h, recv_h = st

                    def chunk(f, a_c):
                        f = jnp.maximum(f, a_c) + tau
                        return f, f

                    f, comp = lax.scan(chunk, F_h, arr_h)
                    nxt = comp[:, idx] + alpha_h
                    recv_h = jnp.where(j + 1 >= hk, nxt[C - 1], recv_h)
                    return j + 1, nxt, f, recv_h

                _, _, F, recv = lax.while_loop(
                    cond, hop, (jnp.zeros((), dtype=h_l.dtype), arr, F, recv))
                return (F, recv), recv.max()

            (F, recv), sd = lax.scan(
                step, (jnp.zeros(n), jnp.zeros(n)), (nb_l, g_l, h_l, ch_l))
            return recv, sd, F

        return jax.vmap(lane)(nb, g, h, changed, delta_eff)

    return play


def _bucket_indices(hops: np.ndarray, max_buckets: int,
                    min_bucket_size: int) -> list[np.ndarray]:
    """Contiguous lane buckets of ascending total hop count.

    The stable sort keeps equal-work lanes in input order; small batches stay
    in one bucket (a bucket per handful of lanes would just multiply compile
    cost without shortening anyone's while_loop).
    """
    order = np.argsort(hops.sum(axis=1), kind="stable")
    k = max(1, min(int(max_buckets), len(order) // max(1, int(min_bucket_size))))
    return [idx for idx in np.array_split(order, k) if idx.size]


def play_certified(*, n: int, C: int, cm: CostModel, nb_step: np.ndarray,
                   g_step: np.ndarray, hops: np.ndarray, changed: np.ndarray,
                   delta_eff: np.ndarray, max_buckets: int = 4,
                   min_bucket_size: int = 32):
    """Guard-free playback of a certified-lane batch on the XLA backend.

    Inputs are the same ``[B, S]`` tape stacks `batchsim.batch_run` builds
    (``nb_step`` per-node payload bytes, ``g_step`` link offsets, ``hops``
    per-step hop counts, ``changed`` rewiring-boundary mask, per-lane
    ``delta_eff``).  Every lane MUST hold a static fast-path certificate —
    the caller (`batch_run`) enforces this; uniformity is what licenses
    dropping the per-port speed/scale arrays and the runtime guards.

    Returns ``(node_done [B, n], step_done [B, S], port_free [B, n])`` as
    NumPy float64 arrays in the original lane order (bucketing is internal).
    """
    require_jax("the JAX batch backend (backend='jax')")
    from jax.experimental import enable_x64

    B, S = nb_step.shape
    play = _kernel()
    node_done = np.empty((B, n))
    step_done = np.empty((B, S))
    port_free = np.empty((B, n))
    nb = np.ascontiguousarray(nb_step, dtype=np.float64)
    g = np.ascontiguousarray(g_step, dtype=np.int64)
    h = np.ascontiguousarray(hops, dtype=np.int64)
    ch = np.ascontiguousarray(changed, dtype=bool)
    ch[:, 0] = False          # step 0 never charges delta (x[0] == 0)
    de = np.ascontiguousarray(delta_eff, dtype=np.float64)
    _STATS["calls"] += 1
    # x64 as a context, not a global flag: float64 playback without leaking
    # the mode into unrelated jax users in the same process
    with enable_x64():
        for idx in _bucket_indices(h, max_buckets, min_bucket_size):
            nd, sd, pf = play(nb[idx], g[idx], h[idx], ch[idx], de[idx],
                              cm.alpha_s, cm.alpha_h, cm.beta, n=n, C=C)
            node_done[idx] = np.asarray(nd)
            step_done[idx] = np.asarray(sd)
            port_free[idx] = np.asarray(pf)
    return node_done, step_done, port_free
