"""Bruck communication-step structure for All-to-All / Reduce-Scatter / AllGather.

Paper Section 3.1:
  - n nodes (power of two for scheduling; arbitrary n supported for the static
    algorithm), s = ceil(log2 n) steps.
  - All-to-All:      step k: node u -> u + 2^k (mod n), data m/2 per step
                     (for 2^{s-1} < n < 2^s the last step sends (m/n)(n - 2^{s-1})).
  - Reduce-Scatter:  same offsets; data m_k = m / 2^{k+1} (halves every step).
  - AllGather:       reversed: offset 2^{s-1-k}; data m_k = m / 2^{s-k}
                     (starts at m/n, doubles every step).

``m`` is the total per-node payload in bytes (the collective's message size as
used throughout the paper's evaluation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import numpy as np

Collective = Literal["a2a", "rs", "ag"]


def num_steps(n: int) -> int:
    if n < 2:
        raise ValueError(f"need at least 2 nodes, got {n}")
    return int(math.ceil(math.log2(n)))


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class Step:
    """One Bruck communication step: every node u sends to (u + offset) mod n."""

    index: int
    offset: int
    nbytes: float


def a2a_steps(n: int, m: float) -> list[Step]:
    """All-to-All: constant m/2 per step (last step reduced for non-pow2 n)."""
    s = num_steps(n)
    steps = []
    for k in range(s):
        if k == s - 1 and not is_pow2(n):
            nbytes = (m / n) * (n - 2 ** (s - 1))
        else:
            nbytes = m / 2
        steps.append(Step(index=k, offset=2**k, nbytes=nbytes))
    return steps


def rs_steps(n: int, m: float) -> list[Step]:
    """Reduce-Scatter: data halves every step, offsets double (paper 3.4)."""
    if not is_pow2(n):
        raise ValueError("Reduce-Scatter scheduling assumes power-of-two n (paper 3.1)")
    s = num_steps(n)
    return [Step(index=k, offset=2**k, nbytes=m / 2 ** (k + 1)) for k in range(s)]


def ag_steps(n: int, m: float) -> list[Step]:
    """AllGather: reverse of Reduce-Scatter (paper 3.5).

    Step k: offset 2^{s-1-k}, data m/2^{s-k} (starts m/n, doubles).
    """
    if not is_pow2(n):
        raise ValueError("AllGather scheduling assumes power-of-two n (paper 3.1)")
    s = num_steps(n)
    return [Step(index=k, offset=2 ** (s - 1 - k), nbytes=m / 2 ** (s - k)) for k in range(s)]


def steps_for(kind: Collective, n: int, m: float) -> list[Step]:
    return {"a2a": a2a_steps, "rs": rs_steps, "ag": ag_steps}[kind](n, m)


# --- Executable reference of Bruck All-to-All data movement -----------------
#
# Used by tests to prove the *algorithm* (which blocks move at which step)
# delivers every block to its destination regardless of the reconfiguration
# schedule (the schedule changes only the cost of a step, never its payload).


def simulate_a2a_data(n: int) -> np.ndarray:
    """Run Bruck all-to-all over integer block ids; return received matrix.

    Node i starts with blocks ``block[i, j] = i * n + j`` destined for node j.
    Returns ``recv`` with ``recv[j, i]`` = the block node j received from node i.
    Correct iff ``recv[j, i] == i * n + j``.
    """
    s = num_steps(n)
    # Phase 1 (local rotation): node i stores block for destination (i + j) % n
    # at local slot j.
    buf = np.empty((n, n), dtype=np.int64)
    for i in range(n):
        for j in range(n):
            buf[i, j] = i * n + (i + j) % n
    # Phase 2: s rounds. In round k, node i sends every slot j whose k-th bit
    # is set to node (i + 2^k) % n (paper uses u + 2^k; directions are
    # symmetric) and keeps the rest.
    for k in range(s):
        send_slots = [j for j in range(n) if (j >> k) & 1]
        new_buf = buf.copy()
        for i in range(n):
            dst = (i + 2**k) % n
            new_buf[dst, send_slots] = buf[i, send_slots]
        buf = new_buf
    # Phase 3 (inverse rotation): slot j at node i now holds the block destined
    # for i that originated at node (i - j) % n.
    recv = np.empty((n, n), dtype=np.int64)
    for i in range(n):
        for j in range(n):
            recv[i, (i - j) % n] = buf[i, j]
    return recv


def simulate_rs_data(n: int) -> np.ndarray:
    """Run the Bruck-pattern reduce-scatter over one-hot contribution vectors.

    Node i contributes the indicator row e_i for every destination block.
    After reduce-scatter, node j must own block j reduced over all nodes,
    i.e. a row of all ones.  Returns ``owned`` of shape (n, n) where
    ``owned[j]`` is node j's reduced block-j vector.

    Block propagation (paper 3.4 / Thakur'05 adapted to the cyclic pattern):
    in step k (offset 2^k), node u sends to u + 2^k the partial sums of every
    block b for which the k-th bit of (b - u) mod n is *not* ... we use the
    standard recursive-halving assignment on the cyclic pattern: node u keeps
    blocks whose offset (b - u) mod n has zero low bits up to k.
    """
    s = num_steps(n)
    if not is_pow2(n):
        raise ValueError("power-of-two n required")
    # partial[u, b, :] = current partial-sum vector node u holds for block b
    partial = np.zeros((n, n, n), dtype=np.int64)
    for u in range(n):
        partial[u, :, u] = 1  # u contributes e_u to every block
    active = [[True] * n for _ in range(n)]  # active[u][b]: u still holds block b
    for k in range(s):
        off = 2**k
        new_partial = partial.copy()
        new_active = [row[:] for row in active]
        for u in range(n):
            dst = (u + off) % n
            for b in range(n):
                if not active[u][b]:
                    continue
                # Send block b onward if its remaining path from u requires the
                # 2^k hop, i.e. bit k of (b - u) mod n is set.
                if ((b - u) % n >> k) & 1:
                    new_partial[dst, b] += partial[u, b]
                    new_active[u][b] = False
        partial, active = new_partial, new_active
    owned = np.empty((n, n), dtype=np.int64)
    for b in range(n):
        owned[b] = partial[b, b]
    return owned
