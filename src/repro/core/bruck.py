"""Bruck communication-step structure for All-to-All / Reduce-Scatter /
AllGather, generalized to arbitrary world sizes n and radix r.

Paper Section 3.1 derives the radix-2 pattern on n = 2^s nodes; this module
implements the mixed-radix generalization that the paper's last paragraph of
Section 3.1 sketches (multiport = radix-(p+1)) and that arbitrary cluster
sizes (48, 96, 384, ...) require:

  - s = ceil(log_r n) digit *phases*; phase k has place value w_k = r^k
    (so offsets are prod of the radixes of all lower phases).
  - Phase k consists of up to r-1 *sub-steps*, one per nonzero digit value
    j in 1..r-1, with message offset j * r^k.  Steps whose digit class is
    empty for this n (j * r^k >= n) are elided.
  - All-to-All:      sub-step (k, j) moves every block whose relative
                     destination offset d = (dst - src) mod n has k-th
                     base-r digit equal to j; each block moves once per
                     nonzero digit of d, so total displacement is exactly d.
  - Reduce-Scatter:  same offsets; sub-step (k, j) forwards the partial sums
                     of blocks whose remaining offset is j * r^k + (higher
                     digits), i.e. d % r^k == 0 and digit_k(d) == j.  Data
                     shrinks every phase (recursive-r-ing).
  - AllGather:       exact time-reverse of Reduce-Scatter: descending place
                     values, data grows every phase.

For r = 2 and n = 2^s each phase has one sub-step at offset 2^k carrying
m/2 (A2A), m/2^{k+1} (RS), m/2^{s-k} (AG) — bit-identical to the paper and
to the seed implementation.  For 2^{s-1} < n < 2^s radix-2 A2A volumes are
the exact digit-class sizes (m/n)·#{d < n : bit_k(d) = 1}: the last step
carries (m/n)(n - 2^{s-1}) as in the paper, while intermediate truncated
classes carry less than the m/2 the paper's closed form assumes (the paper
only models the last step as truncated; the executable algorithm moves
exactly the digit-class blocks, so the exact counts are used throughout).

``m`` is the total per-node payload in bytes (the collective's message size
as used throughout the paper's evaluation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import numpy as np

Collective = Literal["a2a", "rs", "ag"]


def num_steps(n: int, r: int = 2) -> int:
    """Number of digit phases s = ceil(log_r n), computed exactly."""
    if n < 2:
        raise ValueError(f"need at least 2 nodes, got {n}")
    if r < 2:
        raise ValueError(f"radix must be >= 2, got {r}")
    s, v = 0, 1
    while v < n:
        v *= r
        s += 1
    return s


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def digit(d: int, k: int, r: int) -> int:
    """k-th base-r digit of d."""
    return (d // r**k) % r


def a2a_digit_count(n: int, k: int, j: int, r: int) -> int:
    """#{d in [0, n): digit_k(d) == j} — blocks moved by A2A sub-step (k, j)."""
    w = r**k
    cycle = w * r
    full = (n // cycle) * w
    rem = n % cycle
    return full + min(max(rem - j * w, 0), w)


def rs_digit_count(n: int, k: int, j: int, r: int) -> int:
    """#{d in [0, n): d % r^k == 0 and digit_k(d) == j} — RS sub-step (k, j).

    These are the blocks whose remaining relative offset at phase k starts
    with digit j: the partial sums forwarded by sub-step (k, j).
    """
    w = r**k
    t = -(-n // w)  # ceil(n / w): multiples of w below n
    return t // r + (1 if t % r > j else 0)


@dataclasses.dataclass(frozen=True)
class Step:
    """One Bruck communication sub-step: node u sends to (u + offset) mod n.

    ``phase`` is the digit position k and ``digit`` the digit value j, so
    offset == digit * r**phase for the generating radix r.
    """

    index: int
    offset: int
    nbytes: float
    phase: int = 0
    digit: int = 1


def a2a_steps(n: int, m: float, r: int = 2) -> list[Step]:
    """All-to-All sub-steps. Radix 2: constant m/2 per step (last step
    reduced for non-pow2 n); general r: (m/n) * |digit class| per sub-step."""
    s = num_steps(n, r)
    steps: list[Step] = []
    for k in range(s):
        for j in range(1, r):
            cnt = a2a_digit_count(n, k, j, r)
            if cnt == 0:
                continue
            steps.append(Step(index=len(steps), offset=j * r**k,
                              nbytes=m * cnt / n, phase=k, digit=j))
    return steps


def rs_steps(n: int, m: float, r: int = 2) -> list[Step]:
    """Reduce-Scatter sub-steps: data shrinks every phase, offsets grow
    (paper 3.4, generalized to arbitrary n / radix r)."""
    s = num_steps(n, r)
    steps: list[Step] = []
    for k in range(s):
        for j in range(1, r):
            cnt = rs_digit_count(n, k, j, r)
            if cnt == 0:
                continue
            steps.append(Step(index=len(steps), offset=j * r**k,
                              nbytes=m * cnt / n, phase=k, digit=j))
    return steps


def ag_steps(n: int, m: float, r: int = 2) -> list[Step]:
    """AllGather: exact time-reverse of Reduce-Scatter (paper 3.5).

    Radix 2 / pow2: step k has offset 2^{s-1-k} and data m/2^{s-k}
    (starts at m/n, doubles every step) — the seed's sequence.
    """
    rev = list(reversed(rs_steps(n, m, r)))
    return [dataclasses.replace(st, index=i) for i, st in enumerate(rev)]


@functools.lru_cache(maxsize=None)
def step_counts(kind: Collective, n: int, r: int = 2) -> tuple[tuple[int, int, int, int], ...]:
    """m-independent sub-step structure: (offset, block_count, phase, digit).

    The payload of sub-step k is always ``m * block_count / n`` (the digit
    class carries ``block_count`` of the n per-node blocks), so the full step
    sequence for any m is one multiplication away.  Memoized: this is what
    `steps_for` re-derived from scratch on every simulator/planner call, which
    profiling showed dominating sweep loops.
    """
    gen = {"a2a": a2a_steps, "rs": rs_steps, "ag": ag_steps}[kind]
    # Generate with m = n so nbytes == block_count exactly (integers in float).
    return tuple((st.offset, int(st.nbytes), st.phase, st.digit)
                 for st in gen(n, float(n), r))


def steps_for(kind: Collective, n: int, m: float, r: int = 2) -> list[Step]:
    """Sub-step sequence of a collective at payload m (cached structure).

    Bit-identical to calling the per-kind generators directly: the payload is
    computed as ``m * count / n`` in the same expression order.
    """
    return [Step(index=i, offset=off, nbytes=m * cnt / n, phase=ph, digit=dg)
            for i, (off, cnt, ph, dg) in enumerate(step_counts(kind, n, r))]


def schedule_length(kind: Collective, n: int, r: int = 2) -> int:
    """Number of sub-steps of a collective — the length of a Schedule's x.

    Identical for all three kinds at fixed (n, r): a digit class (k, j) is
    non-empty iff j * r^k < n, for A2A and RS alike (and AG is reversed RS).
    For r = 2 this equals num_steps(n) for every n.
    """
    s = num_steps(n, r)
    return sum(1 for k in range(s) for j in range(1, r) if j * r**k < n)


# --- Executable reference of Bruck data movement -----------------------------
#
# Used by tests to prove the *algorithm* (which blocks move at which sub-step)
# delivers every block to its destination for arbitrary n and radix r,
# regardless of the reconfiguration schedule (the schedule changes only the
# cost of a step, never its payload).


def simulate_a2a_data(n: int, r: int = 2) -> np.ndarray:
    """Run radix-r Bruck all-to-all over integer block ids; return received
    matrix.

    Node i starts with blocks ``block[i, j] = i * n + j`` destined for node j.
    Returns ``recv`` with ``recv[j, i]`` = the block node j received from
    node i.  Correct iff ``recv[j, i] == i * n + j``.
    """
    s = num_steps(n, r)
    # Phase 1 (local rotation): node i stores block for destination (i + d) % n
    # at local slot d.
    buf = np.empty((n, n), dtype=np.int64)
    for i in range(n):
        for d in range(n):
            buf[i, d] = i * n + (i + d) % n
    # Phase 2: digit phases. In sub-step (k, j), node i sends every slot d
    # whose k-th base-r digit equals j to node (i + j * r^k) % n.
    for k in range(s):
        for j in range(1, r):
            send_slots = [d for d in range(n) if digit(d, k, r) == j]
            if not send_slots:
                continue
            new_buf = buf.copy()
            for i in range(n):
                dst = (i + j * r**k) % n
                new_buf[dst, send_slots] = buf[i, send_slots]
            buf = new_buf
    # Phase 3 (inverse rotation): slot d at node i now holds the block
    # destined for i that originated at node (i - d) % n.
    recv = np.empty((n, n), dtype=np.int64)
    for i in range(n):
        for d in range(n):
            recv[i, (i - d) % n] = buf[i, d]
    return recv


def simulate_rs_data(n: int, r: int = 2) -> np.ndarray:
    """Run the Bruck-pattern reduce-scatter over one-hot contribution vectors.

    Node i contributes the indicator row e_i for every destination block.
    After reduce-scatter, node b must own block b reduced over all nodes,
    i.e. a row of all ones.  Returns ``owned`` of shape (n, n) where
    ``owned[b]`` is node b's reduced block-b vector.

    Block propagation (paper 3.4 generalized): the partial sum for block b
    held at node u travels the base-r digit decomposition of d = (b - u)
    mod n, least-significant digit first.  In sub-step (k, j), node u
    forwards every active block whose remaining offset d has zero digits
    below k and digit_k(d) == j to u + j * r^k; the receiver merges it into
    its own partial at remaining offset d - j * r^k.
    """
    s = num_steps(n, r)
    # partial[u, b, :] = current partial-sum vector node u holds for block b
    partial = np.zeros((n, n, n), dtype=np.int64)
    for u in range(n):
        partial[u, :, u] = 1  # u contributes e_u to every block
    active = [[True] * n for _ in range(n)]  # active[u][b]: u still holds b
    for k in range(s):
        w = r**k
        for j in range(1, r):
            off = j * w
            if off >= n:
                continue
            new_partial = partial.copy()
            new_active = [row[:] for row in active]
            for u in range(n):
                dst = (u + off) % n
                for b in range(n):
                    if not active[u][b]:
                        continue
                    d = (b - u) % n
                    if d % w == 0 and digit(d, k, r) == j:
                        new_partial[dst, b] += partial[u, b]
                        new_active[u][b] = False
            partial, active = new_partial, new_active
    owned = np.empty((n, n), dtype=np.int64)
    for b in range(n):
        owned[b] = partial[b, b]
    return owned


def simulate_ag_data(n: int, r: int = 2) -> np.ndarray:
    """Run the Bruck-pattern all-gather over integer block ids.

    Node i starts with its own block id i.  Returns ``held`` of shape (n, n)
    where ``held[u, p]`` is the block node u ended up holding for source p.
    Correct iff ``held[u, p] == p`` for all u, p.

    Time-reverse of reduce-scatter: descending place values; in sub-step
    (k, j) every node sends the blocks at relative offsets d with
    d % r^{k+1} == 0 and d + j * r^k < n; the receiver stores them at
    relative offset d + j * r^k.
    """
    s = num_steps(n, r)
    NONE = -1
    # buf[u, d] = block of node (u - d) mod n, or NONE if not yet held
    buf = np.full((n, n), NONE, dtype=np.int64)
    buf[:, 0] = np.arange(n)
    for k in range(s - 1, -1, -1):
        w = r**k
        for j in range(1, r):
            off = j * w
            send = [d for d in range(0, n, w * r) if d + off < n]
            if not send:
                continue
            new_buf = buf.copy()
            for u in range(n):
                dst = (u + off) % n
                for d in send:
                    assert buf[u, d] != NONE, (u, d, k, j)
                    new_buf[dst, d + off] = buf[u, d]
            buf = new_buf
    held = np.empty((n, n), dtype=np.int64)
    for u in range(n):
        for d in range(n):
            held[u, (u - d) % n] = buf[u, d]
    return held
