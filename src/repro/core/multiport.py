"""Multiport Bruck (paper Section 3.1, last paragraph).

With p local ports per node, p independent communication offsets run in
parallel within one step, collapsing Bruck to ceil(log_{p+1} n) steps: the
radix-(p+1) generalization.  In step k, node u sends to the p peers
u + j * (p+1)^k (j = 1..p) simultaneously; data for All-to-All is the blocks
whose destination's k-th radix-(p+1) digit equals j.

This module reuses the mixed-radix step generation in `bruck.py`: a
multiport *step* is one digit phase, executing all of the phase's sub-steps
(one per digit value) concurrently on separate port pairs.  Sub-step data
volumes are the exact digit-class sizes, so arbitrary n is supported.

Subring structure generalizes: reconfiguring at phase k forms interleaved
sub-fabrics (residues mod (p+1)^k); all later offsets are multiples of
(p+1)^k, so reachability and reuse (Conditions 1-3) carry over.

Cost model per step (single-port-per-peer serialization, the paper's
convention): each of the p transfers uses its own port pair, so a step costs
  alpha_s + max_j [ h_{k,j} * alpha_h + m_{k,j} * c_{k,j} * beta ].
"""
from __future__ import annotations

import itertools

from .bruck import a2a_steps, num_steps
from .cost_model import CostModel
from .simulator import StepCost, TimeBreakdown


def num_steps_multiport(n: int, p: int) -> int:
    if p < 1:
        raise ValueError("need p >= 1 ports")
    return num_steps(n, p + 1) if n > 1 else 0


def a2a_multiport_time(
    n: int, m: float, p: int, cm: CostModel, reconfigure_every: int = 0
) -> TimeBreakdown:
    """All-to-All with radix-(p+1) Bruck and optional periodic reconfiguration.

    reconfigure_every = r > 0 reconfigures before phases r, 2r, ... (the
    periodic-optimal structure of Theorem 3.2 applies unchanged: segment cost
    is convex in length for any radix).  r = 0 means static.
    """
    radix = p + 1
    startup = hop_lat = tx = 0.0
    steps: list[StepCost] = []
    n_reconf = 0
    link = 1  # current link offset (smallest offset of the active segment)
    by_phase = itertools.groupby(a2a_steps(n, m, radix), key=lambda st: st.phase)
    for k, phase_steps in by_phase:
        reconf = reconfigure_every and k and k % reconfigure_every == 0
        if reconf:
            link = radix ** k
            n_reconf += 1
        # per-port transfer j: offset j*radix^k, volume = its digit-class size
        worst = 0.0
        h_max = 0
        m_max = 0.0
        for st in phase_steps:
            h = max(1, st.offset // link)
            t_j = h * cm.alpha_h + st.nbytes * h * cm.beta  # c = h on rings
            if t_j > worst:
                worst, h_max, m_max = t_j, h, st.nbytes
        startup += cm.alpha_s
        hop_lat += h_max * cm.alpha_h
        tx += worst - h_max * cm.alpha_h
        steps.append(StepCost(k, h_max, float(h_max), m_max, bool(reconf),
                              cm.alpha_s + worst))
    return TimeBreakdown(startup, hop_lat, tx, n_reconf * cm.delta,
                         tuple(steps))
