"""Event-driven message-level simulator (the paper's ns-3 role, in miniature).

The analytic simulator (simulator.py) evaluates the closed-form cost model.
This module cross-checks it with a chunk-level discrete-event simulation on
the explicit link graph: messages are split into chunks (MTU-like knob),
links serve one chunk at a time (FIFO), chunks store-and-forward with
per-hop latency alpha_h, and a step completes when every destination holds
its full message.  Reconfigurations pause the fabric for delta.

Relationship to the cost model (tested in tests/test_eventsim.py):
  - with many chunks, pipelining makes the event time converge to
    alpha_h * h + beta * m * c  per step (c = h for uniform-offset ring
    traffic): the Section 2 model is exactly the fluid limit;
  - with one chunk (no pipelining) it degrades to h * (alpha_h + beta*m),
    bracketing the model from above.

This is the reproduction-honesty layer: BRIDGE/baseline *ratios* measured at
event level must match the analytic figures (Figs 5-12) within tolerance.

`collective_time_event` is a thin compatibility wrapper over
`fabricsim.FabricSim` in full-pause mode (synchronized steps, whole-fabric
delta pauses); the asynchronous per-link fabric with sparse reconfiguration
and overlap credit lives in `fabricsim.py`.
"""
from __future__ import annotations

import dataclasses
import heapq

from .cost_model import CostModel
from .schedules import Schedule


@dataclasses.dataclass(frozen=True)
class EventStepResult:
    completion: float
    max_link_busy: float
    chunks_moved: int


def simulate_step(
    n: int,
    link_offset: int,
    msg_offset: int,
    nbytes: float,
    cm: CostModel,
    chunks_per_msg: int = 32,
    link_speed: list[float] | None = None,
) -> EventStepResult:
    """One synchronized communication step on topology {u -> u+link_offset}.

    Every node u sends `nbytes` to (u + msg_offset) % n, routed along the
    uniform-offset links (store-and-forward).  Returns the completion time
    (excluding alpha_s, added by the caller).

    link_speed[u]: relative rate of the optical egress at node u (1.0 =
    nominal; < 1 models a degraded transceiver / straggler).
    """
    if msg_offset % link_offset:
        raise ValueError("destination unreachable on this topology")
    if link_speed is not None and len(link_speed) != n:
        raise ValueError(
            f"link_speed has length {len(link_speed)} != n={n}; per-node "
            f"rates would be misattributed")
    hops = msg_offset // link_offset
    if hops == 0 or nbytes <= 0:
        return EventStepResult(0.0, 0.0, 0)
    k = max(1, int(chunks_per_msg))
    chunk = nbytes / k
    speed = link_speed if link_speed is not None else [1.0] * n

    # event = (time, seq, node, chunk_id, hops_done); links serve FIFO.
    link_free = [0.0] * n            # link u: u -> (u + link_offset) % n
    done_at = [0.0] * n              # per source message completion
    heap: list[tuple[float, int, int, int, int]] = []
    seq = 0
    for u in range(n):
        for c in range(k):
            heapq.heappush(heap, (0.0, seq, u, c, 0))
            seq += 1
    while heap:
        t, _, src, c, h = heapq.heappop(heap)
        node = (src + h * link_offset) % n
        tx = chunk * cm.beta / speed[node]
        start = max(t, link_free[node])
        arrive = start + tx + cm.alpha_h
        link_free[node] = start + tx
        if h + 1 == hops:
            done_at[src] = max(done_at[src], arrive)
        else:
            heapq.heappush(heap, (arrive, seq, src, c, h + 1))
            seq += 1
    return EventStepResult(
        completion=max(done_at),
        max_link_busy=max(link_free),
        chunks_moved=n * k * hops,
    )


def collective_time_event(
    schedule: Schedule,
    m: float,
    cm: CostModel,
    chunks_per_msg: int = 32,
    link_speed: list[float] | None = None,
) -> float:
    """Event-level completion time of a Bruck collective under a schedule.

    Thin compatibility wrapper: synchronized steps with whole-fabric delta
    pauses, i.e. `fabricsim.FabricSim` in full-pause mode (bit-stable with
    the pre-FabricSim implementation).  Use `FabricSim(mode="sparse")` for
    the asynchronous per-link fabric with sparse reconfiguration.

    Per-call overhead is one FabricSim construction: the step sequence,
    per-step link offsets, and changed-boundary structure all come from the
    schedule's memoized playback tape (`batchsim.compile_tape`), so sweep
    loops no longer re-derive `steps_for` / segment gcds on every call.
    """
    from .fabricsim import FabricSim  # deferred: fabricsim imports simulate_step

    sim = FabricSim(chunks_per_msg=chunks_per_msg, link_speed=link_speed,
                    mode="full-pause")
    return sim.run(schedule, m, cm).completion


def ring_allreduce_event(n: int, m: float, cm: CostModel) -> float:
    """Event-level RING allreduce: 2(n-1) neighbor steps of m/n."""
    total = 0.0
    for _ in range(2 * (n - 1)):
        total += cm.alpha_s
        total += simulate_step(n, 1, 1, m / n, cm, chunks_per_msg=1).completion
    return total
