"""NumPy-vectorized batch fabric engine: compiled schedule tapes + playback.

`FabricSim`'s sparse mode is a per-chunk Python ``heapq`` loop: every run
re-derives the segment maps, hop counts, and expected service counts from the
`Schedule`, then pushes O(n * chunks * sum(hops)) events through a heap.
That is fine for one scenario, but too slow to sit on the planning hot path
where a candidate set of 30+ schedules must be event-scored per request, or
to reach n >= 768 fabrics at all.

This module splits the work the way a compiler does:

  - `compile_tape(schedule)` lowers a `Schedule` once into a reusable
    `ScheduleTape`: per-sub-step link offsets, hop counts, integer payload
    counts (so any m is one multiply away), segment maps, and the
    changed-circuit mask at every reconfiguration boundary.  Tapes are
    memoized per schedule (`functools.lru_cache`), so even the scalar sparse
    loop stops paying the rebuild cost when only scenario knobs change.
  - `batch_run(lanes, cm)` plays B *lanes* — (schedule, m_bytes, delta,
    overlap, straggler / skew vector) configurations — forward together,
    step by step, with array ops over the ``[B, n, chunks]`` grid.

Exactness.  The playback serves each port's traffic in the *canonical*
order: segments strictly in sequence (the scalar simulator enforces this via
its per-port segment gate), steps in order within a segment, and hop streams
in order within a step, with every chunk's service start computed as
``max(arrival, port_free)`` in the same float-op order as the scalar loop.
The event-driven heap follows exactly this order unless traffic *overtakes*:
a later step's chunk reaching a port before an earlier step's chunk has
arrived (the port could go idle and serve out of order), or a hop-1 chunk
arriving before the port's own injection.  Both conditions are checked from
the computed timeline — they are sufficient conditions for the heap execution
to coincide with the canonical one — and any lane that trips a check is
transparently re-run through the scalar `FabricSim` oracle
(``BatchFabricResult.fast_path`` records which lanes took which path).  The
differential-fuzz suite (tests/test_batchsim.py) pins fast-path results to
the scalar loop at 1e-9 relative tolerance across a seeded
n x r x R x delta x straggler grid.

Most lanes never needed the runtime checks at all: the static fast-path
certifier (`repro.analysis.certifier`) proves, from the tape and the cost-
model regime alone, that neither condition can trip — uniform lanes under a
positive per-step startup latency.  `batch_run` / `batch_run_trace` consult
it first (``certify=True``), exempt certified lanes from the guards, and
skip the guards' per-step bookkeeping entirely when the whole batch is
certified; ``BatchFabricResult.certified`` records who held a certificate.

Backends.  ``batch_run(..., backend=...)`` picks the playback engine for the
*certified* lanes:

  - ``"numpy"`` (default): the `_play` loop below — exact, guarded, no
    dependencies beyond NumPy.
  - ``"jax"``: certified lanes are lowered to the XLA kernel in
    `repro.core.batchsim_jax` (jit + vmap over lanes, float64, bit-identical
    to `_play` on CPU); uncertified lanes keep the guarded NumPy path and
    its scalar-oracle fallback.  Requires jax and ``certify=True`` — the
    JAX kernel is guard-free, so only proven-exact lanes may enter it.
  - ``"auto"``: ``"jax"`` when jax is importable, some lane is certified,
    and the batch is big enough to amortize dispatch/compile overhead
    (`_JAX_AUTO_MIN_WORK`); ``"numpy"`` otherwise.  This is what the
    planner's ``fabric="ocs-sim"`` scoring uses.

The planner's ``fabric="ocs-sim"`` event-scores whole candidate sets through
`batch_run` in a single call; `benchmarks/sim_bench.py` records the wall-time
ratio vs the scalar loop (>= 10x at n = 96 for a 30-candidate batch, and
n >= 768 grids that the scalar engine cannot touch in CI time) and the JAX
rows' gated speedup over this NumPy engine (docs/batch_engine.md has the
measured performance model).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Sequence

import numpy as np

from .bruck import step_counts
from .cost_model import CostModel
from .schedules import Schedule

if TYPE_CHECKING:  # faults imports us; only the annotation needs the type
    from .faults import FaultTimeline


def validate_rates(name: str, rates, n: int) -> list[float]:
    """Shared per-node rate-vector validation (length n, strictly positive)."""
    rates = list(rates)
    if len(rates) != n:
        raise ValueError(f"{name} has length {len(rates)} != n={n}")
    if any(v <= 0 for v in rates):
        raise ValueError(f"{name} entries must be > 0, got {rates}")
    return rates


def validate_phases(phases) -> tuple[tuple[Schedule, float], ...]:
    """Shared trace-phase validation: non-empty (schedule, m >= 0) pairs on
    one fabric (used by `TraceLane` and `FabricSim.run_trace`)."""
    phases = tuple((sched, float(m)) for sched, m in phases)
    if not phases:
        raise ValueError("a trace needs at least one (schedule, m) phase")
    n = phases[0][0].n
    for i, (sched, m) in enumerate(phases):
        if sched.n != n:
            raise ValueError(
                f"all trace phases must share one fabric: phase {i} has "
                f"n={sched.n} != {n}")
        if m < 0:
            raise ValueError(f"phase {i} payload must be >= 0, got {m}")
    return phases


@dataclasses.dataclass(frozen=True)
class FabricSnapshot:
    """Resumable fabric state at a collective boundary of a trace.

    Captured after the last phase of a (prefix) trace has fully drained
    (`FabricSim.run_trace(..., capture_state=True)` or
    `BatchTraceResult.snapshot`) and accepted back as the ``initial`` state by
    both trace engines.  The resumed run continues on the same absolute
    clock, so playing phases [0, k) and then resuming [k, P) from the
    snapshot reproduces the single full run: the sparse engine's per-port
    segment gate means prefix timings never depend on suffix traffic, and the
    boundary swap into the resumed phases is charged on top of ``port_free``
    exactly as the full run charges it.  This is what lets the online planner
    re-plan a trace suffix from the committed prefix without replaying it.

    link_offset  : circuit every egress port is left configured at (uniform —
                   ring traffic drains every port through the final segment).
    node_ready   : per node, the time its final prefix receive completed; the
                   resumed phase injects at ``node_ready[u] + alpha_s``.
    port_free    : per port, busy-until time of its last prefix service.
    chunks_moved / reconfigs_paid / delta_stall carry the prefix accounting so
    resumed results report trace-cumulative totals.
    """

    n: int
    link_offset: int
    node_ready: tuple[float, ...]
    port_free: tuple[float, ...]
    chunks_moved: int = 0
    reconfigs_paid: int = 0
    delta_stall: float = 0.0

    def __post_init__(self):
        if self.n < 2:
            raise ValueError(f"need at least 2 nodes, got n={self.n}")
        object.__setattr__(self, "node_ready",
                           tuple(float(t) for t in self.node_ready))
        object.__setattr__(self, "port_free",
                           tuple(float(t) for t in self.port_free))
        for name in ("node_ready", "port_free"):
            v = getattr(self, name)
            if len(v) != self.n:
                raise ValueError(
                    f"{name} has length {len(v)} != n={self.n}")

    @property
    def clock(self) -> float:
        """Prefix completion time (the last node's final receive)."""
        return max(self.node_ready)


# --- Tape compilation ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleTape:
    """Everything `FabricSim.run` used to rebuild per call, compiled once.

    All payload fields are m-independent: sub-step k moves
    ``m * counts[k] / n`` bytes (the exact expression the step generators
    use, so scaling is bit-identical).  Plain tuples keep the tape hashable
    and cheap for the scalar loop; `arrays` caches the NumPy views the batch
    engine indexes with.
    """

    kind: str
    n: int
    r: int
    S: int
    offsets: tuple[int, ...]        # message offset per sub-step
    counts: tuple[int, ...]         # integer block count per sub-step
    g_step: tuple[int, ...]         # link offset in force per sub-step
    hops: tuple[int, ...]           # offsets[k] // g_step[k]
    boundary: tuple[int, ...]       # schedule.x (1 = reconfigure before k)
    changed_pay: tuple[bool, ...]   # boundary k physically rewires circuits
    seg_of: tuple[int, ...]         # sub-step -> segment index
    seg_g: tuple[int, ...]          # link offset per segment
    seg_hops: tuple[int, ...]       # total hops per segment (per-port services / C)
    changed_links: tuple[int, ...]  # Schedule.reconfig_changed_links()

    @functools.cached_property
    def arrays(self) -> dict[str, np.ndarray]:
        out = {
            "offsets": np.array(self.offsets, dtype=np.int64),
            "counts": np.array(self.counts, dtype=np.float64),
            "g_step": np.array(self.g_step, dtype=np.int64),
            "hops": np.array(self.hops, dtype=np.int64),
            "changed_pay": np.array(self.changed_pay, dtype=bool),
            "boundary": np.array(self.boundary, dtype=bool),
        }
        for arr in out.values():
            arr.setflags(write=False)
        return out


@functools.lru_cache(maxsize=4096)
def compile_tape(schedule: Schedule) -> ScheduleTape:
    """Lower ``schedule`` to its playback tape (memoized per Schedule)."""
    kind, n, r = schedule.kind, schedule.n, schedule.r
    structure = step_counts(kind, n, r)
    offsets = tuple(off for off, _, _, _ in structure)
    counts = tuple(cnt for _, cnt, _, _ in structure)
    g_step = tuple(schedule.link_offsets())
    hops = tuple(off // g for off, g in zip(offsets, g_step, strict=True))
    segs = schedule.segments
    seg_of = [0] * len(offsets)
    for si, (a, b) in enumerate(segs):
        for k in range(a, b + 1):
            seg_of[k] = si
    seg_g = tuple(g_step[a] for a, _ in segs)
    seg_hops = tuple(sum(hops[a:b + 1]) for a, b in segs)
    changed_pay = tuple(
        bool(xk) and g_step[k] != g_step[k - 1]
        for k, xk in enumerate(schedule.x))
    return ScheduleTape(
        kind=kind, n=n, r=r, S=len(offsets), offsets=offsets, counts=counts,
        g_step=g_step, hops=hops, boundary=tuple(schedule.x),
        changed_pay=changed_pay, seg_of=tuple(seg_of), seg_g=seg_g,
        seg_hops=seg_hops, changed_links=schedule.reconfig_changed_links())


def clear_tape_caches() -> None:
    """Drop memoized tapes (benchmarks use this for cold-path timings)."""
    compile_tape.cache_clear()


# --- Batch configuration ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchLane:
    """One (schedule, scenario) configuration in a batch.

    delta          : reconfiguration delay override; None = cm.delta.
    overlap        : fraction of delta hidden behind communication, [0, 1].
    link_speed     : per-node relative egress rate (None = nominal).
    payload_scale  : per-destination payload multiplier (None = uniform).
    """

    schedule: Schedule
    m_bytes: float
    delta: float | None = None
    overlap: float = 0.0
    link_speed: tuple[float, ...] | None = None
    payload_scale: tuple[float, ...] | None = None

    def __post_init__(self):
        if not 0.0 <= self.overlap <= 1.0:
            raise ValueError(f"overlap must be in [0, 1], got {self.overlap}")
        if self.m_bytes < 0:
            raise ValueError(f"payload must be >= 0, got {self.m_bytes}")
        if self.delta is not None and self.delta < 0:
            raise ValueError(f"delta must be >= 0, got {self.delta}")
        n = self.schedule.n
        for name in ("link_speed", "payload_scale"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, tuple(validate_rates(name, v, n)))
        object.__setattr__(self, "m_bytes", float(self.m_bytes))


@dataclasses.dataclass(frozen=True)
class TraceLane:
    """One (trace, scenario) configuration in a `batch_run_trace` batch.

    phases : (schedule, m_bytes) per collective, played back-to-back on one
             fabric with port-state carryover (see `FabricSim.run_trace`).
    initial: optional `FabricSnapshot` to resume from — the lane's ports
             start at the snapshot's busy-until times and configured circuit
             instead of an idle fabric, and results report trace-cumulative
             accounting.
    faults : optional `core.faults.FaultTimeline` — the lane is routed to
             the scalar fault-injecting oracle (`FabricSim.run_trace`) and
             its result carries a `DegradedState` when a fault takes effect.
    Other knobs are per-lane exactly as in `BatchLane`.
    """

    phases: tuple[tuple[Schedule, float], ...]
    delta: float | None = None
    overlap: float = 0.0
    link_speed: tuple[float, ...] | None = None
    payload_scale: tuple[float, ...] | None = None
    initial: FabricSnapshot | None = None
    faults: FaultTimeline | None = None

    def __post_init__(self):
        object.__setattr__(self, "phases", validate_phases(self.phases))
        n = self.phases[0][0].n
        if self.initial is not None and self.initial.n != n:
            raise ValueError(
                f"initial snapshot is for n={self.initial.n}, phases have "
                f"n={n}")
        if self.faults is not None and self.faults.n != n:
            raise ValueError(
                f"fault timeline is for n={self.faults.n}, phases have "
                f"n={n}")
        if not 0.0 <= self.overlap <= 1.0:
            raise ValueError(f"overlap must be in [0, 1], got {self.overlap}")
        if self.delta is not None and self.delta < 0:
            raise ValueError(f"delta must be >= 0, got {self.delta}")
        for name in ("link_speed", "payload_scale"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, tuple(validate_rates(name, v, n)))

    @property
    def n(self) -> int:
        return self.phases[0][0].n


@dataclasses.dataclass(frozen=True)
class BatchFabricResult:
    """Outcome of one `batch_run`: `FabricResult` fields with a lane axis.

    fast_path[b] is True when lane b completed on the vectorized tape
    playback and False when it was re-run through the scalar oracle (the
    canonical-order check tripped, e.g. under a severe straggler).
    certified[b] is True when lane b held a static fast-path certificate
    (`repro.analysis.certifier`): its exactness was proven from the tape and
    regime alone, without running the runtime guards.  certified implies
    fast_path.
    backend is the resolved playback engine ("numpy" or "jax"); under "jax"
    the certified lanes ran on the XLA kernel and the uncertified ones on
    the guarded NumPy path (timing output is identical either way).
    """

    completion: np.ndarray      # [B]
    node_done: np.ndarray       # [B, n]
    step_done: np.ndarray       # [B, S]
    chunks_moved: np.ndarray    # [B] int
    reconfigs_paid: np.ndarray  # [B] int
    delta_stall: np.ndarray     # [B]
    fast_path: np.ndarray       # [B] bool
    certified: np.ndarray       # [B] bool
    lanes: tuple[BatchLane, ...]
    backend: str = "numpy"

    def __len__(self) -> int:
        return len(self.lanes)

    def result(self, i: int):
        """Lane i as a scalar-compatible `FabricResult` (mode='batched')."""
        from .fabricsim import FabricResult  # deferred: fabricsim imports us

        tape = compile_tape(self.lanes[i].schedule)
        return FabricResult(
            completion=float(self.completion[i]), mode="batched",
            step_done=tuple(float(t) for t in self.step_done[i]),
            node_done=tuple(float(t) for t in self.node_done[i]),
            chunks_moved=int(self.chunks_moved[i]),
            changed_links=tape.changed_links,
            reconfigs_paid=int(self.reconfigs_paid[i]),
            delta_stall=float(self.delta_stall[i]))


# --- Batched playback ---------------------------------------------------------


def _knob_arrays(lanes, cm: CostModel, n: int):
    """Per-lane delta/overlap/speed/scale arrays shared by both entry points."""
    B = len(lanes)
    delta = np.array([cm.delta if lane.delta is None else lane.delta
                      for lane in lanes])
    overlap = np.array([lane.overlap for lane in lanes])
    delta_eff = delta * (1.0 - overlap)
    speed = np.ones((B, n))
    for b, lane in enumerate(lanes):
        if lane.link_speed is not None:
            speed[b] = lane.link_speed
    scale = None
    if any(lane.payload_scale is not None for lane in lanes):
        scale = np.ones((B, n))
        for b, lane in enumerate(lanes):
            if lane.payload_scale is not None:
                scale[b] = lane.payload_scale
    return delta, overlap, delta_eff, speed, scale


def _play(*, n: int, C: int, cm: CostModel, nb_step, g_step, hops, boundary,
          changed, delta_eff, speed, scale, F0=None, ready0=None,
          changed0=None, check_order: bool = True):
    """Canonical-order tape playback over [B, S] step arrays.

    ``nb_step[b, k]`` is lane b's per-node payload of sub-step k (before any
    destination scaling); ``boundary`` marks steps that open a new segment
    (the scalar loop's per-port segment gate resets there) and ``changed``
    marks steps whose opening boundary physically rewires circuits (those
    charge ``delta_eff``).  ``F0`` / ``ready0`` / ``changed0`` resume lanes
    from a `FabricSnapshot`: per-port busy-until times, per-node final
    receive times of the committed prefix (step 0 injects at
    ``ready0 + alpha_s``), and the per-lane flag for an entry boundary that
    rewires circuits (charged like any segment boundary).  Returns
    (node_done, step_done, ok, port_free) where ``ok`` flags the lanes whose
    heap execution provably coincides with this canonical order (see module
    docstring) and ``port_free`` is the final per-port busy-until state.

    ``check_order=False`` skips the runtime canonical-order guards and their
    ``first_arr`` / ``last_arr`` / ``seg_max_arr`` bookkeeping entirely and
    returns ``ok`` all-True — only valid when every lane in the batch holds
    a static fast-path certificate (`repro.analysis.certifier`), which
    proves the guards could not have tripped.  The timing arrays are
    bit-identical either way: the guards observe the timeline, they never
    alter it.
    """
    B, S = nb_step.shape
    alpha_s, alpha_h, beta = cm.alpha_s, cm.alpha_h, cm.beta
    ports = np.arange(n, dtype=np.int64)[None, :]           # [1, n]

    # port busy-until / injection times of the current step, warm-started
    # from the snapshot arrays in the same float-op order as the scalar
    # restore (free = port_free [+ delta_eff]; t_inj = node_ready + alpha_s)
    F = np.zeros((B, n)) if F0 is None else np.array(F0, dtype=float)
    if changed0 is not None:
        F = F + np.where(changed0, delta_eff, 0.0)[:, None]
    inj = (np.full((B, n), alpha_s) if ready0 is None
           else np.asarray(ready0, dtype=float) + alpha_s)
    step_done = np.zeros((B, S))
    ok = np.ones(B, dtype=bool)       # canonical-order check per lane
    if check_order:
        seg_max_arr = np.full((B, n), -np.inf)  # latest arrival this segment

    for k in range(S):
        if k > 0:
            inj = recv + alpha_s
            F = F + np.where(changed[:, k], delta_eff, 0.0)[:, None]
        h = hops[:, k]                                       # [B]
        g = g_step[:, k]                                     # [B]
        nb = nb_step[:, k]                                   # [B]
        gather_idx = (ports - g[:, None]) % n                # [B, n]
        gather_idx3 = np.broadcast_to(gather_idx[:, :, None], (B, n, C))
        arr = np.broadcast_to(inj[:, :, None], (B, n, C))    # stream-0 arrivals
        if check_order:
            first_arr, last_arr = inj.copy(), inj.copy()     # min/max over streams
        recv = np.empty((B, n))
        comp = np.empty((B, n, C))
        for j in range(int(h.max())):
            active = j < h                                   # [B]
            # per-port service time of this hop stream (scalar op order:
            # ((nbytes [* dest scale]) / C) * beta / speed)
            if scale is None:
                nbw = np.broadcast_to(nb[:, None], (B, n))
            else:
                dest = (ports + ((h - j) * g)[:, None]) % n
                nbw = nb[:, None] * np.take_along_axis(scale, dest, axis=1)
            tau = (nbw / C) * beta / speed
            f = F
            for c in range(C):
                f = np.maximum(f, arr[:, :, c]) + tau
                comp[:, :, c] = f
            F = np.where(active[:, None], f, F)
            nxt = np.take_along_axis(comp, gather_idx3, axis=1) + alpha_h
            final = active & (j + 1 >= h)
            if final.any():
                deliver = np.take_along_axis(comp[:, :, C - 1],
                                             gather_idx, axis=1) + alpha_h
                recv = np.where(final[:, None], deliver, recv)
            cont = active & (j + 1 < h)
            if not cont.any():
                break
            if check_order:
                if j == 0:
                    # a hop-1 chunk overtaking the port's own injection breaks
                    # the canonical within-step stream order
                    ok &= ~(cont & (nxt[:, :, 0] <= inj).any(axis=1))
                first_arr = np.where(cont[:, None],
                                     np.minimum(first_arr, nxt[:, :, 0]),
                                     first_arr)
                last_arr = np.where(cont[:, None],
                                    np.maximum(last_arr, nxt[:, :, C - 1]),
                                    last_arr)
            arr = nxt
        if check_order:
            # canonical cross-step order within a segment: step k's first
            # arrivals must not precede (or tie with) any earlier arrival at
            # the same port — the scalar loop's segment gate covers
            # boundaries, so the running max resets there
            if k > 0:
                same_seg = ~boundary[:, k]
                ok &= ~(same_seg & (first_arr <= seg_max_arr).any(axis=1))
            reset = boundary[:, k][:, None]
            seg_max_arr = np.where(reset, last_arr,
                                   np.maximum(seg_max_arr, last_arr))
        step_done[:, k] = recv.max(axis=1)
    return recv, step_done, ok, F


# "auto" switches to the JAX backend only above this estimated certified
# work, in chunk-services (C * n * total certified hops).  Calibrated on the
# CI-class single-core CPU (benchmarks/sim_bench.py): below it the NumPy
# loop's per-hop dispatch is cheaper than jit dispatch + (first-call)
# compilation; the planner's n=96 candidate sets (~1e6) stay on NumPy, wide
# n>=768 sets (>=1e7) go to XLA.
_JAX_AUTO_MIN_WORK = 5e6


def _resolve_backend(backend: str, *, certify: bool, certified: np.ndarray,
                     n: int, C: int, hops: np.ndarray) -> str:
    """Resolve a ``backend=`` request to the engine that will actually run.

    "jax" demands jax and ``certify=True`` (the XLA kernel is guard-free —
    only certified lanes may enter it) but degrades to "numpy" when no lane
    in *this* batch is certified, since there would be nothing for the
    kernel to do.  "auto" additionally requires the certified work to clear
    `_JAX_AUTO_MIN_WORK` so small batches keep NumPy's lower fixed cost.
    """
    if backend not in ("numpy", "jax", "auto"):
        raise ValueError(
            f"backend must be 'numpy', 'jax', or 'auto', got {backend!r}")
    if backend == "numpy":
        return "numpy"
    if not certify:
        if backend == "jax":
            raise ValueError(
                "backend='jax' requires certify=True: the JAX fast path is "
                "guard-free and only sound for lanes holding a static "
                "fast-path certificate")
        return "numpy"
    if backend == "jax":
        from .batchsim_jax import jax_available

        if not jax_available():
            from repro.collectives._compat import require_jax

            require_jax("backend='jax' batch playback")  # raises ImportError
        return "jax" if bool(certified.any()) else "numpy"
    # auto: opt in only when jax exists and the certified work amortizes it
    from .batchsim_jax import jax_available

    if not jax_available() or not bool(certified.any()):
        return "numpy"
    work = float(C) * n * float(hops[certified].sum())
    return "jax" if work >= _JAX_AUTO_MIN_WORK else "numpy"


def batch_run(lanes: Sequence[BatchLane], cm: CostModel, *,
              chunks_per_msg: int = 32, allow_fallback: bool = True,
              certify: bool = True, backend: str = "numpy") -> BatchFabricResult:
    """Play every lane's tape forward together (sparse-fabric semantics).

    All lanes must share the same world size n and sub-step count S (any mix
    of collectives / segmentations at one (n, r) qualifies — including the
    RS and AG phases of an AllReduce).  Set ``allow_fallback=False`` to get a
    RuntimeError instead of the scalar re-run when a lane's canonical-order
    check trips (used by tests to prove the fast path was exercised).

    ``certify=True`` (the default) consults the static fast-path certifier
    first: lanes whose (schedule, regime) certificate proves the canonical-
    order guards cannot trip are exempt from them, and when *every* lane is
    certified the guards' per-step bookkeeping is skipped outright.  Timing
    output is bit-identical with ``certify=False`` — the certificate only
    decides whether the guards need to watch.

    ``backend`` selects the playback engine for the certified lanes:
    ``"numpy"`` (default), ``"jax"`` (XLA kernel, requires jax and
    ``certify=True``), or ``"auto"`` (JAX when available and worthwhile).
    Uncertified lanes always run the guarded NumPy path regardless of
    backend; see the module docstring and docs/batch_engine.md.
    """
    lanes = tuple(lanes)
    if not lanes:
        raise ValueError("batch_run needs at least one lane")
    tapes = [compile_tape(lane.schedule) for lane in lanes]
    n, S = tapes[0].n, tapes[0].S
    for lane, tape in zip(lanes, tapes, strict=True):
        if tape.n != n or tape.S != S:
            raise ValueError(
                f"all lanes must share (n, S); got ({tape.n}, {tape.S}) for "
                f"{lane.schedule.kind} vs ({n}, {S})")
    C = max(1, int(chunks_per_msg))

    m = np.array([lane.m_bytes for lane in lanes])
    delta, overlap, delta_eff, speed, scale = _knob_arrays(lanes, cm, n)

    # --- per-lane tape arrays [B, S] ---------------------------------------
    counts = np.stack([t.arrays["counts"] for t in tapes])
    g_step = np.stack([t.arrays["g_step"] for t in tapes])
    hops = np.stack([t.arrays["hops"] for t in tapes])
    boundary = np.stack([t.arrays["boundary"] for t in tapes])
    changed = np.stack([t.arrays["changed_pay"] for t in tapes])
    nb_step = (m[:, None] * counts) / n   # same float-op order as the scalar loop

    if certify:
        from repro.analysis.certifier import certify_batch  # no cycle: analysis imports core only

        certified = certify_batch(lanes, cm)
    else:
        certified = np.zeros(len(lanes), dtype=bool)

    backend = _resolve_backend(backend, certify=certify, certified=certified,
                               n=n, C=C, hops=hops)
    if backend == "jax":
        # certified lanes -> guard-free XLA kernel; the rest keep the
        # guarded NumPy playback (and below, its scalar-oracle fallback)
        from .batchsim_jax import play_certified

        B = len(lanes)
        jidx = np.flatnonzero(certified)
        uidx = np.flatnonzero(~certified)
        node_done = np.empty((B, n))
        step_done = np.empty((B, S))
        ok = np.ones(B, dtype=bool)
        nd_j, sd_j, _ = play_certified(
            n=n, C=C, cm=cm, nb_step=nb_step[jidx], g_step=g_step[jidx],
            hops=hops[jidx], changed=changed[jidx], delta_eff=delta_eff[jidx])
        node_done[jidx] = nd_j
        step_done[jidx] = sd_j
        if uidx.size:
            nd_u, sd_u, ok_u, _ = _play(
                n=n, C=C, cm=cm, nb_step=nb_step[uidx], g_step=g_step[uidx],
                hops=hops[uidx], boundary=boundary[uidx],
                changed=changed[uidx], delta_eff=delta_eff[uidx],
                speed=speed[uidx],
                scale=scale[uidx] if scale is not None else None,
                check_order=True)
            node_done[uidx] = nd_u
            step_done[uidx] = sd_u
            ok[uidx] = ok_u
    else:
        node_done, step_done, ok, _ = _play(
            n=n, C=C, cm=cm, nb_step=nb_step, g_step=g_step, hops=hops,
            boundary=boundary, changed=changed, delta_eff=delta_eff,
            speed=speed, scale=scale, check_order=not bool(certified.all()))
    ok |= certified  # certified lanes are exact by proof, not by observation

    completion = node_done.max(axis=1)
    n_changed = changed.sum(axis=1)
    reconfigs_paid = (n * n_changed).astype(np.int64)
    delta_stall = reconfigs_paid * delta_eff
    chunks_moved = (n * C * hops.sum(axis=1)).astype(np.int64)

    if not ok.all():
        if not allow_fallback:
            raise RuntimeError(
                f"canonical-order check tripped for lanes "
                f"{np.flatnonzero(~ok).tolist()} and fallback is disabled")
        from .fabricsim import FabricSim  # deferred: fabricsim imports us

        for b in np.flatnonzero(~ok):
            lane = lanes[b]
            sim = FabricSim(
                chunks_per_msg=C, overlap=float(overlap[b]), mode="sparse",
                link_speed=(list(lane.link_speed)
                            if lane.link_speed is not None else None),
                payload_scale=(list(lane.payload_scale)
                               if lane.payload_scale is not None else None))
            res = sim.run(lane.schedule, float(m[b]),
                          cm.replace(delta=float(delta[b])))
            completion[b] = res.completion
            node_done[b] = res.node_done
            step_done[b] = res.step_done
            chunks_moved[b] = res.chunks_moved
            reconfigs_paid[b] = res.reconfigs_paid
            delta_stall[b] = res.delta_stall

    return BatchFabricResult(
        completion=completion, node_done=node_done, step_done=step_done,
        chunks_moved=chunks_moved, reconfigs_paid=reconfigs_paid,
        delta_stall=delta_stall, fast_path=ok, certified=certified,
        lanes=lanes, backend=backend)


@dataclasses.dataclass(frozen=True)
class BatchTraceResult:
    """Outcome of one `batch_run_trace`: `TraceFabricResult` fields + lane axis."""

    completion: np.ndarray      # [B]
    node_done: np.ndarray       # [B, n]
    step_done: np.ndarray       # [B, S_total]
    phase_done: np.ndarray      # [B, P]
    chunks_moved: np.ndarray    # [B] int
    reconfigs_paid: np.ndarray  # [B] int
    delta_stall: np.ndarray     # [B]
    fast_path: np.ndarray       # [B] bool
    certified: np.ndarray       # [B] bool (static fast-path certificate held)
    port_free: np.ndarray       # [B, n] final per-port busy-until
    lanes: tuple[TraceLane, ...]
    degraded: tuple = ()        # [B] DegradedState | None (faulted lanes)

    def __len__(self) -> int:
        return len(self.lanes)

    def snapshot(self, i: int) -> FabricSnapshot:
        """Lane i's resumable end-of-trace fabric state."""
        lane = self.lanes[i]
        if self.degraded and self.degraded[i] is not None:
            raise ValueError(
                f"lane {i} ended degraded (a fault took effect); its "
                f"resumable state is the committed-prefix snapshot at "
                f"result({i}).degraded.snapshot")
        return FabricSnapshot(
            n=lane.n,
            link_offset=lane.phases[-1][0].link_offsets()[-1],
            node_ready=tuple(float(t) for t in self.node_done[i]),
            port_free=tuple(float(t) for t in self.port_free[i]),
            chunks_moved=int(self.chunks_moved[i]),
            reconfigs_paid=int(self.reconfigs_paid[i]),
            delta_stall=float(self.delta_stall[i]))

    def result(self, i: int):
        """Lane i as a scalar-compatible `TraceFabricResult` (mode='batched')."""
        # deferred: fabricsim imports us
        from .fabricsim import TraceFabricResult, trace_boundary_changed

        return TraceFabricResult(
            completion=float(self.completion[i]), mode="batched",
            phase_done=tuple(float(t) for t in self.phase_done[i]),
            step_done=tuple(float(t) for t in self.step_done[i]),
            node_done=tuple(float(t) for t in self.node_done[i]),
            chunks_moved=int(self.chunks_moved[i]),
            boundary_changed=trace_boundary_changed(
                [sched for sched, _ in self.lanes[i].phases]),
            reconfigs_paid=int(self.reconfigs_paid[i]),
            delta_stall=float(self.delta_stall[i]),
            degraded=self.degraded[i] if self.degraded else None)


def batch_run_trace(lanes: Sequence[TraceLane], cm: CostModel, *,
                    chunks_per_msg: int = 32, allow_fallback: bool = True,
                    certify: bool = True) -> BatchTraceResult:
    """Play every lane's trace forward together with fabric-state carryover.

    Each lane's phases are concatenated into one tape: a collective boundary
    is exactly a segment boundary (the next phase's injections chain off each
    node's own final receive of the previous phase, ports keep draining), and
    it charges the lane's effective delta only when the initial link offset
    of phase p+1 differs from the final one of phase p.  All lanes must share
    the same world size n and per-phase sub-step counts.  Lanes whose
    canonical-order check trips are re-run through the scalar
    `FabricSim.run_trace` oracle unless ``allow_fallback=False``.
    ``certify`` engages the static fast-path certifier exactly as in
    `batch_run` (snapshot-resumed lanes are never certified — the restored
    per-port state breaks the rotational symmetry the certificate needs).

    Lanes carrying a `TraceLane.faults` timeline always route to the scalar
    fault-injecting oracle (they are never certified and never fast-path —
    the vectorized playback has no notion of a mid-trace world change) and
    their `DegradedState` lands in ``BatchTraceResult.degraded``; such
    lanes therefore require ``allow_fallback=True``.
    """
    lanes = tuple(lanes)
    if not lanes:
        raise ValueError("batch_run_trace needs at least one lane")
    tapes = [[compile_tape(sched) for sched, _ in lane.phases] for lane in lanes]
    n = tapes[0][0].n
    shape = tuple(t.S for t in tapes[0])
    for _lane, ts in zip(lanes, tapes, strict=True):
        if ts[0].n != n or tuple(t.S for t in ts) != shape:
            raise ValueError(
                f"all trace lanes must share (n, per-phase S); got "
                f"({ts[0].n}, {tuple(t.S for t in ts)}) vs ({n}, {shape})")
    B, P, S = len(lanes), len(shape), sum(shape)
    C = max(1, int(chunks_per_msg))
    phase_start = np.cumsum((0,) + shape[:-1])
    phase_last = np.cumsum(shape) - 1

    delta, overlap, delta_eff, speed, scale = _knob_arrays(lanes, cm, n)

    # --- concatenated per-lane tape arrays [B, S] --------------------------
    g_step = np.stack([np.concatenate([t.arrays["g_step"] for t in ts])
                       for ts in tapes])
    hops = np.stack([np.concatenate([t.arrays["hops"] for t in ts])
                     for ts in tapes])
    boundary = np.stack([np.concatenate([t.arrays["boundary"] for t in ts])
                         for ts in tapes])
    changed = np.stack([np.concatenate([t.arrays["changed_pay"] for t in ts])
                        for ts in tapes])
    nb_step = np.stack([
        np.concatenate([(m * t.arrays["counts"]) / n
                        for (_, m), t in zip(lane.phases, ts, strict=True)])
        for lane, ts in zip(lanes, tapes, strict=True)])
    # a phase start opens a new segment (gate reset) and rewires only the
    # circuits that differ from the previous phase's final configuration
    for k in phase_start[1:]:
        boundary[:, k] = True
        changed[:, k] = g_step[:, k] != g_step[:, k - 1]

    # resumed lanes start from their snapshot's port state; entering the
    # first phase is then a boundary like any other (rewire iff the resumed
    # phase's initial offset differs from the snapshot's)
    F0 = ready0 = changed0 = None
    init_chunks = np.zeros(B, dtype=np.int64)
    init_paid = np.zeros(B, dtype=np.int64)
    init_stall = np.zeros(B)
    if any(lane.initial is not None for lane in lanes):
        F0, ready0 = np.zeros((B, n)), np.zeros((B, n))
        changed0 = np.zeros(B, dtype=bool)
        for b, lane in enumerate(lanes):
            snap = lane.initial
            if snap is None:
                continue
            F0[b] = snap.port_free
            ready0[b] = snap.node_ready
            changed0[b] = int(g_step[b, 0]) != snap.link_offset
            init_chunks[b] = snap.chunks_moved
            init_paid[b] = snap.reconfigs_paid
            init_stall[b] = snap.delta_stall

    faulted = np.array([lane.faults is not None for lane in lanes])
    if faulted.any() and not allow_fallback:
        raise ValueError(
            f"fault-injecting trace lanes {np.flatnonzero(faulted).tolist()} "
            f"require allow_fallback=True: faulted lanes always route to "
            f"the scalar oracle")

    if certify:
        from repro.analysis.certifier import certify_trace_batch  # no cycle

        certified = certify_trace_batch(lanes, cm)
    else:
        certified = np.zeros(B, dtype=bool)
    certified &= ~faulted  # a certificate cannot cover a mid-trace fault

    node_done, step_done, ok, port_free = _play(
        n=n, C=C, cm=cm, nb_step=nb_step, g_step=g_step, hops=hops,
        boundary=boundary, changed=changed, delta_eff=delta_eff,
        speed=speed, scale=scale, F0=F0, ready0=ready0, changed0=changed0,
        check_order=not bool(certified.all()))
    ok |= certified  # certified lanes are exact by proof, not by observation
    ok &= ~faulted   # force faulted lanes through the scalar oracle

    completion = node_done.max(axis=1)
    phase_done = step_done[:, phase_last]
    paid_run = n * (changed.sum(axis=1)
                    + (changed0 if changed0 is not None else 0))
    reconfigs_paid = (paid_run + init_paid).astype(np.int64)
    delta_stall = paid_run * delta_eff + init_stall
    chunks_moved = (n * C * hops.sum(axis=1) + init_chunks).astype(np.int64)

    degraded_list: list = [None] * B
    if not ok.all():
        if not allow_fallback:
            raise RuntimeError(
                f"canonical-order check tripped for trace lanes "
                f"{np.flatnonzero(~ok).tolist()} and fallback is disabled")
        from .fabricsim import FabricSim  # deferred: fabricsim imports us

        for b in np.flatnonzero(~ok):
            lane = lanes[b]
            sim = FabricSim(
                chunks_per_msg=C, overlap=float(overlap[b]), mode="sparse",
                link_speed=(list(lane.link_speed)
                            if lane.link_speed is not None else None),
                payload_scale=(list(lane.payload_scale)
                               if lane.payload_scale is not None else None))
            res = sim.run_trace(lane.phases, cm.replace(delta=float(delta[b])),
                                initial=lane.initial, capture_state=True,
                                faults=lane.faults)
            completion[b] = res.completion
            node_done[b] = res.node_done
            step_done[b] = res.step_done
            phase_done[b] = res.phase_done
            chunks_moved[b] = res.chunks_moved
            reconfigs_paid[b] = res.reconfigs_paid
            delta_stall[b] = res.delta_stall
            degraded_list[b] = res.degraded
            if res.final_state is not None:
                port_free[b] = res.final_state.port_free
            else:
                # degraded before any boundary with no initial snapshot:
                # nothing committed, no resumable port state
                port_free[b] = np.inf

    return BatchTraceResult(
        completion=completion, node_done=node_done, step_done=step_done,
        phase_done=phase_done, chunks_moved=chunks_moved,
        reconfigs_paid=reconfigs_paid, delta_stall=delta_stall,
        fast_path=ok, certified=certified, port_free=port_free, lanes=lanes,
        degraded=tuple(degraded_list))


def batch_completion_times(schedules: Sequence[Schedule], m: float,
                           cm: CostModel, *, overlap: float = 0.0,
                           chunks_per_msg: int = 32,
                           backend: str = "numpy") -> np.ndarray:
    """Event-level completion time of every schedule in one batched call.

    The planner's ``fabric='ocs-sim'`` scoring primitive: all schedules share
    (n, S) — e.g. one request's full candidate set — and the same payload /
    cost model / overlap credit.  ``backend`` is forwarded to `batch_run`
    (the planner passes ``"auto"`` so wide large-n candidate sets score on
    the JAX engine when it is available).
    """
    lanes = [BatchLane(schedule=s, m_bytes=m, overlap=overlap)
             for s in schedules]
    return batch_run(lanes, cm, chunks_per_msg=chunks_per_msg,
                     backend=backend).completion
