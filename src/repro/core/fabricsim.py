"""Asynchronous per-link discrete-event fabric simulator.

The synchronized event simulator (`eventsim.collective_time_event`) charges
every reconfiguration as a whole-fabric pause of delta and inserts a global
barrier between sub-steps, so it cannot distinguish BRIDGE's *sparse*
reconfiguration — only the circuits that actually change are rewired while
the surviving subring links keep carrying traffic — from a full-fabric one.
`FabricSim` models the fabric at the granularity the claim is made at:

  - every node's optical egress port is an independent resource with its own
    FIFO queue (oldest-sub-step-first among queued chunks) and its own
    configured circuit;
  - a reconfiguration pays delta only on the ports whose circuit actually
    changes, computed by diffing consecutive segment link offsets
    (`Schedule.reconfig_changed_links`); a port swaps as soon as *it* has
    served its last chunk of the old segment, independently of the rest of
    the fabric, and ports with no traffic in a segment skip its circuit
    entirely;
  - a fraction ``overlap`` of delta is hidden behind concurrent
    communication (SWOT-style reconfiguration/communication overlap), so a
    swapping port blocks for ``delta * (1 - overlap)``
    (`CostModel.delta_sparse`);
  - a node begins sub-step k+1 transmissions as soon as its *own* sub-step-k
    receive completed (per-node dependency tracking; no global barrier);
  - scenario knobs: per-link relative speeds (stragglers) and per-destination
    payload scaling (skew).

``mode="full-pause"`` reproduces the legacy synchronized simulator
bit-for-bit (it runs the exact `collective_time_event` loop), which keeps
the Figs 5-12 event-level cross-checks stable; `collective_time_event` is
now a thin wrapper over it.  ``mode="batched"`` routes through the
vectorized tape-playback engine (`core.batchsim`) — sparse semantics, array
ops instead of the per-chunk heap, scalar-oracle fallback when the
canonical-order check trips.

Both scalar modes read their per-schedule precomputation (segment maps, hop
counts, expected per-port service counts, payload structure) from the
memoized `batchsim.compile_tape`, so repeated runs under different scenario
knobs stop paying the rebuild cost.

`run_trace` plays *back-to-back collectives* on one fabric with state
carryover: the phases' segment lists are concatenated, so a collective
boundary behaves exactly like an intra-schedule segment boundary (ports
mid-drain keep draining, each node injects the next collective off its own
final receive, and only the circuits that differ between the previous
phase's final link offsets and the next phase's initial ones are rewired).
Full-pause `run_trace` is bit-for-bit the legacy sum of independent runs —
the cold-fabric baseline of benchmarks/trace_bench.py.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

from .batchsim import (BatchLane, FabricSnapshot, TraceLane, batch_run,
                       batch_run_trace, compile_tape, validate_phases,
                       validate_rates)
from .cost_model import CostModel
from .faults import (ABRUPT_KINDS, DegradedState, FaultTimeline,
                     snapshot_to_tree, world_after)
from .schedules import Schedule, changed_links

_MODES = ("sparse", "full-pause", "batched")


@dataclasses.dataclass(frozen=True)
class FabricResult:
    """Outcome of one `FabricSim.run`.

    completion     : collective completion time (last receive), seconds.
    mode           : 'sparse' (async per-link) or 'full-pause' (legacy).
    step_done      : per sub-step, the time its last receive completed (in
                     full-pause mode each reconfiguration delta is charged at
                     its boundary step, so the entries attribute stall time
                     correctly even though ``completion`` keeps the legacy
                     R*delta-upfront summation order).
    node_done      : per node, the time its final-sub-step receive completed
                     (all equal to ``completion`` in full-pause mode).
    chunks_moved   : total chunk-hop services performed.
    changed_links  : per reconfiguration point, circuits that physically
                     change (diff of consecutive segment link offsets).
    reconfigs_paid : (port, boundary) swaps that paid a blocking delta
                     (R in full-pause mode, where delta is fabric-global).
    delta_stall    : total port-blocking reconfiguration time, seconds
                     (R * delta in full-pause mode).
    """

    completion: float
    mode: str
    step_done: tuple[float, ...]
    node_done: tuple[float, ...]
    chunks_moved: int
    changed_links: tuple[int, ...]
    reconfigs_paid: int
    delta_stall: float


@dataclasses.dataclass(frozen=True)
class TraceFabricResult:
    """Outcome of one `FabricSim.run_trace` over back-to-back collectives.

    completion       : time the last collective's last receive completed.
    phase_done       : per collective, the time its final sub-step's last
                       receive completed (cumulative; the last entry equals
                       ``completion`` in sparse mode, and the full-pause
                       entries are running sums of the independent runs).
    step_done        : per concatenated sub-step across all phases, the time
                       its last receive completed (full-pause entries are the
                       per-phase `FabricResult.step_done` values offset by
                       the completion of the preceding phases).
    node_done        : per node, its final receive time in the last phase.
    boundary_changed : per collective boundary, circuits that differ between
                       the previous phase's final link offsets and the next
                       phase's initial ones (`schedules.changed_links`).
                       In full-pause mode these are reported but never
                       charged: that mode reproduces the legacy
                       sum-of-independent-collectives number bit-for-bit.
    reconfigs_paid   : (port, boundary) swaps that paid a blocking delta,
                       across all phases *and* phase boundaries.
    delta_stall      : total port-blocking reconfiguration time, seconds.
    final_state      : resumable end-of-trace fabric state (populated only
                       when `run_trace` is called with ``capture_state=True``;
                       feed it back as ``initial`` to continue the trace).
                       For a degraded run this is the committed-prefix
                       snapshot (`degraded.snapshot`).
    degraded         : `core.faults.DegradedState` when a fault timeline cut
                       the run short: ``completion`` / ``node_done`` and the
                       un-committed ``phase_done`` / ``step_done`` entries
                       are inf, the accounting covers the committed prefix
                       plus the in-flight chunks, and recovery
                       (`repro.workloads.recovery`) consumes this state.
                       None for a clean run (including one whose faults all
                       land at/after trace completion).
    """

    completion: float
    mode: str
    phase_done: tuple[float, ...]
    step_done: tuple[float, ...]
    node_done: tuple[float, ...]
    chunks_moved: int
    boundary_changed: tuple[int, ...]
    reconfigs_paid: int
    delta_stall: float
    final_state: FabricSnapshot | None = None
    degraded: DegradedState | None = None


@dataclasses.dataclass(frozen=True)
class _EngineOut:
    """Raw sparse-engine outputs shared by `run` and `run_trace`."""

    completion: float
    step_done: tuple[float, ...]
    node_done: tuple[float, ...]
    chunks_moved: int
    reconfigs_paid: int
    delta_stall: float
    port_free: tuple[float, ...]
    cut_chunks: int = 0  # services started before the fault cutoff (if any)


def trace_boundary_changed(schedules: Sequence[Schedule]) -> tuple[int, ...]:
    """Circuits differing at each collective boundary of a schedule sequence.

    Entry i compares the final per-sub-step link offset of ``schedules[i]``
    with the initial one of ``schedules[i + 1]``: the carryover boundary pays
    delta only on these circuits (0 when collective i ends on exactly the
    offsets collective i + 1 starts with).
    """
    return tuple(
        changed_links(prev.n, prev.link_offsets()[-1], nxt.link_offsets()[0])
        for prev, nxt in zip(schedules, schedules[1:], strict=False))


# canonical implementations live in batchsim (imported by both engines)
_validate_rates = validate_rates
_validate_phases = validate_phases


class FabricSim:
    """Asynchronous per-link discrete-event fabric (see module docstring).

    chunks_per_msg : MTU-like pipelining knob (chunks per per-step message).
    overlap        : fraction of delta hidden behind communication, in [0, 1]
                     (sparse/batched modes; full-pause always blocks the
                     fabric).
    mode           : 'sparse' (per-chunk event loop) | 'full-pause' (legacy
                     synchronized loop) | 'batched' (vectorized tape playback
                     with sparse semantics, see `core.batchsim`).
    link_speed     : per-node relative egress rate (1.0 nominal, < 1 models a
                     degraded transceiver / straggler).
    payload_scale  : per-destination payload multiplier — the message a node
                     sends in a sub-step is scaled by the factor of its
                     (immediate) destination, modeling skewed payloads.
    """

    def __init__(self, *, chunks_per_msg: int = 32, overlap: float = 0.0,
                 mode: str = "sparse",
                 link_speed: list[float] | None = None,
                 payload_scale: list[float] | None = None):
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
        if not 0.0 <= overlap <= 1.0:
            raise ValueError(f"overlap must be in [0, 1], got {overlap}")
        if mode == "full-pause" and payload_scale is not None:
            raise ValueError(
                "payload_scale requires mode='sparse' or 'batched' "
                "(full-pause is the legacy uniform-payload compatibility mode)")
        if mode == "full-pause" and overlap != 0.0:
            raise ValueError(
                "overlap requires mode='sparse' or 'batched': full-pause "
                "always blocks the whole fabric for the full delta")
        self.chunks_per_msg = max(1, int(chunks_per_msg))
        self.overlap = float(overlap)
        self.mode = mode
        self.link_speed = link_speed
        self.payload_scale = payload_scale

    # --- public API ----------------------------------------------------------

    def run(self, schedule: Schedule, m: float, cm: CostModel) -> FabricResult:
        if self.mode == "full-pause":
            return self._run_full_pause(schedule, m, cm)
        if self.mode == "batched":
            return self._run_batched(schedule, m, cm)
        return self._run_sparse(schedule, m, cm)

    def run_trace(self, phases: Sequence[tuple[Schedule, float]],
                  cm: CostModel, *, initial: FabricSnapshot | None = None,
                  capture_state: bool = False,
                  faults: FaultTimeline | None = None,
                  checkpoint_dir: str | None = None,
                  checkpoint_every: int = 1) -> TraceFabricResult:
        """Play back-to-back collectives on one fabric without resetting ports.

        ``phases`` is a sequence of (schedule, m_bytes) pairs sharing one
        world size n.  In sparse/batched mode the phases are concatenated
        into one playback: a port mid-drain at a collective boundary keeps
        draining exactly like at an intra-schedule segment boundary, each
        node injects phase p+1 as soon as its *own* phase-p final receive
        completed, and the boundary pays delta only on the circuits that
        actually change between the previous phase's final link offsets and
        the next phase's initial ones.  ``mode='full-pause'`` reproduces the
        legacy sum-of-independent-collectives number bit-for-bit (each phase
        restarts from a pre-established topology and no boundary is charged),
        which is the cold-fabric execution baseline of benchmarks/trace_bench.

        ``initial`` resumes mid-trace from a `FabricSnapshot` (ports start at
        the snapshot's busy-until times and configured circuit; entering the
        first phase is a carryover boundary like any other) and
        ``capture_state=True`` records the resumable end state in
        ``final_state`` — together they let a trace be split at any
        collective boundary and replayed in pieces, which is what the online
        planner's re-plan-from-committed-prefix relies on.  Both require
        sparse/batched mode (full-pause is the stateless legacy baseline).

        ``faults`` injects a `core.faults.FaultTimeline`: the earliest fault
        that takes effect before the clean run drains cuts the run short and
        the result carries a `DegradedState` (see `core.faults` for the
        phase-granularity semantics; faults at/after completion are no-ops
        and return the clean result).  ``checkpoint_dir`` writes an atomic
        `FabricSnapshot` checkpoint via `repro.checkpoint.store` every
        ``checkpoint_every`` collective boundaries, so recovery can resume
        from the last committed boundary instead of t=0; the returned result
        is equal to the uninterrupted run (the boundary-snapshot invariant).
        The two are mutually exclusive in one call — checkpoint the clean
        run, then replay the faulted one from the restored snapshot
        (`repro.workloads.recovery` drives that loop).
        """
        phases = _validate_phases(phases)
        if self.mode == "full-pause":
            if (initial is not None or capture_state or faults is not None
                    or checkpoint_dir is not None):
                raise ValueError(
                    "snapshot/restore, fault injection and checkpointing "
                    "require mode='sparse' or 'batched': full-pause is the "
                    "stateless legacy baseline (every collective restarts "
                    "from a pre-established topology)")
            return self._trace_full_pause(phases, cm)
        n = phases[0][0].n
        if initial is not None and initial.n != n:
            raise ValueError(
                f"initial snapshot is for n={initial.n}, phases have "
                f"n={n}")
        if faults is not None:
            if checkpoint_dir is not None:
                raise ValueError(
                    "faults and checkpoint_dir are mutually exclusive in "
                    "one call: checkpoint the clean run, then replay the "
                    "faulted one from the restored snapshot "
                    "(repro.workloads.recovery drives that loop)")
            if faults.n != n:
                raise ValueError(
                    f"fault timeline is for n={faults.n}, phases have n={n}")
        if checkpoint_dir is not None:
            return self._trace_checkpointed(
                phases, cm, checkpoint_dir, max(1, int(checkpoint_every)),
                initial=initial, capture_state=capture_state)
        if self.mode == "batched":
            lane = TraceLane(
                phases=phases, overlap=self.overlap,
                link_speed=(tuple(self.link_speed)
                            if self.link_speed is not None else None),
                payload_scale=(tuple(self.payload_scale)
                               if self.payload_scale is not None else None),
                initial=initial, faults=faults)
            batch = batch_run_trace(
                [lane], cm, chunks_per_msg=self.chunks_per_msg)
            res = batch.result(0)
            if capture_state:
                final = (res.degraded.snapshot if res.degraded is not None
                         else batch.snapshot(0))
                res = dataclasses.replace(res, final_state=final)
            return res
        if faults is not None:
            return self._trace_faulted(phases, cm, faults, initial=initial,
                                       capture_state=capture_state)
        out = self._sparse_engine(phases, cm, initial=initial)
        last, k = [], 0
        for sched, _ in phases:
            k += compile_tape(sched).S
            last.append(k - 1)
        final_state = None
        if capture_state:
            final_state = FabricSnapshot(
                n=phases[0][0].n,
                link_offset=phases[-1][0].link_offsets()[-1],
                node_ready=out.node_done, port_free=out.port_free,
                chunks_moved=out.chunks_moved,
                reconfigs_paid=out.reconfigs_paid,
                delta_stall=out.delta_stall)
        return TraceFabricResult(
            completion=out.completion, mode=self.mode,
            phase_done=tuple(out.step_done[i] for i in last),
            step_done=out.step_done,
            node_done=out.node_done, chunks_moved=out.chunks_moved,
            boundary_changed=trace_boundary_changed([s for s, _ in phases]),
            reconfigs_paid=out.reconfigs_paid, delta_stall=out.delta_stall,
            final_state=final_state)

    def _trace_full_pause(self, phases, cm: CostModel) -> TraceFabricResult:
        """Sum of independent full-pause runs, bit-for-bit (the baseline)."""
        total, phase_done = 0.0, []
        step_done: list[float] = []
        chunks = reconfigs = 0
        stall = 0.0
        for sched, m in phases:
            res = self._run_full_pause(sched, m, cm)
            step_done.extend(total + t for t in res.step_done)
            total += res.completion  # same float order as sum(independents)
            phase_done.append(total)
            chunks += res.chunks_moved
            reconfigs += res.reconfigs_paid
            stall += res.delta_stall
        n = phases[0][0].n
        return TraceFabricResult(
            completion=total, mode=self.mode, phase_done=tuple(phase_done),
            step_done=tuple(step_done),
            node_done=(total,) * n, chunks_moved=chunks,
            boundary_changed=trace_boundary_changed([s for s, _ in phases]),
            reconfigs_paid=reconfigs, delta_stall=stall)

    # --- fault injection and checkpointed playback ---------------------------

    def _trace_faulted(self, phases, cm: CostModel, faults: FaultTimeline,
                       *, initial, capture_state) -> TraceFabricResult:
        """Scalar faulted playback: play the trace, find the earliest fault
        that takes effect, and surface the committed prefix as a
        `DegradedState` (phase-granularity semantics, see `core.faults`).

        The clean prefix timings are reused verbatim from the clean run —
        the sparse engine's per-port segment gate means prefix timings never
        depend on suffix traffic, so the committed phases of a faulted run
        are bit-identical to the same phases of the clean one."""
        n = phases[0][0].n
        P = len(phases)
        clean = self.run_trace(phases, cm, initial=initial,
                               capture_state=capture_state)
        pick = None
        for f in faults.faults:
            if f.kind in ABRUPT_KINDS:
                if f.time < clean.completion:
                    done = sum(1 for t in clean.phase_done if t <= f.time)
                    pick = (f, done, done)  # aborts the in-flight phase
                    break
            else:
                # graceful: the in-flight phase drains; effect lands on the
                # first collective boundary at/after the fault time
                done = sum(1 for t in clean.phase_done if t < f.time) + 1
                if done < P:
                    pick = (f, done, None)
                    break
        if pick is None:
            return clean  # no fault takes effect before the trace drains
        fault, completed, aborted = pick

        if fault.kind == "link-down":
            resume = fault.time
        elif fault.kind == "link-flap":
            resume = fault.time + fault.repair_s
        else:
            resume = clean.phase_done[completed - 1]

        if completed > 0:
            snap = self.run_trace(phases[:completed], cm, initial=initial,
                                  capture_state=True).final_state
        else:
            snap = initial
        base = initial.chunks_moved if initial is not None else 0
        committed = (snap.chunks_moved - base) if snap is not None else 0

        in_flight = 0
        if aborted is not None:
            # abrupt: count every chunk service started strictly before the
            # fault; the ones beyond the committed prefix were in flight
            out = self._sparse_engine(phases, cm, initial=initial,
                                      cutoff=fault.time)
            in_flight = max(0, out.cut_chunks - committed)
        survivors, dead = world_after(n, fault)
        degraded = DegradedState(
            fault=fault, policy=faults.policy, n=n, survivors=survivors,
            dead_ports=dead, completed_phases=completed,
            aborted_phase=aborted, resume_clock=resume, snapshot=snap,
            committed_chunks=committed, in_flight_chunks=in_flight,
            lost_chunks=in_flight if faults.policy == "drop" else 0,
            requeued_chunks=in_flight if faults.policy == "requeue" else 0)

        inf = float("inf")
        kept = 0  # concatenated sub-steps belonging to committed phases
        for sched, _ in phases[:completed]:
            kept += compile_tape(sched).S
        return TraceFabricResult(
            completion=inf, mode=self.mode,
            phase_done=(clean.phase_done[:completed]
                        + (inf,) * (P - completed)),
            step_done=(clean.step_done[:kept]
                       + (inf,) * (len(clean.step_done) - kept)),
            node_done=(inf,) * n,
            chunks_moved=base + committed + in_flight,
            boundary_changed=clean.boundary_changed,
            reconfigs_paid=snap.reconfigs_paid if snap is not None else 0,
            delta_stall=snap.delta_stall if snap is not None else 0.0,
            final_state=snap if capture_state else None,
            degraded=degraded)

    def _trace_checkpointed(self, phases, cm: CostModel, directory: str,
                            every: int, *, initial,
                            capture_state) -> TraceFabricResult:
        """Chunked playback with an atomic `FabricSnapshot` checkpoint
        (`repro.checkpoint.store`) every ``every`` collective boundaries.
        Equal to the uninterrupted run: each chunk resumes from the previous
        chunk's captured snapshot, which the boundary-snapshot invariant
        makes exact, and the timings are absolute so concatenation is the
        full-run sequence."""
        from repro.checkpoint import store  # deferred: store imports jax

        phase_done: list[float] = []
        step_done: list[float] = []
        snap, res, done = initial, None, 0
        while done < len(phases):
            chunk = phases[done:done + every]
            res = self.run_trace(chunk, cm, initial=snap, capture_state=True)
            snap = res.final_state
            phase_done.extend(res.phase_done)
            step_done.extend(res.step_done)
            done += len(chunk)
            store.save(directory, done, snapshot_to_tree(snap))
        return TraceFabricResult(
            completion=res.completion, mode=self.mode,
            phase_done=tuple(phase_done), step_done=tuple(step_done),
            node_done=res.node_done, chunks_moved=res.chunks_moved,
            boundary_changed=trace_boundary_changed([s for s, _ in phases]),
            reconfigs_paid=res.reconfigs_paid, delta_stall=res.delta_stall,
            final_state=snap if capture_state else None)

    # --- batched (vectorized tape playback) mode ----------------------------

    def _run_batched(self, schedule: Schedule, m: float,
                     cm: CostModel) -> FabricResult:
        """Single-lane `batchsim.batch_run` (sparse semantics, array ops)."""
        n = schedule.n
        if self.link_speed is not None:
            _validate_rates("link_speed", self.link_speed, n)
        if self.payload_scale is not None:
            _validate_rates("payload_scale", self.payload_scale, n)
        lane = BatchLane(
            schedule=schedule, m_bytes=m, overlap=self.overlap,
            link_speed=(tuple(self.link_speed)
                        if self.link_speed is not None else None),
            payload_scale=(tuple(self.payload_scale)
                           if self.payload_scale is not None else None))
        return batch_run([lane], cm, chunks_per_msg=self.chunks_per_msg).result(0)

    # --- full-pause (legacy-compatible) mode ---------------------------------

    def _run_full_pause(self, schedule: Schedule, m: float,
                        cm: CostModel) -> FabricResult:
        """Synchronized steps + whole-fabric delta pauses, bit-identical to the
        pre-FabricSim `collective_time_event` accumulation order."""
        from .eventsim import simulate_step  # deferred: eventsim wraps us back

        n = schedule.n
        if self.link_speed is not None:
            _validate_rates("link_speed", self.link_speed, n)
        tape = compile_tape(schedule)
        # ``total`` keeps the legacy accumulation order (R*delta upfront) so
        # ``completion`` stays bit-identical to the pre-FabricSim simulator;
        # ``done`` charges each delta at its actual boundary so ``step_done``
        # attributes reconfiguration time to the step that pays it (it can
        # differ from ``total`` in the last ulp due to summation order).
        total = schedule.R * cm.delta
        done = 0.0
        step_done: list[float] = []
        chunks_moved = 0
        for off, cnt, g, xk in zip(tape.offsets, tape.counts, tape.g_step,
                                   tape.boundary, strict=True):
            if xk:
                done += cm.delta
            total += cm.alpha_s
            done += cm.alpha_s
            res = simulate_step(n, g, off, m * cnt / n, cm,
                                self.chunks_per_msg, self.link_speed)
            total += res.completion
            done += res.completion
            chunks_moved += res.chunks_moved
            step_done.append(done)
        return FabricResult(
            completion=total, mode=self.mode, step_done=tuple(step_done),
            node_done=(total,) * n, chunks_moved=chunks_moved,
            changed_links=tape.changed_links,
            reconfigs_paid=schedule.R, delta_stall=schedule.R * cm.delta)

    # --- sparse asynchronous mode --------------------------------------------

    def _run_sparse(self, schedule: Schedule, m: float,
                    cm: CostModel) -> FabricResult:
        out = self._sparse_engine(((schedule, m),), cm)
        return FabricResult(
            completion=out.completion, mode=self.mode,
            step_done=out.step_done, node_done=out.node_done,
            chunks_moved=out.chunks_moved,
            changed_links=compile_tape(schedule).changed_links,
            reconfigs_paid=out.reconfigs_paid, delta_stall=out.delta_stall)

    def _sparse_engine(self, phases: Sequence[tuple[Schedule, float]],
                       cm: CostModel,
                       initial: FabricSnapshot | None = None,
                       cutoff: float | None = None) -> _EngineOut:
        """Asynchronous per-link event loop over one or more concatenated
        phases.  A single phase is exactly the pre-trace `run` semantics; for
        a trace the phases' segment lists are concatenated, so a collective
        boundary behaves like any other segment boundary (ports drain, then
        swap only if the next used segment needs a different circuit).  With
        ``initial`` the ports resume from the snapshot's busy-until times and
        configured circuit, injections chain off the snapshot's per-node
        ready times, and the accounting counters continue cumulatively.
        ``cutoff`` counts (without altering the timeline) the chunk services
        whose start time precedes it — the fault injector's in-flight census
        (strictly-before: a service starting exactly at the cutoff never
        left its source port)."""
        n = phases[0][0].n
        tapes = [compile_tape(sched) for sched, _ in phases]
        offsets: list[int] = []
        hops: list[int] = []
        nbytes_step: list[float] = []
        seg_of: list[int] = []
        seg_g: list[int] = []
        seg_hops: list[int] = []
        for (_, m), tape in zip(phases, tapes, strict=True):
            base = len(seg_g)
            offsets.extend(tape.offsets)
            hops.extend(tape.hops)
            nbytes_step.extend(m * cnt / n for cnt in tape.counts)
            seg_of.extend(base + si for si in tape.seg_of)
            seg_g.extend(tape.seg_g)
            seg_hops.extend(tape.seg_hops)
        S = len(offsets)
        nseg = len(seg_g)
        speed = ([1.0] * n if self.link_speed is None
                 else _validate_rates("link_speed", self.link_speed, n))
        scale = (None if self.payload_scale is None
                 else _validate_rates("payload_scale", self.payload_scale, n))
        C = self.chunks_per_msg
        delta_eff = cm.delta_sparse(1, self.overlap)
        alpha_s, alpha_h, beta = cm.alpha_s, cm.alpha_h, cm.beta

        def chunk_bytes(u: int, k: int) -> float:
            nbytes = nbytes_step[k]
            if scale is not None:
                nbytes *= scale[(u + offsets[k]) % n]
            return nbytes / C

        # expected chunk services per (port, segment): the swap trigger.
        # Uniform-offset ring traffic visits every port identically, so the
        # per-segment count is just C * (total hops in the segment).
        expected = [[C * sh for sh in seg_hops] for _ in range(n)]

        # per-port state (warm-started from the snapshot when resuming)
        cfg_seg = [0] * n            # segment whose traffic the port serves
        cfg_g = [seg_g[0]] * n       # circuit offset physically configured
        free = [0.0] * n             # port busy-until (service or swap)
        served = [[0] * nseg for _ in range(n)]
        pend: list[list] = [[] for _ in range(n)]  # (seg, step, t, seq, u, c, j)

        rcount = [[0] * S for _ in range(n)]
        recv_done = [[0.0] * S for _ in range(n)]
        step_done = [0.0] * S
        chunks_moved = 0
        cut_chunks = 0
        reconfigs_paid = 0
        delta_stall = 0.0
        if initial is not None:
            free = list(initial.port_free)
            chunks_moved = initial.chunks_moved
            reconfigs_paid = initial.reconfigs_paid
            delta_stall = initial.delta_stall
            if seg_g[0] != initial.link_offset:
                # entering the resumed phases is a carryover boundary like
                # any other: every port carries first-segment traffic, so
                # every port swaps off the inherited circuit
                for port in range(n):
                    free[port] += delta_eff
                    delta_stall += delta_eff
                    reconfigs_paid += 1

        heap: list[tuple] = []  # (t, seq, is_free, port, step, src, chunk, hop)
        seq = 0
        if initial is not None:
            # inherited busy-until times have no in-run completion event, so
            # seed one free event per port: a chunk arriving while the port
            # is still draining snapshot-time work (or the entry swap) must
            # be re-triggered, not stranded in pend
            for port in range(n):
                heapq.heappush(heap, (free[port], seq, 1, port, 0, 0, 0, 0))
                seq += 1

        def advance(port: int) -> None:
            """Move the port past fully-served segments, paying delta only
            when the next *used* segment needs a different circuit."""
            nonlocal reconfigs_paid, delta_stall, seq
            moved = False
            while (cfg_seg[port] < nseg - 1
                   and served[port][cfg_seg[port]] >= expected[port][cfg_seg[port]]):
                nxt = cfg_seg[port] + 1
                if expected[port][nxt] > 0 and seg_g[nxt] != cfg_g[port]:
                    free[port] += delta_eff  # swap starts after the last service
                    delta_stall += delta_eff
                    reconfigs_paid += 1
                    cfg_g[port] = seg_g[nxt]
                cfg_seg[port] = nxt
                moved = True
            if moved:
                heapq.heappush(heap, (free[port], seq, 1, port, 0, 0, 0, 0))
                seq += 1

        def serve(port: int, now: float) -> None:
            nonlocal chunks_moved, cut_chunks, seq
            if not pend[port] or pend[port][0][0] != cfg_seg[port]:
                return
            if free[port] > now:
                return  # busy: the pending free event re-triggers us
            si, k, t_arr, _, u, c, j = heapq.heappop(pend[port])
            start = free[port] if free[port] > t_arr else t_arr
            if cutoff is not None and start < cutoff:
                cut_chunks += 1
            tx = chunk_bytes(u, k) * beta / speed[port]
            free[port] = start + tx
            served[port][si] += 1
            chunks_moved += 1
            t_next = start + tx + alpha_h
            heapq.heappush(heap, (free[port], seq, 1, port, 0, 0, 0, 0))
            seq += 1
            g = seg_g[si]
            if j + 1 < hops[k]:
                nxt_port = (u + (j + 1) * g) % n
                heapq.heappush(heap, (t_next, seq, 0, nxt_port, k, u, c, j + 1))
                seq += 1
            else:
                deliver((u + offsets[k]) % n, k, t_next)
            if served[port][si] == expected[port][si]:
                advance(port)

        def deliver(v: int, k: int, t_arr: float) -> None:
            nonlocal seq
            rcount[v][k] += 1
            if t_arr > recv_done[v][k]:
                recv_done[v][k] = t_arr
            if t_arr > step_done[k]:
                step_done[k] = t_arr
            if rcount[v][k] == C and k + 1 < S:
                t_inj = recv_done[v][k] + alpha_s
                for c in range(C):
                    heapq.heappush(heap, (t_inj, seq, 0, v, k + 1, v, c, 0))
                    seq += 1

        for u in range(n):
            t0 = (alpha_s if initial is None
                  else initial.node_ready[u] + alpha_s)
            for c in range(C):
                heapq.heappush(heap, (t0, seq, 0, u, 0, u, c, 0))
                seq += 1
        for port in range(n):
            advance(port)  # fast-forward ports with no early-segment traffic

        while heap:
            t, sq, is_free, port, k, u, c, j = heapq.heappop(heap)
            if not is_free:
                heapq.heappush(pend[port], (seg_of[k], k, t, sq, u, c, j))
            serve(port, t)

        node_done = tuple(recv_done[v][S - 1] for v in range(n))
        return _EngineOut(
            completion=max(node_done), step_done=tuple(step_done),
            node_done=node_done, chunks_moved=chunks_moved,
            reconfigs_paid=reconfigs_paid, delta_stall=delta_stall,
            port_free=tuple(free), cut_chunks=cut_chunks)


def simulate_fabric(schedule: Schedule, m: float, cm: CostModel,
                    **knobs) -> FabricResult:
    """Convenience wrapper: ``FabricSim(**knobs).run(schedule, m, cm)``."""
    return FabricSim(**knobs).run(schedule, m, cm)


def simulate_trace(phases: Sequence[tuple[Schedule, float]], cm: CostModel,
                   **knobs) -> TraceFabricResult:
    """Convenience wrapper: ``FabricSim(**knobs).run_trace(phases, cm)``."""
    return FabricSim(**knobs).run_trace(phases, cm)


def straggler_speeds(n: int, slow: dict[int, float]) -> list[float]:
    """Per-link rate vector with nodes in ``slow`` running at the given rate
    (e.g. ``{n // 2: 0.25}`` = one transceiver at quarter speed)."""
    speeds = [1.0] * n
    for node, rate in slow.items():
        if not 0 <= node < n:
            raise ValueError(f"straggler node {node} outside [0, {n})")
        if rate <= 0:
            raise ValueError(f"straggler rate must be > 0, got {rate}")
        speeds[node] = rate
    return speeds
