"""Minimal connected subrings (paper Section 3.2) and topology evolution.

A topology in this model is always a *uniform-offset ring family*: the OCS
links are { u -> (u + g) mod n : all u } for a single link offset ``g``.

  g = 1      : the initial physical ring.
  g = 2^k    : the BRIDGE reconfiguration for radix-2 Bruck step k.  It
               partitions the network into gcd(g, n) = 2^k subrings
               S_i^{(k)} = { u : u = i (mod 2^k) }, each of size n / 2^k.
  g = r^k    : the radix-r generalization (and, within a segment spanning
               several digit values j * r^k, the gcd of the segment's
               message offsets).

Lemma (3.2), generalized: Topology(n, g) partitions the nodes into
gcd(g, n) subrings of size n / gcd(g, n), and a destination at message
offset ``mo`` is reachable iff g divides mo — in exactly mo / g hops
(mo < n and mo/g < n/g <= subring cycle length, so the walk never wraps).
For the paper's radix-2 power-of-two case every later offset 2^j (j >= k)
is a multiple of 2^k, so traffic never leaves the subring; for mixed-radix
schedules the segment link offset is the gcd of the segment's offsets,
which preserves the same divisibility invariant at arbitrary n.

Port-constrained networks (paper Section 3.7): with z < 2n OCS ports, blocks
of ceil(2n/z) consecutive nodes share one optical ingress/egress pair, so a
reconfiguration reduces the effective distance only to ~2n/z, not to 1.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Topology:
    """Uniform-offset ring family over n nodes with link offset g."""

    n: int
    g: int

    def __post_init__(self):
        if self.n % math.gcd(self.g, self.n) != 0:
            raise ValueError("inconsistent")
        if self.g <= 0 or self.g >= self.n:
            raise ValueError(f"link offset must be in [1, n), got g={self.g} n={self.n}")

    @property
    def num_subrings(self) -> int:
        return math.gcd(self.g, self.n)

    @property
    def subring_size(self) -> int:
        return self.n // self.num_subrings

    def successor(self, u: int) -> int:
        return (u + self.g) % self.n

    def subring_of(self, u: int) -> int:
        return u % self.num_subrings

    def subring_members(self, i: int) -> list[int]:
        """S_i = { u : u = i mod gcd(g, n) } (paper's S_i^{(k)} for g = 2^k)."""
        return [u for u in range(self.n) if u % self.num_subrings == i % self.num_subrings]

    def hops(self, src: int, dst: int, max_hops: int | None = None) -> int:
        """Directed hop count src -> dst by explicitly walking the links.

        Raises ValueError when dst is unreachable (different subring), which a
        *valid* reconfiguration schedule must never trigger.
        """
        limit = max_hops if max_hops is not None else self.n
        u, h = src, 0
        while u != dst:
            u = self.successor(u)
            h += 1
            if h > limit:
                raise ValueError(
                    f"{dst} unreachable from {src} with link offset {self.g} (n={self.n})"
                )
        return h

    def max_link_load(self, msg_offset: int) -> int:
        """Congestion factor when every node u sends one flow to u + msg_offset.

        Computed by explicit routing: each flow occupies every directed link on
        its path; returns the max number of flows sharing any link.
        """
        load: dict[tuple[int, int], int] = {}
        for src in range(self.n):
            dst = (src + msg_offset) % self.n
            u = src
            for _ in range(self.n + 1):
                if u == dst:
                    break
                v = self.successor(u)
                load[(u, v)] = load.get((u, v), 0) + 1
                u = v
            else:
                raise ValueError("unreachable destination while routing")
        return max(load.values()) if load else 0


def ring(n: int) -> Topology:
    return Topology(n=n, g=1)


def subring_topology(n: int, k: int, r: int = 2) -> Topology:
    """The BRIDGE topology after reconfiguring for Bruck phase k (offset r^k)."""
    return Topology(n=n, g=r**k)


def validate_schedule_reachability(n: int, offsets: list[int], link_offsets: list[int]) -> None:
    """Assert every step's destination is reachable on its assigned topology.

    offsets[k]      : message offset of sub-step k (j * r^k for RS/A2A,
                      reversed for AG; 2^k in the radix-2 case)
    link_offsets[k] : OCS link offset in force during sub-step k
    """
    for k, (mo, lo) in enumerate(zip(offsets, link_offsets, strict=True)):
        if mo % lo != 0:
            raise ValueError(
                f"step {k}: message offset {mo} not a multiple of link offset {lo}; "
                "destination would leave the subring"
            )
        topo = Topology(n=n, g=lo)
        # spot-check by walking from node 0 and node 1
        for src in (0, 1 % n):
            topo.hops(src, (src + mo) % n)


# --- Port-constrained extension (paper Section 3.7) -------------------------


@dataclasses.dataclass(frozen=True)
class BlockedRing:
    """Hierarchical ring: blocks of consecutive nodes share 2 OCS ports.

    With z optical ports for n nodes, blocks hold B = ceil(2n/z) nodes.
    Intra-block hops are electrical (static); only block-boundary links are
    reconfigurable.  A reconfiguration therefore reduces the effective
    distance of a step to ~B hops rather than 1 (paper 3.7).
    """

    n: int
    ports: int

    @property
    def block_size(self) -> int:
        return max(1, math.ceil(2 * self.n / self.ports))

    def effective_hops(self, msg_offset: int, link_offset: int) -> int:
        """Hops for a step with message offset given OCS links at link_offset.

        Without port limits this is msg_offset / link_offset.  With blocks of
        size B, the optical shortcut only connects block boundaries, so the
        distance floor after any reconfiguration is B (never worse than the
        static distance).
        """
        if msg_offset % link_offset:
            raise ValueError("unreachable: message offset not multiple of link offset")
        unconstrained = msg_offset // link_offset
        if self.block_size == 1:
            return unconstrained
        if link_offset == 1:
            return msg_offset  # static ring: electrical path, no OCS involved
        return min(msg_offset, unconstrained * self.block_size)
