"""Topology-aware extended Hockney alpha-beta cost model (paper Section 2).

T(m, A) = sigma(A) * alpha_s
        + sum_k h_k * alpha_h
        + sum_k m_k * c_k * beta
        + R * delta

where, per communication step k:
  alpha_s : per-step startup latency (data preparation), seconds
  alpha_h : per-hop latency (propagation + per-hop processing), seconds
  h_k     : hops to reach the step's destination on the current topology
  m_k     : bytes transmitted in step k
  c_k     : congestion factor (overlapping flows per link)
  beta    : seconds per byte (inverse bandwidth)
  delta   : reconfiguration delay, R: number of reconfigurations

All quantities are SI (seconds, bytes). The model deliberately omits compute
cost (identical across collective algorithms; paper Section 2).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Network cost parameters for one deployment."""

    alpha_s: float = 1.7e-6      # per-step latency [s] (InfiniBand-class, paper 4.1)
    alpha_h: float = 1.0e-6      # per-hop latency [s]
    bandwidth: float = 100e9     # bytes/s (800 Gbps default, paper 4.1)
    delta: float = 10e-6         # reconfiguration delay [s] (RotorNet, Table 2)

    @property
    def beta(self) -> float:
        return 1.0 / self.bandwidth

    def step_cost(self, *, hops: int, nbytes: float, congestion: float) -> float:
        """Cost of a single communication step (no reconfiguration term)."""
        return self.alpha_s + hops * self.alpha_h + nbytes * congestion * self.beta

    def delta_sparse(self, changed_links: int, overlap: float = 0.0) -> float:
        """Effective stall of one *sparse* reconfiguration event.

        Only the ``changed_links`` circuits that actually differ between
        consecutive segments are rewired; the surviving subring links keep
        carrying traffic, and a fraction ``overlap`` of the switching time is
        hidden behind concurrent communication (SWOT-style
        reconfiguration/communication overlap).  Switching is parallel across
        ports, so any change blocks its dependent paths for the residual
        ``delta * (1 - overlap)``; a boundary that changes nothing is free.

        The batch fabric engine (`core.batchsim`) applies the same
        ``delta * (1 - overlap)`` charge per lane with the lane's own delta
        override, which is why it computes the term inline rather than
        through this method.
        """
        if not 0.0 <= overlap <= 1.0:
            raise ValueError(f"overlap must be in [0, 1], got {overlap}")
        if changed_links <= 0:
            return 0.0
        return self.delta * (1.0 - overlap)

    def total(self, steps: Iterable[tuple[int, float, float]], n_reconfigs: int) -> float:
        """Sum step costs (hops, nbytes, congestion) plus R * delta."""
        t = n_reconfigs * self.delta
        for hops, nbytes, congestion in steps:
            t += self.step_cost(hops=hops, nbytes=nbytes, congestion=congestion)
        return t

    def replace(self, **kw) -> "CostModel":
        return dataclasses.replace(self, **kw)


def gbps(x: float) -> float:
    """Link rate in Gbps -> bytes/s."""
    return x * 1e9 / 8.0


# --- Hardware presets ------------------------------------------------------

#: OCS technologies from paper Table 2: name -> (reconfig time [s], ports)
OCS_TECHNOLOGIES: dict[str, tuple[float, int]] = {
    "sip_lightmatter": (7e-6, 32),
    "rotornet_infocus": (10e-6, 128),
    "3d_mems_calient": (15e-3, 320),
    "piezo_polatis": (25e-3, 576),
}

#: Paper Section 4.1 headline configuration.
PAPER_DEFAULT = CostModel(
    alpha_s=1.7e-6, alpha_h=1.0e-6, bandwidth=gbps(800), delta=10e-6
)

#: TPU v5e-like constants used by the roofline/bridge planner (per chip).
TPU_V5E = CostModel(
    alpha_s=1.0e-6,           # collective phase launch overhead
    alpha_h=0.5e-6,           # ICI per-hop latency (approx)
    bandwidth=50e9,           # ~50 GB/s per ICI link direction
    delta=1.0e-6,             # per-segment fusion/launch barrier (see DESIGN.md S3)
)


def ocs_preset(tech: str, **overrides) -> CostModel:
    """CostModel preset for an OCS technology from paper Table 2."""
    d, _ports = OCS_TECHNOLOGIES[tech]
    cm = PAPER_DEFAULT.replace(delta=d)
    return cm.replace(**overrides) if overrides else cm


def ocs_ports(tech: str) -> int:
    return OCS_TECHNOLOGIES[tech][1]


def sweep(base: CostModel, **axes: Sequence[float]) -> list[CostModel]:
    """Cartesian sweep over cost-model fields, e.g. sweep(cm, delta=[1e-6, 1e-3])."""
    models = [base]
    for field, values in axes.items():
        models = [m.replace(**{field: v}) for m in models for v in values]
    return models
