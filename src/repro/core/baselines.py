"""Baseline collective algorithms/strategies from the paper's evaluation.

  S-BRUCK : static Bruck, never reconfigures (schedule x = 0).
  G-BRUCK : greedy BvN Bruck, reconfigures before every step (after step 0,
            whose offset-1 exchange is already direct on the initial ring).
  RING    : bandwidth-optimal ring algorithm (Hamiltonian ring);
            (n-1) unit-hop steps of m/n for RS/AG, 2(n-1) for AllReduce.
  DIRECT  : n-1 point-to-point exchange All-to-All on the static ring.
  HD      : static halving-doubling; identical per-step distance/data sequence
            to Bruck on static fabrics (paper Section 2), pairwise not cyclic.
  R-HD    : reconfigurable HD (prior work): ring until the first
            reconfiguration; each reconfigured matching helps only its own
            step, so every step after the first reconfiguration must also
            reconfigure => with R reconfigurations the *last* R steps are
            matched at h = c = 1 and R*delta is charged.
"""
from __future__ import annotations

from .bruck import Collective, steps_for
from .cost_model import CostModel
from .schedules import every_step_schedule, plan, static_schedule
from .simulator import StepCost, TimeBreakdown, collective_time


def s_bruck(kind: Collective, n: int, m: float, cm: CostModel, r: int = 2,
            **kw) -> TimeBreakdown:
    return collective_time(static_schedule(kind, n, r), m, cm, **kw)


def g_bruck(kind: Collective, n: int, m: float, cm: CostModel, r: int = 2,
            **kw) -> TimeBreakdown:
    return collective_time(every_step_schedule(kind, n, r), m, cm, **kw)


def _uniform_steps(count: int, nbytes: float, cm: CostModel) -> TimeBreakdown:
    t_step = cm.step_cost(hops=1, nbytes=nbytes, congestion=1.0)
    steps = tuple(StepCost(i, 1, 1.0, nbytes, False, t_step) for i in range(count))
    return TimeBreakdown(
        startup=count * cm.alpha_s,
        hop_latency=count * cm.alpha_h,
        transmission=count * nbytes * cm.beta,
        reconfig=0.0,
        steps=steps,
    )


def ring(kind: str, n: int, m: float, cm: CostModel) -> TimeBreakdown:
    """RING algorithm: neighbor-only steps, no congestion, no reconfiguration."""
    if kind in ("rs", "ag"):
        return _uniform_steps(n - 1, m / n, cm)
    if kind == "ar":
        return _uniform_steps(2 * (n - 1), m / n, cm)
    raise ValueError(f"ring not defined for {kind}")


def direct_a2a(n: int, m: float, cm: CostModel) -> TimeBreakdown:
    """n-1 point-to-point exchanges on the static ring (paper Section 2)."""
    startup = hop = tx = 0.0
    steps = []
    for j in range(1, n):
        h = j  # node u -> u + j: j hops, congestion j (uniform offset traffic)
        t = cm.step_cost(hops=h, nbytes=m / n, congestion=float(h))
        startup += cm.alpha_s
        hop += h * cm.alpha_h
        tx += (m / n) * h * cm.beta
        steps.append(StepCost(j - 1, h, float(h), m / n, False, t))
    return TimeBreakdown(startup, hop, tx, 0.0, tuple(steps))


# --- Halving-Doubling --------------------------------------------------------


def _hd_phase_steps(kind: Collective, n: int, m: float, r: int = 2) -> list:
    """HD has the same (distance, bytes) sequence per phase as Bruck (paper S2)."""
    return steps_for(kind, n, m, r)


def hd_static(kind: Collective, n: int, m: float, cm: CostModel,
              r: int = 2) -> TimeBreakdown:
    """Static HD: h = c = distance on the ring for every step."""
    startup = hop = tx = 0.0
    per = []
    for st in _hd_phase_steps(kind, n, m, r):
        h = st.offset
        t = cm.step_cost(hops=h, nbytes=st.nbytes, congestion=float(h))
        startup += cm.alpha_s
        hop += h * cm.alpha_h
        tx += st.nbytes * h * cm.beta
        per.append(StepCost(st.index, h, float(h), st.nbytes, False, t))
    return TimeBreakdown(startup, hop, tx, 0.0, tuple(per))


def hd_allreduce_static(n: int, m: float, cm: CostModel) -> TimeBreakdown:
    return hd_static("rs", n, m, cm) + hd_static("ag", n, m, cm)


def r_hd(
    kind: str, n: int, m: float, cm: CostModel, R: int, r: int = 2
) -> TimeBreakdown:
    """Reconfigurable HD with exactly R reconfigurations (suffix-matched).

    kind: 'rs', 'ag' or 'ar' (= rs phase followed by ag phase, 2s steps).
    The last R steps run on per-step matchings (h = c = 1) at delta each; all
    earlier steps run on the static ring.
    """
    if kind == "ar":
        seq = _hd_phase_steps("rs", n, m, r) + _hd_phase_steps("ag", n, m, r)
    else:
        seq = _hd_phase_steps(kind, n, m, r)
    total = len(seq)
    if not (0 <= R <= total):
        raise ValueError(f"R={R} out of range for {total} steps")
    startup = hop = tx = 0.0
    per = []
    for i, st in enumerate(seq):
        matched = i >= total - R
        h = 1 if matched else st.offset
        t = cm.step_cost(hops=h, nbytes=st.nbytes, congestion=float(h))
        if matched:
            t += cm.delta
        startup += cm.alpha_s
        hop += h * cm.alpha_h
        tx += st.nbytes * h * cm.beta
        per.append(StepCost(i, h, float(h), st.nbytes, matched, t))
    return TimeBreakdown(startup, hop, tx, R * cm.delta, tuple(per))


def r_hd_optimal(kind: str, n: int, m: float, cm: CostModel,
                 r: int = 2) -> tuple[TimeBreakdown, int]:
    """R-HD with the completion-time-optimal number of reconfigurations."""
    total = len(_hd_phase_steps("rs", n, m, r)) * (2 if kind == "ar" else 1)
    best, best_R = None, 0
    for R in range(total + 1):
        t = r_hd(kind, n, m, cm, R, r)
        if best is None or t.total < best.total:
            best, best_R = t, R
    assert best is not None
    return best, best_R


def r_hd_episodic_time(kind: str, n: int, m: float, cm: CostModel,
                       r: int = 2) -> float:
    """Beyond-paper *strengthened* R-HD adversary (returns completion time).

    The paper's R-HD reconfigures once and must then keep reconfiguring (the
    matching destroys the ring).  This variant may also pay a second delta to
    restore the ring after a shortcut episode, so any subset of steps can be
    matched.  Optimal choice is per-step: match step k iff the saving
    (alpha_h + beta*m_k)(d_k - 1) exceeds its reconfiguration charge; a step
    adjacent to another matched step shares the return-to-ring delta.
    Solved exactly by a tiny DP over (step, currently-matched) states.
    """
    if kind == "ar":
        seq = _hd_phase_steps("rs", n, m, r) + _hd_phase_steps("ag", n, m, r)
    else:
        seq = _hd_phase_steps(kind, n, m, r)
    INF = float("inf")
    # dp[state]: state 0 = on ring, 1 = on matching (must pay delta to leave
    # or to re-match for the next step's pairs)
    dp = {0: 0.0, 1: INF}
    for st in seq:
        ring_cost = cm.step_cost(hops=st.offset, nbytes=st.nbytes,
                                 congestion=float(st.offset))
        match_cost = cm.step_cost(hops=1, nbytes=st.nbytes, congestion=1.0)
        ndp = {
            # stay/return to ring (returning costs delta)
            0: min(dp[0] + ring_cost, dp[1] + cm.delta + ring_cost),
            # (re-)configure a matching for this step's pairs: delta always
            1: min(dp[0], dp[1]) + cm.delta + match_cost,
        }
        dp = ndp
    return min(dp[0], dp[1] + cm.delta)  # restore the ring at the end


# --- BRIDGE end-to-end -------------------------------------------------------


def bridge(kind: Collective, n: int, m: float, cm: CostModel,
           paper_faithful: bool = True, r: int = 2) -> TimeBreakdown:
    """BRIDGE with the optimal schedule and optimal R (paper Section 3.6)."""
    p = plan(kind, n, m, cm, paper_faithful=paper_faithful, r=r)
    return collective_time(p.schedule, m, cm)


def bridge_allreduce(n: int, m: float, cm: CostModel,
                     paper_faithful: bool = True, r: int = 2) -> TimeBreakdown:
    """BRIDGE AllReduce = optimal RS phase + optimal AG phase (+ transition)."""
    from .simulator import allreduce_time

    rs = plan("rs", n, m, cm, paper_faithful=paper_faithful, r=r).schedule
    ag = plan("ag", n, m, cm, paper_faithful=paper_faithful, r=r).schedule
    return allreduce_time(rs, ag, m, cm)


def bridge_allreduce_fixed_R(n: int, m: float, cm: CostModel, R: int,
                             r: int = 2) -> TimeBreakdown:
    """Best BRIDGE AllReduce using exactly R reconfigurations total (Fig. 1).

    Searches the split of R between the RS and AG phases; within a phase uses
    the exact fixed-R schedule (full-cost DP).
    """
    from .bruck import schedule_length
    from .schedules import full_cost_optimal
    from .simulator import allreduce_time

    s = schedule_length("rs", n, r)
    best = None
    for r_rs in range(0, min(R, s - 1) + 1):
        r_ag = R - r_rs
        if r_ag > s - 1:
            continue
        rs = full_cost_optimal("rs", n, m, cm, r_rs, r)
        ag = full_cost_optimal("ag", n, m, cm, r_ag, r)
        t = allreduce_time(rs, ag, m, cm)
        if best is None or t.total < best.total:
            best = t
    assert best is not None
    return best
