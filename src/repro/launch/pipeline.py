"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The 'pod' axis can run pipeline stages instead of data parallelism: each
device along the axis holds one contiguous stage of layers; microbatches
stream through with a `ppermute(+1)` activation hand-off per tick —
`n_micro + n_stages - 1` ticks total (the classic GPipe schedule; bubble
fraction (S-1)/(M+S-1)).

This is a composable utility deliberately independent of the model zoo: any
`stage_fn(stage_params, x) -> x` works.  Used in tests on a CPU mesh, and
available to the launcher for cross-pod pipelining (DESIGN.md S5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.collectives._compat import axis_size as _axis_size
from repro.collectives._compat import pcast as _pcast
from repro.collectives._compat import shard_map as _shard_map


def _shift_perm(n: int, offset: int) -> list[tuple[int, int]]:
    return [(i, (i + offset) % n) for i in range(n)]


def pipeline_apply(stage_fn, stage_params, x_micro, axis_name: str):
    """Run microbatches through pipeline stages laid out on `axis_name`.

    Must be called inside shard_map.  Args (per device):
      stage_params : this device's stage parameters
      x_micro      : (M, mb, ...) all microbatches (only stage 0 reads them)
    Returns (M, mb, ...) final-stage outputs (valid on the last stage; other
    stages return zeros), suitable for psum/gather by the caller.
    """
    n = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x_micro.shape[0]
    mb_shape = x_micro.shape[1:]
    ticks = m + n - 1

    out = jnp.zeros_like(x_micro)
    carry = jnp.zeros(mb_shape, x_micro.dtype)
    # mark the loop state as device-varying over the pipeline axis (the loop
    # body mixes in axis_index / ppermute results, which are varying)
    out = _pcast(out, (axis_name,), to="varying")
    carry = _pcast(carry, (axis_name,), to="varying")

    def tick(t, state):
        out, carry = state
        # stage 0 ingests microbatch t (if in range); others take the carry
        mb_in = jax.lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, m - 1), axis=0, keepdims=False)
        x_in = jnp.where(idx == 0, mb_in, carry)
        y = stage_fn(stage_params, x_in)
        # last stage writes its finished microbatch (t - (n-1))
        done_idx = t - (n - 1)
        write = (idx == n - 1) & (done_idx >= 0)
        upd = jax.lax.dynamic_update_index_in_dim(
            out, y, jnp.clip(done_idx, 0, m - 1), axis=0)
        out = jnp.where(write, upd, out)
        # hand activations to the next stage
        carry = jax.lax.ppermute(y, axis_name, _shift_perm(n, 1))
        return out, carry

    out, _ = jax.lax.fori_loop(0, ticks, tick, (out, carry))
    return out


def run_pipeline(mesh, axis_name, stage_fn, all_stage_params, x, n_micro):
    """Convenience wrapper: shard params by stage, split x into microbatches,
    run the pipeline, return outputs gathered at the caller.

    all_stage_params: pytree with leading dim = n_stages.
    x: (batch, ...) with batch % n_micro == 0.
    """
    n_stages = mesh.shape[axis_name]
    b = x.shape[0]
    assert b % n_micro == 0
    x_micro = x.reshape(n_micro, b // n_micro, *x.shape[1:])

    def body(stage_params, xm):
        # stage_params arrives with a leading dim of 1 (its stage slice)
        stage_params = jax.tree.map(lambda p: p[0], stage_params)
        out = pipeline_apply(stage_fn, stage_params, xm, axis_name)
        # broadcast final-stage outputs to every stage for uniform return
        return jax.lax.psum(out, axis_name)

    out = _shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
    )(all_stage_params, x_micro)
    return out.reshape(b, *out.shape[2:])
