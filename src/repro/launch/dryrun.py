import os
# Drop any inherited device-count flag (e.g. the CI matrix leg's 8-device
# XLA_FLAGS): the last occurrence wins in XLA, and the dry run needs 512.
_inherited = " ".join(
    tok for tok in os.environ.get("XLA_FLAGS", "").split()
    if not tok.startswith("--xla_force_host_platform_device_count"))
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + _inherited).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  - 16x16 single-pod mesh (256 chips) and 2x16x16 multi-pod mesh (512 chips);
  - train_4k lowers train_step (fwd+bwd+AdamW), prefill_32k lowers
    prefill, decode_32k / long_500k lower serve_step (one token against a
    full KV cache);
  - records memory_analysis(), cost_analysis() and the per-op collective
    byte counts parsed from the compiled HLO into a JSON report consumed by
    benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch rwkv6-3b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.collectives._compat import cost_analysis_dict  # noqa: E402
from repro.data.pipeline import make_batch_specs  # noqa: E402
from repro.models import (SHAPES, decode_step, init_caches, init_params,  # noqa: E402
                          loss_fn, prefill)
from repro.models.sharding import activation_sharding  # noqa: E402
from repro.optim import adamw_init, adamw_update  # noqa: E402

from .mesh import batch_axes, make_production_mesh  # noqa: E402
from .shardings import (activation_rules, batch_shardings, cache_shardings,  # noqa: E402
                        param_shardings)

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


# --- HLO collective accounting ------------------------------------------------


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _dtype_bytes(name: str) -> int:
    return {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
            "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
            "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1}.get(name, 4)


def _first_shape_bytes(text: str) -> int:
    """Bytes of the result shape(s) at the start of an HLO instruction line."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _dtype_bytes(dt)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by op kind."""
    out = {k: {"bytes": 0, "count": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # "%name = f32[...] all-reduce(...)" / fusion-wrapped starts too
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)", ls)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                        r"collective-permute)(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        if "-done(" in rhs:  # avoid double counting start/done pairs
            continue
        kind = opm.group(1)
        head = rhs[:opm.start()]
        out[kind]["bytes"] += _first_shape_bytes(head)
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# --- step builders --------------------------------------------------------------


def build_train_step(cfg, mesh, seq_parallel: bool = False):
    rules = activation_rules(mesh, seq_parallel)

    def train_step(params, opt_state, batch):
        with activation_sharding(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               lr=3e-4)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def build_prefill_step(cfg, mesh, max_seq):
    rules = activation_rules(mesh)

    def prefill_step(params, batch):
        with activation_sharding(mesh, rules):
            return prefill(cfg, params, batch, max_seq=max_seq)

    return prefill_step


def build_decode_step(cfg, mesh):
    rules = activation_rules(mesh)

    def serve_step(params, token, caches):
        with activation_sharding(mesh, rules):
            return decode_step(cfg, params, token, caches)

    return serve_step


# --- cell runner -----------------------------------------------------------------


VARIANTS = ("baseline", "logits-sharded", "moe-ep-data", "remat-dots",
            "remat-none", "kv-seq-sharded", "moe-vmap", "serve-tp-params",
            "seq-parallel")


def _apply_variant(cfg, variant: str):
    tweaks = {v.strip() for v in variant.split(",") if v.strip()}
    unknown = tweaks - set(VARIANTS)
    if unknown:
        raise ValueError(f"unknown variant(s) {unknown}; known: {VARIANTS}")
    if "remat-dots" in tweaks:
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    if "remat-none" in tweaks:
        cfg = dataclasses.replace(cfg, remat_policy="none")
    if "moe-vmap" in tweaks and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, vectorize_groups=True,
                                         group_size=128))
    return cfg, tweaks


def _lower_cell(cfg, shape, mesh, variant: str = "baseline"):
    """Lower one (config, shape) on a mesh; returns the Lowered object."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from .mesh import batch_axes

    cfg, tweaks = _apply_variant(cfg, variant)
    moe_axis = "data" if "moe-ep-data" in tweaks else "model"
    fsdp = "serve-tp-params" not in tweaks

    params_shapes = jax.eval_shape(functools.partial(init_params, cfg),
                                   jax.random.PRNGKey(0))
    p_shard = param_shardings(mesh, params_shapes, moe_expert_axis=moe_axis,
                              fsdp=fsdp)
    batch_specs = make_batch_specs(cfg, shape)
    b_shard = batch_shardings(mesh, batch_specs)

    if shape.mode == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        o_shard = param_shardings(mesh, opt_shapes, moe_expert_axis=moe_axis)
        step = build_train_step(cfg, mesh,
                                seq_parallel="seq-parallel" in tweaks)
        with mesh:
            return jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
            ).lower(params_shapes, opt_shapes, batch_specs)
    if shape.mode == "prefill":
        step = build_prefill_step(cfg, mesh, max_seq=shape.seq_len)
        with mesh:
            return jax.jit(
                step, in_shardings=(p_shard, b_shard),
            ).lower(params_shapes, batch_specs)
    cache_shapes = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len))
    c_shard = cache_shardings(mesh, cache_shapes,
                              kv_seq_shard="kv-seq-sharded" in tweaks)
    step = build_decode_step(cfg, mesh)
    logits_shard = None
    if "logits-sharded" in tweaks:
        # decode returns (logits (B, V), caches): keep logits distributed —
        # batch over (pod, data), vocab over model — instead of replicating
        baxes = batch_axes(mesh)
        vspec = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
        logits_shard = NamedSharding(mesh, P(baxes, vspec))
    with mesh:
        return jax.jit(
            step,
            in_shardings=(p_shard, b_shard["tokens"], c_shard),
            out_shardings=(logits_shard, c_shard),
        ).lower(params_shapes, batch_specs["tokens"], cache_shapes)


def _cell_metrics(compiled) -> dict:
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops") or 0.0,
            "bytes_accessed": cost.get("bytes accessed") or 0.0,
            "collective_bytes": coll["total_bytes"],
            "collectives": coll}


def calibrate_depth(cfg, shape, mesh, variant: str = "baseline") -> dict:
    """Per-layer cost recovery: XLA cost analysis counts scan bodies ONCE, so
    lower unrolled 1-period and 2-period variants and extrapolate:
      P = X(2p) - X(p);  corrected = X(p) + P * (L/p - 1).
    """
    p = len(cfg.pattern)
    L = cfg.num_layers
    factor = L / p
    enc1 = max(1, round(cfg.num_encoder_layers / factor)) if cfg.enc_dec else 0
    small = dataclasses.replace(cfg, num_layers=p, unroll_layers=True,
                                num_encoder_layers=enc1)
    double = dataclasses.replace(cfg, num_layers=2 * p, unroll_layers=True,
                                 num_encoder_layers=2 * enc1)
    m1 = _cell_metrics(_lower_cell(small, shape, mesh, variant).compile())
    m2 = _cell_metrics(_lower_cell(double, shape, mesh, variant).compile())
    out = {}
    for k in ("flops", "bytes_accessed", "collective_bytes"):
        per_period = max(0.0, m2[k] - m1[k])
        out[k] = m1[k] + per_period * (factor - 1)
    out["per_period"] = {k: m2[k] - m1[k]
                         for k in ("flops", "bytes_accessed",
                                   "collective_bytes")}
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             calibrate: bool = True, variant: str = "baseline") -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    lowered = _lower_cell(cfg, shape, mesh, variant)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = cost_analysis_dict(compiled)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "devices": int(n_dev),
        "mode": shape.mode,
        "compile_seconds": round(t_compile, 1),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "memory": mem_info,
        "collectives": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "hlo_lines": hlo.count("\n"),
    }
    if calibrate:
        # scan bodies are cost-counted once; recover per-layer costs from
        # unrolled 1-period / 2-period variants (see calibrate_depth)
        result["calibrated"] = calibrate_depth(cfg, shape, mesh, variant)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline",
                    help="comma-separated tweaks: " + ", ".join(VARIANTS))
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a, s in configs.cells():
            ok, why = configs.runnable(a, s)
            if ok:
                cells.append((a, s))
            else:
                print(f"SKIP {a} x {s}: {why}")
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch, shp in cells:
        for mk in meshes:
            tag = f"{arch}__{shp}__{mk}"
            if args.variant != "baseline":
                tag += "__" + args.variant.replace(",", "+")
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"CACHED {tag}")
                continue
            print(f"RUN {tag} ...", flush=True)
            try:
                res = run_cell(arch, shp, mk, variant=args.variant)
                status = "OK"
            except Exception as e:
                res = {"arch": arch, "shape": shp, "mesh": mk,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()}
                status = "FAIL"
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            extra = ""
            if status == "OK":
                extra = (f" flops={res['flops']:.3g}"
                         f" coll={res['collectives']['total_bytes']:.3g}B"
                         f" compile={res['compile_seconds']}s")
            print(f"{status} {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
