"""Batched serving driver: continuous prefill + decode over the mesh.

A minimal but complete request loop (the serving-side counterpart of
train.py): fixed-batch slots, greedy decode, per-request stop lengths,
KV/recurrent caches managed by the model zoo's cache protocol.

Run small-scale (CPU):
  python -m repro.launch.serve --arch rwkv6-3b --requests 6 --new-tokens 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, init_params, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    out: list = dataclasses.field(default_factory=list)


def serve_requests(cfg, params, requests: list[Request], max_seq: int,
                   progress=print) -> dict[int, list[int]]:
    """Batch all requests together (same prompt length), prefill once, decode
    until every request hits its token budget.  Returns rid -> token ids."""
    batch = len(requests)
    prompts = np.stack([r.prompt for r in requests])
    t0 = time.time()
    logits, caches = prefill(cfg, params, {"tokens": jnp.asarray(prompts)},
                             max_seq=max_seq)
    progress(f"prefill: {batch} x {prompts.shape[1]} tokens "
             f"in {time.time() - t0:.2f}s")

    step = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    budget = max(r.max_new_tokens for r in requests)
    t0 = time.time()
    for i in range(budget):
        for r, t in zip(requests, np.asarray(tok)[:, 0], strict=False):
            if len(r.out) < r.max_new_tokens:
                r.out.append(int(t))
        if i == budget - 1:
            break
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1)[:, None]
    dt = time.time() - t0
    done = sum(len(r.out) for r in requests)
    progress(f"decode: {done} tokens in {dt:.2f}s ({done / max(dt, 1e-9):.1f} tok/s)")
    return {r.rid: r.out for r in requests}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b", choices=list(configs.ARCHS))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get(args.arch).scaled_down()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    out = serve_requests(cfg, params, reqs,
                         max_seq=args.prompt_len + args.new_tokens + 1)
    for rid, toks in out.items():
        print(f"request {rid}: {toks}")


if __name__ == "__main__":
    main()
