"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax init,
and smoke tests/benches must keep seeing 1 device.

Mesh axes:
  pod   : cross-pod data parallelism (and optional pipeline stages)
  data  : in-pod data parallelism + FSDP (params/optimizer sharded here)
  model : tensor parallelism + expert parallelism
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    try:  # AxisType landed after jax 0.4.x; the default axis type is fine
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except AttributeError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic restarts."""
    return _make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes carrying the batch dimension: ('pod','data') when pod exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
