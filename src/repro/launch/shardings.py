"""Parameter / activation / cache sharding rules (GSPMD PartitionSpecs).

Strategy (DESIGN.md S5):
  - TP (Megatron): column-parallel projections shard their output dim over
    'model'; row-parallel (output-side) projections shard their input dim
    over 'model'.
  - FSDP/ZeRO: the *other* weight dim shards over 'data' (params + optimizer
    moments), gathered on use by GSPMD.
  - EP: expert-indexed weights (E, ...) shard E over 'model'.
  - 'pod' is pure DP for parameters (replicated; gradients all-reduce across
    pods); activations/caches shard their batch dim over ('pod','data').

Every rule is divisibility-guarded: an axis that doesn't divide the dim is
dropped (replicated) rather than mis-sharded, so one rule table serves all
ten architectures.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import batch_axes

# weight-name -> (spec for last dims); leading stack/rep dims padded with None
_ROW_PARALLEL = {"wo", "w_down", "w_out", "w_v", "w_o"}  # input dim over model
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_a", "w_x",
                 "w_r", "w_k", "w_g", "w_uq", "w_uk", "w_uv", "w_dq", "w_dkv"}
_EXPERT_WEIGHTS = {"w_gate", "w_up", "w_down"}


def _axis_fits(mesh, axis, dim) -> bool:
    return axis in mesh.axis_names and dim % mesh.shape[axis] == 0


def _leaf_spec(mesh, path_keys: list[str], shape: tuple[int, ...],
               moe_expert_axis: str = "model") -> P:
    name = path_keys[-1]
    in_block = any(k in ("decoder", "encoder") for k in path_keys)
    nd = len(shape)
    lead = 1 if in_block else 0      # scan-stacked rep dim
    core = shape[lead:]

    def guard(spec_core):
        fixed = []
        for dim, ax in zip(core, spec_core, strict=False):
            fixed.append(ax if ax is not None and _axis_fits(mesh, ax, dim)
                         else None)
        return P(*([None] * lead + fixed))

    if name == "table":              # embedding (V, d): vocab over model
        return guard(["model", "data"])
    if name == "w" and len(core) == 2 and not in_block:  # unembed (d, V)
        return guard(["data", "model"])
    # MoE expert stacks (E, d, ff) / (E, ff, d)
    if name in _EXPERT_WEIGHTS and len(core) == 3:
        if moe_expert_axis == "data":
            # EP over 'data' + TP-within-expert over 'model': weights are
            # fully sharded -> zero FSDP all-gathers; tokens all-to-all over
            # 'data' (the Perf hillclimb variant, EXPERIMENTS.md #Perf)
            if name == "w_down":               # (E, ff, d)
                return guard(["data", "model", None])
            return guard(["data", None, "model"])  # (E, d, ff)
        return guard(["model", "data", None])
    if name == "router":
        return guard(["data", None])
    if len(core) == 2 and name in _ROW_PARALLEL:
        return guard(["model", "data"])
    if len(core) == 2 and (name in _COL_PARALLEL or name == "w"):
        return guard(["data", "model"])
    return P(*([None] * nd))         # norms, biases, scalars: replicate


def param_shardings(mesh, params_shapes, moe_expert_axis: str = "model",
                    fsdp: bool = True):
    """Pytree of NamedSharding matching a params (or optimizer-state) tree.

    fsdp=False drops the 'data' axis from every weight spec (TP-only):
    the serving layout — no optimizer state to shard, and per-step weight
    all-gathers disappear (weights are resident once loaded)."""

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        spec = _leaf_spec(mesh, keys, leaf.shape, moe_expert_axis)
        if not fsdp:
            spec = jax.sharding.PartitionSpec(
                *(None if ax == "data" else ax for ax in spec))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_shardings(mesh, batch_shapes):
    """Input batch: leading (global-batch) dim over ('pod','data')."""
    baxes = batch_axes(mesh)

    def one(leaf):
        spec = [baxes if leaf.shape and leaf.shape[0] % _prod(mesh, baxes) == 0
                else None] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shapes)


def _prod(mesh, axes):
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def cache_shardings(mesh, cache_shapes, kv_seq_shard: bool = False):
    """KV caches / recurrent states: batch over ('pod','data'); head or
    feature dims over 'model' when divisible.

    Cache leaves are scan-stacked: (reps, B, ...).  Heuristic: dim 1 = batch;
    for >=4D leaves shard dim 2 (heads / latent) over 'model' when divisible.

    kv_seq_shard: when the head dim does NOT divide the model axis (GQA with
    few KV heads), shard the *sequence* dim (3) over 'model' instead —
    flash-decoding style: each model shard attends over its slice and GSPMD
    inserts the partial-softmax combine.  This removes the KV-cache
    replication that otherwise dominates decode memory (EXPERIMENTS.md #Perf).
    """
    baxes = batch_axes(mesh)

    def one(path, leaf):
        nd = len(leaf.shape)
        spec = [None] * nd
        if nd >= 2 and leaf.shape[1] % _prod(mesh, baxes) == 0:
            spec[1] = baxes
        if nd >= 4 and _axis_fits(mesh, "model", leaf.shape[2]):
            spec[2] = "model"
        elif (kv_seq_shard and nd >= 5
              and _axis_fits(mesh, "model", leaf.shape[3])):
            spec[3] = "model"  # (reps, B, H, S, hd): shard S
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def activation_rules(mesh, seq_parallel: bool = False):
    """Rules consumed by models.sharding.shard().

    seq_parallel: shard the sequence dim of block outputs over 'model'
    (Megatron sequence parallelism): norms/residual segments run 1/TP-th of
    the tokens per device; GSPMD converts the TP all-reduces into
    reduce-scatter + all-gather pairs around the matmuls."""
    baxes = batch_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return {
        "act": P(baxes, model if seq_parallel else None, None),
        "logits": P(baxes, None, model),
    }
