"""Training driver: GSPMD-sharded train loop with BRIDGE gradient sync,
checkpoint/restart, elastic resume and gradient compression.

Two gradient-sync modes (DESIGN.md S3/S5):
  gspmd  : loss is a global mean; XLA inserts the data-parallel all-reduce.
  bridge : per-shard local loss inside shard_map; gradients are summed
           explicitly with the paper's Bruck RS+AG collectives using
           schedules from the BRIDGE planner (repro.core), optionally int8-
           compressed with error feedback.  Used on pure-DP meshes.

Run small-scale (CPU):
  python -m repro.launch.train --arch rwkv6-3b --steps 20 --scale smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import latest_step, restore_into, save
from repro.collectives import (bruck_all_reduce, compressed_all_reduce,
                               gradient_sync_plan, make_error_feedback_state)
from repro.collectives._compat import shard_map as _shard_map
from repro.data import SyntheticLM
from repro.models import init_params, loss_fn
from repro.models.sharding import activation_sharding
from repro.optim import adamw_init, adamw_update, cosine_warmup_schedule

from .mesh import make_mesh
from .shardings import activation_rules, param_shardings


@dataclasses.dataclass
class TrainConfig:
    arch: str = "rwkv6-3b"
    scale: str = "smoke"             # smoke (scaled_down) | full
    steps: int = 20
    batch_size: int = 8              # global
    seq_len: int = 64
    lr: float = 3e-4
    warmup: int = 10
    grad_sync: str = "gspmd"         # gspmd | bridge | bridge-compressed
    checkpoint_dir: str | None = None
    checkpoint_every: int = 10
    mesh_shape: tuple = ()
    mesh_axes: tuple = ()
    seed: int = 0


def model_config(tc: TrainConfig):
    cfg = configs.get(tc.arch)
    if tc.scale == "smoke":
        cfg = cfg.scaled_down()
        cfg = dataclasses.replace(cfg, dtype="float32")
    return cfg


def make_train_step(cfg, tc: TrainConfig, mesh):
    lr = cosine_warmup_schedule(tc.lr, tc.warmup, tc.steps)
    rules = activation_rules(mesh)

    if tc.grad_sync == "gspmd":
        def step(params, opt_state, batch, ef):
            with activation_sharding(mesh, rules):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
            params, opt_state, om = adamw_update(grads, opt_state, params, lr)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics, ef
        return step

    # explicit BRIDGE sync on a pure-DP axis ('data'); params replicated
    axis = "data"
    n_dp = mesh.shape[axis]
    compressed = tc.grad_sync == "bridge-compressed"

    def local_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def step(params, opt_state, batch, ef):
        from jax.sharding import PartitionSpec as P

        def shard_fn(params, batch, ef):
            loss, metrics, grads = local_grads(params, batch)
            if compressed:
                grads, ef2 = compressed_all_reduce(grads, ef, axis)
            else:
                plan = gradient_sync_plan(
                    n_dp, sum(g.size * g.dtype.itemsize
                              for g in jax.tree.leaves(grads)))
                if plan.impl == "bruck":
                    grads = jax.tree.map(
                        lambda g: bruck_all_reduce(g, axis, plan.rs_schedule,
                                                   plan.ag_schedule), grads)
                else:
                    grads = jax.tree.map(
                        lambda g: jax.lax.psum(g, axis), grads)
                ef2 = ef
            grads = jax.tree.map(lambda g: g / n_dp, grads)
            loss = jax.lax.pmean(loss, axis)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
            return loss, metrics, grads, ef2

        pspec_batch = jax.tree.map(lambda _: P(axis), batch)
        # check_vma=False: outputs *are* replicated (explicit Bruck
        # all-reduce), but the ppermute chain defeats static inference.
        loss, metrics, grads, ef = _shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), pspec_batch, P()),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
        )(params, batch, ef)
        params, opt_state, om = adamw_update(grads, opt_state, params, lr)
        metrics.update(om)
        metrics["loss"] = loss
        return params, opt_state, metrics, ef

    return step


def train(tc: TrainConfig, progress=print):
    cfg = model_config(tc)
    if tc.mesh_shape:
        mesh = make_mesh(tuple(tc.mesh_shape), tuple(tc.mesh_axes))
    else:
        mesh = make_mesh((jax.device_count(),), ("data",))
    data = SyntheticLM(cfg.vocab_size, tc.seq_len, seed=tc.seed)

    params = init_params(cfg, jax.random.PRNGKey(tc.seed))
    opt_state = adamw_init(params)
    ef = (make_error_feedback_state(params)
          if tc.grad_sync == "bridge-compressed" else {})

    start = 0
    if tc.checkpoint_dir:
        last = latest_step(tc.checkpoint_dir)
        if last is not None:
            state = restore_into(tc.checkpoint_dir,
                                 {"params": params, "opt": opt_state},
                                 step=last)
            params, opt_state = state["params"], state["opt"]
            start = last
            progress(f"resumed from step {start}")

    p_shard = param_shardings(mesh, jax.eval_shape(lambda: params))
    params = jax.device_put(params, p_shard)
    step_fn = jax.jit(make_train_step(cfg, tc, mesh), donate_argnums=(0, 1))

    losses = []
    for step in range(start, tc.steps):
        # one stream per example: the global batch is identical for any mesh
        # shape / world size (elastic resume and straggler backup workers
        # recompute bit-identical data; DESIGN.md S5)
        host_batch = data.global_batch(step, tc.batch_size, 1)
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        t0 = time.time()
        params, opt_state, metrics, ef = step_fn(params, opt_state, batch, ef)
        loss = float(metrics["loss"])
        losses.append(loss)
        progress(f"step {step:5d} loss {loss:.4f} "
                 f"gnorm {float(metrics['grad_norm']):.3f} "
                 f"dt {time.time() - t0:.2f}s")
        if tc.checkpoint_dir and (step + 1) % tc.checkpoint_every == 0:
            save(tc.checkpoint_dir, step + 1,
                 {"params": jax.device_get(params),
                  "opt": jax.device_get(opt_state)})
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--scale", default="smoke")
    ap.add_argument("--grad-sync", default="gspmd")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()
    tc = TrainConfig(arch=args.arch, steps=args.steps,
                     batch_size=args.batch_size, seq_len=args.seq_len,
                     scale=args.scale, grad_sync=args.grad_sync,
                     checkpoint_dir=args.checkpoint_dir)
    _, _, losses = train(tc)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
