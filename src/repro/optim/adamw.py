"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

No optax dependency; state is a plain pytree so it checkpoints/reshards with
the same machinery as params.  Moments are float32 regardless of param dtype
(bf16-safe); the update is applied in float32 and cast back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def cosine_warmup_schedule(base_lr: float, warmup_steps: int,
                           total_steps: int, min_ratio: float = 0.1
                           ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * (step + 1) / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    lr: Callable[[jax.Array], jax.Array] | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> tuple[PyTree, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return pf.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr_t}
