"""Step-atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/  arrays.npz  manifest.json   (+ <dir>/LATEST)

Guarantees needed for fault tolerance at scale (DESIGN.md S5):
  - *atomic*: written to step_<N>.tmp and renamed; a crash mid-save never
    corrupts the restore point (LATEST only advances after the rename).
  - *elastic*: arrays are stored unsharded (gathered); restore_into() places
    them onto whatever mesh/sharding the *new* job uses — mesh shape can
    change between save and restore (tested in tests/test_fault_tolerance.py).
    At real pod scale this becomes per-shard files + a reshard-on-load pass;
    the API is already sharding-agnostic.
  - pytree structure is stored as key paths, so params/opt-state trees from
    any module reload without pickling code.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> int | None:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(directory: str, step: int | None = None) -> dict:
    """Raw key->np.ndarray mapping (no tree structure needed)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    with np.load(os.path.join(directory, f"step_{step:08d}", "arrays.npz")) as z:
        return {k: z[k] for k in z.files}


def restore_into(directory: str, template, step: int | None = None,
                 sharding_fn=None):
    """Restore into `template`'s pytree structure.

    sharding_fn(keystr, array) -> jax.sharding.Sharding | None lets the caller
    re-shard every leaf for the *current* mesh (elastic restart)."""
    raw = restore(directory, step)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        k = jax.tree_util.keystr(path)
        if k not in raw:
            raise KeyError(f"checkpoint missing {k}")
        arr = raw[k]
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"{k}: checkpoint shape {arr.shape} != "
                             f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if sharding_fn is not None:
            sh = sharding_fn(k, arr)
            if sh is not None:
                arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def garbage_collect(directory: str, keep: int = 3) -> list[str]:
    """Delete all but the newest `keep` checkpoints; returns removed paths."""
    if not os.path.isdir(directory):
        return []
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp"))
    removed = []
    for s in steps[:-keep] if keep else steps:
        p = os.path.join(directory, f"step_{s:08d}")
        shutil.rmtree(p)
        removed.append(p)
    return removed
