from .store import (latest_step, restore, restore_into, save,
                    garbage_collect)

__all__ = ["latest_step", "restore", "restore_into", "save", "garbage_collect"]
