from .store import garbage_collect, latest_step, restore, restore_into, save

__all__ = ["latest_step", "restore", "restore_into", "save", "garbage_collect"]
