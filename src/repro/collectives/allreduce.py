"""AllReduce implementations: BRIDGE (Bruck RS + AG), RING, and psum oracle.

All functions are designed to be called inside `jax.shard_map` with a named
axis.  `bridge_all_reduce` is the paper's technique end-to-end: Rabenseifner
decomposition with a BRIDGE-scheduled Reduce-Scatter (early reconfigurations)
followed by a BRIDGE-scheduled AllGather (late reconfigurations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.schedules import Schedule

from ._compat import axis_size as _axis_size
from .bruck_rs_ag import bruck_all_gather, bruck_reduce_scatter


def _shift_perm(n: int, offset: int) -> list[tuple[int, int]]:
    return [(i, (i + offset) % n) for i in range(n)]


def _to_chunks(x: jax.Array, n: int) -> tuple[jax.Array, int]:
    """Flatten x and pad so it splits into n equal chunks: (n, chunk)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(n, -1), pad


def _from_chunks(chunks: jax.Array, pad: int, shape, dtype) -> jax.Array:
    flat = chunks.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


# --- Ring (bandwidth-optimal baseline; paper Section 2) ----------------------


def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """x: (n, ...) contributions; device i returns reduced block i.
    n - 1 unit-offset steps (neighbor-only: no congestion, minimal bytes)."""
    n = _axis_size(axis_name)
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {n}")
    if n == 1:
        return x[0]
    i = jax.lax.axis_index(axis_name)
    acc = x
    for t in range(n - 1):
        send_idx = (i - 1 - t) % n
        val = jnp.take(acc, send_idx, axis=0)
        recv = jax.lax.ppermute(val, axis_name, _shift_perm(n, 1))
        acc = acc.at[(i - 2 - t) % n].add(recv)
    return jnp.take(acc, i, axis=0)


def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """x: (...) local block; returns (n, ...): n - 1 unit-offset steps."""
    n = _axis_size(axis_name)
    if n == 1:
        return x[None]
    i = jax.lax.axis_index(axis_name)
    buf = jnp.zeros((n,) + x.shape, x.dtype).at[i].set(x)
    for t in range(n - 1):
        send_idx = (i - t) % n
        val = jnp.take(buf, send_idx, axis=0)
        recv = jax.lax.ppermute(val, axis_name, _shift_perm(n, 1))
        buf = buf.at[(i - 1 - t) % n].set(recv)
    return buf


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bandwidth-optimal ring allreduce (sum), any shape."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    chunks, pad = _to_chunks(x, n)
    mine = ring_reduce_scatter(chunks, axis_name)
    full = ring_all_gather(mine, axis_name)
    return _from_chunks(full, pad, x.shape, x.dtype)


# --- BRIDGE / Bruck -----------------------------------------------------------


def bruck_all_reduce(
    x: jax.Array,
    axis_name: str,
    rs_schedule: Schedule | None = None,
    ag_schedule: Schedule | None = None,
) -> jax.Array:
    """AllReduce (sum) via Bruck RS + Bruck AG in 2*log2(n) steps.

    With schedules given, the permute chain follows the BRIDGE subring
    store-and-forward execution (see bruck_rs_ag docstring)."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    chunks, pad = _to_chunks(x, n)
    mine = bruck_reduce_scatter(chunks, axis_name, rs_schedule)
    full = bruck_all_gather(mine, axis_name, ag_schedule)
    return _from_chunks(full, pad, x.shape, x.dtype)


def bridge_all_reduce(
    x: jax.Array,
    axis_name: str,
    n: int,
    m_bytes: float | None = None,
    cost_model=None,
    paper_faithful: bool = True,
) -> jax.Array:
    """The paper's AllReduce: optimal-R BRIDGE schedules for both phases.

    n must be the static axis size (schedules are synthesized at trace time).
    """
    from repro.core import plan
    from repro.core.cost_model import TPU_V5E

    cm = cost_model or TPU_V5E
    if m_bytes is None:
        m_bytes = float(x.size * x.dtype.itemsize)
    rs = plan("rs", n, m_bytes, cm, paper_faithful=paper_faithful).schedule
    ag = plan("ag", n, m_bytes, cm, paper_faithful=paper_faithful).schedule
    return bruck_all_reduce(x, axis_name, rs, ag)
