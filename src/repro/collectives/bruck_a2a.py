"""Bruck all-to-all on a JAX device axis (log-step, subring-patterned).

To be called *inside* `jax.shard_map` with a named mesh axis.  The input is
the local shard `x` of shape (n, ...) where row j is the block destined for
the device at axis index j.  Returns an array of the same shape whose row p
is the block received from device p — identical semantics to
`jax.lax.all_to_all(x, axis, 0, 0)` but communicated in ceil(log2 n) steps of
`ppermute` at offsets 2^k (the paper's Bruck pattern, Section 3.1), instead
of a single monolithic all-to-all.

On an OCS fabric each step is a single hop after a BRIDGE reconfiguration;
on a static TPU ICI ring the offset-2^k permute is routed by hardware over
min(2^k, n - 2^k) hops — the same h_k the cost model scores (DESIGN.md S3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bruck import num_steps

from ._compat import axis_size as _axis_size


def _shift_perm(n: int, offset: int) -> list[tuple[int, int]]:
    """ppermute permutation: device i sends to (i + offset) mod n."""
    return [(i, (i + offset) % n) for i in range(n)]


def bruck_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Log-step all-to-all; x.shape[0] must equal the axis size."""
    n = _axis_size(axis_name)
    if x.shape[0] != n:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {n}")
    if n == 1:
        return x
    i = jax.lax.axis_index(axis_name)
    s = num_steps(n)

    # Phase 1 — local rotation: slot j holds the block destined for (i + j) % n.
    idx = (i + jnp.arange(n)) % n
    buf = jnp.take(x, idx, axis=0)

    # Phase 2 — s rounds: in round k send every slot whose k-th bit is set to
    # the device at offset +2^k.  Slot sets are static (independent of i).
    for k in range(s):
        send = np.array([j for j in range(n) if (j >> k) & 1], dtype=np.int32)
        moved = jax.lax.ppermute(buf[send], axis_name, _shift_perm(n, 2**k))
        buf = buf.at[send].set(moved)

    # Phase 3 — inverse rotation: output slot p = block that originated at p.
    # After phase 2, slot j holds the block destined for me that originated at
    # (i - j) % n, so out[p] = buf[(i - p) % n].
    out_idx = (i - jnp.arange(n)) % n
    return jnp.take(buf, out_idx, axis=0)
